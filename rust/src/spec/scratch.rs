//! Zero-allocation round state (S22): flat arenas + reusable scratch for
//! the draft/verify hot loop.
//!
//! EAGLE's speedup depends on every speculation round being cheap next to
//! a target forward pass, but the original host loop re-allocated its
//! bookkeeping every round: per-node feature vectors (`Vec<Vec<f32>>`),
//! logits behind `Rc<Vec<f32>>` clones, and fresh bias/mask/staging
//! buffers for every verify and draft-step call. This module replaces all
//! of that with state that is allocated once and reused via
//! `clear()`-style resets:
//!
//! * [`FeatArena`] — one contiguous `Vec<f32>` of per-node features,
//!   indexed `node * d`. Replaces `node_feat: Vec<Vec<f32>>`.
//! * [`LogitsSlab`] — one contiguous `Vec<f32>` of per-node logits rows
//!   with a filled bitmap. Replaces `node_logits: Vec<Option<Rc<Vec<f32>>>>`
//!   (greedy path) and `Vec<Vec<f32>>` (batched path).
//! * [`RoundScratch`] — everything else a round touches: candidate
//!   buffers, top-k index buffers, softmax output, step-row staging
//!   (`sf`/`st`/`sp`/`sbias`), verify staging (`vtokens`/`vpos`/`vbias`),
//!   ancestor bitsets as `u64` words, the acceptance-walk path/children
//!   buffers, rerank scratch, and a spare [`DraftTree`] for in-place
//!   rerank swaps.
//! * [`ScratchPool`] — the batched engine's state: one [`RoundScratch`]
//!   per lane **keyed by KV slot**, plus [`BatchScratch`] holding the
//!   `[B, ..]` staging buffers. The pool outlives engine invocations
//!   (the server worker owns one), so width-grouped batches reuse lane
//!   buffers across admissions.
//!
//! Steady-state guarantee: after warm-up (the `reserve` call at engine
//! start plus at most the first round), the round loop performs no
//! per-node heap allocation — every buffer's capacity is retained across
//! `clear()`/`resize()` resets. The engines measure this directly:
//! [`RoundScratch::footprint`] / [`ScratchPool::footprint`] sum the
//! capacity bytes of every buffer, and the per-round delta is recorded as
//! `GenRecord::round_host_alloc_bytes` (0 in steady state) with
//! `GenRecord::scratch_reuse_total` counting fully-reused rounds.
//!
//! T>0 rounds are covered too: the sampled-q distributions the SpecInfer
//! acceptance rule needs are rows of a per-lane **q-slab**
//! ([`RoundScratch::qs`], one flat `Vec<f32>` keyed by `TreeNode::q` row
//! ids), and the acceptance walk stages its child tokens / q ids /
//! working residual in reused buffers (`walk_toks`/`walk_qids`/
//! `presidual`) — no `Rc<Vec<f32>>` clones anywhere on the sampled path.
//! Siblings sampled from the same frontier node share one slab row.
//!
//! Output equivalence against the allocating reference implementations
//! (`spec::tree::reference`, `verify_inputs`, `fill_step_rows`) is
//! property-tested in `rust/tests/prop_scratch.rs`, including dirty-reuse
//! across consecutive rounds; `host/round_scratch` vs `host/round_ref`
//! in `rust/benches/hot_path.rs` tracks the speedup.

use super::dyntree::{DynTreeParams, RerankScratch};
use super::tree::DraftTree;

/// One candidate considered during tree growth: `(parent node, token,
/// cumulative score, q-slab row id of the sampled-from q at T>0)`.
pub type Cand = (usize, u32, f32, Option<u32>);

fn cap_bytes<T>(v: &Vec<T>) -> usize {
    v.capacity() * std::mem::size_of::<T>()
}

/// Grow `v`'s capacity to at least `want` elements without touching its
/// contents. Unlike bare `Vec::reserve(want)` — which reserves RELATIVE
/// to the current length and so over-allocates (roughly doubling) when a
/// warm buffer still holds a previous round's contents — this is a no-op
/// once the buffer has ever reached `want` capacity.
pub(crate) fn ensure_cap<T>(v: &mut Vec<T>, want: usize) {
    if v.capacity() < want {
        v.reserve(want - v.len());
    }
}

/// Flat per-node feature storage: row `i` is `data[i*d .. (i+1)*d]`.
/// A row may be pushed empty (zeroed) and filled later via [`FeatArena::set`]
/// once the node's draft step has run.
#[derive(Debug, Default)]
pub struct FeatArena {
    data: Vec<f32>,
    d: usize,
    n: usize,
}

impl FeatArena {
    pub fn new(d: usize) -> FeatArena {
        FeatArena { data: Vec::new(), d, n: 0 }
    }

    /// Drop all rows, keeping capacity (and allowing a dimension change).
    pub fn clear(&mut self, d: usize) {
        self.data.clear();
        self.d = d;
        self.n = 0;
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Append one node's feature row; returns its node index.
    pub fn push(&mut self, row: &[f32]) -> usize {
        debug_assert_eq!(row.len(), self.d);
        self.data.extend_from_slice(row);
        self.n += 1;
        self.n - 1
    }

    /// Append a zeroed placeholder row (node created, step not yet run).
    pub fn push_empty(&mut self) -> usize {
        self.data.resize(self.data.len() + self.d, 0.0);
        self.n += 1;
        self.n - 1
    }

    /// Fill node `i`'s row (after its draft step produced the feature).
    pub fn set(&mut self, i: usize, row: &[f32]) {
        debug_assert_eq!(row.len(), self.d);
        self.data[i * self.d..(i + 1) * self.d].copy_from_slice(row);
    }

    pub fn get(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn reserve_nodes(&mut self, nodes: usize) {
        ensure_cap(&mut self.data, nodes * self.d);
    }

    pub fn capacity_bytes(&self) -> usize {
        cap_bytes(&self.data)
    }
}

/// Flat per-node logits storage with a filled bitmap — row `i` is
/// `data[i*vocab .. (i+1)*vocab]`, readable only once [`LogitsSlab::set`]
/// has run for it (mirrors the `Option<Rc<Vec<f32>>>` / empty-`Vec`
/// sentinels it replaces).
#[derive(Debug, Default)]
pub struct LogitsSlab {
    data: Vec<f32>,
    filled: Vec<bool>,
    vocab: usize,
}

impl LogitsSlab {
    pub fn new(vocab: usize) -> LogitsSlab {
        LogitsSlab { data: Vec::new(), filled: Vec::new(), vocab }
    }

    pub fn clear(&mut self, vocab: usize) {
        self.data.clear();
        self.filled.clear();
        self.vocab = vocab;
    }

    pub fn len(&self) -> usize {
        self.filled.len()
    }

    pub fn is_empty(&self) -> bool {
        self.filled.is_empty()
    }

    /// Append one node's logits row; returns its node index.
    pub fn push(&mut self, row: &[f32]) -> usize {
        debug_assert_eq!(row.len(), self.vocab);
        self.data.extend_from_slice(row);
        self.filled.push(true);
        self.filled.len() - 1
    }

    /// Append an unfilled placeholder row.
    pub fn push_empty(&mut self) -> usize {
        self.data.resize(self.data.len() + self.vocab, 0.0);
        self.filled.push(false);
        self.filled.len() - 1
    }

    pub fn set(&mut self, i: usize, row: &[f32]) {
        debug_assert_eq!(row.len(), self.vocab);
        self.data[i * self.vocab..(i + 1) * self.vocab].copy_from_slice(row);
        self.filled[i] = true;
    }

    /// Node `i`'s logits, `None` until its draft step has run.
    pub fn get(&self, i: usize) -> Option<&[f32]> {
        if *self.filled.get(i)? {
            Some(&self.data[i * self.vocab..(i + 1) * self.vocab])
        } else {
            None
        }
    }

    pub fn reserve_nodes(&mut self, nodes: usize) {
        ensure_cap(&mut self.data, nodes * self.vocab);
        ensure_cap(&mut self.filled, nodes);
    }

    pub fn capacity_bytes(&self) -> usize {
        cap_bytes(&self.data) + self.filled.capacity()
    }
}

/// Per-round reusable state for ONE lane (the bs=1 engine owns exactly
/// one; the batched engine draws one per lane from a [`ScratchPool`]).
/// Reset per round with [`RoundScratch::begin_round`]; all capacity is
/// retained, so steady-state rounds never touch the allocator.
#[derive(Debug, Default)]
pub struct RoundScratch {
    /// Per-node predicted features (parent-step outputs).
    pub feat: FeatArena,
    /// Per-node draft logits (dist of the node's successor token).
    pub logits: LogitsSlab,
    /// Q-slab: the sampled-from draft distributions retained for the
    /// SpecInfer acceptance rule at T>0, one vocab-wide row per expanded
    /// frontier node (`TreeNode::q` holds the row id; siblings share).
    /// Unused (and empty) on the greedy path.
    pub qs: FeatArena,
    /// Scratch KV slot assigned to each stepped node.
    pub node_slot: Vec<Option<usize>>,
    // -- growth working sets ------------------------------------------------
    pub frontier: Vec<usize>,
    pub new_nodes: Vec<usize>,
    pub expandable: Vec<usize>,
    pub cands: Vec<Cand>,
    /// top-k index buffer (vocab-sized sort arena).
    pub idx: Vec<usize>,
    /// (token, score) pairs from candidate expansion.
    pub pairs: Vec<(u32, f32)>,
    /// softmax output row.
    pub probs: Vec<f32>,
    // -- per-call staging (bs=1 engine; the batched engine stages in
    //    `BatchScratch` instead) -------------------------------------------
    pub sf: Vec<f32>,
    pub st: Vec<i32>,
    pub sp: Vec<i32>,
    pub sbias: Vec<f32>,
    pub vtokens: Vec<i32>,
    pub vpos: Vec<i32>,
    pub vbias: Vec<f32>,
    /// Ancestor-closure bitset (`u64` words over node indices).
    pub anc: Vec<u64>,
    // -- acceptance walk ----------------------------------------------------
    pub path: Vec<usize>,
    pub children: Vec<usize>,
    /// T>0 walk staging: the current node's child tokens...
    pub walk_toks: Vec<usize>,
    /// ...their q-slab row ids...
    pub walk_qids: Vec<u32>,
    /// ...and the recursive-rejection working/residual distribution.
    pub presidual: Vec<f32>,
    pub alpha_before: Vec<(u64, u64)>,
    pub alpha_delta: Vec<(u64, u64)>,
    // -- rerank -------------------------------------------------------------
    pub rr: RerankScratch,
    /// Rerank output buffer, swapped with the live tree when pruning.
    pub spare_tree: DraftTree,
}

impl RoundScratch {
    pub fn new(d: usize, vocab: usize) -> RoundScratch {
        RoundScratch {
            feat: FeatArena::new(d),
            logits: LogitsSlab::new(vocab),
            qs: FeatArena::new(vocab),
            ..Default::default()
        }
    }

    /// Pre-size every buffer so steady-state rounds never allocate:
    /// `max_nodes` is the growth ceiling (static tree total, or the
    /// dynamic `depth * frontier_k * branch + 1` / controller ceiling),
    /// `max_t` the widest verify width, `max_w` the widest draft step,
    /// and `s` the cache length (bias rows are `s` wide).
    pub fn reserve(
        &mut self,
        d: usize,
        vocab: usize,
        s: usize,
        max_nodes: usize,
        max_t: usize,
        max_w: usize,
    ) {
        self.feat.clear(d);
        self.feat.reserve_nodes(max_nodes);
        self.logits.clear(vocab);
        self.logits.reserve_nodes(max_nodes);
        // q-slab capacity is NOT reserved here: greedy (T=0) rounds never
        // write a q row, and eagerly holding max_nodes * vocab floats per
        // lane would roughly double the scratch's dominant allocation for
        // the Table-7 serving setting. Sampled generations reserve it via
        // [`RoundScratch::reserve_q`].
        self.qs.clear(vocab);
        ensure_cap(&mut self.node_slot, max_nodes);
        ensure_cap(&mut self.frontier, max_nodes);
        ensure_cap(&mut self.new_nodes, max_nodes);
        ensure_cap(&mut self.expandable, max_nodes);
        ensure_cap(&mut self.cands, max_nodes);
        ensure_cap(&mut self.idx, vocab);
        ensure_cap(&mut self.pairs, vocab.min(max_nodes + 8));
        ensure_cap(&mut self.probs, vocab);
        ensure_cap(&mut self.sf, max_w * d);
        ensure_cap(&mut self.st, max_w);
        ensure_cap(&mut self.sp, max_w);
        ensure_cap(&mut self.sbias, max_w * s);
        ensure_cap(&mut self.vtokens, max_t);
        ensure_cap(&mut self.vpos, max_t);
        ensure_cap(&mut self.vbias, max_t * s);
        ensure_cap(&mut self.anc, max_nodes.div_ceil(64).max(1));
        ensure_cap(&mut self.path, max_nodes.min(64).max(8));
        ensure_cap(&mut self.children, max_nodes);
        ensure_cap(&mut self.walk_toks, max_nodes);
        ensure_cap(&mut self.walk_qids, max_nodes);
        ensure_cap(&mut self.presidual, vocab);
        ensure_cap(&mut self.alpha_before, 8);
        ensure_cap(&mut self.alpha_delta, 64);
        self.rr.reserve(max_nodes);
        ensure_cap(&mut self.spare_tree.nodes, max_nodes);
    }

    /// Pre-size the q-slab for sampled (T>0) generations: at most one q
    /// row per expanded frontier node per round, and an expansion always
    /// yields at least one node — bounded by `max_nodes`. The engines
    /// call this (in addition to [`RoundScratch::reserve`]) only when
    /// `temperature > 0`, so greedy lanes never pay the slab's memory.
    pub fn reserve_q(&mut self, vocab: usize, max_nodes: usize) {
        self.qs.clear(vocab);
        self.qs.reserve_nodes(max_nodes);
    }

    /// Reset the node-indexed state for a fresh round, seeding node 0
    /// (the tree root) with the extend-step outputs. Growth working sets
    /// are cleared; staging buffers are resized by their call sites.
    pub fn begin_round(&mut self, root_feat: &[f32], root_logits: &[f32]) {
        self.feat.clear(root_feat.len());
        self.logits.clear(root_logits.len());
        self.qs.clear(root_logits.len());
        self.node_slot.clear();
        self.feat.push(root_feat);
        self.logits.push(root_logits);
        self.node_slot.push(None);
        self.frontier.clear();
        self.new_nodes.clear();
        self.expandable.clear();
        self.cands.clear();
    }

    /// Total capacity bytes held — the engine records the per-round delta
    /// of this as `round_host_alloc_bytes` (0 once warm).
    pub fn footprint(&self) -> usize {
        self.feat.capacity_bytes()
            + self.logits.capacity_bytes()
            + self.qs.capacity_bytes()
            + cap_bytes(&self.node_slot)
            + cap_bytes(&self.frontier)
            + cap_bytes(&self.new_nodes)
            + cap_bytes(&self.expandable)
            + cap_bytes(&self.cands)
            + cap_bytes(&self.idx)
            + cap_bytes(&self.pairs)
            + cap_bytes(&self.probs)
            + cap_bytes(&self.sf)
            + cap_bytes(&self.st)
            + cap_bytes(&self.sp)
            + cap_bytes(&self.sbias)
            + cap_bytes(&self.vtokens)
            + cap_bytes(&self.vpos)
            + cap_bytes(&self.vbias)
            + cap_bytes(&self.anc)
            + cap_bytes(&self.path)
            + cap_bytes(&self.children)
            + cap_bytes(&self.walk_toks)
            + cap_bytes(&self.walk_qids)
            + cap_bytes(&self.presidual)
            + cap_bytes(&self.alpha_before)
            + cap_bytes(&self.alpha_delta)
            + self.rr.capacity_bytes()
            + self.spare_tree.capacity_bytes()
    }
}

/// Batch-level staging buffers for the lock-step engine: the `[B, ..]`
/// marshalling blocks for verify and draft-step/extend calls, reused
/// across rounds and admissions (extend and step share `sf`/`st`/`sp`/
/// `sbias` — they never overlap in time).
#[derive(Debug, Default)]
pub struct BatchScratch {
    pub vtokens: Vec<i32>,
    pub vpos: Vec<i32>,
    pub vbias: Vec<f32>,
    pub sf: Vec<f32>,
    pub st: Vec<i32>,
    pub sp: Vec<i32>,
    pub sbias: Vec<f32>,
    pub wb: Vec<i32>,
    pub anc: Vec<u64>,
    /// Per-lane draft-cache scratch slots consumed this round.
    pub used: Vec<usize>,
    /// Lanes live at round start (alloc-metric attribution).
    pub live: Vec<bool>,
    /// Per-lane pre-planned dynamic params for this round.
    pub lane_params: Vec<DynTreeParams>,
}

impl BatchScratch {
    /// Pre-size the `[B, ..]` staging blocks for `b` lanes at the widest
    /// verify width `max_t` and draft-step width `max_w` the engine can
    /// dispatch, so steady-state rounds never grow them — under the
    /// dynamic planner the per-round widths climb with the controllers'
    /// EWMAs, and without this the first wider round would reallocate.
    pub fn reserve(&mut self, b: usize, d: usize, s: usize, max_t: usize, max_w: usize) {
        ensure_cap(&mut self.vtokens, b * max_t);
        ensure_cap(&mut self.vpos, b * max_t);
        ensure_cap(&mut self.vbias, b * max_t * s);
        ensure_cap(&mut self.sf, b * max_w * d);
        ensure_cap(&mut self.st, b * max_w);
        ensure_cap(&mut self.sp, b * max_w);
        ensure_cap(&mut self.sbias, b * max_w * s);
        ensure_cap(&mut self.wb, b);
        ensure_cap(&mut self.anc, max_t.div_ceil(64).max(1));
        ensure_cap(&mut self.used, b);
        ensure_cap(&mut self.live, b);
        ensure_cap(&mut self.lane_params, b);
    }

    pub fn footprint(&self) -> usize {
        cap_bytes(&self.vtokens)
            + cap_bytes(&self.vpos)
            + cap_bytes(&self.vbias)
            + cap_bytes(&self.sf)
            + cap_bytes(&self.st)
            + cap_bytes(&self.sp)
            + cap_bytes(&self.sbias)
            + cap_bytes(&self.wb)
            + cap_bytes(&self.anc)
            + cap_bytes(&self.used)
            + self.live.capacity()
            + cap_bytes(&self.lane_params)
    }
}

/// Reusable scratch for the batched engine: one [`RoundScratch`] per
/// lane, keyed by KV slot (lane index), plus the batch staging buffers.
/// Owned by the caller — the server worker keeps one pool across
/// admissions, so a width-grouped sub-batch landing on the same KV slots
/// reuses the previous group's warm buffers.
#[derive(Debug, Default)]
pub struct ScratchPool {
    pub batch: BatchScratch,
    pub lanes: Vec<RoundScratch>,
}

impl ScratchPool {
    pub fn new() -> ScratchPool {
        ScratchPool::default()
    }

    /// Ensure lanes `0..b` exist (growing the pool on first use of a
    /// larger batch; existing lanes keep their warm buffers).
    pub fn ensure_lanes(&mut self, b: usize, d: usize, vocab: usize) {
        while self.lanes.len() < b {
            self.lanes.push(RoundScratch::new(d, vocab));
        }
    }

    pub fn footprint(&self) -> usize {
        self.batch.footprint() + self.lanes.iter().map(RoundScratch::footprint).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feat_arena_roundtrip_and_reuse() {
        let mut a = FeatArena::new(3);
        let i0 = a.push(&[1.0, 2.0, 3.0]);
        let i1 = a.push_empty();
        assert_eq!((i0, i1), (0, 1));
        assert_eq!(a.get(0), &[1.0, 2.0, 3.0]);
        assert_eq!(a.get(1), &[0.0, 0.0, 0.0]);
        a.set(1, &[4.0, 5.0, 6.0]);
        assert_eq!(a.get(1), &[4.0, 5.0, 6.0]);
        let cap = a.capacity_bytes();
        a.clear(3);
        assert_eq!(a.len(), 0);
        assert_eq!(a.capacity_bytes(), cap, "clear keeps capacity");
        a.push(&[7.0, 8.0, 9.0]);
        assert_eq!(a.get(0), &[7.0, 8.0, 9.0], "no stale data after reuse");
    }

    #[test]
    fn logits_slab_filled_semantics() {
        let mut s = LogitsSlab::new(2);
        s.push(&[0.5, 0.5]);
        let i = s.push_empty();
        assert!(s.get(0).is_some());
        assert!(s.get(i).is_none(), "unfilled row reads as None");
        assert!(s.get(7).is_none(), "out of range reads as None");
        s.set(i, &[0.1, 0.9]);
        assert_eq!(s.get(i), Some(&[0.1f32, 0.9][..]));
        s.clear(2);
        assert!(s.is_empty());
    }

    #[test]
    fn round_scratch_footprint_stable_after_reserve() {
        let mut s = RoundScratch::new(4, 16);
        s.reserve(4, 16, 64, 27, 32, 8);
        let fp = s.footprint();
        for round in 0..5 {
            let root_f = vec![round as f32; 4];
            let root_l = vec![0.1f32; 16];
            s.begin_round(&root_f, &root_l);
            for _ in 0..26 {
                s.feat.push_empty();
                s.logits.push_empty();
                s.node_slot.push(None);
            }
            s.vtokens.clear();
            s.vtokens.resize(32, 0);
            s.vbias.clear();
            s.vbias.resize(32 * 64, 0.0);
            assert_eq!(s.footprint(), fp, "round {round} grew the scratch");
        }
    }

    #[test]
    fn pool_lanes_grow_on_demand_and_persist() {
        let mut p = ScratchPool::new();
        p.ensure_lanes(2, 4, 8);
        assert_eq!(p.lanes.len(), 2);
        p.lanes[1].feat.clear(4);
        p.lanes[1].feat.push(&[1.0; 4]);
        p.ensure_lanes(4, 4, 8);
        assert_eq!(p.lanes.len(), 4);
        assert_eq!(p.lanes[1].feat.get(0), &[1.0; 4], "existing lanes keep state");
        p.ensure_lanes(2, 4, 8);
        assert_eq!(p.lanes.len(), 4, "pool never shrinks");
    }
}
