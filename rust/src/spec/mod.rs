//! The paper's contribution, coordinated: draft trees, lossless sampling
//! rules, the EAGLE engine, and the dynamic draft-tree planner.

pub mod dyntree;
pub mod engine;
pub mod sampling;
pub mod tree;
