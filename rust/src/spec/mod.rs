//! The paper's contribution, coordinated: draft trees, lossless sampling
//! rules, the EAGLE engine, the dynamic draft-tree planner, and the
//! zero-allocation round-state scratch the hot loop runs on.

pub mod dyntree;
pub mod engine;
pub mod sampling;
pub mod scratch;
pub mod source;
pub mod tree;
