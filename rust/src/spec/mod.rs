//! The paper's contribution, coordinated: draft trees, lossless sampling
//! rules, and the EAGLE engine.

pub mod engine;
pub mod sampling;
pub mod tree;
