//! Speculative-sampling core (S12): stable softmax, temperature sampling,
//! and the lossless accept/resample rules of Leviathan et al. (chain) and
//! SpecInfer/SpecTr (multi-child tree) that EAGLE's verification applies
//! recursively. Property-tested for distribution preservation in
//! `rust/tests/prop_sampling.rs` — the paper's central guarantee.

use crate::util::rng::Rng;

/// Numerically stable softmax with temperature. `t == 0` is handled by
/// callers via [`argmax`]; this function requires `t > 0`.
pub fn softmax(logits: &[f32], t: f32) -> Vec<f32> {
    let mut out = Vec::new();
    softmax_into(logits, t, &mut out);
    out
}

/// [`softmax`] into a reused output buffer (cleared first) — the
/// hot-loop form; identical float operations, so results are
/// bit-identical to the allocating wrapper.
pub fn softmax_into(logits: &[f32], t: f32, out: &mut Vec<f32>) {
    debug_assert!(t > 0.0);
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    out.clear();
    out.extend(logits.iter().map(|&l| ((l - m) / t).exp()));
    let s: f32 = out.iter().sum();
    if s > 0.0 {
        for x in out.iter_mut() {
            *x /= s;
        }
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Sample a token id from a probability vector.
pub fn sample(probs: &[f32], rng: &mut Rng) -> usize {
    rng.weighted(probs)
}

/// Top-k (index, prob) pairs, descending.
pub fn top_k(probs: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut idx = Vec::new();
    top_k_into(probs, k, &mut idx);
    idx.into_iter().map(|i| (i, probs[i])).collect()
}

/// Top-k indices by probability (descending) into a reused buffer — the
/// hot-loop form of [`top_k`]: the vocab-sized sort arena is retained
/// across calls, and callers read the probabilities back as `probs[i]`.
/// Same comparator as [`top_k`], so the selection is identical.
pub fn top_k_into(probs: &[f32], k: usize, idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..probs.len());
    idx.sort_unstable_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
    idx.truncate(k);
}

/// Outcome of verifying one draft position.
#[derive(Debug, Clone, PartialEq)]
pub enum Verdict {
    /// The draft token was accepted.
    Accept,
    /// Rejected; the token to emit instead was resampled from the residual.
    Resample(usize),
}

/// Chain speculative sampling rule (Leviathan et al., Appendix A.1):
/// accept draft token `tok` w.p. min(1, p/q); on rejection resample from
/// norm(max(0, p - q)). Lossless for any draft distribution q.
pub fn chain_accept(p: &[f32], q: &[f32], tok: usize, rng: &mut Rng) -> Verdict {
    let pi = p[tok];
    let qi = q[tok].max(1e-20);
    if rng.f32() < (pi / qi).min(1.0) {
        return Verdict::Accept;
    }
    let residual: Vec<f32> = p.iter().zip(q).map(|(&a, &b)| (a - b).max(0.0)).collect();
    let s: f32 = residual.iter().sum();
    if s <= 0.0 {
        // p <= q everywhere can only happen with float slop; fall back to p
        return Verdict::Resample(sample(p, rng));
    }
    Verdict::Resample(rng.weighted(&residual))
}

/// Multi-child (tree) speculative sampling — SpecInfer-style recursive
/// rejection across the candidate set at one node. Children are tried in
/// order; each rejection subtracts the child's mass and renormalizes, so
/// the final output is distributed exactly as `p`.
///
/// Returns (accepted_child_index, token) or the residual-sampled token.
pub enum TreeVerdict {
    AcceptChild(usize),
    Residual(usize),
}

pub fn tree_accept(
    p: &[f32],
    q_per_child: &[&[f32]],
    child_tokens: &[usize],
    rng: &mut Rng,
) -> TreeVerdict {
    let mut p_cur: Vec<f32> = p.to_vec();
    for (ci, (&tok, q)) in child_tokens.iter().zip(q_per_child).enumerate() {
        let pi = p_cur[tok];
        let qi = q[tok].max(1e-20);
        if rng.f32() < (pi / qi).min(1.0) {
            return TreeVerdict::AcceptChild(ci);
        }
        // reject: p <- norm(max(0, p - q))
        let mut s = 0.0f32;
        for (a, &b) in p_cur.iter_mut().zip(q.iter()) {
            *a = (*a - b).max(0.0);
            s += *a;
        }
        if s <= 0.0 {
            return TreeVerdict::Residual(sample(p, rng));
        }
        for a in &mut p_cur {
            *a /= s;
        }
    }
    TreeVerdict::Residual(sample(&p_cur, rng))
}

/// Greedy variants: a draft child is accepted iff it IS the argmax.
pub fn greedy_accept(p_logits_argmax: usize, tok: usize) -> bool {
    p_logits_argmax == tok
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let p = softmax(&[1.0, 2.0, 3.0], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_temperature_sharpens() {
        let hot = softmax(&[1.0, 2.0], 2.0);
        let cold = softmax(&[1.0, 2.0], 0.5);
        assert!(cold[1] > hot[1]);
    }

    #[test]
    fn softmax_handles_extremes() {
        let p = softmax(&[-1e30, 0.0, -1e30], 1.0);
        assert!((p[1] - 1.0).abs() < 1e-6);
        assert!(!p.iter().any(|x| x.is_nan()));
    }

    #[test]
    fn top_k_descending() {
        let t = top_k(&[0.1, 0.5, 0.2, 0.2], 3);
        assert_eq!(t[0].0, 1);
        assert_eq!(t.len(), 3);
        assert!(t[0].1 >= t[1].1 && t[1].1 >= t[2].1);
    }

    /// The heart of losslessness: empirical law of chain_accept == p.
    #[test]
    fn chain_accept_preserves_distribution() {
        prop::check("chain lossless", 12, |rng, _| {
            let n = 2 + rng.below(6);
            let p = prop::random_dist(rng, n);
            let q = prop::random_dist(rng, n);
            let trials = 30_000;
            let mut counts = vec![0usize; n];
            for _ in 0..trials {
                let tok = rng.weighted(&q);
                match chain_accept(&p, &q, tok, rng) {
                    Verdict::Accept => counts[tok] += 1,
                    Verdict::Resample(t) => counts[t] += 1,
                }
            }
            for i in 0..n {
                let emp = counts[i] as f32 / trials as f32;
                assert!(
                    (emp - p[i]).abs() < 0.02,
                    "token {i}: emp {emp} vs p {}",
                    p[i]
                );
            }
        });
    }

    /// Tree acceptance with K children sampled from q must also emit ~ p.
    #[test]
    fn tree_accept_preserves_distribution() {
        prop::check("tree lossless", 8, |rng, _| {
            let n = 2 + rng.below(5);
            let k = 1 + rng.below(3);
            let p = prop::random_dist(rng, n);
            let q = prop::random_dist(rng, n);
            let trials = 30_000;
            let mut counts = vec![0usize; n];
            for _ in 0..trials {
                // draw k distinct-ish children from q (with replacement is
                // fine for the rule as long as q matches what was sampled)
                let child_tokens: Vec<usize> = (0..k).map(|_| rng.weighted(&q)).collect();
                let qs: Vec<&[f32]> = (0..k).map(|_| q.as_slice()).collect();
                match tree_accept(&p, &qs, &child_tokens, rng) {
                    TreeVerdict::AcceptChild(ci) => counts[child_tokens[ci]] += 1,
                    TreeVerdict::Residual(t) => counts[t] += 1,
                }
            }
            for i in 0..n {
                let emp = counts[i] as f32 / trials as f32;
                assert!(
                    (emp - p[i]).abs() < 0.025,
                    "token {i}: emp {emp} vs p {} (k={k})",
                    p[i]
                );
            }
        });
    }

    #[test]
    fn greedy_rule() {
        assert!(greedy_accept(3, 3));
        assert!(!greedy_accept(3, 4));
    }
}
