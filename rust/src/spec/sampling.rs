//! Speculative-sampling core (S12): stable softmax, temperature sampling,
//! and the lossless accept/resample rules of Leviathan et al. (chain) and
//! SpecInfer/SpecTr (multi-child tree) that EAGLE's verification applies
//! recursively. Property-tested for distribution preservation in
//! `rust/tests/prop_sampling.rs` — the paper's central guarantee.

use crate::util::rng::Rng;

/// Numerically stable softmax with temperature. `t == 0` is handled by
/// callers via [`argmax`]; this function requires `t > 0`.
pub fn softmax(logits: &[f32], t: f32) -> Vec<f32> {
    let mut out = Vec::new();
    softmax_into(logits, t, &mut out);
    out
}

/// [`softmax`] into a reused output buffer (cleared first) — the
/// hot-loop form; identical float operations, so results are
/// bit-identical to the allocating wrapper.
///
/// Degenerate rows degrade deterministically instead of leaking
/// zero/NaN mass downstream (a later `sample`/`Rng::weighted` would
/// otherwise draw from non-positive total mass):
/// * a `+inf` logit is mathematically a point mass — the row becomes
///   one-hot at the argmax, the correct limit (and what the greedy path
///   picks on the same row);
/// * every logit `-inf`, or a NaN poisoning the normalizer, has no
///   meaningful limit — the row becomes UNIFORM.
/// A bad artifact row thus yields a deterministic, well-formed
/// distribution, not a panic or an undefined pick.
pub fn softmax_into(logits: &[f32], t: f32, out: &mut Vec<f32>) {
    debug_assert!(t > 0.0);
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    out.clear();
    out.extend(logits.iter().map(|&l| ((l - m) / t).exp()));
    let s: f32 = out.iter().sum();
    if s > 0.0 && s.is_finite() {
        for x in out.iter_mut() {
            *x /= s;
        }
    } else if m == f32::INFINITY {
        let best = argmax(logits);
        out.iter_mut().for_each(|x| *x = 0.0);
        out[best] = 1.0;
    } else {
        let u = 1.0 / out.len().max(1) as f32;
        out.iter_mut().for_each(|x| *x = u);
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Sample a token id from a probability vector.
pub fn sample(probs: &[f32], rng: &mut Rng) -> usize {
    rng.weighted(probs)
}

/// Top-k (index, prob) pairs, descending.
pub fn top_k(probs: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut idx = Vec::new();
    top_k_into(probs, k, &mut idx);
    idx.into_iter().map(|i| (i, probs[i])).collect()
}

/// Top-k indices by probability (descending) into a reused buffer — the
/// hot-loop form of [`top_k`]: the vocab-sized sort arena is retained
/// across calls, and callers read the probabilities back as `probs[i]`.
/// Same comparator as [`top_k`], so the selection is identical.
///
/// `total_cmp` (not `partial_cmp(..).unwrap()`): a single NaN from a bad
/// artifact must degrade to a deterministic total order, not panic the
/// server worker mid-round.
pub fn top_k_into(probs: &[f32], k: usize, idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..probs.len());
    idx.sort_unstable_by(|&a, &b| probs[b].total_cmp(&probs[a]));
    idx.truncate(k);
}

/// Outcome of verifying one draft position.
#[derive(Debug, Clone, PartialEq)]
pub enum Verdict {
    /// The draft token was accepted.
    Accept,
    /// Rejected; the token to emit instead was resampled from the residual.
    Resample(usize),
}

/// Chain speculative sampling rule (Leviathan et al., Appendix A.1):
/// accept draft token `tok` w.p. min(1, p/q); on rejection resample from
/// norm(max(0, p - q)). Lossless for any draft distribution q.
///
/// Thin allocating wrapper over [`chain_accept_into`].
pub fn chain_accept(p: &[f32], q: &[f32], tok: usize, rng: &mut Rng) -> Verdict {
    let mut residual = Vec::new();
    chain_accept_into(p, q, tok, &mut residual, rng)
}

/// [`chain_accept`] with the rejection residual built in a reused buffer
/// (cleared first) — the hot-loop form: identical float operations and
/// RNG draws, so verdicts are bit-identical to the allocating wrapper.
pub fn chain_accept_into(
    p: &[f32],
    q: &[f32],
    tok: usize,
    residual: &mut Vec<f32>,
    rng: &mut Rng,
) -> Verdict {
    let pi = p[tok];
    let qi = q[tok].max(1e-20);
    if rng.f32() < (pi / qi).min(1.0) {
        return Verdict::Accept;
    }
    residual.clear();
    residual.extend(p.iter().zip(q).map(|(&a, &b)| (a - b).max(0.0)));
    let s: f32 = residual.iter().sum();
    if s <= 0.0 {
        // p <= q everywhere can only happen with float slop; fall back to p
        return Verdict::Resample(sample(p, rng));
    }
    Verdict::Resample(rng.weighted(residual))
}

/// Multi-child (tree) speculative sampling — SpecInfer-style recursive
/// rejection across the candidate set at one node. Children are tried in
/// order; each rejection subtracts the child's mass and renormalizes, so
/// the final output is distributed exactly as `p`.
///
/// Returns (accepted_child_index, token) or the residual-sampled token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TreeVerdict {
    AcceptChild(usize),
    Residual(usize),
}

/// Thin allocating wrapper over [`tree_accept_into`].
pub fn tree_accept(
    p: &[f32],
    q_per_child: &[&[f32]],
    child_tokens: &[usize],
    rng: &mut Rng,
) -> TreeVerdict {
    let mut p_work = Vec::new();
    tree_accept_into(p, q_per_child, child_tokens, &mut p_work, rng)
}

/// [`tree_accept`] with the working/residual distribution kept in a
/// reused buffer (overwritten with `p` first) — identical float
/// operations and RNG draws, so verdicts are bit-identical to the
/// allocating wrapper.
pub fn tree_accept_into(
    p: &[f32],
    q_per_child: &[&[f32]],
    child_tokens: &[usize],
    p_work: &mut Vec<f32>,
    rng: &mut Rng,
) -> TreeVerdict {
    tree_accept_rows(p, q_per_child.len(), |ci| q_per_child[ci], child_tokens, p_work, rng)
}

/// The recursive-rejection core with the per-child q distributions
/// fetched through an accessor instead of a slice of slices — the form
/// the engines use so q rows can live in the round scratch's flat
/// q-slab (`RoundScratch::qs`) with no per-call `Vec<&[f32]>` staging.
pub fn tree_accept_rows<'a>(
    p: &[f32],
    n_children: usize,
    q_of: impl Fn(usize) -> &'a [f32],
    child_tokens: &[usize],
    p_work: &mut Vec<f32>,
    rng: &mut Rng,
) -> TreeVerdict {
    p_work.clear();
    p_work.extend_from_slice(p);
    for ci in 0..n_children {
        let tok = child_tokens[ci];
        let q = q_of(ci);
        let pi = p_work[tok];
        let qi = q[tok].max(1e-20);
        if rng.f32() < (pi / qi).min(1.0) {
            return TreeVerdict::AcceptChild(ci);
        }
        // reject: p <- norm(max(0, p - q))
        let mut s = 0.0f32;
        for (a, &b) in p_work.iter_mut().zip(q.iter()) {
            *a = (*a - b).max(0.0);
            s += *a;
        }
        if s <= 0.0 {
            return TreeVerdict::Residual(sample(p, rng));
        }
        for a in p_work.iter_mut() {
            *a /= s;
        }
    }
    TreeVerdict::Residual(sample(p_work, rng))
}

/// Greedy variants: a draft child is accepted iff it IS the argmax.
pub fn greedy_accept(p_logits_argmax: usize, tok: usize) -> bool {
    p_logits_argmax == tok
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let p = softmax(&[1.0, 2.0, 3.0], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_temperature_sharpens() {
        let hot = softmax(&[1.0, 2.0], 2.0);
        let cold = softmax(&[1.0, 2.0], 0.5);
        assert!(cold[1] > hot[1]);
    }

    #[test]
    fn softmax_handles_extremes() {
        let p = softmax(&[-1e30, 0.0, -1e30], 1.0);
        assert!((p[1] - 1.0).abs() < 1e-6);
        assert!(!p.iter().any(|x| x.is_nan()));
    }

    #[test]
    fn softmax_degenerate_rows_fall_back_to_uniform() {
        // all -inf: the max is -inf, every exp is NaN, the sum is NaN
        let p = softmax(&[f32::NEG_INFINITY; 4], 1.0);
        assert!(p.iter().all(|&x| (x - 0.25).abs() < 1e-7), "all -inf -> uniform: {p:?}");
        // a +inf logit is a point mass: one-hot at the argmax (the
        // correct limit, matching what T=0 argmax picks on the same row)
        let p = softmax(&[0.0, f32::INFINITY, -1.0], 1.0);
        assert_eq!(p, vec![0.0, 1.0, 0.0], "inf row -> point mass: {p:?}");
        // a NaN logit poisons the sum; still uniform, never NaN out
        let p = softmax(&[0.0, f32::NAN, 1.0], 1.0);
        assert!(p.iter().all(|&x| (x - 1.0 / 3.0).abs() < 1e-7), "NaN row -> uniform: {p:?}");
        // and sampling from the fallback cannot panic or loop
        let mut rng = Rng::new(5);
        assert!(sample(&p, &mut rng) < 3);
    }

    #[test]
    fn top_k_survives_nan_probs() {
        // NaN sorts deterministically under total_cmp instead of
        // panicking the comparator mid-round
        let t = top_k(&[0.1, f32::NAN, 0.5, 0.2], 2);
        assert_eq!(t.len(), 2);
        let again = top_k(&[0.1, f32::NAN, 0.5, 0.2], 2);
        assert_eq!(
            t.iter().map(|x| x.0).collect::<Vec<_>>(),
            again.iter().map(|x| x.0).collect::<Vec<_>>(),
            "NaN ordering must be deterministic"
        );
    }

    #[test]
    fn top_k_descending() {
        let t = top_k(&[0.1, 0.5, 0.2, 0.2], 3);
        assert_eq!(t[0].0, 1);
        assert_eq!(t.len(), 3);
        assert!(t[0].1 >= t[1].1 && t[1].1 >= t[2].1);
    }

    /// The heart of losslessness: empirical law of chain_accept == p.
    #[test]
    fn chain_accept_preserves_distribution() {
        prop::check("chain lossless", 12, |rng, _| {
            let n = 2 + rng.below(6);
            let p = prop::random_dist(rng, n);
            let q = prop::random_dist(rng, n);
            let trials = 30_000;
            let mut counts = vec![0usize; n];
            for _ in 0..trials {
                let tok = rng.weighted(&q);
                match chain_accept(&p, &q, tok, rng) {
                    Verdict::Accept => counts[tok] += 1,
                    Verdict::Resample(t) => counts[t] += 1,
                }
            }
            for i in 0..n {
                let emp = counts[i] as f32 / trials as f32;
                assert!(
                    (emp - p[i]).abs() < 0.02,
                    "token {i}: emp {emp} vs p {}",
                    p[i]
                );
            }
        });
    }

    /// Tree acceptance with K children sampled from q must also emit ~ p.
    #[test]
    fn tree_accept_preserves_distribution() {
        prop::check("tree lossless", 8, |rng, _| {
            let n = 2 + rng.below(5);
            let k = 1 + rng.below(3);
            let p = prop::random_dist(rng, n);
            let q = prop::random_dist(rng, n);
            let trials = 30_000;
            let mut counts = vec![0usize; n];
            for _ in 0..trials {
                // draw k distinct-ish children from q (with replacement is
                // fine for the rule as long as q matches what was sampled)
                let child_tokens: Vec<usize> = (0..k).map(|_| rng.weighted(&q)).collect();
                let qs: Vec<&[f32]> = (0..k).map(|_| q.as_slice()).collect();
                match tree_accept(&p, &qs, &child_tokens, rng) {
                    TreeVerdict::AcceptChild(ci) => counts[child_tokens[ci]] += 1,
                    TreeVerdict::Residual(t) => counts[t] += 1,
                }
            }
            for i in 0..n {
                let emp = counts[i] as f32 / trials as f32;
                assert!(
                    (emp - p[i]).abs() < 0.025,
                    "token {i}: emp {emp} vs p {} (k={k})",
                    p[i]
                );
            }
        });
    }

    #[test]
    fn greedy_rule() {
        assert!(greedy_accept(3, 3));
        assert!(!greedy_accept(3, 4));
    }
}
