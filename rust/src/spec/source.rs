//! Draft sources (PR 10): one trait unifying the four drafting
//! strategies — EAGLE feature extrapolation, classic chain drafting with
//! a small LM, Lookahead-style n-gram retrieval, and Medusa heads — so a
//! generic round loop ([`SourceEngine`]) can run any of them against the
//! same SpecInfer verification/commit machinery.
//!
//! Contract (see `docs/drafting.md`):
//!
//! * `propose` grows the round's [`DraftTree`] from the committed
//!   boundary `m` (root token pre-seeded at node 0). Per-node
//!   *confidence* travels in `TreeNode::score` (cumulative ln-prob where
//!   the source has one; 0.0 where it does not).
//! * At T>0 every non-root node MUST carry a q-slab row id
//!   ([`push_one_hot_q`] for deterministic sources): the shared
//!   [`sampled_accept_walk`] consumes q under the recursive-rejection
//!   rule, which for a one-hot q degenerates to "accept with probability
//!   p(token), else resample from p with that token zeroed" — exactly
//!   the SpecInfer guarantee, so deterministic n-gram/Medusa proposals
//!   stay lossless at any temperature.
//! * `advance` folds the verified round back into the source (replay
//!   draft KV, refresh the Medusa feature, index fresh n-grams). It runs
//!   only on committed state, so a source can never observe rejected
//!   speculation.
//! * `max_nodes` / `verify_t` / `max_step_w` / `footprint` declare the
//!   scratch + width requirements up front; the engine reserves once and
//!   the warm round path allocates nothing (asserted under
//!   `count-alloc` in `tests/prop_draftsrc.rs`).
//!
//! [`EagleEngine::generate_resumable`] remains the fused production
//! specialization of the eagle source (checkpointing, fused commit,
//! batched lanes); [`EagleSource`] reuses its growth code
//! (`grow_tree` / `grow_tree_dynamic`) behind the trait so the two can
//! never drift.

use anyhow::{bail, Result};
use std::time::Instant;

use super::dyntree::{rerank_into, DynTreeParams, TreePolicy};
use super::engine::{sampled_accept_walk, EagleEngine, GenConfig, PairShift};
use super::sampling::{argmax, sample, softmax_into};
use super::scratch::RoundScratch;
use super::tree::{chain_extend_bias_to, DraftTree};
use crate::metrics::trace::{RoundEvent, RoundObserver};
use crate::metrics::GenRecord;
use crate::models::target::KvCache;
use crate::models::{MedusaHeads, TargetModel};
use crate::util::deadline::DeadlineClock;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// kinds + request-level choice

/// The four drafting strategies, as wire/CLI names.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SourceKind {
    Eagle,
    Chain,
    Ngram,
    Medusa,
}

impl SourceKind {
    pub const ALL: [SourceKind; 4] =
        [SourceKind::Eagle, SourceKind::Chain, SourceKind::Ngram, SourceKind::Medusa];

    pub fn parse(s: &str) -> Option<SourceKind> {
        match s {
            "eagle" => Some(SourceKind::Eagle),
            "chain" => Some(SourceKind::Chain),
            "ngram" => Some(SourceKind::Ngram),
            "medusa" => Some(SourceKind::Medusa),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SourceKind::Eagle => "eagle",
            SourceKind::Chain => "chain",
            SourceKind::Ngram => "ngram",
            SourceKind::Medusa => "medusa",
        }
    }

    pub fn idx(self) -> usize {
        match self {
            SourceKind::Eagle => 0,
            SourceKind::Chain => 1,
            SourceKind::Ngram => 2,
            SourceKind::Medusa => 3,
        }
    }

    pub fn from_idx(i: usize) -> SourceKind {
        Self::ALL[i]
    }

    /// Relative per-round drafting cost (verify cost is shared): the
    /// denominator of the policy score `EWMA(accepted/round) / cost`.
    /// An n-gram lookup is nearly free; a chain of sequential small-LM
    /// decodes is the most expensive per proposed token.
    pub fn cost_hint(self) -> f64 {
        match self {
            SourceKind::Ngram => 1.0,
            SourceKind::Medusa => 1.5,
            SourceKind::Eagle => 2.0,
            SourceKind::Chain => 4.0,
        }
    }
}

/// Request-level draft selection: `"draft"` body field / `--draft` flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftChoice {
    /// Not specified: defer to the server's configured default.
    Default,
    /// Online policy: the [`crate::spec::dyntree::SourceSelector`] picks
    /// per request from live acceptance stats.
    Auto,
    Fixed(SourceKind),
}

impl DraftChoice {
    pub fn parse(s: &str) -> Option<DraftChoice> {
        match s {
            "auto" => Some(DraftChoice::Auto),
            _ => SourceKind::parse(s).map(DraftChoice::Fixed),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DraftChoice::Default => "default",
            DraftChoice::Auto => "auto",
            DraftChoice::Fixed(k) => k.as_str(),
        }
    }
}

// ---------------------------------------------------------------------------
// the trait

/// Verified-round context handed to [`DraftSource::advance`]: everything
/// a source may need to fold the committed tokens back in. Borrowed —
/// building it allocates nothing.
pub struct AdvanceCtx<'a> {
    /// All committed tokens (position i holds token i); the new root
    /// token sits at position `m_new`.
    pub committed: &'a [u32],
    /// Committed boundary before this round.
    pub m_old: usize,
    /// Committed boundary after this round (`m_old + accepted + 1`).
    pub m_new: usize,
    /// Accepted node path through `tree` (root included).
    pub path: &'a [usize],
    /// The verified draft tree of this round.
    pub tree: &'a DraftTree,
    /// Target features from the verify pass, `verify_t` rows of width d
    /// (row i = feature at tree node i) — TRUE features, usable as
    /// drafting state for the next round.
    pub verify_feats: &'a [f32],
    /// Verify width the round actually dispatched at.
    pub verify_t: usize,
}

/// A drafting strategy the generic round loop can run. See the module
/// docs for the contract; all methods are called from a single thread.
pub trait DraftSource {
    fn kind(&self) -> SourceKind;

    /// Scratch reservation ceiling: the most nodes (root included) any
    /// round's tree can hold.
    fn max_nodes(&self) -> usize;

    /// Verify-width budget anchor (the engine bails if a proposed tree
    /// exceeds [`DraftSource::fit_verify`] of its node count).
    fn verify_t(&self) -> usize;

    /// Dispatch width for a tree of `n_nodes` (padding-only shrink).
    /// Sources with a lowered width family override this; the default is
    /// the fixed budget.
    fn fit_verify(&self, _n_nodes: usize) -> usize {
        self.verify_t()
    }

    /// Widest draft-step staging the source writes into the shared
    /// scratch (`sf`/`st`/`sp`/`sbias`); 1 for sources that never step.
    fn max_step_w(&self) -> usize {
        1
    }

    /// Position ceiling of any internal draft cache (the engine stops
    /// before `m + verify_t + 1` reaches it).
    fn cache_limit(&self) -> usize {
        usize::MAX
    }

    /// Bytes of internal reusable state, counted into the per-round
    /// alloc-growth metric so a source growing private buffers mid-run
    /// cannot hide from `round_host_alloc_bytes`.
    fn footprint(&self) -> usize {
        0
    }

    /// One-time setup after the target prefill: `prefill_feats` holds
    /// `plen` feature rows, `committed` the prompt plus the root token.
    fn begin(
        &mut self,
        prefill_feats: &[f32],
        p_win: usize,
        plen: usize,
        committed: &[u32],
        cfg: &GenConfig,
        rec: &mut GenRecord,
    ) -> Result<()>;

    /// Reset per-round scratch. The default clears the q slab only;
    /// the eagle source overrides to seed its root feature/logits rows.
    fn begin_round(&mut self, s: &mut RoundScratch, vocab: usize) {
        s.qs.clear(vocab);
    }

    /// Grow this round's proposals into `tree` (root pre-seeded with the
    /// committed token at position `m`).
    #[allow(clippy::too_many_arguments)]
    fn propose(
        &mut self,
        tree: &mut DraftTree,
        s: &mut RoundScratch,
        committed: &[u32],
        m: usize,
        cfg: &GenConfig,
        rng: &mut Rng,
        rec: &mut GenRecord,
    ) -> Result<()>;

    /// Fold the verified round back into the source's drafting state.
    fn advance(&mut self, ctx: &AdvanceCtx<'_>, s: &mut RoundScratch, rec: &mut GenRecord)
        -> Result<()>;
}

/// Push a one-hot q row (δ at `tok`) into the round's q slab and return
/// its row id. Deterministic sources attach these at T>0 so the shared
/// acceptance walk stays exactly lossless (see module docs).
pub fn push_one_hot_q(s: &mut RoundScratch, vocab: usize, tok: u32) -> u32 {
    s.probs.clear();
    s.probs.resize(vocab, 0.0);
    s.probs[tok as usize] = 1.0;
    s.qs.push(&s.probs) as u32
}

/// Sample/argmax a token from a logits row (the engines' root pick).
pub fn pick_token(logits: &[f32], temperature: f32, rng: &mut Rng, probs: &mut Vec<f32>) -> u32 {
    if temperature <= 0.0 {
        argmax(logits) as u32
    } else {
        softmax_into(logits, temperature, probs);
        sample(probs, rng) as u32
    }
}

/// Greedy (T=0) acceptance walk: accept a child iff it is the argmax of
/// the verified row, exactly mirroring `EagleEngine::accept`. Fills
/// `s.path` (root included) and returns the bonus token.
pub fn greedy_accept_walk<'a>(
    tree: &DraftTree,
    row_of: impl Fn(usize) -> &'a [f32],
    alpha: &mut [(u64, u64)],
    s: &mut RoundScratch,
) -> u32 {
    s.path.clear();
    s.path.push(0);
    let mut cur = 0usize;
    loop {
        let depth = tree.nodes[cur].depth;
        tree.children_into(cur, &mut s.children);
        let want = argmax(row_of(cur));
        let next = s.children.iter().copied().find(|&c| tree.nodes[c].token as usize == want);
        let nbuckets = alpha.len();
        if depth < nbuckets && !s.children.is_empty() {
            let b = depth.min(nbuckets - 1);
            alpha[b].1 += 1;
            if next.is_some() {
                alpha[b].0 += 1;
            }
        }
        match next {
            Some(c) => {
                s.path.push(c);
                cur = c;
            }
            None => return want as u32,
        }
    }
}

// ---------------------------------------------------------------------------
// EagleSource — the paper's method behind the trait

/// Feature-level autoregressive drafting (the paper's method) as a
/// [`DraftSource`]: wraps an [`EagleEngine`] and delegates tree growth
/// to its `grow_tree`/`grow_tree_dynamic`, so the trait path and the
/// fused production path share one growth implementation.
pub struct EagleSource<'a> {
    pub eng: EagleEngine<'a>,
    dcache: KvCache,
    root_feat: Vec<f32>,
    root_logits: Vec<f32>,
    draft_len: usize,
    base_params: Option<DynTreeParams>,
}

impl<'a> EagleSource<'a> {
    pub fn new(eng: EagleEngine<'a>) -> Self {
        let dcache = eng.draft.new_cache(1);
        let base_params = match &eng.policy {
            TreePolicy::Dynamic(dc) => Some(dc.params(eng.verify_t, eng.draft_w, eng.accept_a)),
            TreePolicy::Static(_) => None,
        };
        EagleSource {
            eng,
            dcache,
            root_feat: Vec::new(),
            root_logits: Vec::new(),
            draft_len: 0,
            base_params,
        }
    }
}

impl DraftSource for EagleSource<'_> {
    fn kind(&self) -> SourceKind {
        SourceKind::Eagle
    }

    fn max_nodes(&self) -> usize {
        self.eng.max_tree_nodes()
    }

    fn verify_t(&self) -> usize {
        self.eng.verify_t.max(self.eng.widths.max())
    }

    fn fit_verify(&self, n_nodes: usize) -> usize {
        self.eng.widths.fit(n_nodes)
    }

    fn max_step_w(&self) -> usize {
        self.eng.draft_w.max(self.eng.draft_widths.max())
    }

    fn footprint(&self) -> usize {
        (self.root_feat.capacity() + self.root_logits.capacity()) * std::mem::size_of::<f32>()
    }

    fn begin(
        &mut self,
        prefill_feats: &[f32],
        p_win: usize,
        plen: usize,
        committed: &[u32],
        _cfg: &GenConfig,
        rec: &mut GenRecord,
    ) -> Result<()> {
        let d = self.eng.target.d;
        let mut dtoks = vec![0i32; p_win];
        for (i, slot) in dtoks.iter_mut().enumerate().take(plen) {
            *slot = match self.eng.shift {
                PairShift::Shifted => committed[i + 1] as i32,
                PairShift::Unshifted => committed[i] as i32,
            };
        }
        let mut dfeats = vec![0f32; p_win * d];
        dfeats[..plen * d].copy_from_slice(&prefill_feats[..plen * d]);
        let t0 = Instant::now();
        let dout = self.eng.draft.prefill(&dfeats, &dtoks, plen, &mut self.dcache)?;
        rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
        rec.draft_passes += 1;
        self.root_feat = dout.feats;
        self.root_logits = dout.logits;
        self.draft_len = plen;
        Ok(())
    }

    fn begin_round(&mut self, s: &mut RoundScratch, _vocab: usize) {
        s.begin_round(&self.root_feat, &self.root_logits);
    }

    fn propose(
        &mut self,
        tree: &mut DraftTree,
        s: &mut RoundScratch,
        _committed: &[u32],
        m: usize,
        cfg: &GenConfig,
        rng: &mut Rng,
        rec: &mut GenRecord,
    ) -> Result<()> {
        match &self.eng.policy {
            TreePolicy::Static(spec) => {
                self.eng.grow_tree(
                    tree,
                    spec,
                    m,
                    self.draft_len,
                    &mut self.dcache,
                    cfg,
                    rng,
                    rec,
                    s,
                )?;
            }
            TreePolicy::Dynamic(_) => {
                let params = self.base_params.expect("dynamic policy resolves params");
                self.eng.grow_tree_dynamic(
                    tree,
                    &params,
                    m,
                    self.draft_len,
                    &mut self.dcache,
                    cfg,
                    rng,
                    rec,
                    s,
                )?;
                if tree.len() - 1 > params.budget {
                    rerank_into(tree, params.budget, &mut s.spare_tree, &mut s.rr);
                    std::mem::swap(tree, &mut s.spare_tree);
                }
                rec.drafted += tree.len() - 1;
            }
        }
        Ok(())
    }

    fn advance(
        &mut self,
        ctx: &AdvanceCtx<'_>,
        s: &mut RoundScratch,
        rec: &mut GenRecord,
    ) -> Result<()> {
        let d = self.eng.target.d;
        let vocab = self.eng.target.vocab;
        let s_tot = self.eng.target.max_len;
        let n_pending = ctx.m_new - ctx.m_old;
        if n_pending > self.eng.draft_w {
            bail!("pending pairs {n_pending} exceed draft width {}", self.eng.draft_w);
        }
        let w = self.eng.draft_widths.fit(n_pending);
        rec.round_draft_w.push(w);
        s.sf.clear();
        s.sf.resize(w * d, 0.0);
        s.st.clear();
        s.st.resize(w, 0);
        s.sp.clear();
        s.sp.resize(w, 0);
        for (r, &ni) in ctx.path.iter().enumerate() {
            let f = &ctx.verify_feats[ni * d..(ni + 1) * d];
            s.sf[r * d..(r + 1) * d].copy_from_slice(f);
            let slot_pos = ctx.m_old + r;
            s.st[r] = match self.eng.shift {
                PairShift::Shifted => ctx.committed[slot_pos + 1] as i32,
                PairShift::Unshifted => ctx.committed[slot_pos] as i32,
            };
            s.sp[r] = slot_pos as i32;
        }
        for r in n_pending..w {
            s.sp[r] = (ctx.m_old + r) as i32;
        }
        s.sbias.clear();
        s.sbias.resize(w * s_tot, 0.0);
        chain_extend_bias_to(w, s_tot, ctx.m_old, n_pending, &mut s.sbias);
        let t0 = Instant::now();
        let eout = self.eng.draft.step(
            w,
            &mut self.dcache,
            &[ctx.m_old as i32],
            &s.sf,
            &s.st,
            &s.sp,
            &s.sbias,
        )?;
        rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
        rec.draft_passes += 1;
        let last = n_pending - 1;
        self.root_feat.clear();
        self.root_feat.extend_from_slice(&eout.feats[last * d..(last + 1) * d]);
        self.root_logits.clear();
        self.root_logits.extend_from_slice(&eout.logits[last * vocab..(last + 1) * vocab]);
        self.draft_len = ctx.m_new;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// ChainLmSource — classic speculative sampling with a small LM

/// Token-level chain drafting with a separate small LM (the classic
/// speculative-sampling baseline): gamma sequential draft decodes per
/// round, proposals sampled from the draft distribution (kept as q rows
/// for the acceptance walk at T>0).
pub struct ChainLmSource<'a> {
    draft: &'a TargetModel,
    gamma: usize,
    verify_width: usize,
    dcache: KvCache,
    /// Next position the draft cache needs decoded (rewound to the
    /// committed boundary after every round).
    draft_pos: usize,
    dlogits: Vec<f32>,
}

impl<'a> ChainLmSource<'a> {
    pub fn new(draft: &'a TargetModel, gamma: usize, verify_width: usize) -> Self {
        assert!(gamma + 1 <= verify_width);
        let dcache = draft.new_cache(1);
        ChainLmSource { draft, gamma, verify_width, dcache, draft_pos: 0, dlogits: Vec::new() }
    }
}

impl DraftSource for ChainLmSource<'_> {
    fn kind(&self) -> SourceKind {
        SourceKind::Chain
    }

    fn max_nodes(&self) -> usize {
        self.gamma + 1
    }

    fn verify_t(&self) -> usize {
        self.verify_width
    }

    fn cache_limit(&self) -> usize {
        self.draft.max_len
    }

    fn footprint(&self) -> usize {
        self.dlogits.capacity() * std::mem::size_of::<f32>()
    }

    fn begin(
        &mut self,
        _prefill_feats: &[f32],
        _p_win: usize,
        plen: usize,
        committed: &[u32],
        _cfg: &GenConfig,
        rec: &mut GenRecord,
    ) -> Result<()> {
        let t0 = Instant::now();
        let (dout, dplen) = self.draft.prefill(&committed[..plen], &mut self.dcache)?;
        rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
        rec.draft_passes += 1;
        self.draft_pos = dplen;
        let vocab = self.draft.vocab;
        let last = self.draft.row(&dout.logits, self.draft.prefill_p, 0, dplen - 1, vocab);
        self.dlogits.clear();
        self.dlogits.extend_from_slice(last);
        Ok(())
    }

    fn propose(
        &mut self,
        tree: &mut DraftTree,
        s: &mut RoundScratch,
        committed: &[u32],
        m: usize,
        cfg: &GenConfig,
        rng: &mut Rng,
        rec: &mut GenRecord,
    ) -> Result<()> {
        let vocab = self.draft.vocab;
        // replay committed tokens the draft cache hasn't seen (bonus +
        // rejected-tail rewind from the previous round)
        while self.draft_pos <= m {
            let t0 = Instant::now();
            let out = self.draft.decode(
                &mut self.dcache,
                &[self.draft_pos as i32],
                &[committed[self.draft_pos] as i32],
            )?;
            rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
            rec.draft_passes += 1;
            self.dlogits.clear();
            self.dlogits.extend_from_slice(&out.logits[..vocab]);
            self.draft_pos += 1;
        }
        // gamma chained proposals from the draft distribution
        let mut parent = 0usize;
        for g in 0..self.gamma {
            if m + g + 2 >= self.draft.max_len {
                break;
            }
            let (tok, score, qid) = if cfg.temperature <= 0.0 {
                (argmax(&self.dlogits) as u32, 0.0, None)
            } else {
                softmax_into(&self.dlogits, cfg.temperature, &mut s.probs);
                let qid = s.qs.push(&s.probs) as u32;
                let tok = sample(s.qs.get(qid as usize), rng);
                let score = s.qs.get(qid as usize)[tok].max(1e-20).ln();
                (tok as u32, score, Some(qid))
            };
            parent = tree.add(parent, tok, score, qid);
            rec.drafted += 1;
            if g + 1 < self.gamma {
                let t0 = Instant::now();
                let out =
                    self.draft.decode(&mut self.dcache, &[self.draft_pos as i32], &[tok as i32])?;
                rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
                rec.draft_passes += 1;
                self.dlogits.clear();
                self.dlogits.extend_from_slice(&out.logits[..vocab]);
                self.draft_pos += 1;
            }
        }
        Ok(())
    }

    fn advance(
        &mut self,
        ctx: &AdvanceCtx<'_>,
        _s: &mut RoundScratch,
        _rec: &mut GenRecord,
    ) -> Result<()> {
        // rewind: positions past the committed boundary were speculative
        // and get re-decoded (overwritten) by the next round's replay
        self.draft_pos = self.draft_pos.min(ctx.m_new);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// NgramSource — Lookahead-style retrieval drafting

const NGRAM_EMPTY: u64 = u64::MAX;
const NGRAM_CAP: usize = 1 << 12;
const NGRAM_MAX_PROBE: usize = 16;

/// Fixed-capacity open-addressing map from packed token n-gram keys to
/// continuation tokens. Most-recent-wins: inserting over a full probe
/// chain overwrites the chain's last slot, so the table never grows and
/// warm inserts/lookups are allocation-free.
pub struct NgramTable {
    keys: Vec<u64>,
    vals: Vec<u32>,
    len: usize,
}

impl Default for NgramTable {
    fn default() -> Self {
        NgramTable { keys: vec![NGRAM_EMPTY; NGRAM_CAP], vals: vec![0; NGRAM_CAP], len: 0 }
    }
}

impl NgramTable {
    fn slot_of(key: u64, probe: usize) -> usize {
        // SplitMix64 finalizer — avalanches the packed token pair
        let mut z = key.wrapping_add(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^= z >> 31;
        (z as usize).wrapping_add(probe) & (NGRAM_CAP - 1)
    }

    pub fn clear(&mut self) {
        self.keys.iter_mut().for_each(|k| *k = NGRAM_EMPTY);
        self.len = 0;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn insert(&mut self, key: u64, val: u32) {
        debug_assert_ne!(key, NGRAM_EMPTY);
        let mut last = 0usize;
        for probe in 0..NGRAM_MAX_PROBE {
            let i = Self::slot_of(key, probe);
            last = i;
            if self.keys[i] == key {
                self.vals[i] = val; // most-recent-wins update
                return;
            }
            if self.keys[i] == NGRAM_EMPTY {
                self.keys[i] = key;
                self.vals[i] = val;
                self.len += 1;
                return;
            }
        }
        // probe chain full: evict the chain's last occupant
        self.keys[last] = key;
        self.vals[last] = val;
    }

    pub fn get(&self, key: u64) -> Option<u32> {
        for probe in 0..NGRAM_MAX_PROBE {
            let i = Self::slot_of(key, probe);
            if self.keys[i] == key {
                return Some(self.vals[i]);
            }
            if self.keys[i] == NGRAM_EMPTY {
                return None;
            }
        }
        None
    }
}

fn ngram_key(a: u32, b: u32) -> u64 {
    ((a as u64) << 32) | b as u64
}

/// Lookahead-style 2-gram retrieval drafting: nearly free per round
/// (pure table lookups, no model pass), wins on repetitive code/JSON
/// where recently seen continuations repeat. Proposals are one-hot-q
/// chains, so the source is lossless at any temperature.
pub struct NgramSource {
    table: NgramTable,
    gamma: usize,
    verify_width: usize,
    vocab: usize,
    /// committed.len() already folded into the table
    indexed: usize,
}

impl NgramSource {
    pub const N: usize = 2;

    pub fn new(gamma: usize, verify_width: usize, vocab: usize) -> Self {
        assert!(gamma + 1 <= verify_width);
        NgramSource { table: NgramTable::default(), gamma, verify_width, vocab, indexed: 0 }
    }

    fn index_from(&mut self, committed: &[u32], start: usize) {
        // 2-gram context (prev, cur) -> next, most-recent occurrence wins;
        // restart N-1 back so n-grams straddling `start` are indexed too
        let from = start.saturating_sub(Self::N);
        for i in from..committed.len().saturating_sub(2) {
            self.table.insert(ngram_key(committed[i], committed[i + 1]), committed[i + 2]);
        }
        self.indexed = committed.len();
    }
}

impl DraftSource for NgramSource {
    fn kind(&self) -> SourceKind {
        SourceKind::Ngram
    }

    fn max_nodes(&self) -> usize {
        self.gamma + 1
    }

    fn verify_t(&self) -> usize {
        self.verify_width
    }

    fn begin(
        &mut self,
        _prefill_feats: &[f32],
        _p_win: usize,
        _plen: usize,
        committed: &[u32],
        _cfg: &GenConfig,
        _rec: &mut GenRecord,
    ) -> Result<()> {
        self.table.clear();
        self.index_from(committed, 0);
        Ok(())
    }

    fn propose(
        &mut self,
        tree: &mut DraftTree,
        s: &mut RoundScratch,
        committed: &[u32],
        m: usize,
        cfg: &GenConfig,
        _rng: &mut Rng,
        rec: &mut GenRecord,
    ) -> Result<()> {
        if m == 0 {
            return Ok(());
        }
        let mut prev = committed[m - 1];
        let mut cur = committed[m];
        let mut parent = 0usize;
        for _ in 0..self.gamma {
            let Some(tok) = self.table.get(ngram_key(prev, cur)) else { break };
            let qid = if cfg.temperature > 0.0 {
                Some(push_one_hot_q(s, self.vocab, tok))
            } else {
                None
            };
            parent = tree.add(parent, tok, 0.0, qid);
            rec.drafted += 1;
            prev = cur;
            cur = tok;
        }
        Ok(())
    }

    fn advance(
        &mut self,
        ctx: &AdvanceCtx<'_>,
        _s: &mut RoundScratch,
        _rec: &mut GenRecord,
    ) -> Result<()> {
        let start = self.indexed;
        self.index_from(ctx.committed, start);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// MedusaSource — independent per-position heads

/// Medusa-style drafting: K independent heads over the current target
/// feature, each predicting one position ahead. Proposals form a
/// one-hot-q chain (lossless at any temperature); the feature refreshes
/// from the verify pass's TRUE feature at the deepest accepted node.
pub struct MedusaSource<'a> {
    heads: &'a MedusaHeads,
    k: usize,
    d: usize,
    vocab: usize,
    verify_width: usize,
    feat: Vec<f32>,
}

impl<'a> MedusaSource<'a> {
    pub fn new(heads: &'a MedusaHeads, k: usize, d: usize, vocab: usize, verify_width: usize) -> Self {
        assert!(k + 1 <= verify_width);
        MedusaSource { heads, k, d, vocab, verify_width, feat: Vec::new() }
    }
}

impl DraftSource for MedusaSource<'_> {
    fn kind(&self) -> SourceKind {
        SourceKind::Medusa
    }

    fn max_nodes(&self) -> usize {
        self.k + 1
    }

    fn verify_t(&self) -> usize {
        self.verify_width
    }

    fn footprint(&self) -> usize {
        self.feat.capacity() * std::mem::size_of::<f32>()
    }

    fn begin(
        &mut self,
        prefill_feats: &[f32],
        _p_win: usize,
        plen: usize,
        _committed: &[u32],
        _cfg: &GenConfig,
        _rec: &mut GenRecord,
    ) -> Result<()> {
        let d = self.d;
        self.feat.clear();
        self.feat.extend_from_slice(&prefill_feats[(plen - 1) * d..plen * d]);
        Ok(())
    }

    fn propose(
        &mut self,
        tree: &mut DraftTree,
        s: &mut RoundScratch,
        _committed: &[u32],
        _m: usize,
        cfg: &GenConfig,
        _rng: &mut Rng,
        rec: &mut GenRecord,
    ) -> Result<()> {
        let t0 = Instant::now();
        let hl = self.heads.heads(&self.feat)?;
        rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
        rec.draft_passes += 1;
        let mut parent = 0usize;
        for kk in 0..self.k {
            let row = &hl[kk * self.vocab..(kk + 1) * self.vocab];
            let tok = argmax(row) as u32;
            let qid = if cfg.temperature > 0.0 {
                Some(push_one_hot_q(s, self.vocab, tok))
            } else {
                None
            };
            parent = tree.add(parent, tok, 0.0, qid);
            rec.drafted += 1;
        }
        Ok(())
    }

    fn advance(
        &mut self,
        ctx: &AdvanceCtx<'_>,
        _s: &mut RoundScratch,
        _rec: &mut GenRecord,
    ) -> Result<()> {
        let d = self.d;
        let deepest = *ctx.path.last().expect("accept path includes root");
        self.feat.clear();
        self.feat.extend_from_slice(&ctx.verify_feats[deepest * d..(deepest + 1) * d]);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// SourceEngine — the generic round loop

/// The generic speculative round loop over any [`DraftSource`]:
/// target prefill → (source begin) → rounds of propose / verify /
/// SpecInfer-accept / fused-commit / source-advance. This is the
/// trait-dispatch counterpart of [`EagleEngine::generate_resumable`]
/// (which stays as the fused, checkpointable specialization of the eagle
/// source); the baseline engines delegate here, so chain / n-gram /
/// Medusa drafting all share one verified commit path.
pub struct SourceEngine<'a> {
    pub target: &'a TargetModel,
    pub accept_a: usize,
    pub deadline: DeadlineClock,
    pub observer: Option<&'a dyn RoundObserver>,
}

impl<'a> SourceEngine<'a> {
    pub fn new(target: &'a TargetModel, accept_a: usize) -> Self {
        SourceEngine { target, accept_a, deadline: DeadlineClock::default(), observer: None }
    }

    pub fn with_deadline(mut self, deadline: DeadlineClock) -> Self {
        self.deadline = deadline;
        self
    }

    pub fn with_observer(mut self, observer: &'a dyn RoundObserver) -> Self {
        self.observer = Some(observer);
        self
    }

    pub fn generate(
        &self,
        src: &mut dyn DraftSource,
        prompt: &[u32],
        cfg: &GenConfig,
    ) -> Result<GenRecord> {
        let t_all = Instant::now();
        let tgt = self.target;
        let d = tgt.d;
        let vocab = tgt.vocab;
        let s_tot = tgt.max_len;
        let p_win = tgt.prefill_p;

        let mut cache = tgt.new_cache(1);
        let mut rec = GenRecord::new(prompt.len());
        rec.reserve_rounds(cfg.max_new);
        let mut rng = Rng::new(cfg.seed);

        // ---- target prefill + root token --------------------------------
        let t0 = Instant::now();
        let (out, plen) = tgt.prefill(prompt, &mut cache)?;
        rec.timeline.prefill_ns += t0.elapsed().as_nanos() as u64;
        rec.target_passes += 1;
        let last_logits = tgt.row(&out.logits, p_win, 0, plen - 1, vocab);
        let mut pick_probs = Vec::new();
        let root_tok = pick_token(last_logits, cfg.temperature, &mut rng, &mut pick_probs);
        rec.tokens.push(root_tok);
        rec.ttft_ns = t_all.elapsed().as_nanos() as u64;
        let mut committed = Vec::with_capacity(prompt.len() + cfg.max_new + 2);
        committed.extend_from_slice(prompt);
        committed.push(root_tok);
        let mut m = plen;
        src.begin(&out.feats, p_win, plen, &committed, cfg, &mut rec)?;
        if cfg.eos == Some(root_tok) {
            rec.wall_ns = t_all.elapsed().as_nanos() as u64;
            return Ok(rec);
        }

        // pending acceptance, consumed inside the NEXT verify (fused commit)
        let mut pending_old_m = m;
        let mut pending_idx = vec![0i32; self.accept_a];
        let mut pending_n = 0i32;

        // ---- round state: reserved once, reused every round --------------
        let t_reserve = src.verify_t();
        let max_nodes = src.max_nodes();
        let mut scratch = RoundScratch::new(d, vocab);
        scratch.reserve(d, vocab, s_tot, max_nodes, t_reserve, src.max_step_w().max(1));
        if cfg.temperature > 0.0 {
            scratch.reserve_q(vocab, max_nodes);
        }
        let mut tree = DraftTree::default();
        tree.nodes.reserve(max_nodes);
        let mut path_buf: Vec<usize> = Vec::with_capacity(max_nodes);
        let s_cap = s_tot.min(src.cache_limit());

        // ---- decode rounds ------------------------------------------------
        while rec.tokens.len() < cfg.max_new {
            if self.deadline.expired() {
                rec.truncated = Some("deadline");
                break;
            }
            if m + t_reserve + 1 >= s_cap {
                break; // cache budget exhausted
            }
            let fp0 = scratch.footprint()
                + tree.capacity_bytes()
                + src.footprint()
                + path_buf.capacity() * std::mem::size_of::<usize>();
            let tl0 = (rec.timeline.draft_ns, rec.timeline.verify_ns, rec.timeline.host_ns);

            // 1. propose
            let th = Instant::now();
            tree.reset(committed[m]);
            src.begin_round(&mut scratch, vocab);
            rec.timeline.host_ns += th.elapsed().as_nanos() as u64;
            src.propose(&mut tree, &mut scratch, &committed, m, cfg, &mut rng, &mut rec)?;
            rec.round_tree_nodes.push(tree.len() - 1);

            // 2. verify at the source's dispatch width
            let sel_t = src.fit_verify(tree.len());
            if sel_t < tree.len() {
                bail!(
                    "draft tree of {} nodes exceeds source verify width {}",
                    tree.len(),
                    sel_t
                );
            }
            rec.round_verify_t.push(sel_t);
            let th = Instant::now();
            scratch.vtokens.clear();
            scratch.vtokens.resize(sel_t, 0);
            scratch.vpos.clear();
            scratch.vpos.resize(sel_t, 0);
            scratch.vbias.clear();
            scratch.vbias.resize(sel_t * s_tot, 0.0);
            tree.verify_inputs_to(
                sel_t,
                m,
                s_tot,
                &mut scratch.vtokens,
                &mut scratch.vpos,
                &mut scratch.vbias,
                &mut scratch.anc,
            );
            rec.timeline.host_ns += th.elapsed().as_nanos() as u64;
            let t0 = Instant::now();
            let vout = tgt.verify(
                sel_t,
                &mut cache,
                &[pending_old_m as i32],
                &pending_idx,
                &[pending_n],
                &scratch.vtokens,
                &scratch.vpos,
                &scratch.vbias,
                self.accept_a,
            )?;
            rec.timeline.verify_ns += t0.elapsed().as_nanos() as u64;
            rec.target_passes += 1;

            // 3. acceptance walk (greedy at T=0, SpecInfer at T>0 — the
            //    same walks the eagle engines run)
            let th = Instant::now();
            let bonus = {
                let row = |i: usize| &vout.logits[i * vocab..(i + 1) * vocab];
                if cfg.temperature > 0.0 {
                    sampled_accept_walk(
                        &tree,
                        row,
                        cfg.temperature,
                        &mut rng,
                        &mut rec.alpha,
                        &mut scratch,
                    )
                } else {
                    greedy_accept_walk(&tree, row, &mut rec.alpha, &mut scratch)
                }
            };
            rec.timeline.host_ns += th.elapsed().as_nanos() as u64;

            // 4. record acceptance for the NEXT verify's fused commit
            let n_commit = scratch.path.len();
            pending_old_m = m;
            pending_idx.iter_mut().for_each(|x| *x = 0);
            for (j, &ni) in scratch.path.iter().enumerate() {
                pending_idx[j] = ni as i32;
            }
            pending_n = n_commit as i32;
            path_buf.clear();
            path_buf.extend_from_slice(&scratch.path);

            // 5. emit accepted tokens + bonus
            rec.round_accepts.push(n_commit);
            let mut hit_eos = false;
            for k in 0..n_commit {
                let t = if k + 1 < n_commit {
                    tree.nodes[path_buf[k + 1]].token
                } else {
                    bonus
                };
                committed.push(t);
                rec.tokens.push(t);
                if cfg.eos == Some(t) || rec.tokens.len() >= cfg.max_new {
                    hit_eos = true;
                    break;
                }
            }
            let m_new = m + n_commit;
            if hit_eos || m_new + 2 >= s_cap {
                let grew = (scratch.footprint()
                    + tree.capacity_bytes()
                    + src.footprint()
                    + path_buf.capacity() * std::mem::size_of::<usize>())
                .saturating_sub(fp0);
                rec.round_host_alloc_bytes.push(grew as u64);
                if grew == 0 {
                    rec.scratch_reuse_total += 1;
                }
                self.emit_round_event(&rec, tl0, 0, grew as u64);
                break;
            }

            // 6. fold the verified round back into the source
            let th = Instant::now();
            {
                let ctx = AdvanceCtx {
                    committed: &committed,
                    m_old: m,
                    m_new,
                    path: &path_buf,
                    tree: &tree,
                    verify_feats: &vout.feats,
                    verify_t: sel_t,
                };
                src.advance(&ctx, &mut scratch, &mut rec)?;
            }
            rec.timeline.host_ns += th.elapsed().as_nanos() as u64;
            m = m_new;
            let grew = (scratch.footprint()
                + tree.capacity_bytes()
                + src.footprint()
                + path_buf.capacity() * std::mem::size_of::<usize>())
            .saturating_sub(fp0);
            rec.round_host_alloc_bytes.push(grew as u64);
            if grew == 0 {
                rec.scratch_reuse_total += 1;
            }
            self.emit_round_event(&rec, tl0, rec.round_draft_w.last().copied().unwrap_or(0) as u32, grew as u64);
        }

        rec.wall_ns = t_all.elapsed().as_nanos() as u64;
        Ok(rec)
    }

    #[inline]
    fn emit_round_event(&self, rec: &GenRecord, tl0: (u64, u64, u64), draft_w: u32, alloc: u64) {
        if let Some(obs) = self.observer {
            obs.on_round(&RoundEvent {
                lane: 0,
                round: (rec.round_accepts.len().max(1) - 1) as u32,
                tree_nodes: rec.round_tree_nodes.last().copied().unwrap_or(0) as u32,
                verify_t: rec.round_verify_t.last().copied().unwrap_or(0) as u32,
                draft_w,
                accepted: rec.round_accepts.last().copied().unwrap_or(0) as u32,
                draft_ns: rec.timeline.draft_ns - tl0.0,
                verify_ns: rec.timeline.verify_ns - tl0.1,
                host_ns: rec.timeline.host_ns - tl0.2,
                alloc_bytes: alloc,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// deterministic acceptance simulation (synthetic serving + draftsrc eval)

/// Duplicate-3-gram ratio of a prompt in [0, 1): the synthetic stand-in
/// for workload repetitiveness. Allocation-free (1024-bit stack bitset);
/// a repeated-unit JSON prompt scores near 1.0, varied chat text well
/// under 0.5.
pub fn prompt_repetitiveness(prompt: &str) -> f64 {
    let b = prompt.as_bytes();
    if b.len() < 4 {
        return 0.0;
    }
    let mut seen = [0u64; 16];
    let mut dup = 0usize;
    let mut total = 0usize;
    for w in b.windows(3) {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &c in w {
            h = (h ^ c as u64).wrapping_mul(0x0100_0000_01b3);
        }
        let bit = (h % 1024) as usize;
        let (word, mask) = (bit / 64, 1u64 << (bit % 64));
        if seen[word] & mask != 0 {
            dup += 1;
        } else {
            seen[word] |= mask;
        }
        total += 1;
    }
    dup as f64 / total as f64
}

/// Simulated mean accepted tokens per round for a source on a workload
/// of the given repetitiveness (same curve for the synthetic server and
/// the `draftsrc` eval, so the policy's convergence is testable without
/// artifacts). Shape: n-gram retrieval is useless on varied text but
/// dominates once continuations repeat (crossover vs eagle near
/// r ≈ 0.45 after cost normalization); eagle leads on varied chat; chain
/// and Medusa trail eagle at every r (the paper's result).
pub fn sim_accepted_per_round(kind: SourceKind, repetitiveness: f64) -> f64 {
    let r = repetitiveness.clamp(0.0, 1.0);
    match kind {
        SourceKind::Ngram => 0.3 + (r - 0.35).max(0.0) * 14.0,
        SourceKind::Eagle => 3.0 + 0.8 * r,
        SourceKind::Chain => 2.0 + 0.5 * r,
        SourceKind::Medusa => 1.6 + 0.4 * r,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn source_kind_roundtrip() {
        for k in SourceKind::ALL {
            assert_eq!(SourceKind::parse(k.as_str()), Some(k));
            assert_eq!(SourceKind::from_idx(k.idx()), k);
        }
        assert_eq!(SourceKind::parse("bogus"), None);
        assert_eq!(DraftChoice::parse("auto"), Some(DraftChoice::Auto));
        assert_eq!(DraftChoice::parse("ngram"), Some(DraftChoice::Fixed(SourceKind::Ngram)));
        assert_eq!(DraftChoice::parse(""), None);
    }

    #[test]
    fn ngram_table_insert_get_overwrite() {
        let mut t = NgramTable::default();
        assert!(t.is_empty());
        t.insert(ngram_key(1, 2), 3);
        t.insert(ngram_key(2, 3), 4);
        assert_eq!(t.get(ngram_key(1, 2)), Some(3));
        assert_eq!(t.get(ngram_key(2, 3)), Some(4));
        assert_eq!(t.get(ngram_key(9, 9)), None);
        // most-recent-wins on re-insert
        t.insert(ngram_key(1, 2), 7);
        assert_eq!(t.get(ngram_key(1, 2)), Some(7));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn ngram_table_matches_hashmap_reference() {
        use std::collections::HashMap;
        let mut t = NgramTable::default();
        let mut h: HashMap<u64, u32> = HashMap::new();
        let mut x = 12345u64;
        for _ in 0..2000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let a = (x >> 33) as u32 % 97;
            let b = (x >> 17) as u32 % 97;
            let v = x as u32 % 1000;
            t.insert(ngram_key(a, b), v);
            h.insert(ngram_key(a, b), v);
        }
        // far below capacity and probe limits: the table is exact
        for (&k, &v) in &h {
            assert_eq!(t.get(k), Some(v), "key {k}");
        }
    }

    #[test]
    fn ngram_source_indexes_and_retrieves() {
        let mut src = NgramSource::new(5, 8, 64);
        let committed: Vec<u32> = vec![1, 2, 3, 1, 2, 3, 1, 2];
        src.index_from(&committed, 0);
        // (1,2)->3, (2,3)->1, (3,1)->2 (most recent)
        assert_eq!(src.table.get(ngram_key(1, 2)), Some(3));
        assert_eq!(src.table.get(ngram_key(2, 3)), Some(1));
        assert_eq!(src.table.get(ngram_key(3, 1)), Some(2));
    }

    #[test]
    fn repetitiveness_orders_workloads() {
        let json = "{\"id\":1,\"ok\":true},{\"id\":1,\"ok\":true},{\"id\":1,\"ok\":true},{\"id\":1,\"ok\":true}";
        let chat = "please summarize the key differences between mercurial and git for a newcomer";
        let rj = prompt_repetitiveness(json);
        let rc = prompt_repetitiveness(chat);
        assert!(rj > 0.6, "repetitive json scored {rj}");
        assert!(rc < 0.4, "varied chat scored {rc}");
    }

    #[test]
    fn sim_crossover_ngram_vs_eagle() {
        // cost-normalized policy score: accepted/round ÷ cost_hint
        let score = |k: SourceKind, r: f64| sim_accepted_per_round(k, r) / k.cost_hint();
        assert!(score(SourceKind::Eagle, 0.2) > score(SourceKind::Ngram, 0.2));
        assert!(score(SourceKind::Ngram, 0.9) > score(SourceKind::Eagle, 0.9));
        // chain and medusa never beat eagle (the paper's comparison)
        for r in [0.0, 0.3, 0.6, 0.9] {
            assert!(score(SourceKind::Eagle, r) > score(SourceKind::Chain, r));
            assert!(score(SourceKind::Eagle, r) > score(SourceKind::Medusa, r));
        }
    }

    #[test]
    fn one_hot_q_row() {
        let mut s = RoundScratch::new(4, 16);
        s.reserve_q(16, 8);
        s.qs.clear(16);
        let qid = push_one_hot_q(&mut s, 16, 5);
        let row = s.qs.get(qid as usize);
        assert_eq!(row.len(), 16);
        assert_eq!(row[5], 1.0);
        assert_eq!(row.iter().sum::<f32>(), 1.0);
    }
}

