//! EAGLE decode engine (S13): feature-level auto-regressive drafting with
//! shifted-token inputs, tree (or chain) drafting, SpecInfer-style
//! verification, KV commit, and feature recycling.
//!
//! Position/slot bookkeeping (see DESIGN.md §3): with committed boundary
//! `M` (root token at position M, its KV not yet in the target cache),
//! the draft head processes "pair slots": slot `i` holds
//! (feature φ_i, token τ_i) and its step output is (f̂_{i+1},
//! LM_head(f̂_{i+1}) = dist of t_{i+2}). The pairing per input variant:
//!
//!   eagle    τ_i = t_{i+1}  (shifted — the sampling outcome is visible)
//!   unshift  τ_i = t_i
//!   feat     (feature only)     tok (token only)
//!
//! All four run the same chain engine; the tree engine is used for the
//! `eagle` variant (the paper's method). Losslessness at T=0 is asserted
//! against vanilla greedy in `rust/tests/integration.rs`; at T>0 the
//! acceptance rules are distribution-preserving (prop tests).
//!
//! §Perf iteration 3 (zero-allocation round state): the round loop runs
//! on a [`RoundScratch`] reserved once per generation — flat feature
//! arena, logits slab, staging buffers, ancestor bitsets — so steady-
//! state rounds perform no per-node heap allocation
//! (`GenRecord::round_host_alloc_bytes` records the per-round scratch
//! growth; 0 once warm). This covers T>0 too: the sampled-q
//! distributions the SpecInfer rule retains live in the scratch's
//! q-slab (`RoundScratch::qs`; nodes hold row ids, siblings share a
//! row), and the acceptance walk runs on reused staging buffers via
//! [`sampled_accept_walk`] — the same walk the batched engine calls per
//! lane, so equal-seed bs=1 and batched runs are bit-identical.

use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Instant;

use super::dyntree::{
    expand_candidates_into, plan_round_width, rerank_into, select_frontier_into, width_hint,
    DynTreeParams, SpecController, TreePolicy, WidthFamily,
};
use super::sampling::{
    argmax, sample, softmax, softmax_into, top_k_into, tree_accept_rows, TreeVerdict,
};
use super::scratch::RoundScratch;
use super::tree::{chain_extend_bias_to, fill_step_rows_into, DraftTree, TreeSpec};
use crate::coordinator::batch_engine::{LaneInput, LaneOutcome};
use crate::coordinator::checkpoint::{
    copy_lane_kv_in, copy_lane_kv_out, LaneCheckpoint, PreemptSignal,
};
use crate::metrics::trace::{RoundEvent, RoundObserver};
use crate::metrics::GenRecord;
use crate::models::{EagleDraft, TargetModel};
use crate::util::deadline::DeadlineClock;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct GenConfig {
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
    pub eos: Option<u32>,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { max_new: 64, temperature: 0.0, seed: 7, eos: None }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PairShift {
    /// EAGLE: token advanced one step (resolves sampling uncertainty).
    Shifted,
    /// Ablations: same-position token (or single-input variants).
    Unshifted,
}

pub struct EagleEngine<'a> {
    pub target: &'a TargetModel,
    pub draft: &'a EagleDraft,
    /// How the draft tree is shaped each round (static widths or the
    /// dynamic confidence-driven planner).
    pub policy: TreePolicy,
    pub shift: PairShift,
    /// Max verify width (t) — the budget anchor; must match a lowered
    /// verify_t{t} executable.
    pub verify_t: usize,
    /// Lowered verify-width family; each round dispatches to the
    /// cheapest member that holds its tree (see `dyntree/widths.rs`).
    pub widths: WidthFamily,
    /// Lowered draft-step width family (`"draft_widths"`); each level
    /// runs at the narrowest `step_w{w}` holding its frontier chunk.
    pub draft_widths: WidthFamily,
    pub accept_a: usize,
    pub draft_w: usize,
    /// Optional per-round hook (flight recorder / serving metrics);
    /// called once per completed round and must not allocate — it runs
    /// inside the zero-alloc round loop.
    pub observer: Option<&'a dyn RoundObserver>,
    /// Request deadline, polled at the top of every round (a single
    /// monotonic-clock read — allocation-free). On expiry the engine
    /// stops drafting and returns the partial record with
    /// `rec.truncated = Some("deadline")`. Default: unbounded.
    pub deadline: DeadlineClock,
    /// Suspension requests (this engine is lane 0), polled at round
    /// boundaries by [`EagleEngine::generate_resumable`]. `None` (the
    /// default) disables preemption entirely.
    pub preempt: Option<Arc<PreemptSignal>>,
}

impl<'a> EagleEngine<'a> {
    pub fn new_tree(
        target: &'a TargetModel,
        draft: &'a EagleDraft,
        c: &crate::runtime::manifest::Constants,
    ) -> Self {
        let widths =
            WidthFamily::from_available(&c.verify_widths, c.tree_t, |t| target.has_verify(t, 1));
        let draft_widths =
            WidthFamily::filtered(&c.draft_widths, c.draft_w, 1, |w| draft.has_step(w, 1));
        EagleEngine {
            target,
            draft,
            policy: TreePolicy::default_tree(),
            shift: PairShift::Shifted,
            verify_t: c.tree_t,
            widths,
            draft_widths,
            accept_a: c.accept_a,
            draft_w: c.draft_w,
            observer: None,
            deadline: DeadlineClock::default(),
            preempt: None,
        }
    }

    pub fn new_chain(
        target: &'a TargetModel,
        draft: &'a EagleDraft,
        c: &crate::runtime::manifest::Constants,
        gamma: usize,
        shift: PairShift,
    ) -> Self {
        assert!(gamma + 1 <= c.chain_t);
        EagleEngine {
            target,
            draft,
            policy: TreePolicy::chain(gamma),
            shift,
            verify_t: c.chain_t,
            widths: WidthFamily::single(c.chain_t),
            draft_widths: WidthFamily::filtered(&c.draft_widths, c.draft_w, 1, |w| {
                draft.has_step(w, 1)
            }),
            accept_a: c.accept_a,
            draft_w: c.draft_w,
            observer: None,
            deadline: DeadlineClock::default(),
            preempt: None,
        }
    }

    /// Attach a preemption signal (builder-style): a request for lane 0
    /// suspends the run at its next round boundary and
    /// [`EagleEngine::generate_resumable`] returns the checkpoint.
    pub fn with_preempt(mut self, sig: Arc<PreemptSignal>) -> Self {
        self.preempt = Some(sig);
        self
    }

    /// Swap the tree policy (builder-style; used by the runner/server to
    /// select `TreePolicy::Dynamic` per request).
    pub fn with_policy(mut self, policy: TreePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Attach a request deadline (builder-style): generation stops at
    /// the first round boundary past expiry and returns partial output
    /// marked `truncated = Some("deadline")`.
    pub fn with_deadline(mut self, deadline: DeadlineClock) -> Self {
        self.deadline = deadline;
        self
    }

    /// Attach a per-round observer (builder-style; the server threads
    /// its flight recorder + metrics registry through here).
    pub fn with_observer(mut self, observer: &'a dyn RoundObserver) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Override the verify-width family (builder-style; used by the
    /// `--verify-width N` pin, which passes `WidthFamily::single(t)`).
    pub fn with_widths(mut self, widths: WidthFamily) -> Self {
        self.widths = widths;
        self
    }

    /// The largest draft tree any round of this engine can grow (the
    /// scratch reservation ceiling): the static tree's node total, or
    /// the dynamic planner's growth ceiling including the controller's
    /// adaptation bounds. `pub(crate)` so [`crate::spec::source::EagleSource`]
    /// can declare the same ceiling through the `DraftSource` trait.
    pub(crate) fn max_tree_nodes(&self) -> usize {
        match &self.policy {
            TreePolicy::Static(spec) => spec.total_nodes(),
            TreePolicy::Dynamic(dc) => {
                let base = dc.params(self.verify_t, self.draft_w, self.accept_a);
                let cc = dc.clamped_controller(self.draft_w, self.accept_a);
                let depth = base.depth.max(cc.max_depth);
                let fk = base.frontier_k.max(cc.max_frontier);
                depth * fk * base.branch + 1
            }
        }
    }

    /// Sample/argmax from target logits row.
    fn pick(&self, logits: &[f32], temperature: f32, rng: &mut Rng) -> u32 {
        if temperature <= 0.0 {
            argmax(logits) as u32
        } else {
            let p = softmax(logits, temperature);
            sample(&p, rng) as u32
        }
    }

    pub fn generate(&self, prompt: &[u32], cfg: &GenConfig) -> Result<GenRecord> {
        let input = LaneInput::Fresh { prompt, seed: cfg.seed };
        match self.generate_resumable(input, cfg)? {
            LaneOutcome::Done(rec) => Ok(rec),
            LaneOutcome::Suspended(_) => {
                unreachable!("record-only callers run without a preempt signal")
            }
        }
    }

    /// [`EagleEngine::generate`] with checkpoint support: the input is a
    /// fresh prompt or a suspended lane's [`LaneCheckpoint`], and the
    /// outcome is a finished record or a new checkpoint, captured when
    /// the attached [`PreemptSignal`] requested lane 0 at a round
    /// boundary. Resume is bit-identical to the uninterrupted run; an
    /// evicted checkpoint first rebuilds its KV by re-prefilling the
    /// committed prefix (which must fit the prefill window). Semantics
    /// mirror the batched engine's `generate_pooled_entries`.
    pub fn generate_resumable(&self, input: LaneInput<'_>, cfg: &GenConfig) -> Result<LaneOutcome> {
        let t_all = Instant::now();
        let tgt = self.target;
        let d = tgt.d;
        let vocab = tgt.vocab;
        let s_tot = tgt.max_len;
        let p_win = tgt.prefill_p;

        let mut cache = tgt.new_cache(1);
        let mut dcache = self.draft.new_cache(1);
        // lane state, assigned by the input arm below: fresh prefill, or
        // checkpoint restore (resident KV splice vs evicted re-prefill)
        let mut rec: GenRecord;
        let mut rng: Rng;
        let lane_seed: u64;
        let mut committed: Vec<u32>;
        let mut m: usize;
        let mut root_feat: Vec<f32>;
        let mut root_logits: Vec<f32>;
        // parked checkpoint box, reused on re-suspension (warm capture
        // allocates nothing — the buffers are already sized)
        let mut ckpt_box: Option<Box<LaneCheckpoint>> = None;
        // pending acceptance from the previous round, committed inside
        // the NEXT verify call (fused commit — §Perf iteration 1)
        let mut pending_old_m: usize;
        let mut pending_idx = vec![0i32; self.accept_a];
        let mut pending_n = 0i32;
        match input {
            LaneInput::Fresh { prompt, seed } => {
                rec = GenRecord::new(prompt.len());
                // pre-size the record's per-round vectors so steady-state
                // rounds never touch the allocator through metrics either
                rec.reserve_rounds(cfg.max_new);
                rng = Rng::new(seed);
                lane_seed = seed;

                // ---- target prefill ----------------------------------------
                let t0 = Instant::now();
                let (out, plen) = tgt.prefill(prompt, &mut cache)?;
                rec.timeline.prefill_ns += t0.elapsed().as_nanos() as u64;
                rec.target_passes += 1;
                let last_logits = tgt.row(&out.logits, p_win, 0, plen - 1, vocab);
                let root_tok = self.pick(last_logits, cfg.temperature, &mut rng);
                rec.tokens.push(root_tok);
                // first committed token: the engine-side TTFT component
                rec.ttft_ns = t_all.elapsed().as_nanos() as u64;
                committed = Vec::with_capacity(prompt.len() + cfg.max_new + 2);
                committed.extend_from_slice(prompt);
                committed.push(root_tok);
                m = plen; // committed boundary: root at position m

                // ---- draft prefill (pair slots 0..m-1) ---------------------
                let mut dtoks = vec![0i32; p_win];
                for (i, slot) in dtoks.iter_mut().enumerate().take(m) {
                    *slot = match self.shift {
                        PairShift::Shifted => committed[i + 1] as i32,
                        PairShift::Unshifted => committed[i] as i32,
                    };
                }
                // features f_0..f_{m-1} from the target prefill
                let mut dfeats = vec![0f32; p_win * d];
                dfeats[..m * d].copy_from_slice(&out.feats[..m * d]);
                let t0 = Instant::now();
                let dout = self.draft.prefill(&dfeats, &dtoks, m, &mut dcache)?;
                rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
                rec.draft_passes += 1;
                root_feat = dout.feats; // f̂ at root position m
                root_logits = dout.logits; // dist of t_{m+1}
                pending_old_m = m;

                if cfg.eos == Some(root_tok) {
                    rec.wall_ns = t_all.elapsed().as_nanos() as u64;
                    return Ok(LaneOutcome::Done(rec));
                }
            }
            LaneInput::Resume { mut ckpt } => {
                // the RNG stream continues at its exact draw position, so
                // sampled acceptance replays bit-identically
                rng = Rng::resume(ckpt.rng_seed, ckpt.rng_draws);
                lane_seed = ckpt.rng_seed;
                committed = std::mem::take(&mut ckpt.committed);
                m = ckpt.m;
                root_feat = std::mem::take(&mut ckpt.root_feat);
                root_logits = std::mem::take(&mut ckpt.root_logits);
                rec = std::mem::replace(&mut ckpt.rec, GenRecord::new(0));
                rec.reserve_rounds(cfg.max_new);
                if crate::failpoint!("resume") {
                    // degenerate resume: force the slow re-prefill path
                    ckpt.evict_kv();
                }
                if ckpt.kv_resident {
                    copy_lane_kv_in(&mut cache, 0, &ckpt.kv_target);
                    copy_lane_kv_in(&mut dcache, 0, &ckpt.kv_draft);
                    pending_old_m = ckpt.pending_old as usize;
                    pending_idx.copy_from_slice(&ckpt.pending_idx);
                    pending_n = ckpt.pending_n;
                } else {
                    // evicted KV: rebuild by prefix re-prefill; the pending
                    // triple resets to the fresh-prefill initial condition
                    // (the suspended round's acceptance is already folded
                    // into `committed`, so outputs are unchanged)
                    let t0 = Instant::now();
                    let (out, plen) = tgt.prefill(&committed[..m], &mut cache)?;
                    rec.timeline.prefill_ns += t0.elapsed().as_nanos() as u64;
                    rec.target_passes += 1;
                    debug_assert_eq!(plen, m);
                    let mut dtoks = vec![0i32; p_win];
                    for (i, slot) in dtoks.iter_mut().enumerate().take(m) {
                        *slot = match self.shift {
                            PairShift::Shifted => committed[i + 1] as i32,
                            PairShift::Unshifted => committed[i] as i32,
                        };
                    }
                    let mut dfeats = vec![0f32; p_win * d];
                    dfeats[..m * d].copy_from_slice(&out.feats[..m * d]);
                    let t0 = Instant::now();
                    self.draft.prefill(&dfeats, &dtoks, m, &mut dcache)?;
                    rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
                    rec.draft_passes += 1;
                    pending_old_m = m;
                    ckpt.refill_rounds += 1;
                    rec.resume_refill_rounds += 1;
                }
                ckpt_box = Some(ckpt);
            }
        }
        let mut draft_len = m;

        // dynamic policy: resolved shape limits + optional per-request
        // controller (EWMA acceptance tracker adapting depth/frontier)
        let base_params: Option<DynTreeParams> = match &self.policy {
            TreePolicy::Dynamic(dc) => Some(dc.params(self.verify_t, self.draft_w, self.accept_a)),
            TreePolicy::Static(_) => None,
        };
        let mut controller: Option<SpecController> = match &self.policy {
            TreePolicy::Dynamic(dc) if dc.adaptive => Some(SpecController::new(
                dc.clamped_controller(self.draft_w, self.accept_a),
                base_params.expect("dynamic policy resolves params"),
            )),
            _ => None,
        };
        // a resumed lane continues from its captured adaptation state
        // (EWMA + width hysteresis), not a cold restart
        if let (Some(c), Some(snap)) =
            (controller.as_mut(), ckpt_box.as_ref().and_then(|k| k.controller.as_ref()))
        {
            c.restore(snap);
        }

        // ---- round state (S22): reserved once, reused every round ----------
        let t_reserve = self.verify_t.max(self.widths.max());
        let w_reserve = self.draft_w.max(self.draft_widths.max());
        let max_nodes = self.max_tree_nodes();
        let mut scratch = RoundScratch::new(d, vocab);
        scratch.reserve(d, vocab, s_tot, max_nodes, t_reserve, w_reserve);
        if cfg.temperature > 0.0 {
            scratch.reserve_q(vocab, max_nodes);
        }
        let mut tree = DraftTree::default();
        tree.nodes.reserve(max_nodes);

        // ---- decode rounds --------------------------------------------------
        while rec.tokens.len() < cfg.max_new {
            if self.deadline.expired() {
                // cancellation: stop drafting, hand back what we have
                rec.truncated = Some("deadline");
                break;
            }
            // round-boundary preemption (this engine is lane 0): capture
            // into the parked checkpoint and hand it back instead of a
            // finished record; a degenerate `checkpoint` failpoint drops
            // the request and the lane runs on
            if let Some(sig) = self.preempt.as_deref() {
                if sig.take(0) && !crate::failpoint!("checkpoint") {
                    let mut ck = ckpt_box.take().unwrap_or_default();
                    ck.capture_tokens(&committed, m);
                    ck.capture_root(&root_feat, &root_logits);
                    ck.capture_pending(pending_old_m as i32, &pending_idx, pending_n);
                    ck.rng_seed = lane_seed;
                    ck.rng_draws = rng.draws();
                    match controller.as_ref() {
                        Some(c) => {
                            let snap = ck.controller.get_or_insert_with(Default::default);
                            c.snapshot_into(snap);
                            let hint = width_hint(Some(c));
                            ck.width_hint =
                                Some(plan_round_width(&self.widths, &c.params(), hint).0);
                        }
                        None => {
                            ck.controller = None;
                            ck.width_hint = None;
                        }
                    }
                    ck.deadline = self.deadline;
                    // full-S lane copy: the fused-commit scratch rows must
                    // survive so a resident resume replays the pending
                    // acceptance exactly
                    copy_lane_kv_out(&cache, 0, &mut ck.kv_target);
                    copy_lane_kv_out(&dcache, 0, &mut ck.kv_draft);
                    ck.kv_resident = true;
                    ck.kv_slot = None;
                    ck.rec = rec;
                    return Ok(LaneOutcome::Suspended(ck));
                }
            }
            if m + t_reserve + 1 >= s_tot {
                break; // cache budget exhausted
            }
            let fp0 = scratch.footprint() + tree.capacity_bytes();
            #[cfg(feature = "count-alloc")]
            let counted0 = crate::util::count_alloc::thread_allocated_bytes();
            let tl0 = (rec.timeline.draft_ns, rec.timeline.verify_ns, rec.timeline.host_ns);
            // 1. build the draft tree
            let th = Instant::now();
            tree.reset(committed[m]);
            scratch.begin_round(&root_feat, &root_logits);
            rec.timeline.host_ns += th.elapsed().as_nanos() as u64;
            match &self.policy {
                TreePolicy::Static(spec) => {
                    self.grow_tree(
                        &mut tree, spec, m, draft_len, &mut dcache, cfg, &mut rng, &mut rec,
                        &mut scratch,
                    )?;
                }
                TreePolicy::Dynamic(_) => {
                    let params = controller
                        .as_ref()
                        .map(|c| c.params())
                        .or(base_params)
                        .expect("dynamic policy resolves params");
                    // width plan BEFORE growth: the controller's EWMA may
                    // cap the node budget to a cheaper executable; a
                    // value-independent cap, so T>0 sampling stays exact
                    let (_plan_t, params) =
                        plan_round_width(&self.widths, &params, width_hint(controller.as_ref()));
                    self.grow_tree_dynamic(
                        &mut tree, &params, m, draft_len, &mut dcache, cfg, &mut rng, &mut rec,
                        &mut scratch,
                    )?;
                    let th = Instant::now();
                    if tree.len() - 1 > params.budget {
                        let s = &mut scratch;
                        rerank_into(&tree, params.budget, &mut s.spare_tree, &mut s.rr);
                        std::mem::swap(&mut tree, &mut s.spare_tree);
                    }
                    rec.drafted += tree.len() - 1;
                    rec.timeline.host_ns += th.elapsed().as_nanos() as u64;
                }
            }
            rec.round_tree_nodes.push(tree.len() - 1);

            // 2. verify at the cheapest lowered width that holds the tree
            //    (padding-only shrink: every grown node is still verified)
            let sel_t = self.widths.fit(tree.len());
            if sel_t < tree.len() {
                bail!(
                    "draft tree of {} nodes exceeds the verify width family (max {})",
                    tree.len(),
                    self.widths.max()
                );
            }
            rec.round_verify_t.push(sel_t);
            let th = Instant::now();
            scratch.vtokens.clear();
            scratch.vtokens.resize(sel_t, 0);
            scratch.vpos.clear();
            scratch.vpos.resize(sel_t, 0);
            scratch.vbias.clear();
            scratch.vbias.resize(sel_t * s_tot, 0.0);
            tree.verify_inputs_to(
                sel_t,
                m,
                s_tot,
                &mut scratch.vtokens,
                &mut scratch.vpos,
                &mut scratch.vbias,
                &mut scratch.anc,
            );
            rec.timeline.host_ns += th.elapsed().as_nanos() as u64;
            let t0 = Instant::now();
            let fp_degenerate_verify = crate::failpoint!("verify");
            let mut vout = tgt.verify(
                sel_t,
                &mut cache,
                &[pending_old_m as i32],
                &pending_idx,
                &[pending_n],
                &scratch.vtokens,
                &scratch.vpos,
                &scratch.vbias,
                self.accept_a,
            )?;
            if fp_degenerate_verify {
                vout.logits.iter_mut().for_each(|x| *x = f32::NAN);
            }
            rec.timeline.verify_ns += t0.elapsed().as_nanos() as u64;
            rec.target_passes += 1;

            // 3. acceptance walk (snapshot alpha so the controller can
            //    consume this round's per-depth increments — delta
            //    buffers reused, no per-round clone)
            let th = Instant::now();
            scratch.alpha_before.clear();
            scratch.alpha_before.extend_from_slice(&rec.alpha);
            let bonus = self.accept(&tree, &vout.logits, cfg, &mut rng, &mut rec, &mut scratch);
            if let Some(c) = controller.as_mut() {
                scratch.alpha_delta.clear();
                scratch.alpha_delta.extend(
                    rec.alpha
                        .iter()
                        .zip(&scratch.alpha_before)
                        .map(|(&(h, t), &(h0, t0))| (h - h0, t - t0)),
                );
                // the metrics layer buckets alpha only up to delta.len()
                // depths; deeper positions (dynamic trees can exceed them)
                // are synthesized from the accepted path so the controller
                // is never blind to deep levels that never commit
                let attempted = tree.nodes.iter().map(|n| n.depth).max().unwrap_or(0);
                let accepted = scratch.path.len() - 1;
                for dpt in scratch.alpha_delta.len()..attempted {
                    scratch.alpha_delta.push((u64::from(dpt < accepted), 1));
                }
                c.observe(&scratch.alpha_delta);
            }
            rec.timeline.host_ns += th.elapsed().as_nanos() as u64;

            // 4. record acceptance; the compaction happens inside the NEXT
            //    verify call (fused commit)
            let n_commit = scratch.path.len();
            pending_old_m = m;
            pending_idx.iter_mut().for_each(|x| *x = 0);
            for (j, &ni) in scratch.path.iter().enumerate() {
                pending_idx[j] = ni as i32;
            }
            pending_n = n_commit as i32;

            // 5. bookkeeping: emit accepted tokens + bonus
            rec.round_accepts.push(n_commit);
            let mut hit_eos = false;
            for k in 0..n_commit {
                let t = if k + 1 < n_commit {
                    tree.nodes[scratch.path[k + 1]].token
                } else {
                    bonus
                };
                committed.push(t);
                rec.tokens.push(t);
                if cfg.eos == Some(t) || rec.tokens.len() >= cfg.max_new {
                    hit_eos = true;
                    break;
                }
            }
            let m_new = m + n_commit;
            if hit_eos || m_new + 2 >= s_tot {
                let grew = (scratch.footprint() + tree.capacity_bytes()).saturating_sub(fp0);
                rec.round_host_alloc_bytes.push(grew as u64);
                if grew == 0 {
                    rec.scratch_reuse_total += 1;
                }
                // observer runs BEFORE the counted-alloc delta is taken so
                // the zero-alloc assertion covers it too (no extend ran:
                // draft_w = 0)
                self.emit_round_event(&rec, tl0, 0, grew as u64);
                #[cfg(feature = "count-alloc")]
                rec.round_alloc_counted_bytes
                    .push(crate::util::count_alloc::thread_allocated_bytes() - counted0);
                break;
            }

            // 6. draft chain-extend over the newly committed pair slots
            //    [m, m_new-1] with TRUE features from the verify pass.
            let n_pending = m_new - m; // == n_commit
            if n_pending > self.draft_w {
                bail!("pending pairs {n_pending} exceed draft width {}", self.draft_w);
            }
            // the extend replays n_pending pair slots: run it on the
            // narrowest lowered step width that holds them
            let w = self.draft_widths.fit(n_pending);
            rec.round_draft_w.push(w);
            scratch.sf.clear();
            scratch.sf.resize(w * d, 0.0);
            scratch.st.clear();
            scratch.st.resize(w, 0);
            scratch.sp.clear();
            scratch.sp.resize(w, 0);
            for (r, &ni) in scratch.path.iter().enumerate() {
                // slot m + r holds (f_{m+r}, τ); feature = target feature at
                // tree node `ni` (exact — computed during verification)
                let f = tgt.row(&vout.feats, sel_t, 0, ni, d);
                scratch.sf[r * d..(r + 1) * d].copy_from_slice(f);
                let slot_pos = m + r;
                scratch.st[r] = match self.shift {
                    PairShift::Shifted => committed[slot_pos + 1] as i32,
                    PairShift::Unshifted => committed[slot_pos] as i32,
                };
                scratch.sp[r] = slot_pos as i32;
            }
            for r in n_pending..w {
                scratch.sp[r] = (m + r) as i32; // padded rows (ignored)
            }
            scratch.sbias.clear();
            scratch.sbias.resize(w * s_tot, 0.0);
            chain_extend_bias_to(w, s_tot, m, n_pending, &mut scratch.sbias);
            let t0 = Instant::now();
            let fp_degenerate_draft = crate::failpoint!("draft-step");
            let mut eout = self.draft.step(
                w,
                &mut dcache,
                &[m as i32],
                &scratch.sf,
                &scratch.st,
                &scratch.sp,
                &scratch.sbias,
            )?;
            if fp_degenerate_draft {
                eout.logits.iter_mut().for_each(|x| *x = f32::NAN);
            }
            rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
            rec.draft_passes += 1;
            let last = n_pending - 1;
            root_feat.clear();
            root_feat.extend_from_slice(&eout.feats[last * d..(last + 1) * d]);
            root_logits.clear();
            root_logits.extend_from_slice(&eout.logits[last * vocab..(last + 1) * vocab]);
            m = m_new;
            draft_len = m;
            let grew = (scratch.footprint() + tree.capacity_bytes()).saturating_sub(fp0);
            rec.round_host_alloc_bytes.push(grew as u64);
            if grew == 0 {
                rec.scratch_reuse_total += 1;
            }
            // observer runs BEFORE the counted-alloc delta is taken so the
            // zero-alloc assertion covers it too
            self.emit_round_event(&rec, tl0, w as u32, grew as u64);
            #[cfg(feature = "count-alloc")]
            rec.round_alloc_counted_bytes
                .push(crate::util::count_alloc::thread_allocated_bytes() - counted0);
        }

        rec.wall_ns = t_all.elapsed().as_nanos() as u64;
        Ok(LaneOutcome::Done(rec))
    }

    /// Report the just-finished round to the attached observer (no-op
    /// without one). Reads the round's stats back off the record tails
    /// and the timeline deltas since `tl0` = (draft, verify, host) ns at
    /// round start. Stack-only: safe inside the zero-alloc round loop.
    #[inline]
    fn emit_round_event(&self, rec: &GenRecord, tl0: (u64, u64, u64), draft_w: u32, alloc: u64) {
        if let Some(obs) = self.observer {
            obs.on_round(&RoundEvent {
                lane: 0,
                round: (rec.round_accepts.len().max(1) - 1) as u32,
                tree_nodes: rec.round_tree_nodes.last().copied().unwrap_or(0) as u32,
                verify_t: rec.round_verify_t.last().copied().unwrap_or(0) as u32,
                draft_w,
                accepted: rec.round_accepts.last().copied().unwrap_or(0) as u32,
                draft_ns: rec.timeline.draft_ns - tl0.0,
                verify_ns: rec.timeline.verify_ns - tl0.1,
                host_ns: rec.timeline.host_ns - tl0.2,
                alloc_bytes: alloc,
            });
        }
    }

    /// Expand the draft tree level by level with STATIC per-level widths.
    /// The root's extend outputs (f̂ at the root position, dist of
    /// t_{m+1}) are pre-seeded as node 0 of the scratch arena/slab by
    /// [`RoundScratch::begin_round`]. `pub(crate)`: the trait-dispatch
    /// eagle source (`spec::source::EagleSource`) delegates its growth
    /// here, so the fused and generic paths can never drift.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn grow_tree(
        &self,
        tree: &mut DraftTree,
        spec: &TreeSpec,
        m: usize,
        draft_len: usize,
        dcache: &mut crate::models::target::KvCache,
        cfg: &GenConfig,
        rng: &mut Rng,
        rec: &mut GenRecord,
        s: &mut RoundScratch,
    ) -> Result<()> {
        let d = self.target.d;
        let vocab = self.target.vocab;
        let s_tot = self.target.max_len;
        let w_cap = self.draft_w;
        let mut scratch_used = 0usize;

        s.frontier.clear();
        s.frontier.push(0); // node indices to expand from
        for (li, &width) in spec.level_widths.iter().enumerate() {
            // --- select candidates for this level --------------------------
            let th = Instant::now();
            s.cands.clear();
            if cfg.temperature <= 0.0 {
                for &p in &s.frontier {
                    let q = s.logits.get(p).expect("frontier node has logits");
                    softmax_into(q, 1.0, &mut s.probs);
                    top_k_into(&s.probs, spec.branch, &mut s.idx);
                    for &ti in &s.idx {
                        let score = self.target_score(&tree.nodes[p], s.probs[ti]);
                        s.cands.push((p, ti as u32, score, None));
                    }
                }
                // allocation-free unstable sort; (parent, token) tiebreak
                // makes the order total, so exact-score ties stay
                // deterministic across std versions; `total_cmp` keeps
                // it total even for NaN scores from a bad artifact (no
                // mid-round comparator panic in the server worker)
                s.cands.sort_unstable_by(|a, b| {
                    b.2.total_cmp(&a.2).then(a.0.cmp(&b.0)).then(a.1.cmp(&b.1))
                });
                s.cands.truncate(width);
            } else {
                // T>0: sample children i.i.d. from q (SpecInfer rule); the
                // tree shape is fixed by distributing `width` over frontier.
                // q lands in the round's slab — one row per frontier node,
                // shared by its sampled children (no Rc allocation).
                let per = (width / s.frontier.len().max(1)).max(1);
                for &p in &s.frontier {
                    let logits = s.logits.get(p).expect("frontier node has logits");
                    softmax_into(logits, cfg.temperature, &mut s.probs);
                    let qid = s.qs.push(&s.probs) as u32;
                    for _ in 0..per {
                        if s.cands.len() >= width {
                            break;
                        }
                        let tok = sample(s.qs.get(qid as usize), rng) as u32;
                        s.cands.push((p, tok, 0.0, Some(qid)));
                    }
                }
            }
            rec.timeline.host_ns += th.elapsed().as_nanos() as u64;
            if s.cands.is_empty() {
                break;
            }
            // --- create nodes ----------------------------------------------
            s.new_nodes.clear();
            rec.drafted += s.cands.len();
            for (p, tok, score, q) in s.cands.drain(..) {
                let ni = tree.add(p, tok, score, q);
                s.feat.push_empty();
                s.logits.push_empty();
                s.node_slot.push(None);
                s.new_nodes.push(ni);
            }

            // last level: leaves need no draft step
            if li + 1 == spec.level_widths.len() {
                break;
            }

            // --- draft-step the new nodes, padded to the smallest lowered
            //     width that fits the chunk (§Perf iteration 2) --------------
            for chunk in s.new_nodes.chunks(w_cap) {
                let w = self.draft_widths.fit(chunk.len());
                let th = Instant::now();
                let write_base = draft_len + scratch_used;
                if write_base + w >= s_tot {
                    return Ok(()); // scratch exhausted; verify what we have
                }
                s.sf.clear();
                s.sf.resize(w * d, 0.0);
                s.st.clear();
                s.st.resize(w, 0);
                s.sp.clear();
                s.sp.resize(w, 0);
                s.sbias.clear();
                s.sbias.resize(w * s_tot, 0.0);
                fill_step_rows_into(
                    tree,
                    chunk,
                    &s.feat,
                    &mut s.node_slot,
                    self.shift == PairShift::Shifted,
                    d,
                    s_tot,
                    m,
                    draft_len,
                    write_base,
                    w,
                    &mut s.sf,
                    &mut s.st,
                    &mut s.sp,
                    &mut s.sbias,
                );
                rec.timeline.host_ns += th.elapsed().as_nanos() as u64;
                let t0 = Instant::now();
                let sout = self.draft.step(
                    w,
                    dcache,
                    &[write_base as i32],
                    &s.sf,
                    &s.st,
                    &s.sp,
                    &s.sbias,
                )?;
                rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
                rec.draft_passes += 1;
                rec.round_draft_w.push(w);
                scratch_used += w;
                for (r, &ni) in chunk.iter().enumerate() {
                    s.feat.set(ni, &sout.feats[r * d..(r + 1) * d]);
                    s.logits.set(ni, &sout.logits[r * vocab..(r + 1) * vocab]);
                }
            }
            std::mem::swap(&mut s.frontier, &mut s.new_nodes);
        }
        Ok(())
    }

    /// Expand the draft tree with the DYNAMIC planner: at each level the
    /// top-`frontier_k` nodes by cumulative draft log-prob are expanded
    /// into `branch` scored candidates each; only the most confident
    /// `frontier_k` of the new candidates are draft-stepped (those may
    /// expand further). The caller reranks the finished candidate tree
    /// down to the verify budget; drafted-token accounting happens there.
    /// `pub(crate)` for the same reason as [`EagleEngine::grow_tree`].
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn grow_tree_dynamic(
        &self,
        tree: &mut DraftTree,
        params: &DynTreeParams,
        m: usize,
        draft_len: usize,
        dcache: &mut crate::models::target::KvCache,
        cfg: &GenConfig,
        rng: &mut Rng,
        rec: &mut GenRecord,
        s: &mut RoundScratch,
    ) -> Result<()> {
        let d = self.target.d;
        let vocab = self.target.vocab;
        let s_tot = self.target.max_len;
        let w_cap = self.draft_w;
        let mut scratch_used = 0usize;

        // Losslessness at T>0: the SpecInfer acceptance rule is exact only
        // if every candidate sampled from q is actually presented for
        // verification — dropping sampled siblings by score would bias the
        // output toward high-q tokens. So at T>0 growth is capped at the
        // verify budget up front (a value-independent count cap) and the
        // caller's rerank becomes an identity; over-generate-then-rerank
        // remains a greedy-only (T=0) optimization.
        let cap = if cfg.temperature > 0.0 { params.budget } else { usize::MAX };

        // nodes whose draft step has run (children logits available)
        s.expandable.clear();
        s.expandable.push(0);
        for lvl in 0..params.depth {
            // --- choose the frontier and score its children ----------------
            let th = Instant::now();
            select_frontier_into(tree, &s.expandable, params.frontier_k, &mut s.frontier);
            s.cands.clear();
            if cfg.temperature <= 0.0 {
                for &p in &s.frontier {
                    let q = s.logits.get(p).expect("frontier node has logits");
                    softmax_into(q, 1.0, &mut s.probs);
                    expand_candidates_into(
                        tree.nodes[p].score,
                        &s.probs,
                        params.branch,
                        &mut s.idx,
                        &mut s.pairs,
                    );
                    for &(tok, score) in &s.pairs {
                        s.cands.push((p, tok, score, None));
                    }
                }
            } else {
                // T>0: children sampled i.i.d. from q (SpecInfer rule); the
                // cumulative ln q(tok) stands in as the confidence score.
                // q lands in the round's slab (row shared by siblings).
                for &p in &s.frontier {
                    let logits = s.logits.get(p).expect("frontier node has logits");
                    softmax_into(logits, cfg.temperature, &mut s.probs);
                    let qid = s.qs.push(&s.probs) as u32;
                    for _ in 0..params.branch {
                        let q = s.qs.get(qid as usize);
                        let tok = sample(q, rng);
                        let score = tree.nodes[p].score + q[tok].max(1e-20).ln();
                        s.cands.push((p, tok as u32, score, Some(qid)));
                    }
                }
            }
            // budget cap (T>0): truncation by generation order, decided
            // before looking at the dropped candidates' values
            let room = cap.saturating_sub(tree.len() - 1);
            s.cands.truncate(room);
            rec.timeline.host_ns += th.elapsed().as_nanos() as u64;
            if s.cands.is_empty() {
                break;
            }
            s.new_nodes.clear();
            for (p, tok, score, q) in s.cands.drain(..) {
                let ni = tree.add(p, tok, score, q);
                s.feat.push_empty();
                s.logits.push_empty();
                s.node_slot.push(None);
                s.new_nodes.push(ni);
            }
            if lvl + 1 == params.depth {
                break; // leaves need no draft step
            }

            // --- draft-step only the most confident new nodes --------------
            select_frontier_into(tree, &s.new_nodes, params.frontier_k, &mut s.expandable);
            for chunk in s.expandable.chunks(w_cap) {
                let w = self.draft_widths.fit(chunk.len());
                let th = Instant::now();
                let write_base = draft_len + scratch_used;
                if write_base + w >= s_tot {
                    return Ok(()); // scratch exhausted; rerank what we have
                }
                s.sf.clear();
                s.sf.resize(w * d, 0.0);
                s.st.clear();
                s.st.resize(w, 0);
                s.sp.clear();
                s.sp.resize(w, 0);
                s.sbias.clear();
                s.sbias.resize(w * s_tot, 0.0);
                fill_step_rows_into(
                    tree,
                    chunk,
                    &s.feat,
                    &mut s.node_slot,
                    self.shift == PairShift::Shifted,
                    d,
                    s_tot,
                    m,
                    draft_len,
                    write_base,
                    w,
                    &mut s.sf,
                    &mut s.st,
                    &mut s.sp,
                    &mut s.sbias,
                );
                rec.timeline.host_ns += th.elapsed().as_nanos() as u64;
                let t0 = Instant::now();
                let sout = self.draft.step(
                    w,
                    dcache,
                    &[write_base as i32],
                    &s.sf,
                    &s.st,
                    &s.sp,
                    &s.sbias,
                )?;
                rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
                rec.draft_passes += 1;
                rec.round_draft_w.push(w);
                scratch_used += w;
                for (r, &ni) in chunk.iter().enumerate() {
                    s.feat.set(ni, &sout.feats[r * d..(r + 1) * d]);
                    s.logits.set(ni, &sout.logits[r * vocab..(r + 1) * vocab]);
                }
            }
        }
        Ok(())
    }

    fn target_score(&self, parent: &super::tree::TreeNode, prob: f32) -> f32 {
        parent.score + prob.max(1e-20).ln()
    }

    /// Acceptance walk over verified logits. Fills `s.path` with the
    /// accepted node indices (incl. root) and returns the bonus token.
    /// All walk state (path, child lists, softmax row, T>0 staging)
    /// comes from the round scratch. Chain-position stats feed n-α.
    fn accept(
        &self,
        tree: &DraftTree,
        vlogits: &[f32],
        cfg: &GenConfig,
        rng: &mut Rng,
        rec: &mut GenRecord,
        s: &mut RoundScratch,
    ) -> u32 {
        let vocab = self.target.vocab;
        let row = |i: usize| &vlogits[i * vocab..(i + 1) * vocab];
        if cfg.temperature > 0.0 {
            return sampled_accept_walk(tree, row, cfg.temperature, rng, &mut rec.alpha, s);
        }
        s.path.clear();
        s.path.push(0);
        let mut cur = 0usize;
        loop {
            let depth = tree.nodes[cur].depth; // n-α bucket = depth of child - 1
            tree.children_into(cur, &mut s.children);
            let want = argmax(row(cur));
            let next = s.children.iter().copied().find(|&c| tree.nodes[c].token as usize == want);
            let nbuckets = rec.alpha.len();
            if depth < nbuckets && !s.children.is_empty() {
                let b = depth.min(nbuckets - 1);
                rec.alpha[b].1 += 1;
                if next.is_some() {
                    rec.alpha[b].0 += 1;
                }
            }
            match next {
                Some(c) => {
                    s.path.push(c);
                    cur = c;
                }
                None => return want as u32,
            }
        }
    }
}

/// SpecInfer acceptance walk at T>0, shared by the bs=1 and the batched
/// engine (per lane, with the lane's own RNG stream and scratch) — one
/// code path, so a request's sampled output is bit-identical whether it
/// runs alone or inside a batch. At each accepted node the children are
/// tried under the recursive-rejection rule ([`tree_accept_rows`]) with
/// their sampled-from q rows fetched from the scratch's q-slab; the walk
/// returns the bonus/residual token emitted after the accepted path
/// (`s.path`, root included). `alpha` collects per-depth (hit, tried)
/// chain stats. Allocation-free on warm scratch: child tokens / q ids /
/// the working residual live in `s.walk_toks` / `s.walk_qids` /
/// `s.presidual`.
pub fn sampled_accept_walk<'a>(
    tree: &DraftTree,
    row_of: impl Fn(usize) -> &'a [f32],
    temperature: f32,
    rng: &mut Rng,
    alpha: &mut [(u64, u64)],
    s: &mut RoundScratch,
) -> u32 {
    let _ = crate::failpoint!("accept-walk");
    s.path.clear();
    s.path.push(0);
    let mut cur = 0usize;
    loop {
        let depth = tree.nodes[cur].depth; // n-α bucket = depth of child - 1
        tree.children_into(cur, &mut s.children);
        softmax_into(row_of(cur), temperature, &mut s.probs);
        if s.children.is_empty() {
            return sample(&s.probs, rng) as u32;
        }
        s.walk_toks.clear();
        s.walk_qids.clear();
        for &c in &s.children {
            s.walk_toks.push(tree.nodes[c].token as usize);
            s.walk_qids.push(tree.nodes[c].q.expect("sampled node missing q"));
        }
        let nbuckets = alpha.len();
        if depth < nbuckets {
            alpha[depth.min(nbuckets - 1)].1 += 1;
        }
        let verdict = tree_accept_rows(
            &s.probs,
            s.children.len(),
            |ci| s.qs.get(s.walk_qids[ci] as usize),
            &s.walk_toks,
            &mut s.presidual,
            rng,
        );
        match verdict {
            TreeVerdict::AcceptChild(ci) => {
                if depth < nbuckets {
                    alpha[depth.min(nbuckets - 1)].0 += 1;
                }
                let c = s.children[ci];
                s.path.push(c);
                cur = c;
            }
            TreeVerdict::Residual(t) => return t as u32,
        }
    }
}
