//! Confidence-driven tree planning: frontier selection, candidate
//! scoring, and the global rerank that turns an over-grown candidate tree
//! into the node set actually sent to verification.
//!
//! The planner exploits the paper's observation that draft confidence
//! tracks acceptance probability (the EAGLE-2 direction): instead of
//! fixed per-level widths, each draft step expands the top-K frontier
//! nodes by *cumulative* draft log-prob, and a final global rerank keeps
//! the best `budget` nodes across all depths — ancestor-closed, so the
//! result is always a valid [`DraftTree`] for `verify_inputs`.
//! All invariants are property-tested in `rust/tests/prop_dyntree.rs`.

use crate::spec::sampling::top_k;
use crate::spec::tree::DraftTree;

/// Concrete per-round shape limits for dynamic growth (the resolved form
/// of `DynTreeConfig`, after executable-shape clamping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DynTreeParams {
    /// Maximum draft depth (number of draft-step levels).
    pub depth: usize,
    /// Frontier width: nodes expanded per level, by cumulative score.
    pub frontier_k: usize,
    /// Children considered per expanded node.
    pub branch: usize,
    /// Maximum non-root nodes kept for verification (`<= verify_t - 1`).
    pub budget: usize,
}

/// Top-`k` of `candidates` by cumulative draft log-prob. Ties break by
/// construction order; the result is returned in ascending node order so
/// downstream slot assignment stays deterministic.
pub fn select_frontier(tree: &DraftTree, candidates: &[usize], k: usize) -> Vec<usize> {
    if candidates.len() <= k {
        return candidates.to_vec();
    }
    let mut ranked: Vec<usize> = candidates.to_vec();
    ranked.sort_by(|&a, &b| {
        tree.nodes[b]
            .score
            .partial_cmp(&tree.nodes[a].score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    ranked.truncate(k);
    ranked.sort_unstable();
    ranked
}

/// Score the top-`branch` children of an expanded node from its draft
/// probability row: `(token, cumulative log-prob)` pairs, best first.
pub fn expand_candidates(parent_score: f32, probs: &[f32], branch: usize) -> Vec<(u32, f32)> {
    top_k(probs, branch)
        .into_iter()
        .map(|(tok, pr)| (tok as u32, parent_score + pr.max(1e-20).ln()))
        .collect()
}

/// Global rerank: keep the root plus the best `budget` nodes by
/// cumulative score, ancestor-closed. Returns the pruned tree and the
/// kept ORIGINAL node indices (ascending; `kept[i]` is the original
/// index of pruned node `i`, so `kept[0] == 0`).
///
/// With real cumulative log-probs a child never outscores its parent, so
/// the kept set is simply the top-`budget` scores; the explicit
/// ancestor-closure walk below also keeps the function total for
/// arbitrary score assignments (the property tests feed it those).
pub fn rerank(tree: &DraftTree, budget: usize) -> (DraftTree, Vec<usize>) {
    let n = tree.len();
    if n == 0 || n - 1 <= budget {
        return (tree.clone(), (0..n).collect());
    }
    let mut order: Vec<usize> = (1..n).collect();
    order.sort_by(|&a, &b| {
        tree.nodes[b]
            .score
            .partial_cmp(&tree.nodes[a].score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut keep = vec![false; n];
    keep[0] = true;
    let mut kept = 0usize;
    for &i in &order {
        if kept >= budget {
            break;
        }
        if keep[i] {
            continue;
        }
        // unkept ancestors (root excluded — always kept) plus the node itself
        let mut need = Vec::new();
        let mut cur = Some(i);
        while let Some(c) = cur {
            if !keep[c] {
                need.push(c);
            }
            cur = tree.nodes[c].parent;
        }
        if kept + need.len() <= budget {
            kept += need.len();
            for &c in &need {
                keep[c] = true;
            }
        }
    }
    // Rebuild in original index order (parents always precede children).
    let mut remap = vec![usize::MAX; n];
    let mut kept_idx = Vec::with_capacity(kept + 1);
    let mut out = DraftTree::with_root(tree.nodes[0].token);
    remap[0] = 0;
    kept_idx.push(0);
    for i in 1..n {
        if !keep[i] {
            continue;
        }
        let p = tree.nodes[i].parent.expect("non-root node must have a parent");
        let ni =
            out.add(remap[p], tree.nodes[i].token, tree.nodes[i].score, tree.nodes[i].q.clone());
        remap[i] = ni;
        kept_idx.push(i);
    }
    (out, kept_idx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scored_tree() -> DraftTree {
        // root -> a(-0.1), b(-0.9); a -> c(-0.2), d(-1.5); b -> e(-1.0)
        let mut t = DraftTree::with_root(0);
        let a = t.add(0, 1, -0.1, None);
        let b = t.add(0, 2, -0.9, None);
        t.add(a, 3, -0.2, None);
        t.add(a, 4, -1.5, None);
        t.add(b, 5, -1.0, None);
        t
    }

    #[test]
    fn frontier_picks_top_scores_in_node_order() {
        let t = scored_tree();
        assert_eq!(select_frontier(&t, &[1, 2, 3, 4, 5], 2), vec![1, 3]);
        assert_eq!(select_frontier(&t, &[2, 5], 4), vec![2, 5]);
    }

    #[test]
    fn expand_orders_by_confidence() {
        let c = expand_candidates(-1.0, &[0.1, 0.6, 0.3], 2);
        assert_eq!(c[0].0, 1);
        assert_eq!(c[1].0, 2);
        assert!(c[0].1 > c[1].1);
        assert!(c[0].1 < -1.0); // cumulative: parent score + ln(p) < parent score
    }

    #[test]
    fn rerank_keeps_best_and_stays_closed() {
        let t = scored_tree();
        let (pruned, kept) = rerank(&t, 3);
        // top-3 by score: a(-0.1), c(-0.2), b(-0.9) — all closure-complete
        assert_eq!(kept, vec![0, 1, 2, 3]);
        assert_eq!(pruned.len(), 4);
        assert_eq!(pruned.nodes[3].parent, Some(1)); // c reparented onto pruned a
    }

    #[test]
    fn rerank_identity_when_under_budget() {
        let t = scored_tree();
        let (pruned, kept) = rerank(&t, 16);
        assert_eq!(pruned.len(), t.len());
        assert_eq!(kept, vec![0, 1, 2, 3, 4, 5]);
    }
}
