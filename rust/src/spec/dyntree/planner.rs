//! Confidence-driven tree planning: frontier selection, candidate
//! scoring, and the global rerank that turns an over-grown candidate tree
//! into the node set actually sent to verification.
//!
//! The planner exploits the paper's observation that draft confidence
//! tracks acceptance probability (the EAGLE-2 direction): instead of
//! fixed per-level widths, each draft step expands the top-K frontier
//! nodes by *cumulative* draft log-prob, and a final global rerank keeps
//! the best `budget` nodes across all depths — ancestor-closed, so the
//! result is always a valid [`DraftTree`] for `verify_inputs`.
//! All invariants are property-tested in `rust/tests/prop_dyntree.rs`.

use crate::spec::sampling::top_k;
use crate::spec::tree::DraftTree;

/// Concrete per-round shape limits for dynamic growth (the resolved form
/// of `DynTreeConfig`, after executable-shape clamping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DynTreeParams {
    /// Maximum draft depth (number of draft-step levels).
    pub depth: usize,
    /// Frontier width: nodes expanded per level, by cumulative score.
    pub frontier_k: usize,
    /// Children considered per expanded node.
    pub branch: usize,
    /// Maximum non-root nodes kept for verification (`<= verify_t - 1`).
    pub budget: usize,
}

/// Top-`k` of `candidates` by cumulative draft log-prob. Ties break by
/// construction order; the result is returned in ascending node order so
/// downstream slot assignment stays deterministic.
pub fn select_frontier(tree: &DraftTree, candidates: &[usize], k: usize) -> Vec<usize> {
    let mut out = Vec::new();
    select_frontier_into(tree, candidates, k, &mut out);
    out
}

/// [`select_frontier`] into a reused buffer (cleared first) — the
/// hot-loop form used with [`crate::spec::scratch::RoundScratch`].
pub fn select_frontier_into(
    tree: &DraftTree,
    candidates: &[usize],
    k: usize,
    out: &mut Vec<usize>,
) {
    out.clear();
    out.extend_from_slice(candidates);
    if candidates.len() <= k {
        return;
    }
    // total order (score desc, index asc), so the allocation-free
    // unstable sort is deterministic and equal to the stable one;
    // `total_cmp` keeps it total even for NaN scores from a bad artifact
    out.sort_unstable_by(|&a, &b| {
        tree.nodes[b].score.total_cmp(&tree.nodes[a].score).then(a.cmp(&b))
    });
    out.truncate(k);
    out.sort_unstable();
}

/// Score the top-`branch` children of an expanded node from its draft
/// probability row: `(token, cumulative log-prob)` pairs, best first.
pub fn expand_candidates(parent_score: f32, probs: &[f32], branch: usize) -> Vec<(u32, f32)> {
    top_k(probs, branch)
        .into_iter()
        .map(|(tok, pr)| (tok as u32, parent_score + pr.max(1e-20).ln()))
        .collect()
}

/// [`expand_candidates`] into reused buffers: `idx` is the vocab-sized
/// top-k sort arena, `out` is cleared and filled with the scored pairs.
/// Same selection and scoring as the allocating wrapper.
pub fn expand_candidates_into(
    parent_score: f32,
    probs: &[f32],
    branch: usize,
    idx: &mut Vec<usize>,
    out: &mut Vec<(u32, f32)>,
) {
    crate::spec::sampling::top_k_into(probs, branch, idx);
    out.clear();
    out.extend(idx.iter().map(|&i| (i as u32, parent_score + probs[i].max(1e-20).ln())));
}

/// Reusable working buffers for [`rerank_into`]: the score order, keep
/// flags, index remap, and the kept ORIGINAL node indices (readable
/// after the call). Lives in [`crate::spec::scratch::RoundScratch`] so
/// the per-round rerank allocates nothing once warm.
#[derive(Debug, Default)]
pub struct RerankScratch {
    order: Vec<usize>,
    keep: Vec<bool>,
    remap: Vec<usize>,
    need: Vec<usize>,
    /// Ascending original indices of the kept nodes (`kept[i]` is the
    /// original index of pruned node `i`; `kept[0] == 0`).
    pub kept: Vec<usize>,
}

impl RerankScratch {
    /// Capacity-guarded pre-size (a no-op once warm — plain
    /// `Vec::reserve` would over-allocate relative to stale lengths).
    pub fn reserve(&mut self, nodes: usize) {
        let want_need = nodes.min(64).max(8);
        for v in [&mut self.order, &mut self.remap, &mut self.kept] {
            if v.capacity() < nodes {
                v.reserve(nodes - v.len());
            }
        }
        if self.keep.capacity() < nodes {
            self.keep.reserve(nodes - self.keep.len());
        }
        if self.need.capacity() < want_need {
            self.need.reserve(want_need - self.need.len());
        }
    }

    pub fn capacity_bytes(&self) -> usize {
        let idx = self.order.capacity()
            + self.remap.capacity()
            + self.need.capacity()
            + self.kept.capacity();
        idx * std::mem::size_of::<usize>() + self.keep.capacity()
    }
}

/// Global rerank: keep the root plus the best `budget` nodes by
/// cumulative score, ancestor-closed. Returns the pruned tree and the
/// kept ORIGINAL node indices (ascending; `kept[i]` is the original
/// index of pruned node `i`, so `kept[0] == 0`).
///
/// Thin allocating wrapper over [`rerank_into`].
pub fn rerank(tree: &DraftTree, budget: usize) -> (DraftTree, Vec<usize>) {
    let mut out = DraftTree::default();
    let mut rr = RerankScratch::default();
    rerank_into(tree, budget, &mut out, &mut rr);
    (out, rr.kept)
}

/// [`rerank`] into a reused output tree + working buffers; the engines
/// swap `out` with the live tree when the candidate set exceeds the
/// budget, so pruning allocates nothing in steady state. The kept
/// original indices land in `rr.kept`.
///
/// With real cumulative log-probs a child never outscores its parent, so
/// the kept set is simply the top-`budget` scores; the explicit
/// ancestor-closure walk below also keeps the function total for
/// arbitrary score assignments (the property tests feed it those).
pub fn rerank_into(tree: &DraftTree, budget: usize, out: &mut DraftTree, rr: &mut RerankScratch) {
    let n = tree.len();
    rr.kept.clear();
    if n == 0 || n - 1 <= budget {
        out.nodes.clear();
        out.nodes.extend(tree.nodes.iter().cloned());
        rr.kept.extend(0..n);
        return;
    }
    rr.order.clear();
    rr.order.extend(1..n);
    // total order (score desc, index asc): unstable sort is exact and
    // allocation-free (stable sort would heap-allocate a merge buffer
    // every round, invisibly to the capacity-delta metric); `total_cmp`
    // keeps it total even for NaN scores from a bad artifact
    rr.order.sort_unstable_by(|&a, &b| {
        tree.nodes[b].score.total_cmp(&tree.nodes[a].score).then(a.cmp(&b))
    });
    rr.keep.clear();
    rr.keep.resize(n, false);
    rr.keep[0] = true;
    let mut kept = 0usize;
    for oi in 0..rr.order.len() {
        let i = rr.order[oi];
        if kept >= budget {
            break;
        }
        if rr.keep[i] {
            continue;
        }
        // unkept ancestors (root excluded — always kept) plus the node itself
        rr.need.clear();
        let mut cur = Some(i);
        while let Some(c) = cur {
            if !rr.keep[c] {
                rr.need.push(c);
            }
            cur = tree.nodes[c].parent;
        }
        if kept + rr.need.len() <= budget {
            kept += rr.need.len();
            for &c in &rr.need {
                rr.keep[c] = true;
            }
        }
    }
    // Rebuild in original index order (parents always precede children).
    rr.remap.clear();
    rr.remap.resize(n, usize::MAX);
    out.reset(tree.nodes[0].token);
    rr.remap[0] = 0;
    rr.kept.push(0);
    for i in 1..n {
        if !rr.keep[i] {
            continue;
        }
        let p = tree.nodes[i].parent.expect("non-root node must have a parent");
        let nd = &tree.nodes[i];
        let ni = out.add(rr.remap[p], nd.token, nd.score, nd.q);
        rr.remap[i] = ni;
        rr.kept.push(i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scored_tree() -> DraftTree {
        // root -> a(-0.1), b(-0.9); a -> c(-0.2), d(-1.5); b -> e(-1.0)
        let mut t = DraftTree::with_root(0);
        let a = t.add(0, 1, -0.1, None);
        let b = t.add(0, 2, -0.9, None);
        t.add(a, 3, -0.2, None);
        t.add(a, 4, -1.5, None);
        t.add(b, 5, -1.0, None);
        t
    }

    #[test]
    fn frontier_picks_top_scores_in_node_order() {
        let t = scored_tree();
        assert_eq!(select_frontier(&t, &[1, 2, 3, 4, 5], 2), vec![1, 3]);
        assert_eq!(select_frontier(&t, &[2, 5], 4), vec![2, 5]);
    }

    #[test]
    fn expand_orders_by_confidence() {
        let c = expand_candidates(-1.0, &[0.1, 0.6, 0.3], 2);
        assert_eq!(c[0].0, 1);
        assert_eq!(c[1].0, 2);
        assert!(c[0].1 > c[1].1);
        assert!(c[0].1 < -1.0); // cumulative: parent score + ln(p) < parent score
    }

    #[test]
    fn rerank_keeps_best_and_stays_closed() {
        let t = scored_tree();
        let (pruned, kept) = rerank(&t, 3);
        // top-3 by score: a(-0.1), c(-0.2), b(-0.9) — all closure-complete
        assert_eq!(kept, vec![0, 1, 2, 3]);
        assert_eq!(pruned.len(), 4);
        assert_eq!(pruned.nodes[3].parent, Some(1)); // c reparented onto pruned a
    }

    #[test]
    fn rerank_identity_when_under_budget() {
        let t = scored_tree();
        let (pruned, kept) = rerank(&t, 16);
        assert_eq!(pruned.len(), t.len());
        assert_eq!(kept, vec![0, 1, 2, 3, 4, 5]);
    }
}
