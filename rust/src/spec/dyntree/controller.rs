//! Adaptive per-request speculation controller.
//!
//! Tracks acceptance online as an EWMA over the per-depth `alpha` stats
//! the metrics layer already records (`GenRecord::alpha` increments), and
//! adapts the dynamic planner's draft depth / frontier width round by
//! round: speculation deepens while acceptance stays high and shrinks
//! when it collapses, so a hard prompt stops paying for drafts that
//! never survive verification. The total-nodes `budget` is never touched
//! here — it is fixed by the lowered `verify_t` executable shape and
//! enforced by the planner's rerank.
//!
//! This subsumes the classic-spec optimal-γ question (Chen et al.): with
//! `frontier_k = branch = 1` the controller is exactly an online γ tuner
//! for chain drafting.

use super::planner::DynTreeParams;

/// Tuning knobs for [`SpecController`].
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// EWMA weight on history, in `[0, 1)`; higher = smoother.
    pub ewma_beta: f32,
    /// Smoothed acceptance rate above which speculation deepens/widens.
    pub high: f32,
    /// Smoothed acceptance rate below which speculation shrinks.
    pub low: f32,
    pub min_depth: usize,
    pub max_depth: usize,
    pub min_frontier: usize,
    pub max_frontier: usize,
    /// Observe-only rounds before the first adaptation step.
    pub warmup_rounds: u64,
    /// Width-hysteresis dwell band: once the EWMA has crossed `low` and
    /// the request downshifted to the cheapest verify width, it only
    /// upshifts again after the EWMA recovers above `low + width_dwell`.
    /// Without the band, a rate oscillating around `low` flaps between
    /// differently-shaped `verify_t{t}` executables every round (on a
    /// real backend that thrashes compilation/autotuning caches).
    pub width_dwell: f32,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            ewma_beta: 0.7,
            high: 0.7,
            low: 0.35,
            min_depth: 1,
            max_depth: 7,
            min_frontier: 1,
            max_frontier: 8,
            warmup_rounds: 2,
            width_dwell: 0.1,
        }
    }
}

/// Online acceptance tracker + shape adapter. One instance per request
/// (bs=1 engine) or per lane (batched engine).
#[derive(Debug, Clone)]
pub struct SpecController {
    pub cfg: ControllerConfig,
    params: DynTreeParams,
    /// Per-depth acceptance EWMA (index = draft chain position).
    pub alpha_ewma: Vec<f32>,
    alpha_seen: Vec<bool>,
    /// Overall smoothed acceptance rate across depths.
    pub rate_ewma: f32,
    rate_seen: bool,
    /// Sticky width-downshift state (hysteresis): set when the EWMA
    /// crosses `low`, cleared only once it recovers past
    /// `low + width_dwell`.
    width_down: bool,
    pub rounds: u64,
}

impl SpecController {
    pub fn new(cfg: ControllerConfig, init: DynTreeParams) -> SpecController {
        let depth = init.depth.clamp(cfg.min_depth.max(1), cfg.max_depth.max(1));
        let frontier_k = init.frontier_k.clamp(cfg.min_frontier.max(1), cfg.max_frontier.max(1));
        let n = cfg.max_depth.max(depth);
        SpecController {
            params: DynTreeParams { depth, frontier_k, ..init },
            alpha_ewma: vec![0.0; n],
            alpha_seen: vec![false; n],
            rate_ewma: 0.0,
            rate_seen: false,
            width_down: false,
            rounds: 0,
            cfg,
        }
    }

    /// The shape to draft with this round.
    pub fn params(&self) -> DynTreeParams {
        self.params
    }

    /// Whether the overall acceptance EWMA has observed any round yet
    /// (width selection must not act on the 0.0 initial value).
    pub fn has_rate(&self) -> bool {
        self.rate_seen
    }

    /// The width-downshift threshold with hysteresis applied: `low`
    /// while the request runs at full width, `low + width_dwell` once it
    /// has downshifted — so leaving the cheap executable requires the
    /// EWMA to clear the whole dwell band, not just tick above `low`.
    pub fn effective_low(&self) -> f32 {
        if self.width_down {
            self.cfg.low + self.cfg.width_dwell
        } else {
            self.cfg.low
        }
    }

    /// Whether the request is currently held at the cheapest verify
    /// width by the hysteresis state.
    pub fn is_width_down(&self) -> bool {
        self.width_down
    }

    /// Fold in one round's per-depth `(accepted, tried)` increments — the
    /// delta of `GenRecord::alpha` across the round — then adapt.
    pub fn observe(&mut self, alpha_delta: &[(u64, u64)]) {
        let beta = self.cfg.ewma_beta;
        let (mut hit, mut tried) = (0u64, 0u64);
        for (d, &(h, t)) in alpha_delta.iter().enumerate() {
            if t == 0 {
                continue;
            }
            hit += h;
            tried += t;
            let r = h as f32 / t as f32;
            if d < self.alpha_ewma.len() {
                self.alpha_ewma[d] = if self.alpha_seen[d] {
                    beta * self.alpha_ewma[d] + (1.0 - beta) * r
                } else {
                    r
                };
                self.alpha_seen[d] = true;
            }
        }
        self.rounds += 1;
        if tried == 0 {
            return;
        }
        let r = hit as f32 / tried as f32;
        self.rate_ewma = if self.rate_seen { beta * self.rate_ewma + (1.0 - beta) * r } else { r };
        self.rate_seen = true;
        // width hysteresis: the state only flips when the EWMA clears the
        // threshold on the far side of the dwell band
        self.width_down = self.rate_ewma <= self.effective_low();
        if self.rounds > self.cfg.warmup_rounds {
            self.adapt();
        }
    }

    /// Convenience for engines that only know the accepted chain length
    /// (the batched greedy engine): synthesizes per-depth increments —
    /// position `d` was tried, and hit iff `d < accepted`.
    pub fn observe_round(&mut self, accepted: usize, attempted: usize) {
        let n = attempted.max(accepted).min(64);
        if n == 0 {
            self.rounds += 1;
            return;
        }
        let delta: Vec<(u64, u64)> = (0..n).map(|d| (u64::from(d < accepted), 1u64)).collect();
        self.observe(&delta);
    }

    fn adapt(&mut self) {
        let c = &self.cfg;
        if self.rate_ewma >= c.high {
            self.params.depth = (self.params.depth + 1).min(c.max_depth);
            self.params.frontier_k = (self.params.frontier_k + 1).min(c.max_frontier);
        } else if self.rate_ewma <= c.low {
            self.params.depth = self.params.depth.saturating_sub(1).max(c.min_depth);
            self.params.frontier_k = self.params.frontier_k.saturating_sub(1).max(c.min_frontier);
        }
    }

    /// Capture the full adaptive state (shape params, per-depth EWMAs,
    /// rate EWMA, width-hysteresis latch, round count) into a pre-sized
    /// snapshot. `clear` + `extend_from_slice` into the snapshot's
    /// existing capacity, so a warm capture allocates nothing (the lane-
    /// checkpoint zero-alloc guarantee; see `coordinator/checkpoint.rs`).
    pub fn snapshot_into(&self, s: &mut ControllerSnapshot) {
        s.params = self.params;
        s.alpha_ewma.clear();
        s.alpha_ewma.extend_from_slice(&self.alpha_ewma);
        s.alpha_seen.clear();
        s.alpha_seen.extend_from_slice(&self.alpha_seen);
        s.rate_ewma = self.rate_ewma;
        s.rate_seen = self.rate_seen;
        s.width_down = self.width_down;
        s.rounds = self.rounds;
    }

    /// Restore adaptive state from a snapshot (inverse of
    /// [`SpecController::snapshot_into`]); `cfg` is kept from `self`,
    /// matching checkpoint resume where the engine rebuilds the
    /// controller from its own config and splices the learned state in.
    pub fn restore(&mut self, s: &ControllerSnapshot) {
        self.params = s.params;
        self.alpha_ewma.clear();
        self.alpha_ewma.extend_from_slice(&s.alpha_ewma);
        self.alpha_seen.clear();
        self.alpha_seen.extend_from_slice(&s.alpha_seen);
        self.rate_ewma = s.rate_ewma;
        self.rate_seen = s.rate_seen;
        self.width_down = s.width_down;
        self.rounds = s.rounds;
    }
}

/// Plain-data image of a [`SpecController`]'s adaptive state, carried by
/// lane checkpoints across suspend/resume. Buffers are pre-sized once
/// (`reserve`) so warm round-boundary captures stay allocation-free.
#[derive(Debug, Clone)]
pub struct ControllerSnapshot {
    pub params: DynTreeParams,
    pub alpha_ewma: Vec<f32>,
    pub alpha_seen: Vec<bool>,
    pub rate_ewma: f32,
    pub rate_seen: bool,
    pub width_down: bool,
    pub rounds: u64,
}

impl Default for ControllerSnapshot {
    fn default() -> Self {
        ControllerSnapshot {
            params: DynTreeParams { depth: 1, frontier_k: 1, branch: 1, budget: 1 },
            alpha_ewma: Vec::new(),
            alpha_seen: Vec::new(),
            rate_ewma: 0.0,
            rate_seen: false,
            width_down: false,
            rounds: 0,
        }
    }
}

impl ControllerSnapshot {
    /// Pre-size for controllers tracking up to `max_depth` per-depth
    /// EWMAs (the capture path never grows past the controller's vecs).
    pub fn reserve(&mut self, max_depth: usize) {
        crate::spec::scratch::ensure_cap(&mut self.alpha_ewma, max_depth);
        crate::spec::scratch::ensure_cap(&mut self.alpha_seen, max_depth);
    }

    pub fn capacity_bytes(&self) -> usize {
        self.alpha_ewma.capacity() * std::mem::size_of::<f32>() + self.alpha_seen.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn init() -> DynTreeParams {
        DynTreeParams { depth: 3, frontier_k: 4, branch: 4, budget: 31 }
    }

    #[test]
    fn high_acceptance_deepens_to_max() {
        let cfg = ControllerConfig::default();
        let mut c = SpecController::new(cfg.clone(), init());
        for _ in 0..12 {
            c.observe_round(5, 5);
        }
        assert_eq!(c.params().depth, cfg.max_depth);
        assert_eq!(c.params().frontier_k, cfg.max_frontier);
        assert!(c.rate_ewma > 0.9);
        assert_eq!(c.params().budget, 31, "controller must not touch the node budget");
    }

    #[test]
    fn collapsed_acceptance_shrinks_to_min() {
        let cfg = ControllerConfig::default();
        let mut c = SpecController::new(cfg.clone(), init());
        for _ in 0..12 {
            c.observe_round(0, 5);
        }
        assert_eq!(c.params().depth, cfg.min_depth);
        assert_eq!(c.params().frontier_k, cfg.min_frontier);
        assert!(c.rate_ewma < 0.1);
    }

    #[test]
    fn warmup_rounds_do_not_adapt() {
        let cfg = ControllerConfig { warmup_rounds: 3, ..Default::default() };
        let mut c = SpecController::new(cfg, init());
        c.observe_round(5, 5);
        c.observe_round(5, 5);
        c.observe_round(5, 5);
        assert_eq!(c.params().depth, 3, "no adaptation during warmup");
        c.observe_round(5, 5);
        assert_eq!(c.params().depth, 4, "adapts after warmup");
    }

    #[test]
    fn per_depth_ewma_tracks_shallow_vs_deep() {
        let mut c = SpecController::new(ControllerConfig::default(), init());
        // depth 0 always accepted, depth 1 never
        for _ in 0..8 {
            c.observe(&[(1, 1), (0, 1)]);
        }
        assert!(c.alpha_ewma[0] > 0.95);
        assert!(c.alpha_ewma[1] < 0.05);
    }

    #[test]
    fn width_dwell_prevents_flapping_around_low() {
        // cfg: low = 0.35, dwell = 0.1 -> effective band [0.35, 0.45]
        let cfg = ControllerConfig::default();
        let mut c = SpecController::new(cfg.clone(), init());
        assert!(!c.is_width_down());
        assert!((c.effective_low() - cfg.low).abs() < 1e-6);
        // collapse acceptance: EWMA falls through `low`, state goes down
        for _ in 0..8 {
            c.observe_round(0, 5);
        }
        assert!(c.is_width_down());
        assert!((c.effective_low() - (cfg.low + cfg.width_dwell)).abs() < 1e-6);
        // steady 0.4 sits INSIDE the band: a dwell-free controller would
        // upshift (0.4 > low) — hysteresis must hold the downshift
        for _ in 0..40 {
            c.observe_round(2, 5);
            assert!(c.is_width_down(), "EWMA {} flapped up inside the band", c.rate_ewma);
        }
        assert!(c.rate_ewma > cfg.low, "steady rate converged above low");
        // recovery clears the whole band -> upshift
        for _ in 0..12 {
            c.observe_round(5, 5);
        }
        assert!(!c.is_width_down());
        // and steady 0.4 from the UP side stays up (0.4 > low)
        for _ in 0..40 {
            c.observe_round(2, 5);
            if (c.rate_ewma - 0.4).abs() < 0.02 {
                assert!(!c.is_width_down(), "EWMA {} flapped down inside the band", c.rate_ewma);
            }
        }
    }

    #[test]
    fn width_dwell_still_downshifts_on_a_real_collapse() {
        let mut c = SpecController::new(ControllerConfig::default(), init());
        for _ in 0..6 {
            c.observe_round(5, 5);
        }
        assert!(!c.is_width_down());
        for _ in 0..10 {
            c.observe_round(0, 5);
        }
        assert!(c.is_width_down(), "a genuine collapse must still cross `low`");
    }

    #[test]
    fn snapshot_restore_is_bit_identical() {
        let cfg = ControllerConfig::default();
        let mut a = SpecController::new(cfg.clone(), init());
        // drive through warmup, adaptation, and a width downshift so
        // every piece of private state is non-trivial at the cut point
        for i in 0..9 {
            a.observe_round(if i < 5 { 5 } else { 0 }, 5);
        }
        let mut snap = ControllerSnapshot::default();
        snap.reserve(cfg.max_depth);
        a.snapshot_into(&mut snap);
        let mut b = SpecController::new(cfg, init());
        b.restore(&snap);
        assert_eq!(a.params(), b.params());
        assert_eq!(a.rounds, b.rounds);
        assert!(a.rate_ewma.to_bits() == b.rate_ewma.to_bits());
        assert_eq!(a.is_width_down(), b.is_width_down());
        // continue both controllers: every subsequent decision matches
        for i in 0..20 {
            let acc = [0usize, 2, 5, 3, 1][i % 5];
            a.observe_round(acc, 5);
            b.observe_round(acc, 5);
            assert_eq!(a.params(), b.params(), "round {i}");
            assert!(a.rate_ewma.to_bits() == b.rate_ewma.to_bits(), "round {i}");
            assert_eq!(a.effective_low().to_bits(), b.effective_low().to_bits());
        }
        // warm re-capture into the same snapshot must not grow it
        let cap0 = snap.capacity_bytes();
        a.snapshot_into(&mut snap);
        assert_eq!(snap.capacity_bytes(), cap0, "warm capture grew the snapshot");
    }

    #[test]
    fn init_clamps_to_config_bounds() {
        let cfg = ControllerConfig { max_depth: 4, max_frontier: 3, ..Default::default() };
        let init = DynTreeParams { depth: 9, frontier_k: 9, branch: 4, budget: 10 };
        let c = SpecController::new(cfg, init);
        assert_eq!(c.params().depth, 4);
        assert_eq!(c.params().frontier_k, 3);
    }
}
