//! Dynamic draft-tree planning (S20) — the EAGLE-2 direction built on the
//! paper's own insight that draft confidence tracks acceptance
//! probability:
//!
//! * [`planner`] — confidence-driven expansion (top-K frontier by
//!   cumulative draft log-prob) and the global rerank that keeps the best
//!   `verify_t - 1` nodes, ancestor-closed, per round;
//! * [`controller`] — an online EWMA acceptance tracker (over the
//!   per-depth `alpha` stats the metrics layer records) that adapts draft
//!   depth / frontier width per request, shrinking speculation when
//!   acceptance collapses and deepening it when acceptance is high;
//! * [`policy`] — [`TreePolicy`] (`Static(TreeSpec)` | `Dynamic(..)`),
//!   threaded through `EagleEngine`, `BatchEagleEngine`, the server/CLI
//!   config, and the eval harness (`repro eval --exp dyntree`);
//! * [`widths`] — per-round width selection over the lowered executable
//!   families: `verify_t{t}` (the `"verify_widths"` manifest constant)
//!   and `step_w{w}` (`"draft_widths"`), driven by the controller's
//!   acceptance EWMA (with a dwell band so a rate oscillating around
//!   `low` doesn't flap executables) at bs=1, and by group-local fits in
//!   the batched engine — the scheduler's width-grouped admission
//!   (`coordinator::scheduler`) caps each group's family at its planned
//!   width so low-acceptance lanes never ride a hot lane's widths.
//!
//! Topology invariants (ancestor closure, node budget, uniform-confidence
//! degradation to the static tree) are property-tested in
//! `rust/tests/prop_dyntree.rs`; planner overhead is benchmarked next to
//! bias-building and softmax in `rust/benches/hot_path.rs`.

pub mod controller;
pub mod planner;
pub mod policy;
pub mod widths;

pub use controller::{ControllerConfig, ControllerSnapshot, SpecController};
pub use planner::{
    expand_candidates, expand_candidates_into, rerank, rerank_into, select_frontier,
    select_frontier_into, DynTreeParams, RerankScratch,
};
pub use policy::{DynTreeConfig, SourceSelector, TreePolicy};
pub use widths::{plan_round_width, width_hint, WidthFamily, WidthSelect};
