//! Tree-policy configuration: the switch between the paper's static
//! draft tree and the dynamic planner, threaded through the engines, the
//! server/CLI config, and the eval harness. Since PR 10 this module also
//! hosts [`SourceSelector`], the online per-request draft-source policy
//! behind `--draft auto`.

use std::sync::atomic::{AtomicU64, Ordering};

use super::controller::ControllerConfig;
use super::planner::DynTreeParams;
use crate::spec::source::SourceKind;
use crate::spec::tree::TreeSpec;

/// User-facing dynamic-tree configuration. Executable-shape limits
/// (`verify_t`, `draft_w`, `accept_a`) are not known here; they are
/// applied by [`DynTreeConfig::params`] / [`DynTreeConfig::clamped_controller`]
/// at engine-construction time, so lowered shapes are always respected.
#[derive(Debug, Clone)]
pub struct DynTreeConfig {
    /// Initial draft depth (draft-step levels per round).
    pub depth: usize,
    /// Initial frontier width (nodes expanded per level).
    pub frontier_k: usize,
    /// Children considered per expanded node.
    pub branch: usize,
    /// Max nodes sent to verification excluding the root;
    /// `None` resolves to `verify_t - 1` (the full verify budget).
    pub budget: Option<usize>,
    /// Enable the per-request acceptance controller.
    pub adaptive: bool,
    pub controller: ControllerConfig,
}

impl Default for DynTreeConfig {
    fn default() -> Self {
        // Starts at the static 4/8/8/5 tree's depth with a slightly wider
        // frontier. The node budget defaults to the FULL verify width
        // (verify_t - 1); pass `budget: Some(n)` for equal-budget
        // comparisons against a static tree of n nodes.
        DynTreeConfig {
            depth: 4,
            frontier_k: 6,
            branch: 4,
            budget: None,
            adaptive: true,
            controller: ControllerConfig::default(),
        }
    }
}

impl DynTreeConfig {
    /// Resolve shape-dependent limits into concrete planner params:
    /// * kept tree fits the verify call: `budget <= verify_t - 1`;
    /// * the accepted chain replayed by the draft extend call fits:
    ///   `depth + 1 <= draft_w` and `depth + 1 <= accept_a`;
    /// * per-level step width fits: `frontier_k <= draft_w`.
    pub fn params(&self, verify_t: usize, draft_w: usize, accept_a: usize) -> DynTreeParams {
        let max_depth = draft_w.min(accept_a).saturating_sub(1).max(1);
        let verify_budget = verify_t.saturating_sub(1).max(1);
        let budget = self.budget.unwrap_or(verify_budget).clamp(1, verify_budget);
        DynTreeParams {
            depth: self.depth.clamp(1, max_depth),
            frontier_k: self.frontier_k.clamp(1, draft_w.max(1)),
            branch: self.branch.max(1),
            budget,
        }
    }

    /// Controller config with adaptation ceilings clamped to the same
    /// executable-shape limits as [`DynTreeConfig::params`].
    pub fn clamped_controller(&self, draft_w: usize, accept_a: usize) -> ControllerConfig {
        let mut c = self.controller.clone();
        let max_depth = draft_w.min(accept_a).saturating_sub(1).max(1);
        c.max_depth = c.max_depth.clamp(1, max_depth);
        c.min_depth = c.min_depth.clamp(1, c.max_depth);
        c.max_frontier = c.max_frontier.clamp(1, draft_w.max(1));
        c.min_frontier = c.min_frontier.clamp(1, c.max_frontier);
        c
    }
}

/// How an EAGLE engine shapes its draft tree each round.
#[derive(Debug, Clone)]
pub enum TreePolicy {
    /// Fixed per-level widths — the paper's 4/8/8/5 default or a chain.
    Static(TreeSpec),
    /// Confidence-driven expansion + global rerank, optionally with the
    /// adaptive per-request controller.
    Dynamic(DynTreeConfig),
}

impl TreePolicy {
    /// The paper's default static tree.
    pub fn default_tree() -> TreePolicy {
        TreePolicy::Static(TreeSpec::tree_default())
    }

    /// Classic-spec chain shape.
    pub fn chain(gamma: usize) -> TreePolicy {
        TreePolicy::Static(TreeSpec::chain(gamma))
    }

    /// Dynamic planning with default knobs (adaptive controller on).
    pub fn dynamic_default() -> TreePolicy {
        TreePolicy::Dynamic(DynTreeConfig::default())
    }

    pub fn is_dynamic(&self) -> bool {
        matches!(self, TreePolicy::Dynamic(_))
    }

    pub fn name(&self) -> &'static str {
        match self {
            TreePolicy::Static(_) => "static",
            TreePolicy::Dynamic(_) => "dynamic",
        }
    }
}

// ---------------------------------------------------------------------------
// SourceSelector — the `--draft auto` online policy

/// EWMA smoothing for per-source accepted-tokens-per-round observations
/// (same idiom as the cost model's online re-fit).
const SEL_ALPHA: f64 = 0.2;
/// Observations before a source's EWMA is trusted; until every valid
/// source has this many, `pick` probes them round-robin (deterministic).
const SEL_MIN_OBS: u64 = 4;

#[inline]
fn load_f64(a: &AtomicU64) -> f64 {
    f64::from_bits(a.load(Ordering::Relaxed))
}

#[inline]
fn store_f64(a: &AtomicU64, v: f64) {
    a.store(v.to_bits(), Ordering::Relaxed);
}

/// Online per-source acceptance tracker driving `--draft auto`: one EWMA
/// of accepted tokens per round per [`SourceKind`], scored against the
/// source's relative drafting cost ([`SourceKind::cost_hint`]). Shared
/// across the server (an `Arc` threaded from the route to the workers);
/// all state is relaxed atomics — observations are advisory, a torn
/// ordering only delays convergence by a round.
#[derive(Debug, Default)]
pub struct SourceSelector {
    ewma: [AtomicU64; 4],
    obs: [AtomicU64; 4],
    picks: [AtomicU64; 4],
    switches: AtomicU64,
    /// last picked kind + 1 (0 = never picked)
    last: AtomicU64,
}

impl SourceSelector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sources `--draft auto` may pick at this temperature: the n-gram
    /// and Medusa serving paths are greedy-only facades, so sampled
    /// requests are restricted to eagle / chain (both exact at T>0).
    pub fn valid(kind: SourceKind, temperature: f32) -> bool {
        temperature <= 0.0 || matches!(kind, SourceKind::Eagle | SourceKind::Chain)
    }

    /// Fold one finished request's mean accepted tokens per round into
    /// the source's EWMA.
    pub fn observe(&self, kind: SourceKind, accepted_per_round: f64) {
        if !accepted_per_round.is_finite() {
            return;
        }
        let i = kind.idx();
        let n = self.obs[i].fetch_add(1, Ordering::Relaxed);
        let prev = load_f64(&self.ewma[i]);
        let next = if n == 0 {
            accepted_per_round
        } else {
            SEL_ALPHA * accepted_per_round + (1.0 - SEL_ALPHA) * prev
        };
        store_f64(&self.ewma[i], next);
    }

    /// Cost-normalized policy score for a source (0 until observed).
    pub fn score(&self, kind: SourceKind) -> f64 {
        load_f64(&self.ewma[kind.idx()]) / kind.cost_hint()
    }

    pub fn observations(&self, kind: SourceKind) -> u64 {
        self.obs[kind.idx()].load(Ordering::Relaxed)
    }

    pub fn picks(&self, kind: SourceKind) -> u64 {
        self.picks[kind.idx()].load(Ordering::Relaxed)
    }

    pub fn switches(&self) -> u64 {
        self.switches.load(Ordering::Relaxed)
    }

    /// The current best source without recording a pick (used by the
    /// `draftsrc` eval to read the converged winner).
    pub fn best(&self, temperature: f32) -> SourceKind {
        let mut best = SourceKind::Eagle;
        let mut best_score = f64::NEG_INFINITY;
        for k in SourceKind::ALL {
            if !Self::valid(k, temperature) {
                continue;
            }
            let s = self.score(k);
            // cost-ascending tiebreak: ALL is not cost-ordered, so compare
            if s > best_score || (s == best_score && k.cost_hint() < best.cost_hint()) {
                best = k;
                best_score = s;
            }
        }
        best
    }

    /// Pick the source for a new request: deterministic round-robin
    /// probing until every valid source has [`SEL_MIN_OBS`]
    /// observations, then the best cost-normalized EWMA. Records the
    /// pick and counts a policy switch when it differs from the
    /// previous one.
    pub fn pick(&self, temperature: f32) -> SourceKind {
        let under = SourceKind::ALL
            .into_iter()
            .filter(|&k| Self::valid(k, temperature))
            .find(|&k| self.observations(k) < SEL_MIN_OBS);
        let kind = under.unwrap_or_else(|| self.best(temperature));
        self.picks[kind.idx()].fetch_add(1, Ordering::Relaxed);
        let tag = kind.idx() as u64 + 1;
        let prev = self.last.swap(tag, Ordering::Relaxed);
        if prev != 0 && prev != tag {
            self.switches.fetch_add(1, Ordering::Relaxed);
        }
        kind
    }

    /// Speculation-depth hint for the picked source: roughly one past
    /// the tokens a round is expected to accept, clamped to sane draft
    /// lengths.
    pub fn depth_hint(&self, kind: SourceKind) -> usize {
        let e = load_f64(&self.ewma[kind.idx()]);
        ((e.ceil() as usize) + 1).clamp(2, 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_respect_lowered_shapes() {
        let dc =
            DynTreeConfig { depth: 99, frontier_k: 99, budget: Some(999), ..Default::default() };
        let p = dc.params(32, 8, 8);
        assert_eq!(p.depth, 7, "depth + 1 must fit draft_w and accept_a");
        assert_eq!(p.frontier_k, 8);
        assert_eq!(p.budget, 31, "root + budget must fit verify_t");
    }

    #[test]
    fn default_budget_matches_verify_width() {
        let p = DynTreeConfig::default().params(26, 8, 8);
        assert_eq!(p.budget, 25); // same class as the static 4/8/8/5 tree
        assert_eq!(p.depth, 4);
    }

    #[test]
    fn clamped_controller_bounds() {
        let dc = DynTreeConfig::default();
        let c = dc.clamped_controller(4, 8);
        assert_eq!(c.max_depth, 3);
        assert!(c.min_depth <= c.max_depth);
        assert_eq!(c.max_frontier, 4);
    }

    #[test]
    fn policy_names() {
        assert_eq!(TreePolicy::default_tree().name(), "static");
        assert_eq!(TreePolicy::dynamic_default().name(), "dynamic");
        assert!(TreePolicy::dynamic_default().is_dynamic());
        assert!(!TreePolicy::chain(5).is_dynamic());
    }

    #[test]
    fn selector_probes_then_converges() {
        use crate::spec::source::sim_accepted_per_round;
        let sel = SourceSelector::new();
        // repetitive workload: after the probe phase the policy must
        // settle on the n-gram source
        for _ in 0..64 {
            let k = sel.pick(0.0);
            sel.observe(k, sim_accepted_per_round(k, 0.9));
        }
        assert_eq!(sel.best(0.0), SourceKind::Ngram);
        assert!(sel.picks(SourceKind::Ngram) > sel.picks(SourceKind::Eagle));
        // every source got its probe observations
        for k in SourceKind::ALL {
            assert!(sel.observations(k) >= 4, "{k:?} never probed");
        }
        assert!(sel.switches() > 0);
    }

    #[test]
    fn selector_converges_to_eagle_on_chat() {
        use crate::spec::source::sim_accepted_per_round;
        let sel = SourceSelector::new();
        for _ in 0..64 {
            let k = sel.pick(0.0);
            sel.observe(k, sim_accepted_per_round(k, 0.15));
        }
        assert_eq!(sel.best(0.0), SourceKind::Eagle);
    }

    #[test]
    fn selector_sampled_requests_avoid_greedy_only_sources() {
        let sel = SourceSelector::new();
        for _ in 0..32 {
            let k = sel.pick(0.8);
            assert!(matches!(k, SourceKind::Eagle | SourceKind::Chain), "picked {k:?} at T>0");
            sel.observe(k, 5.0);
        }
        assert!(SourceSelector::valid(SourceKind::Ngram, 0.0));
        assert!(!SourceSelector::valid(SourceKind::Ngram, 0.5));
    }

    #[test]
    fn selector_depth_hint_tracks_acceptance() {
        let sel = SourceSelector::new();
        assert_eq!(sel.depth_hint(SourceKind::Eagle), 2); // cold: minimum
        for _ in 0..16 {
            sel.observe(SourceKind::Eagle, 4.0);
        }
        assert_eq!(sel.depth_hint(SourceKind::Eagle), 5);
        for _ in 0..64 {
            sel.observe(SourceKind::Ngram, 40.0);
        }
        assert_eq!(sel.depth_hint(SourceKind::Ngram), 8); // clamped
    }
}
