//! Tree-policy configuration: the switch between the paper's static
//! draft tree and the dynamic planner, threaded through the engines, the
//! server/CLI config, and the eval harness.

use super::controller::ControllerConfig;
use super::planner::DynTreeParams;
use crate::spec::tree::TreeSpec;

/// User-facing dynamic-tree configuration. Executable-shape limits
/// (`verify_t`, `draft_w`, `accept_a`) are not known here; they are
/// applied by [`DynTreeConfig::params`] / [`DynTreeConfig::clamped_controller`]
/// at engine-construction time, so lowered shapes are always respected.
#[derive(Debug, Clone)]
pub struct DynTreeConfig {
    /// Initial draft depth (draft-step levels per round).
    pub depth: usize,
    /// Initial frontier width (nodes expanded per level).
    pub frontier_k: usize,
    /// Children considered per expanded node.
    pub branch: usize,
    /// Max nodes sent to verification excluding the root;
    /// `None` resolves to `verify_t - 1` (the full verify budget).
    pub budget: Option<usize>,
    /// Enable the per-request acceptance controller.
    pub adaptive: bool,
    pub controller: ControllerConfig,
}

impl Default for DynTreeConfig {
    fn default() -> Self {
        // Starts at the static 4/8/8/5 tree's depth with a slightly wider
        // frontier. The node budget defaults to the FULL verify width
        // (verify_t - 1); pass `budget: Some(n)` for equal-budget
        // comparisons against a static tree of n nodes.
        DynTreeConfig {
            depth: 4,
            frontier_k: 6,
            branch: 4,
            budget: None,
            adaptive: true,
            controller: ControllerConfig::default(),
        }
    }
}

impl DynTreeConfig {
    /// Resolve shape-dependent limits into concrete planner params:
    /// * kept tree fits the verify call: `budget <= verify_t - 1`;
    /// * the accepted chain replayed by the draft extend call fits:
    ///   `depth + 1 <= draft_w` and `depth + 1 <= accept_a`;
    /// * per-level step width fits: `frontier_k <= draft_w`.
    pub fn params(&self, verify_t: usize, draft_w: usize, accept_a: usize) -> DynTreeParams {
        let max_depth = draft_w.min(accept_a).saturating_sub(1).max(1);
        let verify_budget = verify_t.saturating_sub(1).max(1);
        let budget = self.budget.unwrap_or(verify_budget).clamp(1, verify_budget);
        DynTreeParams {
            depth: self.depth.clamp(1, max_depth),
            frontier_k: self.frontier_k.clamp(1, draft_w.max(1)),
            branch: self.branch.max(1),
            budget,
        }
    }

    /// Controller config with adaptation ceilings clamped to the same
    /// executable-shape limits as [`DynTreeConfig::params`].
    pub fn clamped_controller(&self, draft_w: usize, accept_a: usize) -> ControllerConfig {
        let mut c = self.controller.clone();
        let max_depth = draft_w.min(accept_a).saturating_sub(1).max(1);
        c.max_depth = c.max_depth.clamp(1, max_depth);
        c.min_depth = c.min_depth.clamp(1, c.max_depth);
        c.max_frontier = c.max_frontier.clamp(1, draft_w.max(1));
        c.min_frontier = c.min_frontier.clamp(1, c.max_frontier);
        c
    }
}

/// How an EAGLE engine shapes its draft tree each round.
#[derive(Debug, Clone)]
pub enum TreePolicy {
    /// Fixed per-level widths — the paper's 4/8/8/5 default or a chain.
    Static(TreeSpec),
    /// Confidence-driven expansion + global rerank, optionally with the
    /// adaptive per-request controller.
    Dynamic(DynTreeConfig),
}

impl TreePolicy {
    /// The paper's default static tree.
    pub fn default_tree() -> TreePolicy {
        TreePolicy::Static(TreeSpec::tree_default())
    }

    /// Classic-spec chain shape.
    pub fn chain(gamma: usize) -> TreePolicy {
        TreePolicy::Static(TreeSpec::chain(gamma))
    }

    /// Dynamic planning with default knobs (adaptive controller on).
    pub fn dynamic_default() -> TreePolicy {
        TreePolicy::Dynamic(DynTreeConfig::default())
    }

    pub fn is_dynamic(&self) -> bool {
        matches!(self, TreePolicy::Dynamic(_))
    }

    pub fn name(&self) -> &'static str {
        match self {
            TreePolicy::Static(_) => "static",
            TreePolicy::Dynamic(_) => "dynamic",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_respect_lowered_shapes() {
        let dc =
            DynTreeConfig { depth: 99, frontier_k: 99, budget: Some(999), ..Default::default() };
        let p = dc.params(32, 8, 8);
        assert_eq!(p.depth, 7, "depth + 1 must fit draft_w and accept_a");
        assert_eq!(p.frontier_k, 8);
        assert_eq!(p.budget, 31, "root + budget must fit verify_t");
    }

    #[test]
    fn default_budget_matches_verify_width() {
        let p = DynTreeConfig::default().params(26, 8, 8);
        assert_eq!(p.budget, 25); // same class as the static 4/8/8/5 tree
        assert_eq!(p.depth, 4);
    }

    #[test]
    fn clamped_controller_bounds() {
        let dc = DynTreeConfig::default();
        let c = dc.clamped_controller(4, 8);
        assert_eq!(c.max_depth, 3);
        assert!(c.min_depth <= c.max_depth);
        assert_eq!(c.max_frontier, 4);
    }

    #[test]
    fn policy_names() {
        assert_eq!(TreePolicy::default_tree().name(), "static");
        assert_eq!(TreePolicy::dynamic_default().name(), "dynamic");
        assert!(TreePolicy::dynamic_default().is_dynamic());
        assert!(!TreePolicy::chain(5).is_dynamic());
    }
}
