//! Verify-width selection (S21): pick the cheapest lowered `verify_t{t}`
//! executable that still fits the round's draft tree.
//!
//! Verify-step FLOPs scale linearly with the lowered tree width `t`, yet
//! a single `verify_t{tree_t}` executable pads every round to the worst
//! case. The AOT pipeline now lowers a small *family* of verify widths
//! (the `"verify_widths"` manifest constant, e.g. `[8, 16, 32]`, plus
//! their `_bs{b}` variants), and this module owns the per-round choice:
//!
//! * [`WidthFamily`] — the widths actually lowered for one (model, batch
//!   size), ascending; [`WidthFamily::fit`] returns the smallest member
//!   that holds a given node count (falling back to the largest).
//! * [`plan_round_width`] — the PRE-growth plan for the dynamic planner:
//!   caps the round's node budget to the width the controller's
//!   acceptance EWMA justifies (chronically low-acceptance requests drop
//!   to the cheapest, chain-like executable). The cap is applied *before*
//!   growth/sampling, so at T>0 no sampled sibling is ever dropped and
//!   the SpecInfer acceptance rule stays unbiased (see
//!   `rust/tests/prop_dyntree.rs`).
//! * [`WidthSelect`] — the user-facing override (`auto` | fixed `t`)
//!   threaded through the CLI (`--verify-width`), the server flag, and
//!   the per-request `"verify_width"` field.
//!
//! After growth the engines re-fit the *actual* tree size
//! (`family.fit(tree.len())`): shrinking padding never changes which
//! nodes are verified, so greedy outputs are identical to the fixed
//! `tree_t` path and T>0 sampling is untouched. The batched engine takes
//! the max over its lanes' fits so no lane is truncated below its
//! planned tree.

use super::controller::SpecController;
use super::planner::DynTreeParams;

/// The verify widths lowered for one (model, batch size), ascending and
/// deduplicated. Always non-empty.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WidthFamily {
    widths: Vec<usize>,
}

impl WidthFamily {
    /// Single-width family — the legacy fixed-`verify_t` behavior (also
    /// used by the chain engines and the `--verify-width N` override).
    pub fn single(t: usize) -> WidthFamily {
        WidthFamily { widths: vec![t.max(1)] }
    }

    /// Family from the manifest's declared widths, keeping only widths
    /// `<= max_t` for which `available` reports a lowered executable.
    /// `max_t` (the engine's configured `verify_t`) is always a member,
    /// so the family degrades to the legacy single width when the
    /// manifest declares nothing or the executables are missing.
    pub fn from_available(
        declared: &[usize],
        max_t: usize,
        available: impl Fn(usize) -> bool,
    ) -> WidthFamily {
        Self::filtered(declared, max_t, 2, available)
    }

    /// Same as [`WidthFamily::from_available`] but with an explicit
    /// minimum width. Verify families require `t >= 2` (root + one
    /// child); draft-step families (`"draft_widths"`, the lowered
    /// `step_w{w}` set) legitimately include `w = 1`.
    pub fn filtered(
        declared: &[usize],
        max_t: usize,
        min_t: usize,
        available: impl Fn(usize) -> bool,
    ) -> WidthFamily {
        let mut widths: Vec<usize> = declared
            .iter()
            .copied()
            .filter(|&t| t >= min_t.max(1) && t <= max_t && available(t))
            .collect();
        widths.push(max_t.max(1));
        widths.sort_unstable();
        widths.dedup();
        WidthFamily { widths }
    }

    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    pub fn min(&self) -> usize {
        self.widths[0]
    }

    pub fn max(&self) -> usize {
        *self.widths.last().unwrap()
    }

    pub fn is_single(&self) -> bool {
        self.widths.len() == 1
    }

    /// Smallest family width that holds `nodes` tree nodes (root
    /// included); the largest width when none fits — callers must still
    /// check `fit(n) >= n` before building verify inputs.
    pub fn fit(&self, nodes: usize) -> usize {
        for &t in &self.widths {
            if t >= nodes {
                return t;
            }
        }
        self.max()
    }
}

/// Pre-growth width plan for one dynamic round: returns the planned
/// width and the planner params with the node budget clamped to it.
///
/// The budget cap combines two value-independent signals:
/// * the planner's own growth ceiling (`depth * frontier_k * branch`
///   non-root nodes can ever be grown), and
/// * the controller's smoothed acceptance rate — once it collapses below
///   the controller's `low` threshold, the round is capped to the
///   cheapest executable in the family (the chain-like width).
///
/// Both caps shrink the budget BEFORE any candidate is scored or
/// sampled, which keeps T>0 growth lossless (no sampled sibling is
/// dropped after the fact; see the module doc).
pub fn plan_round_width(
    family: &WidthFamily,
    params: &DynTreeParams,
    rate_hint: Option<(f32, f32)>,
) -> (usize, DynTreeParams) {
    let growth = params
        .depth
        .saturating_mul(params.frontier_k)
        .saturating_mul(params.branch)
        .max(1);
    let mut budget = params.budget.min(growth).max(1);
    if let Some((rate, low)) = rate_hint {
        if rate <= low {
            budget = budget.min(family.min().saturating_sub(1).max(1));
        }
    }
    let t = family.fit(budget + 1);
    let clamped = DynTreeParams { budget: budget.min(t.saturating_sub(1).max(1)), ..*params };
    (t, clamped)
}

/// The controller's width hint: `(smoothed acceptance rate, low
/// threshold)`, available only once the EWMA has matured past warmup so
/// a cold request never gets prematurely downshifted. The threshold is
/// the controller's *effective* low — raised by the dwell band while the
/// request is already downshifted — so an EWMA oscillating around `low`
/// does not flap between `verify_t8` and `verify_t32` shapes (see
/// [`SpecController::effective_low`]).
pub fn width_hint(controller: Option<&SpecController>) -> Option<(f32, f32)> {
    let c = controller?;
    if c.rounds > c.cfg.warmup_rounds && c.has_rate() {
        Some((c.rate_ewma, c.effective_low()))
    } else {
        None
    }
}

/// User-facing verify-width override, threaded through the CLI
/// (`--verify-width auto|N`), the serve flag, and the per-request
/// `"verify_width"` field.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WidthSelect {
    /// Controller-driven selection over the lowered family (default).
    #[default]
    Auto,
    /// Pin every round to one width (must be lowered; a round whose tree
    /// exceeds it fails with a clear error instead of truncating).
    Fixed(usize),
}

impl WidthSelect {
    pub fn parse(s: &str) -> Option<WidthSelect> {
        match s {
            "auto" | "0" => Some(WidthSelect::Auto),
            _ => s.parse::<usize>().ok().filter(|&t| t >= 2).map(WidthSelect::Fixed),
        }
    }

    pub fn describe(&self) -> String {
        match self {
            WidthSelect::Auto => "auto".into(),
            WidthSelect::Fixed(t) => t.to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fam() -> WidthFamily {
        WidthFamily::from_available(&[8, 16, 32], 32, |_| true)
    }

    fn params(depth: usize, frontier_k: usize, branch: usize, budget: usize) -> DynTreeParams {
        DynTreeParams { depth, frontier_k, branch, budget }
    }

    #[test]
    fn family_filters_and_keeps_fallback() {
        let f = WidthFamily::from_available(&[8, 16, 32, 64], 32, |t| t != 16);
        assert_eq!(f.widths(), &[8, 32], "16 unavailable, 64 over max_t");
        let legacy = WidthFamily::from_available(&[], 26, |_| false);
        assert_eq!(legacy.widths(), &[26]);
        assert!(legacy.is_single());
    }

    #[test]
    fn filtered_allows_width_one_for_draft_families() {
        let f = WidthFamily::filtered(&[1, 4, 8], 8, 1, |_| true);
        assert_eq!(f.widths(), &[1, 4, 8]);
        assert_eq!(f.fit(1), 1);
        assert_eq!(f.fit(3), 4);
        let legacy = WidthFamily::filtered(&[], 8, 1, |_| false);
        assert_eq!(legacy.widths(), &[8], "degrades to the single max width");
    }

    #[test]
    fn fit_picks_smallest_holding_width() {
        let f = fam();
        assert_eq!(f.fit(1), 8);
        assert_eq!(f.fit(8), 8);
        assert_eq!(f.fit(9), 16);
        assert_eq!(f.fit(26), 32);
        assert_eq!(f.fit(40), 32, "falls back to max when nothing fits");
    }

    #[test]
    fn plan_full_budget_uses_max_width() {
        let (t, p) = plan_round_width(&fam(), &params(4, 6, 4, 31), None);
        assert_eq!(t, 32);
        assert_eq!(p.budget, 31);
    }

    #[test]
    fn plan_small_growth_downshifts() {
        // controller shrank to depth 1 / frontier 1: at most 4 nodes grow
        let (t, p) = plan_round_width(&fam(), &params(1, 1, 4, 31), None);
        assert_eq!(t, 8);
        assert_eq!(p.budget, 4);
        assert_eq!(p.depth, 1, "shape params pass through");
    }

    #[test]
    fn plan_low_acceptance_drops_to_cheapest() {
        let (t, p) = plan_round_width(&fam(), &params(4, 6, 4, 31), Some((0.1, 0.35)));
        assert_eq!(t, 8);
        assert_eq!(p.budget, 7, "capped to the cheapest width's budget");
        let (t2, p2) = plan_round_width(&fam(), &params(4, 6, 4, 31), Some((0.9, 0.35)));
        assert_eq!(t2, 32);
        assert_eq!(p2.budget, 31);
    }

    #[test]
    fn plan_never_grows_budget() {
        let (t, p) = plan_round_width(&fam(), &params(7, 8, 4, 13), None);
        assert_eq!(t, 16);
        assert_eq!(p.budget, 13);
    }

    #[test]
    fn width_select_parsing() {
        assert_eq!(WidthSelect::parse("auto"), Some(WidthSelect::Auto));
        assert_eq!(WidthSelect::parse("0"), Some(WidthSelect::Auto));
        assert_eq!(WidthSelect::parse("16"), Some(WidthSelect::Fixed(16)));
        assert_eq!(WidthSelect::parse("1"), None, "width 1 cannot hold a root+child");
        assert_eq!(WidthSelect::parse("nope"), None);
        assert_eq!(WidthSelect::Fixed(8).describe(), "8");
        assert_eq!(WidthSelect::default(), WidthSelect::Auto);
    }
}
