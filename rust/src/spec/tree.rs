//! Draft-tree data structure + mask/bias construction (S11).
//!
//! The rust coordinator owns tree topology: node bookkeeping, ancestor
//! closures, the additive attention biases fed to the verify and
//! draft-step executables, and the accepted-path extraction. All
//! invariants here are property-tested (`rust/tests/prop_tree.rs`).
//!
//! Hot-path construction writes into caller-provided buffers (the `_to`
//! / `_into` variants, fed by [`crate::spec::scratch::RoundScratch`]) so
//! the round loop stays allocation-free in steady state; the thin
//! allocating wrappers remain the public convenience API, and the
//! [`reference`] module keeps the original allocating implementations as
//! the oracle the property tests compare against
//! (`rust/tests/prop_scratch.rs`).

use crate::models::NEG;
use crate::spec::scratch::FeatArena;

/// Static tree shape: how many nodes are kept per level and how many
/// children are considered per expanded node. EAGLE's default draft tree
/// (depth-m via m draft passes, >m tokens) maps to `level_widths`.
#[derive(Debug, Clone)]
pub struct TreeSpec {
    pub level_widths: Vec<usize>,
    pub branch: usize,
}

impl TreeSpec {
    /// Default EAGLE-style tree: 25 draft nodes over 4 levels (+ root = 26).
    pub fn tree_default() -> TreeSpec {
        TreeSpec { level_widths: vec![4, 8, 8, 5], branch: 4 }
    }

    /// Chain drafting with `gamma` tokens (classic-spec shape).
    pub fn chain(gamma: usize) -> TreeSpec {
        TreeSpec { level_widths: vec![1; gamma], branch: 1 }
    }

    pub fn is_chain(&self) -> bool {
        self.level_widths.iter().all(|&w| w == 1)
    }

    pub fn total_nodes(&self) -> usize {
        1 + self.level_widths.iter().sum::<usize>()
    }

    pub fn depth(&self) -> usize {
        self.level_widths.len()
    }
}

#[derive(Debug, Clone)]
pub struct TreeNode {
    pub token: u32,
    /// Parent node index (root has none).
    pub parent: Option<usize>,
    /// Root = depth 0.
    pub depth: usize,
    /// Cumulative draft log-prob (selection score).
    pub score: f32,
    /// Row id into the round's q-slab ([`crate::spec::scratch::RoundScratch::qs`])
    /// holding the draft distribution this token was sampled from — kept
    /// at T>0 for the SpecInfer acceptance rule; `None` in greedy mode.
    /// A plain `Copy` id (not an `Rc<Vec<f32>>`), so sampled rounds stay
    /// allocation-free: siblings sampled from the same frontier node
    /// share one slab row.
    pub q: Option<u32>,
}

/// The draft tree under construction / verification. Node 0 is the root:
/// the last committed token, whose KV is not yet in the target cache.
#[derive(Debug, Clone, Default)]
pub struct DraftTree {
    pub nodes: Vec<TreeNode>,
}

impl DraftTree {
    pub fn with_root(token: u32) -> DraftTree {
        DraftTree {
            nodes: vec![TreeNode { token, parent: None, depth: 0, score: 0.0, q: None }],
        }
    }

    /// Reset to a fresh root-only tree, keeping the node buffer's
    /// capacity (the per-round reuse path — no allocation once warm).
    pub fn reset(&mut self, token: u32) {
        self.nodes.clear();
        self.nodes.push(TreeNode { token, parent: None, depth: 0, score: 0.0, q: None });
    }

    /// Capacity bytes held by the node buffer (feeds the engines'
    /// `round_host_alloc_bytes` accounting).
    pub fn capacity_bytes(&self) -> usize {
        self.nodes.capacity() * std::mem::size_of::<TreeNode>()
    }

    pub fn add(&mut self, parent: usize, token: u32, score: f32, q: Option<u32>) -> usize {
        assert!(parent < self.nodes.len(), "parent out of range");
        let depth = self.nodes[parent].depth + 1;
        self.nodes.push(TreeNode { token, parent: Some(parent), depth, score, q });
        self.nodes.len() - 1
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn children(&self, i: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.children_into(i, &mut out);
        out
    }

    /// [`DraftTree::children`] into a reused buffer (cleared first).
    pub fn children_into(&self, i: usize, out: &mut Vec<usize>) {
        out.clear();
        out.extend((0..self.nodes.len()).filter(|&j| self.nodes[j].parent == Some(i)));
    }

    /// Ancestor-or-self closure as a bitmask over node indices.
    pub fn ancestor_mask(&self, i: usize) -> Vec<bool> {
        let mut mask = vec![false; self.nodes.len()];
        let mut cur = Some(i);
        while let Some(c) = cur {
            mask[c] = true;
            cur = self.nodes[c].parent;
        }
        mask
    }

    /// Ancestor-or-self closure as `u64` bitset words (bit `j` of word
    /// `j / 64` set iff node `j` is in the closure). O(depth) to build,
    /// O(n/64) to scan — the hot-path form of [`DraftTree::ancestor_mask`].
    pub fn ancestor_bits_into(&self, i: usize, words: &mut Vec<u64>) {
        words.clear();
        words.resize(self.nodes.len().div_ceil(64), 0);
        let mut cur = Some(i);
        while let Some(c) = cur {
            words[c / 64] |= 1u64 << (c % 64);
            cur = self.nodes[c].parent;
        }
    }

    /// Root-to-node path (inclusive).
    pub fn path(&self, i: usize) -> Vec<usize> {
        let mut p = Vec::new();
        let mut cur = Some(i);
        while let Some(c) = cur {
            p.push(c);
            cur = self.nodes[c].parent;
        }
        p.reverse();
        p
    }

    /// Verify-call inputs: (tokens[t_pad], pos[t_pad], bias[t_pad * s]).
    /// Tree node i sits at cache slot `cache_len + i` and RoPE position
    /// `cache_len + depth(i)`; it attends the committed prefix plus its
    /// ancestor closure. Padding rows self-attend only (outputs ignored).
    ///
    /// Thin allocating wrapper over [`DraftTree::verify_inputs_to`]; the
    /// original implementation survives as [`reference::verify_inputs_ref`]
    /// for the equivalence property tests.
    pub fn verify_inputs(
        &self,
        t_pad: usize,
        cache_len: usize,
        s: usize,
    ) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let mut tokens = vec![0i32; t_pad];
        let mut pos = vec![0i32; t_pad];
        let mut bias = vec![0f32; t_pad * s];
        let mut anc = Vec::new();
        self.verify_inputs_to(t_pad, cache_len, s, &mut tokens, &mut pos, &mut bias, &mut anc);
        (tokens, pos, bias)
    }

    /// [`DraftTree::verify_inputs`] into caller-provided exact-size
    /// slices (`tokens`/`pos` of `t_pad`, `bias` of `t_pad * s`) plus a
    /// reused ancestor-bitset buffer. Every cell of every row is written,
    /// so stale buffer contents never leak; the batched engine points the
    /// slices at per-lane blocks of its `[B, t, ..]` staging buffers.
    #[allow(clippy::too_many_arguments)]
    pub fn verify_inputs_to(
        &self,
        t_pad: usize,
        cache_len: usize,
        s: usize,
        tokens: &mut [i32],
        pos: &mut [i32],
        bias: &mut [f32],
        anc: &mut Vec<u64>,
    ) {
        let n = self.nodes.len();
        assert!(n <= t_pad, "tree of {n} nodes exceeds verify width {t_pad}");
        assert!(cache_len + t_pad < s, "tree region overflows cache");
        assert!(tokens.len() == t_pad && pos.len() == t_pad && bias.len() == t_pad * s);
        for i in 0..t_pad {
            let row = &mut bias[i * s..(i + 1) * s];
            if i < n {
                tokens[i] = self.nodes[i].token as i32;
                pos[i] = (cache_len + self.nodes[i].depth) as i32;
                row[..cache_len].fill(0.0);
                row[cache_len..].fill(NEG);
                self.ancestor_bits_into(i, anc);
                for (wi, &word) in anc.iter().enumerate() {
                    let mut w = word;
                    while w != 0 {
                        let j = wi * 64 + w.trailing_zeros() as usize;
                        row[cache_len + j] = 0.0;
                        w &= w - 1;
                    }
                }
            } else {
                tokens[i] = 0;
                pos[i] = (cache_len + 1) as i32;
                row.fill(NEG);
                row[cache_len + i] = 0.0; // self only, avoids NaN rows
            }
        }
    }

    /// Greedy acceptance walk: at each node take the child whose token is
    /// the target argmax; stop when none matches. Returns (path node
    /// indices incl. root, per-depth (hit, tried) chain stats).
    pub fn greedy_walk(&self, argmax_at: impl Fn(usize) -> usize) -> Vec<usize> {
        let mut path = Vec::new();
        self.greedy_walk_into(argmax_at, &mut path);
        path
    }

    /// [`DraftTree::greedy_walk`] into a reused path buffer (cleared
    /// first) — no child-list or path allocation in steady state.
    pub fn greedy_walk_into(&self, argmax_at: impl Fn(usize) -> usize, path: &mut Vec<usize>) {
        path.clear();
        path.push(0);
        let mut cur = 0usize;
        loop {
            let want = argmax_at(cur);
            let next = (0..self.nodes.len()).find(|&c| {
                self.nodes[c].parent == Some(cur) && self.nodes[c].token as usize == want
            });
            match next {
                Some(c) => {
                    path.push(c);
                    cur = c;
                }
                None => return,
            }
        }
    }
}

/// Fill one lane's draft-step rows for a chunk of freshly added tree
/// nodes, writing the bias directly into a caller-provided `w * s`
/// block: feature pairing (parent's step output from the [`FeatArena`]),
/// token pairing (shifted: the node's own token; unshifted: the
/// parent's), pair-slot positions, scratch-slot assignment into
/// `node_slot`, and the ancestor-closure attention bias. Rows beyond the
/// chunk are padded in place (position `m`, self-attending bias). Every
/// cell of `bias` is written, so dirty reuse is safe.
///
/// This is the single row-marshalling path shared by
/// `EagleEngine::grow_tree{,_dynamic}` and
/// `BatchEagleEngine::grow_{static,dynamic}_batch` — the batched callers
/// pass per-lane sub-slices of their `[B, w, ..]` buffers. The
/// allocating [`fill_step_rows`] is kept as the reference implementation
/// the property tests compare against.
#[allow(clippy::too_many_arguments)]
pub fn fill_step_rows_into(
    tree: &DraftTree,
    chunk: &[usize],
    feat: &FeatArena,
    node_slot: &mut [Option<usize>],
    shifted: bool,
    d: usize,
    s: usize,
    m: usize,
    chain_len: usize,
    write_base: usize,
    w: usize,
    feats: &mut [f32],
    toks: &mut [i32],
    pos: &mut [i32],
    bias: &mut [f32],
) {
    debug_assert!(chunk.len() <= w);
    debug_assert!(feats.len() >= w * d && toks.len() >= w && pos.len() >= w);
    debug_assert!(bias.len() >= w * s);
    for (r, &ni) in chunk.iter().enumerate() {
        let parent = tree.nodes[ni].parent.expect("stepped node must have a parent");
        // feature pairing: the parent's step output (see engine module doc)
        feats[r * d..(r + 1) * d].copy_from_slice(feat.get(parent));
        toks[r] =
            if shifted { tree.nodes[ni].token as i32 } else { tree.nodes[parent].token as i32 };
        // pair slot position: node position - 1 = m + depth - 1
        pos[r] = (m + tree.nodes[ni].depth - 1) as i32;
        node_slot[ni] = Some(write_base + r);
        // bias row: committed prefix + ancestors' scratch slots + self
        // (the root pair is in the committed region, so it has no slot)
        let row = &mut bias[r * s..(r + 1) * s];
        row[..chain_len].fill(0.0);
        row[chain_len..].fill(NEG);
        let mut cur = Some(parent);
        while let Some(c) = cur {
            if let Some(slot) = node_slot[c] {
                row[slot] = 0.0;
            }
            cur = tree.nodes[c].parent;
        }
        row[write_base + r] = 0.0; // self
    }
    for r in chunk.len()..w {
        feats[r * d..(r + 1) * d].fill(0.0);
        toks[r] = 0;
        pos[r] = m as i32;
        let row = &mut bias[r * s..(r + 1) * s];
        row.fill(NEG);
        row[write_base + r] = 0.0; // self only
    }
}

/// Reference (allocating) form of [`fill_step_rows_into`]: same row
/// marshalling, but the bias block is freshly allocated and returned and
/// node features arrive as `Vec<Vec<f32>>`. Retained as the oracle for
/// the arena-path property tests (`rust/tests/prop_scratch.rs`).
#[allow(clippy::too_many_arguments)]
pub fn fill_step_rows(
    tree: &DraftTree,
    chunk: &[usize],
    node_feat: &[Vec<f32>],
    node_slot: &mut [Option<usize>],
    shifted: bool,
    d: usize,
    s: usize,
    m: usize,
    chain_len: usize,
    write_base: usize,
    w: usize,
    feats: &mut [f32],
    toks: &mut [i32],
    pos: &mut [i32],
) -> Vec<f32> {
    debug_assert!(chunk.len() <= w);
    debug_assert!(feats.len() >= w * d && toks.len() >= w && pos.len() >= w);
    let mut anc: Vec<Vec<usize>> = Vec::with_capacity(chunk.len());
    for (r, &ni) in chunk.iter().enumerate() {
        let parent = tree.nodes[ni].parent.expect("stepped node must have a parent");
        // feature pairing: the parent's step output (see engine module doc)
        feats[r * d..(r + 1) * d].copy_from_slice(&node_feat[parent]);
        toks[r] =
            if shifted { tree.nodes[ni].token as i32 } else { tree.nodes[parent].token as i32 };
        // pair slot position: node position - 1 = m + depth - 1
        pos[r] = (m + tree.nodes[ni].depth - 1) as i32;
        node_slot[ni] = Some(write_base + r);
        // ancestors' scratch slots (the root pair is in the committed region)
        let mut a = Vec::new();
        let mut cur = Some(parent);
        while let Some(c) = cur {
            if let Some(slot) = node_slot[c] {
                a.push(slot);
            }
            cur = tree.nodes[c].parent;
        }
        anc.push(a);
    }
    for r in chunk.len()..w {
        pos[r] = m as i32;
    }
    draft_step_bias(w, s, chain_len, write_base, &anc)
}

/// Bias rows for a draft `step` call over `w` frontier slots.
///
/// Frontier entry r describes a node written to draft-cache slot
/// `write_base + r`; it attends the committed draft prefix
/// `[0, chain_len)` plus the scratch slots of its draft-tree ancestors
/// (`anc_slots[r]`) plus itself. Unused rows self-attend only.
pub fn draft_step_bias(
    w: usize,
    s: usize,
    chain_len: usize,
    write_base: usize,
    anc_slots: &[Vec<usize>],
) -> Vec<f32> {
    let mut bias = vec![NEG; w * s];
    for r in 0..w {
        let row = &mut bias[r * s..(r + 1) * s];
        if r < anc_slots.len() {
            for cell in row.iter_mut().take(chain_len) {
                *cell = 0.0;
            }
            for &slot in &anc_slots[r] {
                row[slot] = 0.0;
            }
        }
        row[write_base + r] = 0.0; // self
    }
    bias
}

/// Chain-extension bias: rows r=0..n over pairs written at
/// [write_base, write_base+n); row r attends [0, write_base + r].
/// Thin allocating wrapper over [`chain_extend_bias_to`].
pub fn chain_extend_bias(w: usize, s: usize, write_base: usize, n: usize) -> Vec<f32> {
    let mut bias = vec![0f32; w * s];
    chain_extend_bias_to(w, s, write_base, n, &mut bias);
    bias
}

/// [`chain_extend_bias`] into a caller-provided `w * s` block (every
/// cell written, so dirty reuse is safe); the batched engine points this
/// at per-lane sub-slices of its extend staging buffer.
pub fn chain_extend_bias_to(w: usize, s: usize, write_base: usize, n: usize, bias: &mut [f32]) {
    debug_assert!(bias.len() >= w * s);
    for r in 0..w {
        let row = &mut bias[r * s..(r + 1) * s];
        let upto = if r < n { write_base + r } else { write_base + r.min(n.saturating_sub(1)) };
        let end = (upto + 1).min(s);
        row[..end].fill(0.0);
        row[end..].fill(NEG);
    }
}

/// Original allocating implementations, kept verbatim as the oracle the
/// zero-allocation paths are property-tested against
/// (`rust/tests/prop_scratch.rs`). Not used by the engines.
pub mod reference {
    use super::{DraftTree, NEG};

    /// Original [`DraftTree::verify_inputs`] (bool-mask ancestor walk,
    /// fresh buffers every call).
    pub fn verify_inputs_ref(
        tree: &DraftTree,
        t_pad: usize,
        cache_len: usize,
        s: usize,
    ) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let n = tree.nodes.len();
        assert!(n <= t_pad, "tree of {n} nodes exceeds verify width {t_pad}");
        assert!(cache_len + t_pad < s, "tree region overflows cache");
        let mut tokens = vec![0i32; t_pad];
        let mut pos = vec![0i32; t_pad];
        let mut bias = vec![NEG; t_pad * s];
        for i in 0..t_pad {
            if i < n {
                tokens[i] = tree.nodes[i].token as i32;
                pos[i] = (cache_len + tree.nodes[i].depth) as i32;
                let row = &mut bias[i * s..(i + 1) * s];
                for cell in row.iter_mut().take(cache_len) {
                    *cell = 0.0;
                }
                let anc = tree.ancestor_mask(i);
                for (j, &a) in anc.iter().enumerate() {
                    if a {
                        row[cache_len + j] = 0.0;
                    }
                }
            } else {
                pos[i] = (cache_len + 1) as i32;
                bias[i * s + cache_len + i] = 0.0; // self only, avoids NaN rows
            }
        }
        (tokens, pos, bias)
    }

    /// Original [`super::chain_extend_bias`].
    pub fn chain_extend_bias_ref(w: usize, s: usize, write_base: usize, n: usize) -> Vec<f32> {
        let mut bias = vec![NEG; w * s];
        for r in 0..w {
            let row = &mut bias[r * s..(r + 1) * s];
            let upto = if r < n { write_base + r } else { write_base + r.min(n.saturating_sub(1)) };
            for cell in row.iter_mut().take(upto + 1) {
                *cell = 0.0;
            }
        }
        bias
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_tree() -> DraftTree {
        // root(10) -> a(1), b(2); a -> c(3); b -> d(4), e(5)
        let mut t = DraftTree::with_root(10);
        let a = t.add(0, 1, -0.1, None);
        let b = t.add(0, 2, -0.5, None);
        t.add(a, 3, -0.3, None);
        t.add(b, 4, -0.9, None);
        t.add(b, 5, -1.0, None);
        t
    }

    #[test]
    fn depths_and_paths() {
        let t = sample_tree();
        assert_eq!(t.nodes[3].depth, 2);
        assert_eq!(t.path(3), vec![0, 1, 3]);
        assert_eq!(t.path(0), vec![0]);
        assert_eq!(t.children(2), vec![4, 5]);
    }

    #[test]
    fn ancestor_closure() {
        let t = sample_tree();
        let m = t.ancestor_mask(4);
        assert_eq!(m, vec![true, false, true, false, true, false]);
    }

    #[test]
    fn verify_inputs_bias_semantics() {
        let t = sample_tree();
        let (tokens, pos, bias) = t.verify_inputs(8, 5, 20);
        assert_eq!(tokens[0], 10);
        assert_eq!(pos[0], 5);
        assert_eq!(pos[3], 7); // depth 2
        let s = 20;
        // node 3 (c) attends prefix 0..5, root slot 5, a slot 6, self 8
        let row = &bias[3 * s..4 * s];
        for j in 0..5 {
            assert_eq!(row[j], 0.0);
        }
        assert_eq!(row[5], 0.0);
        assert_eq!(row[5 + 1], 0.0);
        assert_eq!(row[5 + 3], 0.0);
        assert_eq!(row[5 + 2], NEG); // b is not an ancestor
        // padding row 7 self-attends only
        let prow = &bias[7 * s..8 * s];
        assert_eq!(prow[5 + 7], 0.0);
        assert_eq!(prow.iter().filter(|&&x| x == 0.0).count(), 1);
    }

    #[test]
    #[should_panic(expected = "overflows")]
    fn verify_inputs_bounds_checked() {
        let t = sample_tree();
        t.verify_inputs(8, 14, 20);
    }

    #[test]
    fn greedy_walk_follows_argmax() {
        let t = sample_tree();
        // argmax at root = 2 (-> b), at b = 5 (-> e), at e = 99 (stop)
        let path = t.greedy_walk(|i| match i {
            0 => 2,
            2 => 5,
            _ => 99,
        });
        assert_eq!(path, vec![0, 2, 5]);
    }

    #[test]
    fn chain_spec_shape() {
        let c = TreeSpec::chain(5);
        assert!(c.is_chain());
        assert_eq!(c.total_nodes(), 6);
        let t = TreeSpec::tree_default();
        assert_eq!(t.total_nodes(), 26);
        assert!(!t.is_chain());
    }

    #[test]
    fn fill_step_rows_marshals_one_lane() {
        let t = sample_tree();
        let d = 2;
        let (s, m, w) = (32usize, 6usize, 4usize);
        // parent features: root + both depth-1 nodes have step outputs
        let node_feat: Vec<Vec<f32>> = (0..t.len()).map(|i| vec![i as f32; d]).collect();
        let mut node_slot: Vec<Option<usize>> = vec![None; t.len()];
        node_slot[1] = Some(8); // node a already stepped at scratch slot 8
        let chunk = [3usize, 4]; // c (child of a), d (child of b)
        let mut feats = vec![0f32; w * d];
        let mut toks = vec![0i32; w];
        let mut pos = vec![0i32; w];
        let bias = fill_step_rows(
            &t, &chunk, &node_feat, &mut node_slot, true, d, s, m, m, 10, w,
            &mut feats, &mut toks, &mut pos,
        );
        // row 0 = node c: parent a's feature, own token (shifted), pos m+1
        assert_eq!(&feats[0..d], &[1.0, 1.0]);
        assert_eq!(toks[0], 3);
        assert_eq!(pos[0], (m + 1) as i32);
        assert_eq!(node_slot[3], Some(10));
        assert_eq!(node_slot[4], Some(11));
        // padded rows sit at m
        assert_eq!(pos[2], m as i32);
        assert_eq!(pos[3], m as i32);
        // row 0 bias: prefix [0, m), ancestor a's slot 8, self slot 10
        let row0 = &bias[0..s];
        for cell in row0.iter().take(m) {
            assert_eq!(*cell, 0.0);
        }
        assert_eq!(row0[8], 0.0);
        assert_eq!(row0[10], 0.0);
        assert_eq!(row0[9], NEG);
        // row 1 = node d: parent b never stepped -> no scratch ancestors
        let row1 = &bias[s..2 * s];
        assert_eq!(row1[11], 0.0);
        assert_eq!(row1[8], NEG);
        // unshifted pairing takes the parent's token
        let mut node_slot2: Vec<Option<usize>> = vec![None; t.len()];
        fill_step_rows(
            &t, &chunk, &node_feat, &mut node_slot2, false, d, s, m, m, 10, w,
            &mut feats, &mut toks, &mut pos,
        );
        assert_eq!(toks[0], 1, "unshifted: parent a's token");
    }

    #[test]
    fn draft_step_bias_rows() {
        let anc = vec![vec![10usize], vec![]];
        let bias = draft_step_bias(4, 16, 8, 11, &anc);
        let row0 = &bias[0..16];
        for j in 0..8 {
            assert_eq!(row0[j], 0.0);
        }
        assert_eq!(row0[10], 0.0);
        assert_eq!(row0[11], 0.0); // self
        assert_eq!(row0[9], NEG);
        // unused row 3: self only
        let row3 = &bias[3 * 16..4 * 16];
        assert_eq!(row3.iter().filter(|&&x| x == 0.0).count(), 1);
        assert_eq!(row3[14], 0.0);
    }
}
