//! Experiment runner (S18): dispatches (model, method, temperature) over a
//! prompt set and aggregates metrics. The single entry point behind both
//! the `repro eval` CLI and the bench harness, so paper tables and
//! criterion-style benches measure exactly the same code path.

use anyhow::Result;
use std::rc::Rc;

use super::workload::Prompt;
use crate::baselines::{ClassicSpecEngine, LookaheadEngine, MedusaEngine, VanillaEngine};
use crate::coordinator::request::Method;
use crate::metrics::{Aggregate, GenRecord};
use crate::models::ModelBundle;
use crate::runtime::{Manifest, Runtime};
use crate::spec::dyntree::{TreePolicy, WidthFamily, WidthSelect};
use crate::spec::engine::{EagleEngine, GenConfig, PairShift};
use crate::util::deadline::DeadlineClock;

pub struct Runner {
    pub rt: Rc<Runtime>,
    pub man: Manifest,
}

#[derive(Debug, Clone)]
pub struct RunSpec {
    pub method: Method,
    pub temperature: f32,
    pub max_new: usize,
    /// draft head variant for eagle-family methods
    pub variant: String,
    pub gamma: usize,
    pub seed: u64,
    /// draft-tree policy for `Method::Eagle` (chain methods fix their own
    /// shape); defaults to the paper's static 4/8/8/5 tree
    pub tree: TreePolicy,
    /// verify-width policy for `Method::Eagle`: `Auto` dispatches each
    /// round to the cheapest lowered `verify_t{t}` executable that holds
    /// its tree; `Fixed(t)` pins every round to one width
    pub verify_width: WidthSelect,
    /// wall-clock deadline for eagle-family runs: an expired clock stops
    /// the round loop and returns the partial record with
    /// `truncated = Some("deadline")`. Unbounded by default; the serving
    /// bs=1 path threads each request's deadline through here
    pub deadline: DeadlineClock,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec {
            method: Method::Eagle,
            temperature: 0.0,
            max_new: 48,
            variant: "eagle".into(),
            gamma: 5,
            seed: 7,
            tree: TreePolicy::default_tree(),
            verify_width: WidthSelect::Auto,
            deadline: DeadlineClock::unbounded(),
        }
    }
}

impl Runner {
    pub fn new(artifacts: &std::path::Path) -> Result<Runner> {
        let rt = Runtime::cpu()?;
        let man = Manifest::load(artifacts)?;
        Ok(Runner { rt, man })
    }

    /// Run `spec` over `prompts` with a pre-loaded bundle.
    pub fn run_with(
        &self,
        bundle: &ModelBundle,
        prompts: &[&Prompt],
        spec: &RunSpec,
    ) -> Result<Aggregate> {
        let mut agg = Aggregate::new();
        let cfg = GenConfig {
            max_new: spec.max_new,
            temperature: spec.temperature,
            seed: spec.seed,
            eos: None,
        };
        for (i, p) in prompts.iter().enumerate() {
            let cfg = GenConfig { seed: spec.seed + i as u64, ..cfg.clone() };
            let rec = self.run_one(bundle, &p.ids, spec, &cfg)?;
            agg.add(&rec);
        }
        Ok(agg)
    }

    pub fn run_one(
        &self,
        bundle: &ModelBundle,
        prompt: &[u32],
        spec: &RunSpec,
        cfg: &GenConfig,
    ) -> Result<GenRecord> {
        self.run_one_observed(bundle, prompt, spec, cfg, None)
    }

    /// [`Runner::run_one`] with an optional per-round observer attached
    /// to the eagle-family engines (the server's bs=1 path threads its
    /// flight recorder + metrics registry through here; baselines have
    /// no speculation rounds to report).
    pub fn run_one_observed(
        &self,
        bundle: &ModelBundle,
        prompt: &[u32],
        spec: &RunSpec,
        cfg: &GenConfig,
        observer: Option<&dyn crate::metrics::trace::RoundObserver>,
    ) -> Result<GenRecord> {
        let c = &self.man.constants;
        match spec.method {
            Method::Vanilla => VanillaEngine::new(&bundle.target).generate(prompt, cfg),
            Method::Eagle => {
                let draft = bundle
                    .drafts
                    .get(&spec.variant)
                    .ok_or_else(|| anyhow::anyhow!("draft variant '{}' not loaded", spec.variant))?;
                let mut eng = EagleEngine::new_tree(&bundle.target, draft, c)
                    .with_policy(spec.tree.clone())
                    .with_deadline(spec.deadline);
                if let WidthSelect::Fixed(t) = spec.verify_width {
                    anyhow::ensure!(
                        bundle.target.has_verify(t, 1),
                        "verify width {t} is not lowered for '{}' (declared family: {:?})",
                        bundle.name,
                        c.verify_widths
                    );
                    eng = eng.with_widths(WidthFamily::single(t));
                }
                if let Some(obs) = observer {
                    eng = eng.with_observer(obs);
                }
                eng.generate(prompt, cfg)
            }
            Method::EagleChain => {
                let draft = bundle
                    .drafts
                    .get(&spec.variant)
                    .ok_or_else(|| anyhow::anyhow!("draft variant '{}' not loaded", spec.variant))?;
                let shift = if spec.variant == "eagle" || spec.variant == "eagle_gen" {
                    PairShift::Shifted
                } else {
                    PairShift::Unshifted
                };
                let mut eng = EagleEngine::new_chain(&bundle.target, draft, c, spec.gamma, shift)
                    .with_deadline(spec.deadline);
                if let Some(obs) = observer {
                    eng = eng.with_observer(obs);
                }
                eng.generate(prompt, cfg)
            }
            Method::Medusa => {
                let heads = bundle
                    .medusa
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("medusa heads not loaded for {}", bundle.name))?;
                MedusaEngine::new(&bundle.target, heads, c).generate(prompt, cfg)
            }
            Method::Lookahead => LookaheadEngine::new(&bundle.target, c).generate(prompt, cfg),
            Method::ClassicSpec => {
                let tdlm = bundle
                    .tdlm
                    .as_ref()
                    .ok_or_else(|| {
                        anyhow::anyhow!("token draft LM not loaded for {}", bundle.name)
                    })?;
                ClassicSpecEngine::new(&bundle.target, tdlm, c, spec.gamma).generate(prompt, cfg)
            }
        }
    }
}

/// Speedup of `a` vs baseline `b` on identical prompt sets (walltime per
/// generated token, the paper's metric).
pub fn speedup(a: &Aggregate, baseline: &Aggregate) -> f64 {
    let a_tps = a.tokens_per_sec();
    let b_tps = baseline.tokens_per_sec();
    if b_tps <= 0.0 {
        return 0.0;
    }
    a_tps / b_tps
}
