//! Evaluation harness (S18): workloads, the experiment runner, the
//! paper-table generators (DESIGN.md §4 experiment index), and the
//! bench support behind `repro bench --json` (S23).

pub mod bench;
pub mod loadgen;
pub mod runner;
pub mod tables;
pub mod workload;

pub use runner::{speedup, RunSpec, Runner};
pub use tables::EvalCtx;
pub use workload::{Prompt, Workload};
