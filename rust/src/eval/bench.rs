//! Host-path micro-bench support (S23), shared by the
//! `rust/benches/hot_path.rs` harness and the `repro bench --json` CLI:
//! median timing, the arena-vs-reference round simulations behind the
//! `host/round_scratch` / `host/round_ref` pair, and the
//! `BENCH_host.json` emitter. The emitted file doubles as a
//! `--cost-model` calibration input — when the exe benches ran, the
//! `exe/verify_t{t}` curve is fit into a `cost_model` stanza
//! (see [`crate::coordinator::CostModel`]).

use anyhow::Result;
use std::time::Instant;

use crate::coordinator::CostModel;
use crate::eval::runner::Runner;
use crate::models::ModelBundle;
use crate::spec::sampling::{sample, softmax_into};
use crate::spec::scratch::RoundScratch;
use crate::spec::tree::{self, DraftTree, TreeSpec};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One measured bench point.
pub struct BenchResult {
    pub name: String,
    pub median_ms: f64,
    pub iters: usize,
}

/// Median wall-time of `f` in milliseconds over `iters` runs (after a
/// short warm-up) — the same estimator `hot_path.rs` prints. `iters` is
/// clamped to at least 1 (an empty sample has no median).
pub fn median_ms<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let iters = iters.max(1);
    for _ in 0..iters.min(3) {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Simulation shape: feature dim, cache length, committed boundary, and
/// the draft-step width used by the round sims.
pub const SIM_D: usize = 64;
pub const SIM_S: usize = 192;
pub const SIM_M: usize = 40;
pub const SIM_W: usize = 8;

/// The paper's default 26-node draft tree (chain-ish fill, as in
/// `hot_path.rs`) — the tree both round sims run on.
pub fn default_bench_tree() -> DraftTree {
    let mut tree = DraftTree::with_root(1);
    let spec = TreeSpec::tree_default();
    let mut parent = 0;
    for (d, &w) in spec.level_widths.iter().enumerate() {
        for i in 0..w {
            let p = if d == 0 { 0 } else { parent };
            tree.add(p, (d * 10 + i) as u32, 0.0, None);
        }
        parent = tree.len() - 1;
    }
    tree
}

/// One round of host-side bookkeeping on the ALLOCATING reference path:
/// per-node feature `Vec`s, fresh verify-input buffers, fresh step-row
/// staging (bias returned by value), and the acceptance-walk child
/// scans — what the engines did before the S22 scratch subsystem.
/// Returns a checksum equal to [`sim_round_scratch`]'s (property-tested
/// in `rust/tests/prop_scratch.rs`).
pub fn sim_round_ref(tree: &DraftTree) -> usize {
    let (d, s, m, w) = (SIM_D, SIM_S, SIM_M, SIM_W);
    let node_feat: Vec<Vec<f32>> = (0..tree.len()).map(|i| vec![i as f32; d]).collect();
    let mut node_slot: Vec<Option<usize>> = vec![None; tree.len()];
    let (tokens, _pos, vbias) = tree::reference::verify_inputs_ref(tree, 32, m, s);
    let chunk: Vec<usize> = (1..tree.len().min(1 + w)).collect();
    let mut sf = vec![0f32; w * d];
    let mut st = vec![0i32; w];
    let mut sp = vec![0i32; w];
    let sbias = tree::fill_step_rows(
        tree, &chunk, &node_feat, &mut node_slot, true, d, s, m, m, m + 2, w, &mut sf, &mut st,
        &mut sp,
    );
    let mut acc = tokens.iter().map(|&t| t as usize).sum::<usize>();
    let mut cur = 0usize;
    loop {
        let ch = tree.children(cur);
        acc += ch.len();
        match ch.first() {
            Some(&c) => cur = c,
            None => break,
        }
    }
    acc + zeros(&vbias) + zeros(&sbias)
}

/// The same round of host-side bookkeeping on the S22 scratch path:
/// arena repopulation, `verify_inputs_to`, `fill_step_rows_into`, and
/// `children_into` — all on reused buffers. Zero heap allocation once
/// `scratch` is warm.
pub fn sim_round_scratch(tree: &DraftTree, s: &mut RoundScratch) -> usize {
    let (d, s_tot, m, w) = (SIM_D, SIM_S, SIM_M, SIM_W);
    s.feat.clear(d);
    for i in 0..tree.len() {
        s.probs.clear();
        s.probs.resize(d, i as f32);
        s.feat.push(&s.probs);
    }
    s.node_slot.clear();
    s.node_slot.resize(tree.len(), None);
    s.vtokens.clear();
    s.vtokens.resize(32, 0);
    s.vpos.clear();
    s.vpos.resize(32, 0);
    s.vbias.clear();
    s.vbias.resize(32 * s_tot, 0.0);
    tree.verify_inputs_to(32, m, s_tot, &mut s.vtokens, &mut s.vpos, &mut s.vbias, &mut s.anc);
    s.new_nodes.clear();
    s.new_nodes.extend(1..tree.len().min(1 + w));
    s.sf.clear();
    s.sf.resize(w * d, 0.0);
    s.st.clear();
    s.st.resize(w, 0);
    s.sp.clear();
    s.sp.resize(w, 0);
    s.sbias.clear();
    s.sbias.resize(w * s_tot, 0.0);
    tree::fill_step_rows_into(
        tree,
        &s.new_nodes,
        &s.feat,
        &mut s.node_slot,
        true,
        d,
        s_tot,
        m,
        m,
        m + 2,
        w,
        &mut s.sf,
        &mut s.st,
        &mut s.sp,
        &mut s.sbias,
    );
    let mut acc = s.vtokens.iter().map(|&t| t as usize).sum::<usize>();
    let mut cur = 0usize;
    loop {
        tree.children_into(cur, &mut s.children);
        acc += s.children.len();
        match s.children.first() {
            Some(&c) => cur = c,
            None => break,
        }
    }
    acc + zeros(&s.vbias) + zeros(&s.sbias)
}

fn zeros(xs: &[f32]) -> usize {
    xs.iter().filter(|&&x| x == 0.0).count()
}

/// One lane-round of SLAB-based sampled (T>0) growth, mirroring the
/// engines' static T>0 branch draw-for-draw: per level, each frontier
/// node's q goes into the scratch's q-slab (one row, shared by its
/// sampled siblings via the stored row id) and `per` children are drawn
/// i.i.d. from it on `rng`. All nodes share one draft logits row — the
/// distribution under test. The single simulation shared by the T>0
/// property tests (`rust/tests/prop_batch_t1.rs`, where it is checked
/// bit-for-bit against the pre-slab `Rc<Vec<f32>>` reference) and the
/// allocator-level checks (`rust/tests/count_alloc.rs`), so the test
/// sims cannot drift from each other when the engines' draw sequence
/// changes.
pub fn sim_sampled_grow(
    tree: &mut DraftTree,
    s: &mut RoundScratch,
    draft_logits: &[f32],
    temp: f32,
    levels: &[usize],
    rng: &mut Rng,
) {
    tree.reset(0);
    s.begin_round(&[0.0], draft_logits);
    s.frontier.clear();
    s.frontier.push(0);
    for &width in levels {
        s.cands.clear();
        let per = (width / s.frontier.len().max(1)).max(1);
        for &parent in &s.frontier {
            softmax_into(draft_logits, temp, &mut s.probs);
            let qid = s.qs.push(&s.probs) as u32;
            for _ in 0..per {
                if s.cands.len() >= width {
                    break;
                }
                let tok = sample(s.qs.get(qid as usize), rng) as u32;
                s.cands.push((parent, tok, 0.0, Some(qid)));
            }
        }
        if s.cands.is_empty() {
            break;
        }
        s.new_nodes.clear();
        for (p, tok, score, q) in s.cands.drain(..) {
            let ni = tree.add(p, tok, score, q);
            s.new_nodes.push(ni);
        }
        std::mem::swap(&mut s.frontier, &mut s.new_nodes);
    }
}

/// A warm scratch sized for the round sims.
pub fn sim_scratch() -> RoundScratch {
    let mut s = RoundScratch::new(SIM_D, 16);
    s.reserve(SIM_D, 16, SIM_S, 64, 32, SIM_W);
    s
}

/// The host-only suite behind `repro bench`: the verify-input pair
/// (allocating reference vs arena `_to` path) and the full round pair
/// (`host/round_ref` vs `host/round_scratch`).
pub fn host_suite(iters: usize) -> Vec<BenchResult> {
    let tree = default_bench_tree();
    let mut s = sim_scratch();
    let mut out = Vec::new();
    let ms = median_ms(iters, || {
        std::hint::black_box(tree::reference::verify_inputs_ref(&tree, 32, SIM_M, SIM_S));
    });
    out.push(BenchResult { name: "host/verify_inputs(32x192)".into(), median_ms: ms, iters });
    let ms = median_ms(iters, || {
        s.vtokens.clear();
        s.vtokens.resize(32, 0);
        s.vpos.clear();
        s.vpos.resize(32, 0);
        s.vbias.clear();
        s.vbias.resize(32 * SIM_S, 0.0);
        tree.verify_inputs_to(
            32, SIM_M, SIM_S, &mut s.vtokens, &mut s.vpos, &mut s.vbias, &mut s.anc,
        );
        std::hint::black_box(s.vtokens.len());
    });
    out.push(BenchResult { name: "host/verify_inputs_into(32x192)".into(), median_ms: ms, iters });
    let ms = median_ms(iters, || {
        std::hint::black_box(sim_round_ref(&tree));
    });
    out.push(BenchResult { name: "host/round_ref".into(), median_ms: ms, iters });
    let ms = median_ms(iters, || {
        std::hint::black_box(sim_round_scratch(&tree, &mut s));
    });
    out.push(BenchResult { name: "host/round_scratch".into(), median_ms: ms, iters });
    out
}

/// The artifact-gated exe suite: one fused-commit verify bench per
/// lowered `verify_t{t}` width — the curve [`CostModel`] fits the
/// dispatch overhead from.
pub fn exe_verify_suite(runner: &Runner, bundle: &ModelBundle, iters: usize) -> Vec<BenchResult> {
    let tgt = &bundle.target;
    let c = &runner.man.constants;
    let mut out = Vec::new();
    let prompt: Vec<u32> = (1..30).collect();
    let mut cache = tgt.new_cache(1);
    let Ok((_, m)) = tgt.prefill(&prompt, &mut cache) else {
        return out;
    };
    let zero_idx = vec![0i32; c.accept_a];
    for &t in &c.verify_widths {
        if !tgt.has_verify(t, 1) {
            continue;
        }
        let mut wtree = DraftTree::with_root(1);
        for i in 1..t {
            let parent = if i <= c.accept_a - 1 { i - 1 } else { 1 + (i % (c.accept_a - 1)) };
            wtree.add(parent, i as u32, -(i as f32), None);
        }
        let (tokens, pos, bias) = wtree.verify_inputs(t, m, tgt.max_len);
        let ms = median_ms(iters, || {
            tgt.verify(
                t, &mut cache, &[m as i32], &zero_idx, &[0], &tokens, &pos, &bias, c.accept_a,
            )
            .unwrap();
        });
        out.push(BenchResult { name: format!("exe/verify_t{t}"), median_ms: ms, iters });
    }
    out
}

/// Fit the dispatch overhead from the `exe/verify_t{t}` results (None
/// without at least two widths).
pub fn fit_cost_model(results: &[BenchResult]) -> Option<CostModel> {
    let points: Vec<(usize, f64)> = results
        .iter()
        .filter_map(|r| {
            let rest = r.name.strip_prefix("exe/verify_t")?;
            let digits: String = rest.chars().take_while(char::is_ascii_digit).collect();
            Some((digits.parse().ok()?, r.median_ms))
        })
        .collect();
    CostModel::fit_dispatch_overhead(&points).map(|d| CostModel { dispatch_overhead: d })
}

/// Serialize results (+ optional fitted cost model) as the
/// `BENCH_host.json` schema — consumable by `--cost-model`.
pub fn to_json(results: &[BenchResult], cost: Option<CostModel>) -> Json {
    let benches: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("median_ms", Json::Num(r.median_ms)),
                ("iters", Json::Num(r.iters as f64)),
            ])
        })
        .collect();
    let mut fields = vec![
        ("schema", Json::Str("bench_host_v1".into())),
        ("benches", Json::Arr(benches)),
    ];
    if let Some(cm) = cost {
        fields.push((
            "cost_model",
            Json::obj(vec![("dispatch_overhead", Json::Num(cm.dispatch_overhead as f64))]),
        ));
    }
    Json::obj(fields)
}

/// Write `BENCH_host.json` to `path`.
pub fn write_json(
    path: &std::path::Path,
    results: &[BenchResult],
    cost: Option<CostModel>,
) -> Result<()> {
    std::fs::write(path, to_json(results, cost).to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_sims_agree_and_scratch_is_stable() {
        let tree = default_bench_tree();
        let mut s = sim_scratch();
        let reference = sim_round_ref(&tree);
        assert_eq!(sim_round_scratch(&tree, &mut s), reference);
        let fp = s.footprint();
        for _ in 0..3 {
            assert_eq!(sim_round_scratch(&tree, &mut s), reference, "dirty reuse diverged");
        }
        assert_eq!(s.footprint(), fp, "steady-state sim rounds must not allocate");
    }

    #[test]
    fn bench_json_round_trips_into_cost_model() {
        let results = vec![
            BenchResult { name: "exe/verify_t8".into(), median_ms: 0.9, iters: 5 },
            BenchResult { name: "exe/verify_t16".into(), median_ms: 1.3, iters: 5 },
            BenchResult { name: "exe/verify_t32".into(), median_ms: 2.1, iters: 5 },
            BenchResult { name: "host/round_scratch".into(), median_ms: 0.02, iters: 5 },
        ];
        let fitted = fit_cost_model(&results).expect("three widths fit");
        assert_eq!(fitted.dispatch_overhead, 10);
        // the emitted file parses back through the --cost-model loader,
        // both via the fitted stanza and via the raw bench curve
        let with_stanza = to_json(&results, Some(fitted));
        assert_eq!(CostModel::from_json(&with_stanza).unwrap(), fitted);
        let curve_only = to_json(&results, None);
        assert_eq!(CostModel::from_json(&curve_only).unwrap(), fitted);
    }

    #[test]
    fn fit_needs_two_widths() {
        let one = vec![BenchResult { name: "exe/verify_t8".into(), median_ms: 0.9, iters: 5 }];
        assert!(fit_cost_model(&one).is_none());
        assert!(fit_cost_model(&[]).is_none());
    }
}
