//! Closed-loop load harness for a live `repro serve` instance
//! (`repro loadgen`): arrival processes, a shed-aware retrying client,
//! offered-vs-goodput level sweeps, an EDF-vs-FCFS comparison with a
//! losslessness check, and a chaos soak that asserts the server neither
//! stalls, nor leaks queue depth, nor allocates on the round path while
//! being driven hard.
//!
//! Everything here talks HTTP to a real server process — the harness
//! exercises the same admission/shedding/deadline/drain code paths a
//! production client would, not in-process shortcuts. Results are
//! written as `BENCH_serve.json` (`schema: bench_serve_v1`): one stanza
//! per offered-load level plus optional `edf_vs_fcfs`,
//! `preempt_vs_run_to_completion`, `p99_search`, and `soak` stanzas.
//!
//! Determinism: all randomness (arrival gaps, request mix, retry
//! jitter) flows from one seeded xorshift PRNG, so a sweep is
//! reproducible and — critically — the EDF and FCFS legs of the
//! comparison replay the *same* pre-generated workload. Combined with
//! the synthetic worker's content-deterministic output, that turns
//! "EDF reorders but never changes results" into an assertable
//! property over live HTTP.

use anyhow::{anyhow, ensure, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::metrics::registry::{parse_exposition, Exposition};
use crate::server::http::{get, post_json_full};
use crate::util::json::Json;

// ---- deterministic PRNG ------------------------------------------------

/// xorshift64* — tiny, seedable, good enough for arrival sampling and
/// retry jitter (the offline crate set has no `rand`).
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng(seed | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Exponential with the given rate (inter-arrival gap for a Poisson
    /// process at `rate` events/sec).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0, 1]
        -u.ln() / rate.max(1e-9)
    }
}

// ---- arrival processes -------------------------------------------------

/// How request start times are generated.
#[derive(Debug, Clone)]
pub enum Arrival {
    /// `clients` workers each issue the next request the moment the
    /// previous one completes — offered load tracks capacity.
    Closed { clients: usize },
    /// Open-loop Poisson at `rps` requests/sec.
    Poisson { rps: f64 },
    /// Markov-modulated on/off: exponentially-distributed phases
    /// alternating a hot rate and a trickle — the bursty profile the
    /// shedding EWMA and EDF queue are sized against.
    Bursty { rps_hi: f64, rps_lo: f64, mean_on_secs: f64, mean_off_secs: f64 },
    /// Replay recorded inter-arrival gaps (milliseconds, one per line).
    Replay { gaps_ms: Vec<u64> },
}

impl Arrival {
    /// Parse `--arrivals closed|poisson|bursty|replay` with its
    /// supporting options.
    pub fn parse(kind: &str, rps: f64, clients: usize, trace: Option<&str>) -> Result<Arrival> {
        match kind {
            "closed" => Ok(Arrival::Closed { clients: clients.max(1) }),
            "poisson" => Ok(Arrival::Poisson { rps }),
            "bursty" => Ok(Arrival::Bursty {
                rps_hi: rps * 3.0,
                rps_lo: rps * 0.2,
                mean_on_secs: 2.0,
                mean_off_secs: 3.0,
            }),
            "replay" => {
                let path = trace.ok_or_else(|| anyhow!("--arrivals replay needs --trace PATH"))?;
                let text = std::fs::read_to_string(path)?;
                let gaps_ms: Vec<u64> =
                    text.lines().filter_map(|l| l.trim().parse().ok()).collect();
                ensure!(!gaps_ms.is_empty(), "trace {path} has no parseable gaps");
                Ok(Arrival::Replay { gaps_ms })
            }
            other => Err(anyhow!("unknown --arrivals '{other}' (closed|poisson|bursty|replay)")),
        }
    }

    /// Pre-generate arrival offsets (seconds from start) covering
    /// `duration_secs`. `None` for closed-loop (no schedule — pacing is
    /// completion-driven).
    pub fn schedule(&self, duration_secs: f64, rng: &mut Rng) -> Option<Vec<f64>> {
        match self {
            Arrival::Closed { .. } => None,
            Arrival::Poisson { rps } => {
                let mut t = 0.0;
                let mut out = Vec::new();
                while t < duration_secs {
                    t += rng.exp(*rps);
                    if t < duration_secs {
                        out.push(t);
                    }
                }
                Some(out)
            }
            Arrival::Bursty { rps_hi, rps_lo, mean_on_secs, mean_off_secs } => {
                let mut out = Vec::new();
                let mut t = 0.0;
                let mut on = true;
                while t < duration_secs {
                    let phase = if on { rng.exp(1.0 / mean_on_secs) } else { rng.exp(1.0 / mean_off_secs) };
                    let rate = if on { *rps_hi } else { *rps_lo };
                    let end = (t + phase).min(duration_secs);
                    let mut at = t;
                    loop {
                        at += rng.exp(rate);
                        if at >= end {
                            break;
                        }
                        out.push(at);
                    }
                    t = end;
                    on = !on;
                }
                Some(out)
            }
            Arrival::Replay { gaps_ms } => {
                let mut t = 0.0;
                let mut out = Vec::new();
                for gap in gaps_ms.iter().cycle() {
                    t += *gap as f64 / 1e3;
                    if t >= duration_secs {
                        break;
                    }
                    out.push(t);
                }
                Some(out)
            }
        }
    }
}

// ---- request mix -------------------------------------------------------

/// The request mix one run draws from: a scenario blend of tight- and
/// loose-deadline requests at mixed temperatures.
#[derive(Debug, Clone)]
pub struct Profile {
    pub max_tokens: usize,
    /// Deadline for the tight class (ms from arrival).
    pub tight_deadline_ms: u64,
    /// Fraction of requests in the tight class.
    pub tight_frac: f64,
    /// Fraction of requests sampled at T=0.8 (the rest greedy).
    pub sampled_frac: f64,
    /// Stamp every request's `"draft"` field (`--draft`); `None` omits
    /// the field so the server's `--draft` default applies.
    pub draft: Option<String>,
    /// `--profile mixed`: half the prompts are chat-like, half carry a
    /// repetitive JSON-ish payload, so a `--draft auto` run gives the
    /// online source policy two distinguishable workloads (the
    /// synthetic worker prices acceptance off prompt repetitiveness).
    pub mixed: bool,
}

impl Default for Profile {
    fn default() -> Profile {
        Profile {
            max_tokens: 48,
            tight_deadline_ms: 300,
            tight_frac: 0.3,
            sampled_frac: 0.25,
            draft: None,
            mixed: false,
        }
    }
}

/// One pre-generated request: its arrival offset, serialized body, and
/// the class bookkeeping the reports slice by. `key` is unique per item
/// and embedded in the prompt, so responses can be matched across an
/// EDF-vs-FCFS replay by content.
#[derive(Debug, Clone)]
pub struct WorkItem {
    pub at_secs: f64,
    pub body: String,
    pub tight: bool,
    pub deadline_ms: Option<u64>,
    pub key: usize,
}

/// Materialize the workload: one item per scheduled arrival (or
/// `count` items for closed-loop runs, paced by completion).
pub fn build_workload(arrivals: &[f64], profile: &Profile, rng: &mut Rng) -> Vec<WorkItem> {
    arrivals
        .iter()
        .enumerate()
        .map(|(key, &at_secs)| {
            let tight = rng.next_f64() < profile.tight_frac;
            let deadline_ms = tight.then_some(profile.tight_deadline_ms);
            let temperature = if rng.next_f64() < profile.sampled_frac { 0.8 } else { 0.0 };
            // prompts keep the unique load-{key} prefix (replay matching
            // is by content); the mixed profile appends either a chat
            // phrase or a highly repetitive JSON-ish payload — no JSON
            // string escapes needed, so the body stays hand-serialized
            let prompt = if profile.mixed && key % 2 == 1 {
                format!("load-{key:06} {}", "{id:1,ok:true},".repeat(8))
            } else if profile.mixed {
                format!("load-{key:06} summarize the discussion and list open questions")
            } else {
                format!("load-{key:06}")
            };
            let mut body = format!(
                "{{\"prompt\":\"{prompt}\",\"max_tokens\":{},\"temperature\":{temperature},\"seed\":{}",
                profile.max_tokens,
                7 + key as u64,
            );
            if let Some(d) = &profile.draft {
                body.push_str(&format!(",\"draft\":\"{d}\""));
            }
            if let Some(d) = deadline_ms {
                body.push_str(&format!(",\"deadline_ms\":{d}"));
            } else {
                // explicit opt-out so a server-side default deadline
                // never reclassifies the loose cohort
                body.push_str(",\"deadline_ms\":0");
            }
            body.push('}');
            WorkItem { at_secs, body, tight, deadline_ms, key }
        })
        .collect()
}

// ---- shed-aware retrying client ----------------------------------------

/// What one request observed end to end, including shed retries.
#[derive(Debug, Clone)]
pub struct Sample {
    pub key: usize,
    pub status: u16,
    pub retries: u32,
    /// Client-observed wall time across all attempts (ms).
    pub e2e_ms: f64,
    pub queue_ms: f64,
    pub gen_ms: f64,
    pub tokens: usize,
    pub tight: bool,
    pub truncated: bool,
    pub text: String,
}

/// Base backoff before the first retry when the server's `Retry-After`
/// is absent (it never is on our 429s, but transport errors retry too).
const BACKOFF_BASE_MS: u64 = 50;
/// Hard cap on any single retry sleep, so a pathological estimate
/// cannot park a client for the whole run.
const BACKOFF_CAP_MS: u64 = 2_000;
/// Transport-level retry budget (connection refused during boot, etc.).
const MAX_TRANSPORT_RETRIES: u32 = 3;

/// Sleep for a shed retry: honor the server's `Retry-After` estimate,
/// floor it with exponential backoff on repeated sheds, cap it, and
/// jitter the result by ×[0.5, 1.5) so synchronized clients decorrelate
/// instead of re-arriving as the same thundering herd the shed was
/// protecting against.
pub fn retry_sleep_ms(retry_after_secs: Option<u64>, attempt: u32, rng: &mut Rng) -> u64 {
    let backoff = BACKOFF_BASE_MS.saturating_mul(1u64 << attempt.min(10));
    let base = retry_after_secs.map(|s| s * 1_000).unwrap_or(0).max(backoff).min(BACKOFF_CAP_MS);
    let jitter = 0.5 + rng.next_f64();
    (base as f64 * jitter) as u64
}

/// Issue one request with shed-aware retries. Returns the terminal
/// sample: the first non-429 response, or the last 429 once the retry
/// budget (`max_retries`) or the run's stop time is exhausted.
pub fn send_with_retries(
    addr: &str,
    item: &WorkItem,
    max_retries: u32,
    stop_at: Instant,
    rng: &mut Rng,
) -> Sample {
    let t0 = Instant::now();
    let mut attempt = 0u32;
    let mut transport_errors = 0u32;
    loop {
        match post_json_full(addr, "/v1/generate", &item.body) {
            Ok((429, headers, _)) => {
                let ra = headers
                    .iter()
                    .find(|(k, _)| k == "retry-after")
                    .and_then(|(_, v)| v.parse().ok());
                let sleep_ms = retry_sleep_ms(ra, attempt, rng);
                attempt += 1;
                let give_up = attempt > max_retries
                    || Instant::now() + Duration::from_millis(sleep_ms) >= stop_at;
                if give_up {
                    return Sample {
                        key: item.key,
                        status: 429,
                        retries: attempt - 1,
                        e2e_ms: t0.elapsed().as_secs_f64() * 1e3,
                        queue_ms: 0.0,
                        gen_ms: 0.0,
                        tokens: 0,
                        tight: item.tight,
                        truncated: false,
                        text: String::new(),
                    };
                }
                std::thread::sleep(Duration::from_millis(sleep_ms));
            }
            Ok((status, _, body)) => {
                let v = Json::parse(&body).unwrap_or(Json::Null);
                let f = |k: &str| v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
                return Sample {
                    key: item.key,
                    status,
                    retries: attempt,
                    e2e_ms: t0.elapsed().as_secs_f64() * 1e3,
                    queue_ms: f("queue_ms"),
                    gen_ms: f("latency_ms"),
                    tokens: f("tokens") as usize,
                    tight: item.tight,
                    truncated: v.get("truncated").is_some(),
                    text: v.get("text").and_then(|t| t.as_str()).unwrap_or("").to_string(),
                };
            }
            Err(_) if transport_errors < MAX_TRANSPORT_RETRIES && Instant::now() < stop_at => {
                transport_errors += 1;
                std::thread::sleep(Duration::from_millis(
                    BACKOFF_BASE_MS << transport_errors.min(6),
                ));
            }
            Err(_) => {
                return Sample {
                    key: item.key,
                    status: 0,
                    retries: attempt,
                    e2e_ms: t0.elapsed().as_secs_f64() * 1e3,
                    queue_ms: 0.0,
                    gen_ms: 0.0,
                    tokens: 0,
                    tight: item.tight,
                    truncated: false,
                    text: String::new(),
                };
            }
        }
    }
}

// ---- workload execution ------------------------------------------------

/// Drive one workload against the server. Open-loop items are paced by
/// their `at_secs` offsets (one thread per in-flight request);
/// closed-loop runs `clients` workers that each take the next item as
/// soon as their previous request resolves. Returns every sample.
pub fn run_workload(
    addr: &str,
    items: &[WorkItem],
    closed_clients: Option<usize>,
    max_retries: u32,
    stop_after: Duration,
    seed: u64,
) -> Vec<Sample> {
    let stop_at = Instant::now() + stop_after;
    let samples: Mutex<Vec<Sample>> = Mutex::new(Vec::with_capacity(items.len()));
    std::thread::scope(|scope| {
        match closed_clients {
            Some(clients) => {
                let next = AtomicUsize::new(0);
                for c in 0..clients {
                    let (samples, next) = (&samples, &next);
                    scope.spawn(move || {
                        let mut rng = Rng::new(seed ^ (0x9e37_79b9_7f4a_7c15u64).wrapping_mul(c as u64 + 1));
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= items.len() || Instant::now() >= stop_at {
                                break;
                            }
                            let s = send_with_retries(addr, &items[i], max_retries, stop_at, &mut rng);
                            samples.lock().unwrap().push(s);
                        }
                    });
                }
            }
            None => {
                let t0 = Instant::now();
                for item in items {
                    let due = t0 + Duration::from_secs_f64(item.at_secs);
                    let now = Instant::now();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    if Instant::now() >= stop_at {
                        break;
                    }
                    let samples = &samples;
                    scope.spawn(move || {
                        let mut rng = Rng::new(seed ^ (item.key as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
                        let s = send_with_retries(addr, item, max_retries, stop_at, &mut rng);
                        samples.lock().unwrap().push(s);
                    });
                }
            }
        }
    });
    samples.into_inner().unwrap()
}

// ---- metrics scraping --------------------------------------------------

/// A parsed `/metrics` snapshot with the accessors the reports need.
pub struct Snapshot(pub Exposition);

pub fn snapshot(addr: &str) -> Result<Snapshot> {
    let (code, body) = get(addr, "/metrics")?;
    ensure!(code == 200, "GET /metrics returned {code}");
    Ok(Snapshot(parse_exposition(&body)?))
}

impl Snapshot {
    /// Sum of all samples of a counter/gauge family (labels summed).
    pub fn total(&self, family: &str) -> f64 {
        self.0
            .family(family)
            .map(|f| f.samples.iter().map(|s| s.value).sum())
            .unwrap_or(0.0)
    }

    /// Cumulative `(le, count)` buckets of a histogram family.
    pub fn buckets(&self, family: &str) -> Vec<(f64, f64)> {
        let name = format!("{family}_bucket");
        self.0
            .family(family)
            .map(|f| {
                f.samples
                    .iter()
                    .filter(|s| s.name == name)
                    .filter_map(|s| {
                        let le = s.label("le")?;
                        let le = if le == "+Inf" { f64::INFINITY } else { le.parse().ok()? };
                        Some((le, s.value))
                    })
                    .collect()
            })
            .unwrap_or_default()
    }
}

/// Estimate a quantile of the observations a histogram family gained
/// between two snapshots, by linear interpolation inside the first
/// bucket whose delta-cumulative count crosses the target rank. `None`
/// when the window saw no observations.
pub fn hist_delta_quantile(before: &Snapshot, after: &Snapshot, family: &str, q: f64) -> Option<f64> {
    let b = before.buckets(family);
    let a = after.buckets(family);
    if a.is_empty() || a.len() != b.len() {
        return None;
    }
    let delta: Vec<(f64, f64)> =
        a.iter().zip(&b).map(|(&(le, ac), &(_, bc))| (le, (ac - bc).max(0.0))).collect();
    let total = delta.last()?.1;
    if total <= 0.0 {
        return None;
    }
    let rank = q.clamp(0.0, 1.0) * total;
    let mut lo_le = 0.0;
    let mut lo_count = 0.0;
    for &(le, count) in &delta {
        if count >= rank {
            if le.is_infinite() {
                // open-ended top bucket: report its lower edge
                return Some(lo_le);
            }
            let span = (count - lo_count).max(1e-12);
            return Some(lo_le + (le - lo_le) * ((rank - lo_count) / span).clamp(0.0, 1.0));
        }
        lo_le = le;
        lo_count = count;
    }
    delta.last().map(|&(le, _)| if le.is_infinite() { lo_le } else { le })
}

// ---- percentiles over client samples -----------------------------------

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn sorted_by<F: Fn(&Sample) -> Option<f64>>(samples: &[Sample], f: F) -> Vec<f64> {
    let mut v: Vec<f64> = samples.iter().filter_map(&f).collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v
}

// ---- level reports -----------------------------------------------------

/// Aggregated result of one offered-load level.
#[derive(Debug)]
pub struct LevelReport {
    pub offered_rps: f64,
    pub sent: usize,
    pub ok: usize,
    pub shed: usize,
    pub missed: usize,
    pub errors: usize,
    pub retries: u64,
    pub goodput_rps: f64,
    pub p50_e2e_ms: f64,
    pub p99_e2e_ms: f64,
    pub p99_tight_e2e_ms: f64,
    pub p50_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub p50_tok_ms: f64,
    pub p99_tok_ms: f64,
    pub shed_rate: f64,
    pub miss_rate: f64,
}

impl LevelReport {
    /// Fold client samples + the server's histogram deltas into one
    /// report. "Good" means 200 and not deadline-truncated; a miss is a
    /// truncation or a queue-expired 504.
    pub fn from_samples(
        offered_rps: f64,
        wall_secs: f64,
        samples: &[Sample],
        before: &Snapshot,
        after: &Snapshot,
    ) -> LevelReport {
        let sent = samples.len();
        let ok = samples.iter().filter(|s| s.status == 200 && !s.truncated).count();
        let shed = samples.iter().filter(|s| s.status == 429).count();
        let missed =
            samples.iter().filter(|s| s.truncated || s.status == 504).count();
        let errors =
            samples.iter().filter(|s| !matches!(s.status, 200 | 429 | 504)).count();
        let retries = samples.iter().map(|s| s.retries as u64).sum();
        let e2e = sorted_by(samples, |s| (s.status == 200).then_some(s.e2e_ms));
        let tight_e2e =
            sorted_by(samples, |s| (s.status == 200 && s.tight).then_some(s.e2e_ms));
        let tok = sorted_by(samples, |s| {
            (s.status == 200 && s.tokens > 0).then(|| s.gen_ms / s.tokens as f64)
        });
        let ttft = |q| hist_delta_quantile(before, after, "eagle_ttft_seconds", q)
            .map(|s| s * 1e3)
            .unwrap_or(0.0);
        LevelReport {
            offered_rps,
            sent,
            ok,
            shed,
            missed,
            errors,
            retries,
            goodput_rps: ok as f64 / wall_secs.max(1e-9),
            p50_e2e_ms: percentile(&e2e, 0.50),
            p99_e2e_ms: percentile(&e2e, 0.99),
            p99_tight_e2e_ms: percentile(&tight_e2e, 0.99),
            p50_ttft_ms: ttft(0.50),
            p99_ttft_ms: ttft(0.99),
            p50_tok_ms: percentile(&tok, 0.50),
            p99_tok_ms: percentile(&tok, 0.99),
            shed_rate: shed as f64 / sent.max(1) as f64,
            miss_rate: missed as f64 / sent.max(1) as f64,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("offered_rps", Json::Num(self.offered_rps)),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("missed", Json::Num(self.missed as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("goodput_rps", Json::Num(self.goodput_rps)),
            ("p50_e2e_ms", Json::Num(self.p50_e2e_ms)),
            ("p99_e2e_ms", Json::Num(self.p99_e2e_ms)),
            ("p99_tight_e2e_ms", Json::Num(self.p99_tight_e2e_ms)),
            ("p50_ttft_ms", Json::Num(self.p50_ttft_ms)),
            ("p99_ttft_ms", Json::Num(self.p99_ttft_ms)),
            ("p50_token_ms", Json::Num(self.p50_tok_ms)),
            ("p99_token_ms", Json::Num(self.p99_tok_ms)),
            ("shed_rate", Json::Num(self.shed_rate)),
            ("miss_rate", Json::Num(self.miss_rate)),
        ])
    }
}

// ---- drain / quiescence helper -----------------------------------------

/// Wait until the server's queue is empty and nothing is in flight, so
/// back-to-back runs (level sweep, EDF/FCFS legs) don't bleed load into
/// each other. Errors out rather than hanging forever.
pub fn wait_quiescent(addr: &str, timeout: Duration) -> Result<()> {
    let give_up = Instant::now() + timeout;
    loop {
        let s = snapshot(addr)?;
        if s.total("eagle_queue_depth") == 0.0 && s.total("eagle_inflight_lanes") == 0.0 {
            return Ok(());
        }
        ensure!(Instant::now() < give_up, "server did not quiesce within {timeout:?}");
        std::thread::sleep(Duration::from_millis(100));
    }
}

// ---- top-level runs ----------------------------------------------------

/// Configuration for one `repro loadgen` invocation.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    pub addr: String,
    pub arrivals: Arrival,
    pub duration_secs: f64,
    /// Offered-rate multipliers for the sweep (each level runs the
    /// arrival process at `rps * level`).
    pub levels: Vec<f64>,
    pub rps: f64,
    pub profile: Profile,
    pub max_retries: u32,
    pub seed: u64,
    pub soak: bool,
    pub compare_edf: bool,
    /// Replay one workload with preemption off then on and assert the
    /// outputs are byte-identical (`--compare-preempt`).
    pub compare_preempt: bool,
    /// Closed-loop search (`--target-p99-ttft-ms N`): bisect the
    /// offered-load multiplier for the highest level whose p99 TTFT
    /// stays under the target.
    pub target_p99_ttft_ms: Option<f64>,
    pub out: std::path::PathBuf,
}

/// One offered-load level: generate the workload, bracket it with
/// metric snapshots, run it, and wait for the server to quiesce.
pub fn run_level(cfg: &LoadgenConfig, level: f64) -> Result<LevelReport> {
    let rps = cfg.rps * level;
    let mut rng = Rng::new(cfg.seed.wrapping_add((level * 1e3) as u64));
    let arrivals = match &cfg.arrivals {
        Arrival::Closed { .. } => Arrival::Closed { clients: (level.ceil() as usize).max(1) },
        Arrival::Poisson { .. } => Arrival::Poisson { rps },
        Arrival::Bursty { mean_on_secs, mean_off_secs, .. } => Arrival::Bursty {
            rps_hi: rps * 3.0,
            rps_lo: rps * 0.2,
            mean_on_secs: *mean_on_secs,
            mean_off_secs: *mean_off_secs,
        },
        replay @ Arrival::Replay { .. } => replay.clone(),
    };
    let (items, closed) = match &arrivals {
        Arrival::Closed { clients } => {
            // enough items that the clients are never starved
            let n = (rps.max(1.0) * cfg.duration_secs * 4.0) as usize + *clients;
            let offsets: Vec<f64> = (0..n).map(|_| 0.0).collect();
            (build_workload(&offsets, &cfg.profile, &mut rng), Some(*clients))
        }
        _ => {
            let offsets = arrivals.schedule(cfg.duration_secs, &mut rng).unwrap_or_default();
            (build_workload(&offsets, &cfg.profile, &mut rng), None)
        }
    };
    let offered = items.len() as f64 / cfg.duration_secs.max(1e-9);
    let before = snapshot(&cfg.addr)?;
    let t0 = Instant::now();
    let samples = run_workload(
        &cfg.addr,
        &items,
        closed,
        cfg.max_retries,
        Duration::from_secs_f64(cfg.duration_secs + 30.0),
        cfg.seed,
    );
    let wall = t0.elapsed().as_secs_f64();
    wait_quiescent(&cfg.addr, Duration::from_secs(30))?;
    let after = snapshot(&cfg.addr)?;
    Ok(LevelReport::from_samples(offered, wall, &samples, &before, &after))
}

/// EDF-vs-FCFS comparison: replay ONE pre-generated workload under each
/// admission order and check (a) losslessness — every request completed
/// untruncated in both legs produced byte-identical text — and (b) the
/// tight-deadline p99 under EDF against FCFS.
pub fn compare_edf(cfg: &LoadgenConfig) -> Result<Json> {
    let mut rng = Rng::new(cfg.seed ^ 0xedf0_edf0);
    let offsets = Arrival::Poisson { rps: cfg.rps }
        .schedule(cfg.duration_secs, &mut rng)
        .unwrap_or_default();
    let items = build_workload(&offsets, &cfg.profile, &mut rng);
    let mut legs: Vec<(&str, Vec<Sample>)> = Vec::new();
    for order in ["fcfs", "edf"] {
        let (code, _, _) = post_json_full(
            &cfg.addr,
            "/admin/sched",
            &format!("{{\"order\":\"{order}\"}}"),
        )?;
        ensure!(code == 200, "POST /admin/sched {order} returned {code}");
        let samples = run_workload(
            &cfg.addr,
            &items,
            None,
            cfg.max_retries,
            Duration::from_secs_f64(cfg.duration_secs + 30.0),
            cfg.seed,
        );
        wait_quiescent(&cfg.addr, Duration::from_secs(30))?;
        legs.push((order, samples));
    }
    let (_, fcfs) = &legs[0];
    let (_, edf) = &legs[1];
    // losslessness over the intersection of clean completions
    let mut mismatches = 0usize;
    let mut compared = 0usize;
    for f in fcfs.iter().filter(|s| s.status == 200 && !s.truncated) {
        if let Some(e) = edf.iter().find(|s| s.key == f.key && s.status == 200 && !s.truncated) {
            compared += 1;
            if e.text != f.text {
                mismatches += 1;
            }
        }
    }
    ensure!(
        mismatches == 0,
        "EDF reordering changed output text on {mismatches}/{compared} requests"
    );
    let p99 = |samples: &[Sample], tight: bool| {
        percentile(
            &sorted_by(samples, |s| (s.status == 200 && s.tight == tight).then_some(s.e2e_ms)),
            0.99,
        )
    };
    let fcfs_tight = p99(fcfs, true);
    let edf_tight = p99(edf, true);
    eprintln!(
        "[loadgen] edf-vs-fcfs: tight p99 {edf_tight:.1} ms (edf) vs {fcfs_tight:.1} ms (fcfs); \
         {compared} outputs compared, 0 mismatches"
    );
    Ok(Json::obj(vec![
        ("compared_outputs", Json::Num(compared as f64)),
        ("output_mismatches", Json::Num(mismatches as f64)),
        ("fcfs_p99_tight_e2e_ms", Json::Num(fcfs_tight)),
        ("edf_p99_tight_e2e_ms", Json::Num(edf_tight)),
        ("fcfs_p99_loose_e2e_ms", Json::Num(p99(fcfs, false))),
        ("edf_p99_loose_e2e_ms", Json::Num(p99(edf, false))),
        ("edf_improved_tight_p99", Json::Bool(edf_tight < fcfs_tight)),
    ]))
}

/// Preemption-on-vs-off comparison: replay ONE pre-generated workload
/// with lane preemption disabled, then enabled, and check (a)
/// losslessness — every request completed untruncated in both legs
/// produced byte-identical text, which holds only if suspend/resume is
/// bit-identical end to end — and (b) the tight-deadline p99 both ways
/// (the deadline governor suspends long-running lanes so tight arrivals
/// dispatch sooner). Reports the on-leg's suspension/resume counts from
/// the server's own counters so "nothing was preempted" is visible.
pub fn compare_preempt(cfg: &LoadgenConfig) -> Result<Json> {
    let mut rng = Rng::new(cfg.seed ^ 0x9ee3_9ee3);
    let offsets = Arrival::Poisson { rps: cfg.rps }
        .schedule(cfg.duration_secs, &mut rng)
        .unwrap_or_default();
    let items = build_workload(&offsets, &cfg.profile, &mut rng);
    let mut legs: Vec<(&str, Vec<Sample>, f64, f64)> = Vec::new();
    for enabled in [false, true] {
        let (code, _, _) = post_json_full(
            &cfg.addr,
            "/admin/preempt",
            &format!("{{\"enabled\":{enabled}}}"),
        )?;
        ensure!(code == 200, "POST /admin/preempt {enabled} returned {code}");
        let before = snapshot(&cfg.addr)?;
        let samples = run_workload(
            &cfg.addr,
            &items,
            None,
            cfg.max_retries,
            Duration::from_secs_f64(cfg.duration_secs + 30.0),
            cfg.seed,
        );
        wait_quiescent(&cfg.addr, Duration::from_secs(30))?;
        let after = snapshot(&cfg.addr)?;
        let preempts = after.total("eagle_preempt_total") - before.total("eagle_preempt_total");
        let resumes = after.total("eagle_resumes_total") - before.total("eagle_resumes_total");
        legs.push((if enabled { "on" } else { "off" }, samples, preempts, resumes));
    }
    let (_, off, _, _) = &legs[0];
    let (_, on, preempts, resumes) = &legs[1];
    let mut mismatches = 0usize;
    let mut compared = 0usize;
    for o in off.iter().filter(|s| s.status == 200 && !s.truncated) {
        if let Some(p) = on.iter().find(|s| s.key == o.key && s.status == 200 && !s.truncated) {
            compared += 1;
            if p.text != o.text {
                mismatches += 1;
            }
        }
    }
    ensure!(
        mismatches == 0,
        "preemption changed output text on {mismatches}/{compared} requests"
    );
    let p99 = |samples: &[Sample], tight: bool| {
        percentile(
            &sorted_by(samples, |s| (s.status == 200 && s.tight == tight).then_some(s.e2e_ms)),
            0.99,
        )
    };
    let off_tight = p99(off, true);
    let on_tight = p99(on, true);
    eprintln!(
        "[loadgen] preempt-vs-off: tight p99 {on_tight:.1} ms (on) vs {off_tight:.1} ms (off); \
         {preempts:.0} preempts, {resumes:.0} resumes, {compared} outputs compared, 0 mismatches"
    );
    Ok(Json::obj(vec![
        ("compared_outputs", Json::Num(compared as f64)),
        ("output_mismatches", Json::Num(mismatches as f64)),
        ("off_p99_tight_e2e_ms", Json::Num(off_tight)),
        ("on_p99_tight_e2e_ms", Json::Num(on_tight)),
        ("off_p99_loose_e2e_ms", Json::Num(p99(off, false))),
        ("on_p99_loose_e2e_ms", Json::Num(p99(on, false))),
        ("on_preempts", Json::Num(*preempts)),
        ("on_resumes", Json::Num(*resumes)),
        ("preempt_improved_tight_p99", Json::Bool(on_tight < off_tight)),
    ]))
}

/// Closed-loop capacity search: bisect the offered-load multiplier for
/// the highest level whose p99 TTFT stays at or under `target_ms`.
/// Bounds come from the sweep's `--levels` (min/max); when even the
/// lowest level misses the target the stanza says so instead of
/// reporting a fake capacity. Monotonicity of p99-TTFT-vs-load is the
/// search invariant — true of an admission-queue server under a fixed
/// mix.
pub fn p99_search(cfg: &LoadgenConfig, target_ms: f64) -> Result<Json> {
    let mut lo = cfg.levels.iter().cloned().fold(f64::INFINITY, f64::min).max(0.05);
    let mut hi = cfg.levels.iter().cloned().fold(0.0f64, f64::max).max(lo);
    let probe = |level: f64| -> Result<(f64, f64)> {
        let rep = run_level(cfg, level)?;
        eprintln!(
            "[loadgen] search x{level:.3}: {:.1} rps offered, p99 ttft {:.1} ms \
             (target {target_ms} ms)",
            rep.offered_rps, rep.p99_ttft_ms
        );
        Ok((rep.offered_rps, rep.p99_ttft_ms))
    };
    let mut iterations: Vec<Json> = Vec::new();
    let note = |level: f64, rps: f64, p99: f64| {
        Json::obj(vec![
            ("level", Json::Num(level)),
            ("offered_rps", Json::Num(rps)),
            ("p99_ttft_ms", Json::Num(p99)),
        ])
    };
    // feasibility at the floor, capacity short-circuit at the ceiling
    let (lo_rps, lo_p99) = probe(lo)?;
    iterations.push(note(lo, lo_rps, lo_p99));
    if lo_p99 > target_ms {
        eprintln!("[loadgen] search: even x{lo} misses the target; no feasible level");
        return Ok(Json::obj(vec![
            ("target_p99_ttft_ms", Json::Num(target_ms)),
            ("feasible", Json::Bool(false)),
            ("iterations", Json::Arr(iterations)),
        ]));
    }
    let mut best = (lo, lo_rps, lo_p99);
    let (hi_rps, hi_p99) = probe(hi)?;
    iterations.push(note(hi, hi_rps, hi_p99));
    if hi_p99 <= target_ms {
        best = (hi, hi_rps, hi_p99);
        lo = hi; // the whole range fits: nothing to bisect
    }
    let mut iters = 0;
    while hi - lo > 0.05 && iters < 6 {
        let mid = (lo + hi) / 2.0;
        let (rps, p99) = probe(mid)?;
        iterations.push(note(mid, rps, p99));
        if p99 <= target_ms {
            best = (mid, rps, p99);
            lo = mid;
        } else {
            hi = mid;
        }
        iters += 1;
    }
    eprintln!(
        "[loadgen] search: highest level under target x{:.3} ({:.1} rps, p99 ttft {:.1} ms)",
        best.0, best.1, best.2
    );
    Ok(Json::obj(vec![
        ("target_p99_ttft_ms", Json::Num(target_ms)),
        ("feasible", Json::Bool(true)),
        ("best_level", Json::Num(best.0)),
        ("best_offered_rps", Json::Num(best.1)),
        ("best_p99_ttft_ms", Json::Num(best.2)),
        ("iterations", Json::Arr(iterations)),
    ]))
}

/// Chaos soak: drive the bursty profile for the whole duration while a
/// monitor thread polls `/healthz` and the queue-depth gauge. Asserts
/// the server never reports a stall, the queue drains back to empty
/// after the load stops (no hung slots, no monotonic growth), and the
/// round path allocated zero bytes across the entire soak.
pub fn soak(cfg: &LoadgenConfig) -> Result<Json> {
    let mut rng = Rng::new(cfg.seed ^ 0x50a6_50a6);
    let offsets = cfg.arrivals.schedule(cfg.duration_secs, &mut rng).unwrap_or_default();
    let items = build_workload(&offsets, &cfg.profile, &mut rng);
    let before = snapshot(&cfg.addr)?;
    let health_failures = AtomicUsize::new(0);
    let max_depth = Mutex::new(0.0f64);
    let load_done = std::sync::atomic::AtomicBool::new(false);
    let samples = std::thread::scope(|scope| {
        scope.spawn(|| {
            while !load_done.load(Ordering::Relaxed) {
                match get(&cfg.addr, "/healthz") {
                    Ok((200, _)) => {}
                    _ => {
                        health_failures.fetch_add(1, Ordering::Relaxed);
                    }
                }
                if let Ok(s) = snapshot(&cfg.addr) {
                    let d = s.total("eagle_queue_depth");
                    let mut m = max_depth.lock().unwrap();
                    if d > *m {
                        *m = d;
                    }
                }
                std::thread::sleep(Duration::from_millis(500));
            }
        });
        let samples = run_workload(
            &cfg.addr,
            &items,
            None,
            cfg.max_retries,
            Duration::from_secs_f64(cfg.duration_secs + 25.0),
            cfg.seed,
        );
        load_done.store(true, Ordering::Relaxed);
        samples
    });
    wait_quiescent(&cfg.addr, Duration::from_secs(30))?;
    let after = snapshot(&cfg.addr)?;
    let alloc_delta =
        after.total("eagle_round_alloc_bytes_total") - before.total("eagle_round_alloc_bytes_total");
    let panics =
        after.total("eagle_worker_panics_total") - before.total("eagle_worker_panics_total");
    let answered = samples.iter().filter(|s| s.status != 0).count();
    let hung = samples.len() - answered;
    let failures = health_failures.load(Ordering::Relaxed);
    ensure!(failures == 0, "soak: /healthz failed {failures} times (stall or crash)");
    ensure!(hung == 0, "soak: {hung} requests got no response (hung slots)");
    ensure!(alloc_delta == 0.0, "soak: round path allocated {alloc_delta} bytes");
    let miss_rate = samples.iter().filter(|s| s.truncated || s.status == 504).count() as f64
        / samples.len().max(1) as f64;
    eprintln!(
        "[loadgen] soak ok: {} requests, {panics} supervised panics, queue drained, \
         0 alloc bytes, miss rate {miss_rate:.3}",
        samples.len()
    );
    Ok(Json::obj(vec![
        ("requests", Json::Num(samples.len() as f64)),
        ("healthz_failures", Json::Num(failures as f64)),
        ("hung", Json::Num(hung as f64)),
        ("supervised_panics", Json::Num(panics)),
        ("max_queue_depth", Json::Num(*max_depth.lock().unwrap())),
        ("round_alloc_bytes_delta", Json::Num(alloc_delta)),
        ("miss_rate", Json::Num(miss_rate)),
        ("drained", Json::Bool(true)),
    ]))
}

/// Entry point behind `repro loadgen`: level sweep, then the optional
/// comparison/soak stanzas, then `BENCH_serve.json`.
pub fn run(cfg: &LoadgenConfig) -> Result<()> {
    let mut stanzas: Vec<(&str, Json)> = vec![
        ("schema", Json::Str("bench_serve_v1".into())),
        (
            "config",
            Json::obj(vec![
                ("addr", Json::Str(cfg.addr.clone())),
                ("arrivals", Json::Str(format!("{:?}", cfg.arrivals))),
                ("duration_secs", Json::Num(cfg.duration_secs)),
                ("base_rps", Json::Num(cfg.rps)),
                ("max_tokens", Json::Num(cfg.profile.max_tokens as f64)),
                ("tight_deadline_ms", Json::Num(cfg.profile.tight_deadline_ms as f64)),
                ("tight_frac", Json::Num(cfg.profile.tight_frac)),
                ("profile", Json::from(if cfg.profile.mixed { "mixed" } else { "chat" })),
                (
                    "draft",
                    Json::Str(cfg.profile.draft.clone().unwrap_or_else(|| "default".into())),
                ),
                ("seed", Json::Num(cfg.seed as f64)),
            ]),
        ),
    ];
    if cfg.soak {
        stanzas.push(("soak", soak(cfg)?));
    } else {
        let mut levels = Vec::new();
        for &level in &cfg.levels {
            eprintln!("[loadgen] level x{level} ({} rps offered) ...", cfg.rps * level);
            let rep = run_level(cfg, level)?;
            eprintln!(
                "[loadgen]   offered {:.1} rps -> goodput {:.1} rps, p99 e2e {:.0} ms, \
                 shed {:.1}%, miss {:.1}%",
                rep.offered_rps,
                rep.goodput_rps,
                rep.p99_e2e_ms,
                rep.shed_rate * 1e2,
                rep.miss_rate * 1e2,
            );
            levels.push(rep.to_json());
        }
        stanzas.push(("levels", Json::Arr(levels)));
        if cfg.compare_edf {
            stanzas.push(("edf_vs_fcfs", compare_edf(cfg)?));
        }
        if cfg.compare_preempt {
            stanzas.push(("preempt_vs_run_to_completion", compare_preempt(cfg)?));
        }
        if let Some(target) = cfg.target_p99_ttft_ms {
            stanzas.push(("p99_search", p99_search(cfg, target)?));
        }
    }
    let out = Json::obj(stanzas);
    std::fs::write(&cfg.out, out.to_string())?;
    println!("wrote {}", cfg.out.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic_and_uniformish() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
        let mean: f64 = (0..10_000).map(|_| a.next_f64()).sum::<f64>() / 1e4;
        assert!((mean - 0.5).abs() < 0.02, "uniform mean {mean}");
    }

    #[test]
    fn poisson_schedule_matches_rate() {
        let mut rng = Rng::new(7);
        let sched = Arrival::Poisson { rps: 50.0 }.schedule(20.0, &mut rng).unwrap();
        // 1000 expected arrivals; 10% tolerance at this sample size
        assert!((sched.len() as f64 - 1000.0).abs() < 100.0, "n = {}", sched.len());
        assert!(sched.windows(2).all(|w| w[0] <= w[1]), "sorted offsets");
        assert!(*sched.last().unwrap() < 20.0);
    }

    #[test]
    fn bursty_schedule_alternates_phases() {
        let mut rng = Rng::new(11);
        let a = Arrival::Bursty { rps_hi: 100.0, rps_lo: 1.0, mean_on_secs: 1.0, mean_off_secs: 1.0 };
        let sched = a.schedule(30.0, &mut rng).unwrap();
        // far fewer than 30s of pure rps_hi, far more than pure rps_lo
        assert!(sched.len() > 100 && sched.len() < 2_900, "n = {}", sched.len());
    }

    #[test]
    fn replay_schedule_wraps_trace() {
        let mut rng = Rng::new(1);
        let a = Arrival::Replay { gaps_ms: vec![100, 400] };
        let sched = a.schedule(2.0, &mut rng).unwrap();
        // gaps cycle 0.1, 0.4, 0.1, 0.4 -> 0.1, 0.5, 0.6, 1.0, 1.1, 1.5, 1.6
        assert_eq!(sched.len(), 7);
        assert!((sched[1] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn workload_mix_and_keys_are_deterministic() {
        let profile = Profile { tight_frac: 0.5, ..Profile::default() };
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let offsets: Vec<f64> = (0..200).map(|i| i as f64 * 0.01).collect();
        let w1 = build_workload(&offsets, &profile, &mut r1);
        let w2 = build_workload(&offsets, &profile, &mut r2);
        assert_eq!(w1.len(), 200);
        for (a, b) in w1.iter().zip(&w2) {
            assert_eq!(a.body, b.body);
        }
        let tight = w1.iter().filter(|i| i.tight).count();
        assert!(tight > 60 && tight < 140, "tight mix {tight}/200");
        // tight items carry the deadline; loose items explicitly opt out
        assert!(w1.iter().all(|i| i.body.contains("deadline_ms")));
        // keys unique (losslessness matching relies on it)
        let mut keys: Vec<usize> = w1.iter().map(|i| i.key).collect();
        keys.dedup();
        assert_eq!(keys.len(), 200);
    }

    #[test]
    fn retry_sleep_honors_server_estimate_with_jitter() {
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            // server said 1s: jittered into [500, 1500)
            let ms = retry_sleep_ms(Some(1), 0, &mut rng);
            assert!((500..1500).contains(&ms), "jittered sleep {ms}");
        }
        // no header: exponential backoff floor
        let ms = retry_sleep_ms(None, 3, &mut rng);
        assert!(ms >= 200, "backoff floor {ms}");
        // cap: a huge estimate cannot park the client
        let ms = retry_sleep_ms(Some(3_600), 0, &mut rng);
        assert!(ms < 3_000, "capped sleep {ms}");
    }

    fn snap(text: &str) -> Snapshot {
        Snapshot(parse_exposition(text).unwrap())
    }

    #[test]
    fn hist_delta_quantile_interpolates_new_observations() {
        let before = snap(
            "# TYPE t histogram\n\
             t_bucket{le=\"0.1\"} 10\nt_bucket{le=\"1\"} 10\nt_bucket{le=\"+Inf\"} 10\n\
             t_sum 1\nt_count 10\n",
        );
        let after = snap(
            "# TYPE t histogram\n\
             t_bucket{le=\"0.1\"} 10\nt_bucket{le=\"1\"} 110\nt_bucket{le=\"+Inf\"} 110\n\
             t_sum 51\nt_count 110\n",
        );
        // all 100 new observations landed in (0.1, 1]
        let p50 = hist_delta_quantile(&before, &after, "t", 0.5).unwrap();
        assert!(p50 > 0.1 && p50 <= 1.0, "p50 {p50}");
        // the old 10 observations don't drag the estimate down
        let p01 = hist_delta_quantile(&before, &after, "t", 0.01).unwrap();
        assert!(p01 > 0.1, "p01 {p01} polluted by pre-window counts");
        // empty window: no estimate rather than a stale one
        assert!(hist_delta_quantile(&before, &before, "t", 0.5).is_none());
    }

    #[test]
    fn level_report_classifies_outcomes() {
        let mk = |status, truncated, tight| Sample {
            key: 0,
            status,
            retries: 1,
            e2e_ms: 100.0,
            queue_ms: 10.0,
            gen_ms: 80.0,
            tokens: 40,
            tight,
            truncated,
            text: String::new(),
        };
        let samples = vec![
            mk(200, false, true),
            mk(200, false, false),
            mk(200, true, false), // deadline-truncated partial
            mk(429, false, false),
            mk(504, false, true),
        ];
        let empty = snap("# TYPE t histogram\nt_bucket{le=\"+Inf\"} 0\nt_sum 0\nt_count 0\n");
        let rep = LevelReport::from_samples(5.0, 1.0, &samples, &empty, &empty);
        assert_eq!(rep.sent, 5);
        assert_eq!(rep.ok, 2);
        assert_eq!(rep.shed, 1);
        assert_eq!(rep.missed, 2); // truncation + 504
        assert_eq!(rep.retries, 5);
        assert!((rep.shed_rate - 0.2).abs() < 1e-9);
        assert!((rep.miss_rate - 0.4).abs() < 1e-9);
        assert!((rep.goodput_rps - 2.0).abs() < 1e-9);
        let j = rep.to_json().to_string();
        assert!(j.contains("\"goodput_rps\""));
    }
}
