//! Workload loading (S2-rust): eval prompt sets written by the AOT
//! pipeline (`artifacts/workloads/*.json`), grouped by task category.

use anyhow::{anyhow, Result};

use crate::runtime::Manifest;
use crate::text::bpe::Bpe;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Prompt {
    pub category: String,
    pub text: String,
    pub ids: Vec<u32>,
}

#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub prompts: Vec<Prompt>,
}

impl Workload {
    pub fn load(man: &Manifest, bpe: &Bpe, name: &str, max_prompt: usize) -> Result<Workload> {
        let rel = man
            .workloads
            .get(name)
            .ok_or_else(|| anyhow!("workload '{name}' not in manifest"))?;
        let text = std::fs::read_to_string(man.path(rel))?;
        let v = Json::parse(&text)?;
        let mut prompts = Vec::new();
        for p in v.req("prompts")?.as_arr().ok_or_else(|| anyhow!("prompts"))? {
            let user = p.req("user")?.as_str().unwrap_or_default().to_string();
            let ids = bpe.encode_prompt(&user);
            if ids.len() > max_prompt {
                continue; // keep within the prefill window
            }
            prompts.push(Prompt {
                category: p
                    .get("category")
                    .and_then(|c| c.as_str())
                    .unwrap_or("unknown")
                    .to_string(),
                text: user,
                ids,
            });
        }
        if prompts.is_empty() {
            return Err(anyhow!("workload {name}: no prompts fit the prefill window"));
        }
        Ok(Workload { name: name.to_string(), prompts })
    }

    pub fn categories(&self) -> Vec<String> {
        let mut cats: Vec<String> = self.prompts.iter().map(|p| p.category.clone()).collect();
        cats.sort();
        cats.dedup();
        cats
    }

    pub fn by_category(&self, cat: &str) -> Vec<&Prompt> {
        self.prompts.iter().filter(|p| p.category == cat).collect()
    }

    pub fn take(&self, n: usize) -> Vec<&Prompt> {
        self.prompts.iter().take(n).collect()
    }
}
