//! Workload loading (S2-rust): eval prompt sets written by the AOT
//! pipeline (`artifacts/workloads/*.json`), grouped by task category.

use anyhow::{anyhow, Result};

use crate::runtime::Manifest;
use crate::text::bpe::Bpe;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Prompt {
    pub category: String,
    pub text: String,
    pub ids: Vec<u32>,
}

#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub prompts: Vec<Prompt>,
}

impl Workload {
    pub fn load(man: &Manifest, bpe: &Bpe, name: &str, max_prompt: usize) -> Result<Workload> {
        let rel = man
            .workloads
            .get(name)
            .ok_or_else(|| anyhow!("workload '{name}' not in manifest"))?;
        let text = std::fs::read_to_string(man.path(rel))?;
        let v = Json::parse(&text)?;
        let mut prompts = Vec::new();
        for p in v.req("prompts")?.as_arr().ok_or_else(|| anyhow!("prompts"))? {
            let user = p.req("user")?.as_str().unwrap_or_default().to_string();
            let ids = bpe.encode_prompt(&user);
            if ids.len() > max_prompt {
                continue; // keep within the prefill window
            }
            prompts.push(Prompt {
                category: p
                    .get("category")
                    .and_then(|c| c.as_str())
                    .unwrap_or("unknown")
                    .to_string(),
                text: user,
                ids,
            });
        }
        if prompts.is_empty() {
            return Err(anyhow!("workload {name}: no prompts fit the prefill window"));
        }
        Ok(Workload { name: name.to_string(), prompts })
    }

    pub fn categories(&self) -> Vec<String> {
        let mut cats: Vec<String> = self.prompts.iter().map(|p| p.category.clone()).collect();
        cats.sort();
        cats.dedup();
        cats
    }

    pub fn by_category(&self, cat: &str) -> Vec<&Prompt> {
        self.prompts.iter().filter(|p| p.category == cat).collect()
    }

    pub fn take(&self, n: usize) -> Vec<&Prompt> {
        self.prompts.iter().take(n).collect()
    }
}

/// A named artifact-free scenario for the `draftsrc` eval: a workload
/// class plus a representative prompt whose duplicate-3-gram ratio
/// (`spec::source::prompt_repetitiveness`) places it on the right side
/// of the n-gram/eagle crossover. No tokenizer or manifest needed —
/// the draft-source policy only consumes the repetitiveness signal.
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    pub name: &'static str,
    pub prompt: &'static str,
}

/// The three `draftsrc` scenarios: varied dialogue (eagle territory),
/// code (mildly repetitive), and repeated-unit JSON (n-gram territory).
pub fn synthetic_scenarios() -> [Scenario; 3] {
    [
        Scenario {
            name: "dialogue",
            prompt: "please compare the tradeoffs between optimistic and pessimistic \
                     locking for a busy checkout service, then recommend one with reasons",
        },
        Scenario {
            name: "code",
            prompt: "fn main() { for i in 0..10 { println!(\"{i}\"); } }\n\
                     fn main() { for j in 0..20 { println!(\"{j}\"); } }\n\
                     refactor these two entry points into one parameterized helper",
        },
        Scenario {
            name: "repetitive-json",
            prompt: "{\"id\":1,\"ok\":true},{\"id\":1,\"ok\":true},{\"id\":1,\"ok\":true},\
                     {\"id\":1,\"ok\":true},{\"id\":1,\"ok\":true},{\"id\":1,\"ok\":true}",
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::source::prompt_repetitiveness;

    #[test]
    fn scenarios_span_the_repetitiveness_axis() {
        let [dialogue, code, json] = synthetic_scenarios();
        let rd = prompt_repetitiveness(dialogue.prompt);
        let rj = prompt_repetitiveness(json.prompt);
        assert!(rd < 0.4, "dialogue scored {rd}");
        assert!(rj > 0.6, "repetitive json scored {rj}");
        assert!(rd < prompt_repetitiveness(code.prompt) || rd < rj);
    }
}
