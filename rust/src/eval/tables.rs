//! Paper-table regeneration (S18): one function per experiment id in
//! DESIGN.md §4 (fig1, fig2, fig3, fig5, fig8, fig9+tab5, fig10, tab1,
//! tab2, tab3, tab4, tab6, tab7). Each writes a markdown table to
//! `results/<id>.md` and returns it.

use anyhow::Result;
use std::fmt::Write as _;

use super::runner::{speedup, RunSpec, Runner};
use super::workload::{synthetic_scenarios, Workload};
use crate::coordinator::request::{Method, Request};
use crate::coordinator::{AdmissionPolicy, BatchEagleEngine, RequestQueue, Scheduler};
use crate::metrics::{Aggregate, GenRecord};
use crate::models::ModelBundle;
use crate::spec::dyntree::{DynTreeConfig, SourceSelector, TreePolicy};
use crate::spec::source::{prompt_repetitiveness, sim_accepted_per_round, SourceKind};
use crate::spec::engine::GenConfig;
use crate::spec::tree::TreeSpec;
use crate::text::bpe::Bpe;
use crate::util::deadline::DeadlineClock;
use crate::util::rng::Rng;

pub struct EvalCtx {
    pub runner: Runner,
    pub bpe: Bpe,
    pub n_prompts: usize,
    pub max_new: usize,
}

impl EvalCtx {
    pub fn new(artifacts: &std::path::Path, n_prompts: usize, max_new: usize) -> Result<EvalCtx> {
        let runner = Runner::new(artifacts)?;
        let bpe = Bpe::load(
            runner.man.path(&runner.man.tokenizer).to_str().unwrap(),
        )?;
        Ok(EvalCtx { runner, bpe, n_prompts, max_new })
    }

    fn workload(&self, name: &str) -> Result<Workload> {
        Workload::load(&self.runner.man, &self.bpe, name, self.runner.man.constants.prefill_p)
    }

    fn spec(&self, method: Method, t: f32) -> RunSpec {
        RunSpec { method, temperature: t, max_new: self.max_new, ..Default::default() }
    }

    fn fmt_alpha(a: &Aggregate) -> String {
        a.alphas()
            .iter()
            .map(|x| x.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()))
            .collect::<Vec<_>>()
            .join(" | ")
    }

    // ---------------------------------------------------------------------
    // fig1: greedy speedups, EAGLE vs Medusa vs Lookahead vs vanilla
    // ---------------------------------------------------------------------
    pub fn fig1(&self) -> Result<String> {
        let wl = self.workload("mtbench")?;
        let prompts = wl.take(self.n_prompts);
        let mut out = String::from(
            "# fig1 — Speedup on MT-bench analog, greedy (T=0)\n\n| model | method | speedup | tau | tokens/s |\n|---|---|---|---|---|\n",
        );
        for model in ["toy-s", "toy-m"] {
            let with_extras = model == "toy-s";
            let bundle = ModelBundle::load(
                &self.runner.rt, &self.runner.man, model, &["eagle"], with_extras, with_extras,
            )?;
            let base = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Vanilla, 0.0))?;
            let mut methods: Vec<(&str, Method)> = vec![("eagle", Method::Eagle)];
            if model == "toy-s" {
                methods.push(("medusa", Method::Medusa));
                methods.push(("lookahead", Method::Lookahead));
            }
            writeln!(
                out,
                "| {model} | vanilla | 1.00x | {:.2} | {:.1} |",
                base.tau(),
                base.tokens_per_sec()
            )?;
            for (name, m) in methods {
                let agg = self.runner.run_with(&bundle, &prompts, &self.spec(m, 0.0))?;
                writeln!(
                    out,
                    "| {model} | {name} | {:.2}x | {:.2} | {:.1} |",
                    speedup(&agg, &base),
                    agg.tau(),
                    agg.tokens_per_sec()
                )?;
            }
        }
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // fig2: non-greedy (T=1) speedups, EAGLE vs classic spec vs vanilla
    // ---------------------------------------------------------------------
    pub fn fig2(&self) -> Result<String> {
        let wl = self.workload("mtbench")?;
        let prompts = wl.take(self.n_prompts);
        let mut out = String::from(
            "# fig2 — Speedup on MT-bench analog, sampling (T=1)\n\n| model | method | speedup | tau |\n|---|---|---|---|\n",
        );
        for model in ["toy-s", "toy-m"] {
            let bundle = ModelBundle::load(
                &self.runner.rt, &self.runner.man, model, &["eagle"], false, model == "toy-s",
            )?;
            let base = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Vanilla, 1.0))?;
            writeln!(out, "| {model} | vanilla | 1.00x | {:.2} |", base.tau())?;
            let eagle = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Eagle, 1.0))?;
            writeln!(
                out,
                "| {model} | eagle | {:.2}x | {:.2} |",
                speedup(&eagle, &base),
                eagle.tau()
            )?;
            if model == "toy-s" {
                let cs =
                    self.runner.run_with(&bundle, &prompts, &self.spec(Method::ClassicSpec, 1.0))?;
                writeln!(
                    out,
                    "| {model} | classic-spec | {:.2}x | {:.2} |",
                    speedup(&cs, &base),
                    cs.tau()
                )?;
            }
        }
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // fig3/fig5/fig10: draft-input ablations (chain mode, toy-s)
    // ---------------------------------------------------------------------
    pub fn fig10(&self) -> Result<String> {
        let wl = self.workload("mtbench")?;
        let prompts = wl.take(self.n_prompts);
        let bundle = ModelBundle::load(
            &self.runner.rt,
            &self.runner.man,
            "toy-s",
            &["eagle", "unshift", "feat", "tok"],
            false,
            false,
        )?;
        let mut out = String::from(
            "# fig10 (also fig3, fig5) — draft-input ablation, chain drafting, toy-s\n\n| input | T | speedup | tau | 0-a | 1-a |\n|---|---|---|---|---|---|\n",
        );
        for t in [0.0f32, 1.0] {
            let base = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Vanilla, t))?;
            for variant in ["eagle", "unshift", "feat", "tok"] {
                let mut spec = self.spec(Method::EagleChain, t);
                spec.variant = variant.into();
                let agg = self.runner.run_with(&bundle, &prompts, &spec)?;
                let al = agg.alphas();
                writeln!(
                    out,
                    "| {} | {t} | {:.2}x | {:.2} | {} | {} |",
                    match variant {
                        "eagle" => "feature&shifted-token (EAGLE)",
                        "unshift" => "feature&unshifted-token",
                        "feat" => "feature",
                        _ => "token",
                    },
                    speedup(&agg, &base),
                    agg.tau(),
                    al[0].map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
                    al[1].map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
                )?;
            }
        }
        out.push_str("\nfig3 = token vs feature rows; fig5 = feature vs feature&shifted rows.\n");
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // fig8: speedup per task category
    // ---------------------------------------------------------------------
    pub fn fig8(&self) -> Result<String> {
        let wl = self.workload("mtbench")?;
        let bundle = ModelBundle::load(
            &self.runner.rt, &self.runner.man, "toy-s", &["eagle"], false, false,
        )?;
        let mut out = String::from(
            "# fig8 — EAGLE speedup by task category (toy-s, T=0)\n\n| category | speedup | tau |\n|---|---|---|\n",
        );
        let per_cat = (self.n_prompts / 4).max(2);
        for cat in wl.categories() {
            let prompts: Vec<_> = wl.by_category(&cat).into_iter().take(per_cat).collect();
            if prompts.is_empty() {
                continue;
            }
            let base = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Vanilla, 0.0))?;
            let agg = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Eagle, 0.0))?;
            writeln!(out, "| {cat} | {:.2}x | {:.2} |", speedup(&agg, &base), agg.tau())?;
        }
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // fig9 + tab5: tree vs chain
    // ---------------------------------------------------------------------
    pub fn fig9(&self) -> Result<String> {
        let wl = self.workload("mtbench")?;
        let prompts = wl.take(self.n_prompts);
        let mut out = String::from(
            "# fig9 + tab5 — tree vs chain draft (T=0)\n\n| model | mode | speedup | tau |\n|---|---|---|---|\n",
        );
        for model in ["toy-s", "toy-m"] {
            let bundle = ModelBundle::load(
                &self.runner.rt, &self.runner.man, model, &["eagle"], false, false,
            )?;
            let base = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Vanilla, 0.0))?;
            for (mode, m) in [("chain", Method::EagleChain), ("tree", Method::Eagle)] {
                let agg = self.runner.run_with(&bundle, &prompts, &self.spec(m, 0.0))?;
                writeln!(
                    out,
                    "| {model} | {mode} | {:.2}x | {:.2} |",
                    speedup(&agg, &base),
                    agg.tau()
                )?;
            }
        }
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // tab1/tab2: tau + n-alpha per model (chain stats for alpha)
    // ---------------------------------------------------------------------
    pub fn tab12(&self, workload: &str) -> Result<String> {
        let wl = self.workload(workload)?;
        let prompts = wl.take(self.n_prompts);
        let mut out = format!(
            "# {} — tau (tree) and n-alpha (chain) per model\n\n| model | T | speedup | tau | 0-a | 1-a | 2-a | 3-a | 4-a |\n|---|---|---|---|---|---|---|---|---|\n",
            if workload == "gsm8k" { "tab2" } else { "tab1" }
        );
        for model in ["toy-s", "toy-m"] {
            let bundle = ModelBundle::load(
                &self.runner.rt, &self.runner.man, model, &["eagle"], false, false,
            )?;
            for t in [0.0f32, 1.0] {
                let base = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Vanilla, t))?;
                let tree = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Eagle, t))?;
                let chain =
                    self.runner.run_with(&bundle, &prompts, &self.spec(Method::EagleChain, t))?;
                writeln!(
                    out,
                    "| {model} | {t} | {:.2}x | {:.2} | {} |",
                    speedup(&tree, &base),
                    tree.tau(),
                    Self::fmt_alpha(&chain)
                )?;
            }
        }
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // tab3: MoE target
    // ---------------------------------------------------------------------
    pub fn tab3(&self) -> Result<String> {
        let wl = self.workload("mtbench")?;
        let prompts = wl.take(self.n_prompts);
        let bundle = ModelBundle::load(
            &self.runner.rt, &self.runner.man, "toy-moe", &["eagle"], false, false,
        )?;
        let base = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Vanilla, 0.0))?;
        let tree = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Eagle, 0.0))?;
        let chain = self.runner.run_with(&bundle, &prompts, &self.spec(Method::EagleChain, 0.0))?;
        let mut out = String::from(
            "# tab3 — MoE target (Mixtral analog), MT-bench analog, T=0\n\n| speedup | tau | 0-a | 1-a | 2-a | 3-a | 4-a |\n|---|---|---|---|---|---|---|\n",
        );
        writeln!(
            out,
            "| {:.2}x | {:.2} | {} |",
            speedup(&tree, &base),
            tree.tau(),
            Self::fmt_alpha(&chain)
        )?;
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // tab4: quantization composition (gpt-fast analog)
    // ---------------------------------------------------------------------
    pub fn tab4(&self) -> Result<String> {
        let wl = self.workload("mtbench")?;
        let prompts = wl.take(self.n_prompts.min(8));
        let mut out = String::from(
            "# tab4 — EAGLE composes with weight quantization (gpt-fast analog)\n\n| precision | method | tokens/s | weights MB |\n|---|---|---|---|\n",
        );
        for model in ["toy-s", "toy-s-int8"] {
            let bundle = ModelBundle::load(
                &self.runner.rt, &self.runner.man, model, &["eagle"], false, false,
            )?;
            let mb = bundle.target.exes.params.total_bytes as f64 / 1e6;
            let base = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Vanilla, 0.0))?;
            let eagle = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Eagle, 0.0))?;
            let prec = if model.ends_with("int8") { "int8" } else { "fp32" };
            writeln!(out, "| {prec} | vanilla | {:.1} | {mb:.1} |", base.tokens_per_sec())?;
            writeln!(out, "| {prec} | eagle | {:.1} | {mb:.1} |", eagle.tokens_per_sec())?;
        }
        out.push_str(
            "\nNote: on this CPU-f32 substrate int8 shows the composition + memory\nreduction, not a wallclock win (dequant-in-graph); see DESIGN.md.\n",
        );
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // tab6: training-data ablation (fixed vs target-generated)
    // ---------------------------------------------------------------------
    pub fn tab6(&self) -> Result<String> {
        let wl = self.workload("mtbench")?;
        let prompts = wl.take(self.n_prompts);
        let bundle = ModelBundle::load(
            &self.runner.rt, &self.runner.man, "toy-s", &["eagle", "eagle_gen"], false, false,
        )?;
        let base = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Vanilla, 0.0))?;
        let mut out = String::from(
            "# tab6 — training data ablation (toy-s, T=0)\n\n| training data | speedup | tau |\n|---|---|---|\n",
        );
        let ablations = [("fixed dataset", "eagle"), ("generated by target LLM", "eagle_gen")];
        for (label, variant) in ablations {
            let mut spec = self.spec(Method::Eagle, 0.0);
            spec.variant = variant.into();
            let agg = self.runner.run_with(&bundle, &prompts, &spec)?;
            writeln!(out, "| {label} | {:.2}x | {:.2} |", speedup(&agg, &base), agg.tau())?;
        }
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // tab7: batch-size sweep + throughput
    // ---------------------------------------------------------------------
    pub fn tab7(&self) -> Result<String> {
        let wl = self.workload("mtbench")?;
        let bundle = ModelBundle::load(
            &self.runner.rt, &self.runner.man, "toy-s", &["eagle"], false, false,
        )?;
        let c = &self.runner.man.constants;
        let cfg = GenConfig { max_new: self.max_new, temperature: 0.0, seed: 7, eos: None };
        let mut out = String::from(
            "# tab7 — speedup vs batch size + throughput (toy-s, T=0)\n\n| bs | vanilla tok/s | eagle tok/s | speedup |\n|---|---|---|---|\n",
        );
        let mut best_v = 0.0f64;
        let mut best_e = 0.0f64;
        // bs=1 via the latency engines
        let prompts1 = wl.take(self.n_prompts.min(6));
        let base1 = self.runner.run_with(&bundle, &prompts1, &self.spec(Method::Vanilla, 0.0))?;
        let eagle1 = self.runner.run_with(&bundle, &prompts1, &self.spec(Method::Eagle, 0.0))?;
        writeln!(
            out,
            "| 1 | {:.1} | {:.1} | {:.2}x |",
            base1.tokens_per_sec(),
            eagle1.tokens_per_sec(),
            speedup(&eagle1, &base1)
        )?;
        best_v = best_v.max(base1.tokens_per_sec());
        best_e = best_e.max(eagle1.tokens_per_sec());
        for bs in [2usize, 3, 4] {
            let groups = 2usize;
            let be = BatchEagleEngine::new(&bundle.target, &bundle.drafts["eagle"], c);
            let (mut vtok, mut vns, mut etok, mut ens) = (0usize, 0u64, 0usize, 0u64);
            for g in 0..groups {
                let prompts: Vec<Vec<u32>> = wl
                    .prompts
                    .iter()
                    .cycle()
                    .skip(g * bs)
                    .take(bs)
                    .map(|p| p.ids.clone())
                    .collect();
                let vrecs = be.vanilla_batch(&prompts, &cfg)?;
                vtok += vrecs.iter().map(|r| r.tokens.len()).sum::<usize>();
                vns += vrecs[0].wall_ns;
                let erecs = be.generate(&prompts, &cfg)?;
                etok += erecs.iter().map(|r| r.tokens.len()).sum::<usize>();
                ens += erecs[0].wall_ns;
            }
            let vtps = vtok as f64 / (vns as f64 / 1e9);
            let etps = etok as f64 / (ens as f64 / 1e9);
            writeln!(out, "| {bs} | {vtps:.1} | {etps:.1} | {:.2}x |", etps / vtps)?;
            best_v = best_v.max(vtps);
            best_e = best_e.max(etps);
        }
        writeln!(
            out,
            "\nMax throughput: vanilla {best_v:.1} tok/s, eagle {best_e:.1} tok/s -> {:.2}x",
            best_e / best_v
        )?;
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // dyntree: tau vs verify budget — static vs dynamic, plus the
    // controller-driven verify-width selection (mean verify t column)
    // and the sampled (T=1) tau per budget (the SpecInfer acceptance
    // path — distribution-preserving, so tau is the cost of sampling)
    // ---------------------------------------------------------------------
    pub fn dyntree(&self) -> Result<String> {
        let wl = self.workload("mtbench")?;
        let prompts = wl.take(self.n_prompts);
        let bundle = ModelBundle::load(
            &self.runner.rt, &self.runner.man, "toy-s", &["eagle", "tok"], false, false,
        )?;
        let mut out = String::from(
            "# dyntree — tau vs verify budget, static vs dynamic (toy-s, T=0 + T=1)\n\n\
             | policy | budget t | speedup | tau | tau T=1 | tokens/s | mean tree nodes \
             | mean verify t |\n\
             |---|---|---|---|---|---|---|---|\n",
        );
        let base = self.runner.run_with(&bundle, &prompts, &self.spec(Method::Vanilla, 0.0))?;
        writeln!(
            out,
            "| vanilla | - | 1.00x | {:.2} | - | {:.1} | - | - |",
            base.tau(),
            base.tokens_per_sec()
        )?;
        // sampled tau for the same spec: T=1 rounds run the SpecInfer
        // recursive-rejection walk instead of the greedy match
        let t1_tau = |spec: &RunSpec| -> Result<f64> {
            let mut s1 = spec.clone();
            s1.temperature = 1.0;
            Ok(self.runner.run_with(&bundle, &prompts, &s1)?.tau())
        };
        // tau-vs-budget sweep: equal-budget static/dynamic pairs per tree_t
        // each level width must be reachable: <= prev level's count * branch
        let static_shapes: [(usize, Vec<usize>); 4] = [
            (8, vec![3, 2, 2]),
            (16, vec![4, 6, 5]),
            (26, TreeSpec::tree_default().level_widths),
            (32, vec![4, 10, 10, 7]),
        ];
        // all dyntree rows run the eagle feature-extrapolation source;
        // dyntree_row_label keeps their historical labels byte-stable
        // (regression-guarded) while non-eagle sources would be tagged
        let src = SourceKind::Eagle;
        for (t, widths) in static_shapes {
            let label: Vec<String> = widths.iter().map(|w| w.to_string()).collect();
            let mut spec = self.spec(Method::Eagle, 0.0);
            spec.tree = TreePolicy::Static(TreeSpec { level_widths: widths, branch: 4 });
            let st = self.runner.run_with(&bundle, &prompts, &spec)?;
            writeln!(
                out,
                "| {} | {t} | {:.2}x | {:.2} | {:.2} | {:.1} | {:.1} | {:.1} |",
                dyntree_row_label(&format!("static {}", label.join("/")), src),
                speedup(&st, &base),
                st.tau(),
                t1_tau(&spec)?,
                st.tokens_per_sec(),
                st.mean_tree_nodes(),
                st.mean_verify_t()
            )?;
            let mut spec = self.spec(Method::Eagle, 0.0);
            spec.tree =
                TreePolicy::Dynamic(DynTreeConfig { budget: Some(t - 1), ..Default::default() });
            let dy = self.runner.run_with(&bundle, &prompts, &spec)?;
            writeln!(
                out,
                "| {} | {t} | {:.2}x | {:.2} | {:.2} | {:.1} | {:.1} | {:.1} |",
                dyntree_row_label("dynamic (adaptive)", src),
                speedup(&dy, &base),
                dy.tau(),
                t1_tau(&spec)?,
                dy.tokens_per_sec(),
                dy.mean_tree_nodes(),
                dy.mean_verify_t()
            )?;
        }
        // low-acceptance synthetic workload: the weak token-only draft head
        // collapses acceptance, the per-request controller shrinks its
        // speculation, and width selection drops below tree_t
        let mut weak = self.spec(Method::Eagle, 0.0);
        weak.variant = "tok".into();
        weak.tree = TreePolicy::Dynamic(DynTreeConfig::default());
        if bundle.drafts.contains_key("tok") {
            let lo = self.runner.run_with(&bundle, &prompts, &weak)?;
            writeln!(
                out,
                "| {} | full | {:.2}x | {:.2} | {:.2} | {:.1} \
                 | {:.1} | {:.1} |",
                dyntree_row_label("dynamic, weak tok draft (low alpha)", src),
                speedup(&lo, &base),
                lo.tau(),
                t1_tau(&weak)?,
                lo.tokens_per_sec(),
                lo.mean_tree_nodes(),
                lo.mean_verify_t()
            )?;
        }
        // batched lanes: per-lane controllers adapt each lane independently;
        // the round width is the max over lane fits
        let bprompts: Vec<Vec<u32>> = wl.prompts.iter().take(2).map(|p| p.ids.clone()).collect();
        if bprompts.len() == 2 {
            let c = &self.runner.man.constants;
            let cfg = GenConfig { max_new: self.max_new, temperature: 0.0, seed: 7, eos: None };
            let eq_budget = Some(TreeSpec::tree_default().total_nodes() - 1);
            for (label, policy) in [
                ("bs=2 static", TreePolicy::default_tree()),
                (
                    "bs=2 dynamic (per-lane)",
                    TreePolicy::Dynamic(DynTreeConfig { budget: eq_budget, ..Default::default() }),
                ),
            ] {
                let be = BatchEagleEngine::new(&bundle.target, &bundle.drafts["eagle"], c)
                    .with_policy(policy);
                let recs = be.generate(&bprompts, &cfg)?;
                // sampled lock-step lanes: per-lane RNG streams + the
                // SpecInfer walk — the batched T=1 column
                let cfg1 = GenConfig { temperature: 1.0, ..cfg.clone() };
                let recs1 = be.generate(&bprompts, &cfg1)?;
                let (mut agg, mut agg1) = (Aggregate::new(), Aggregate::new());
                for r in &recs {
                    agg.add(r);
                }
                for r in &recs1 {
                    agg1.add(r);
                }
                writeln!(
                    out,
                    "| {} | 26 | - | {:.2} | {:.2} | {:.1} | {:.1} | {:.1} |",
                    dyntree_row_label(label, src),
                    agg.tau(),
                    agg1.tau(),
                    agg.tokens_per_sec(),
                    agg.mean_tree_nodes(),
                    agg.mean_verify_t()
                )?;
            }
        }
        out.push_str(
            "\nEach budget row pairs a static tree of budget-1 nodes with the dynamic\n\
             planner at the same node budget. 'mean verify t' is the mean lowered\n\
             verify_t{t} width actually dispatched per round (the verify_widths\n\
             family); it falls below tree_t whenever the controller's acceptance\n\
             EWMA caps a request's budget to a cheaper executable. The weak-draft\n\
             row is the low-acceptance regime: speculation shrinks and rounds run\n\
             on the chain-like t8 width. 'tau T=1' re-runs the same spec at\n\
             temperature 1: rounds sample their trees from q and accept via the\n\
             SpecInfer recursive-rejection rule (distribution-preserving), so the\n\
             column shows what sampling costs in accepted tokens per pass; at T>0\n\
             dynamic growth is budget-capped BEFORE sampling, so it stays\n\
             lossless. The bs=2 rows run the batched engine (per-lane RNG\n\
             streams at T=1 — each lane matches its equal-seed bs=1 run).\n",
        );
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // phases: per-method phase breakdown (GenRecord.timeline) + latency
    // percentiles from the Aggregate's sorted cache — the offline twin
    // of the server's eagle_phase_seconds_total counters and p50/p99
    // gauges
    // ---------------------------------------------------------------------
    pub fn phases(&self) -> Result<String> {
        let wl = self.workload("mtbench")?;
        let prompts = wl.take(self.n_prompts);
        let bundle = ModelBundle::load(
            &self.runner.rt, &self.runner.man, "toy-s", &["eagle"], false, false,
        )?;
        let mut out = String::from(
            "# phases — per-method phase breakdown + latency percentiles (toy-s, T=0)\n\n\
             | method | prefill % | draft % | verify % | commit % | host % | p50 ms | p90 ms \
             | p99 ms | tok/s |\n\
             |---|---|---|---|---|---|---|---|---|---|\n",
        );
        for (name, m) in [
            ("vanilla", Method::Vanilla),
            ("eagle", Method::Eagle),
            ("eagle-chain", Method::EagleChain),
        ] {
            let agg = self.runner.run_with(&bundle, &prompts, &self.spec(m, 0.0))?;
            let tl = &agg.timeline;
            let tot = (tl.total_ns() as f64).max(1.0);
            writeln!(
                out,
                "| {name} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} \
                 | {:.1} |",
                tl.prefill_ns as f64 / tot * 100.0,
                tl.draft_ns as f64 / tot * 100.0,
                tl.verify_ns as f64 / tot * 100.0,
                tl.commit_ns as f64 / tot * 100.0,
                tl.host_ns as f64 / tot * 100.0,
                agg.latency_p50_ms(),
                agg.latency_p90_ms(),
                agg.latency_p99_ms(),
                agg.tokens_per_sec(),
            )?;
        }
        out.push_str(
            "\nPhase columns split each method's wall time by `GenRecord.timeline`\n\
             (prefill / draft / verify / commit / host); vanilla has no draft or\n\
             verify phase, so its decode cost lands in commit+host. Percentiles\n\
             come from the Aggregate's sorted latency cache — the same helpers\n\
             behind the server's eagle_latency_p50/p99_seconds gauges.\n",
        );
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // widthsched: width-grouped admission vs FCFS max-width batching at
    // equal offered load (half the lanes low-acceptance)
    // ---------------------------------------------------------------------
    pub fn widthsched(&self) -> Result<String> {
        let wl = self.workload("mtbench")?;
        let bundle = ModelBundle::load(
            &self.runner.rt, &self.runner.man, "toy-s", &["eagle"], false, false,
        )?;
        let c = &self.runner.man.constants;
        let narrow = *c.verify_widths.first().unwrap_or(&c.tree_t);
        let half = 2usize;
        // offered load: `half` hot in-distribution lanes + `half`
        // low-acceptance lanes (random-token prompts collapse the draft
        // head's hit rate), interleaved in arrival order. Low lanes carry
        // a narrow width hint — the prediction a client profile or a
        // requeue path with a live controller EWMA would supply.
        let mut rng = Rng::new(41);
        let hot: Vec<Vec<u32>> = wl.prompts.iter().take(half).map(|p| p.ids.clone()).collect();
        let low: Vec<Vec<u32>> = (0..half)
            .map(|_| (0..32).map(|_| rng.below(bundle.target.vocab) as u32).collect())
            .collect();
        let mut prompts: Vec<Vec<u32>> = Vec::new();
        let mut hints: Vec<usize> = Vec::new();
        let mut is_low: Vec<bool> = Vec::new();
        for i in 0..half {
            prompts.push(hot[i].clone());
            hints.push(c.tree_t);
            is_low.push(false);
            prompts.push(low[i].clone());
            hints.push(narrow);
            is_low.push(true);
        }
        let n = prompts.len();
        let offered = |q: &RequestQueue| -> Result<()> {
            for (i, &hint) in hints.iter().enumerate() {
                let mut r = Request::synthetic(i as u64);
                r.method = Method::Eagle;
                r.max_tokens = self.max_new;
                r.width_hint = Some(hint);
                q.push(r).map_err(|e| anyhow::anyhow!("queue push failed: {e:?}"))?;
            }
            Ok(())
        };
        let policy = || TreePolicy::Dynamic(DynTreeConfig::default());
        let cfg = GenConfig { max_new: self.max_new, temperature: 0.0, seed: 7, eos: None };

        // --- FCFS: one arrival-ordered batch; execution width is the
        //     max over lane fits (low lanes dragged by hot lanes) -------
        let q = RequestQueue::new(n * 2);
        offered(&q)?;
        let sched = Scheduler::new(n, 0);
        let batch = sched.next_batch(&q);
        anyhow::ensure!(batch.len() == n, "fcfs admission lost requests");
        let be = BatchEagleEngine::new(&bundle.target, &bundle.drafts["eagle"], c)
            .with_policy(policy());
        let fcfs_recs = be.generate(&prompts, &cfg)?;
        let fcfs_queue_ms = sched.mean_queue_ms();

        // --- grouped: width-aware sub-batches, each executed with the
        //     group's verify cap (group-local fits) ---------------------
        let q = RequestQueue::new(n * 2);
        offered(&q)?;
        let sched = Scheduler::new(n, 0).with_policy(AdmissionPolicy::WidthGrouped {
            verify_widths: c.verify_widths.clone(),
            max_t: c.tree_t,
        });
        let groups = sched.next_groups(&q);
        let mut grp_recs: Vec<Option<GenRecord>> = (0..n).map(|_| None).collect();
        let mut shape: Vec<String> = Vec::new();
        for g in &groups {
            let idx: Vec<usize> = g.requests.iter().map(|r| r.id as usize).collect();
            let cap = g.verify_cap.unwrap_or(c.tree_t);
            shape.push(format!("t{cap} bs{}", idx.len()));
            anyhow::ensure!(idx.len() >= 2, "widthsched load must form multi-lane groups");
            let gp: Vec<Vec<u32>> = idx.iter().map(|&i| prompts[i].clone()).collect();
            let be = BatchEagleEngine::new(&bundle.target, &bundle.drafts["eagle"], c)
                .with_policy(policy())
                .with_verify_cap(cap);
            for (j, rec) in be.generate(&gp, &cfg)?.into_iter().enumerate() {
                grp_recs[idx[j]] = Some(rec);
            }
        }
        let grp_recs: Vec<GenRecord> =
            grp_recs.into_iter().map(|r| r.expect("every lane ran in a group")).collect();
        let grp_queue_ms = sched.mean_queue_ms();

        // --- compare ---------------------------------------------------
        let agg = |recs: &[GenRecord], only_low: Option<bool>| {
            let mut a = Aggregate::new();
            for (i, r) in recs.iter().enumerate() {
                if only_low.map(|v| is_low[i] == v).unwrap_or(true) {
                    a.add(r);
                }
            }
            a
        };
        let mut out = String::from(
            "# widthsched — width-grouped admission vs FCFS max-width batching (toy-s, T=0)\n\n",
        );
        out.push_str("| mode | lanes | mean verify-t | mean draft-w | tau | tok/s | p50 ms |");
        out.push_str(" p99 ms | queue-ms | dragged lane-rounds |\n");
        out.push_str("|---|---|---|---|---|---|---|---|---|---|\n");
        for (mode, recs, qms) in [
            ("fcfs", &fcfs_recs, fcfs_queue_ms),
            ("grouped", &grp_recs, grp_queue_ms),
        ] {
            for (label, sel) in
                [("all", None), ("hot lanes", Some(false)), ("low lanes", Some(true))]
            {
                let a = agg(recs, sel);
                writeln!(
                    out,
                    "| {mode} | {label} ({}) | {:.1} | {:.1} | {:.2} | {:.1} | {:.1} | {:.1} \
                     | {:.3} | {} |",
                    a.n,
                    a.mean_verify_t(),
                    a.mean_draft_w(),
                    a.tau(),
                    a.tokens_per_sec(),
                    a.latency_p50_ms(),
                    a.latency_p99_ms(),
                    qms,
                    a.dragged_rounds
                )?;
            }
        }
        writeln!(
            out,
            "\ngroup shapes: fcfs = bs{n} at the max over lane fits; grouped = {}",
            shape.join(" + ")
        )?;
        // acceptance: identical greedy outputs per request, and the
        // grouped schedule strictly cheaper on both width axes
        let identical = fcfs_recs.iter().zip(&grp_recs).all(|(a, b)| a.tokens == b.tokens);
        writeln!(out, "outputs identical per request: {}", if identical { "yes" } else { "NO" })?;
        anyhow::ensure!(identical, "width grouping changed greedy outputs");
        let (fa, ga) = (agg(&fcfs_recs, None), agg(&grp_recs, None));
        anyhow::ensure!(
            ga.mean_verify_t() < fa.mean_verify_t(),
            "grouped mean verify-t {:.2} not below fcfs {:.2}",
            ga.mean_verify_t(),
            fa.mean_verify_t()
        );
        anyhow::ensure!(
            ga.mean_draft_w() < fa.mean_draft_w(),
            "grouped mean draft-w {:.2} not below fcfs {:.2}",
            ga.mean_draft_w(),
            fa.mean_draft_w()
        );
        anyhow::ensure!(
            ga.dragged_rounds < fa.dragged_rounds,
            "grouping did not reduce dragged lane-rounds"
        );
        out.push_str(
            "\nEqual offered load (same prompts, arrival order, and max-new). FCFS admits\n\
             one batch and every round executes at the max over lane width fits, so the\n\
             low-acceptance lanes ride the hot lanes' verify_t and step_w executables\n\
             ('dragged lane-rounds'). Width-grouped admission splits the batch by each\n\
             request's width_hint under the scheduler cost model; the low group runs\n\
             chain-like (t8 verify, w1/w4 draft steps) while the hot group keeps its\n\
             width — outputs stay bit-identical because greedy speculative decoding is\n\
             lossless for any tree shape.\n",
        );

        // --- robustness surface: per-lane deadlines on the same batch --
        // Low lanes carry an already-expired deadline: they stop at the
        // first round boundary with partial output marked truncated,
        // while their unbounded batch peers must finish bit-identically
        // (done-lane padding is harmless). The same generations feed the
        // serving registry's derived gauges, so the eval prints exactly
        // what `GET /metrics` would.
        let start = std::time::Instant::now();
        let deadlines: Vec<DeadlineClock> = (0..n)
            .map(|i| if is_low[i] { DeadlineClock::at(start) } else { DeadlineClock::unbounded() })
            .collect();
        let be = BatchEagleEngine::new(&bundle.target, &bundle.drafts["eagle"], c)
            .with_policy(policy())
            .with_deadlines(deadlines);
        let dl_recs = be.generate(&prompts, &cfg)?;
        let m = crate::server::ServerMetrics::new(8);
        for r in &dl_recs {
            m.on_request();
            m.record_gen(r, 0.0, r.wall_ns as f64 / 1e9, n as u64);
        }
        m.refresh_derived();
        let exp = crate::metrics::registry::parse_exposition(&m.render())?;
        let g = |name: &str| exp.value(name).unwrap_or(0.0);
        let truncated = dl_recs.iter().filter(|r| r.truncated.is_some()).count();
        writeln!(
            out,
            "\nrobustness (expired deadline on the low lanes): {truncated}/{n} lanes \
             truncated; deadline-miss rate {:.2}, shed rate {:.2}, worker restarts {}, \
             est service {:.4}s",
            g("eagle_deadline_miss_rate"),
            g("eagle_shed_rate"),
            g("eagle_worker_restarts"),
            g("eagle_est_service_seconds"),
        )?;
        for (i, r) in dl_recs.iter().enumerate() {
            anyhow::ensure!(
                r.truncated.is_some() == is_low[i],
                "lane {i}: deadline truncation must match the armed lanes"
            );
            anyhow::ensure!(
                is_low[i] || r.tokens == fcfs_recs[i].tokens,
                "lane {i}: an unbounded lane must not be perturbed by expired batch peers"
            );
        }
        anyhow::ensure!(
            (g("eagle_deadline_miss_rate") - truncated as f64 / n as f64).abs() < 1e-9,
            "deadline-miss gauge must mirror the truncated-lane ratio"
        );
        Ok(out)
    }

    /// Run one experiment by id.
    pub fn run(&self, id: &str) -> Result<String> {
        match id {
            "fig1" => self.fig1(),
            "fig2" => self.fig2(),
            "fig3" | "fig5" | "fig10" => self.fig10(),
            "fig8" => self.fig8(),
            "fig9" | "tab5" => self.fig9(),
            "tab1" => self.tab12("mtbench"),
            "tab2" => self.tab12("gsm8k"),
            "tab3" => self.tab3(),
            "tab4" => self.tab4(),
            "tab6" => self.tab6(),
            "tab7" => self.tab7(),
            "dyntree" => self.dyntree(),
            "widthsched" => self.widthsched(),
            "phases" => self.phases(),
            "draftsrc" => draftsrc(),
            _ => Err(anyhow::anyhow!("unknown experiment id '{id}'")),
        }
    }

    pub const ALL: [&'static str; 15] = [
        "fig1", "fig2", "fig8", "fig9", "fig10", "tab1", "tab2", "tab3", "tab4", "tab6", "tab7",
        "dyntree", "widthsched", "phases", "draftsrc",
    ];
}

/// Label a dyntree row with its draft source. The default eagle source
/// returns the historical label unchanged — byte-for-byte, so existing
/// `results/dyntree.md` diffs stay clean (regression-guarded below) —
/// while any other source appends a `[source]` tag.
pub fn dyntree_row_label(base: &str, source: SourceKind) -> String {
    match source {
        SourceKind::Eagle => base.to_string(),
        other => format!("{base} [{}]", other.as_str()),
    }
}

// ---------------------------------------------------------------------------
// draftsrc: online draft-source policy convergence per workload scenario
// ---------------------------------------------------------------------------

/// `draftsrc` — artifact-free convergence table for the `--draft auto`
/// policy. Per scenario a fresh [`SourceSelector`] runs the same
/// pick/observe loop the server runs (observations come from the shared
/// acceptance simulation keyed on the scenario prompt's duplicate-3-gram
/// ratio), and the row reports the converged winner, its cost-normalized
/// score, the policy's depth hint, per-source pick counts, and switch
/// count. Convergence is asserted: repetitive JSON must settle on the
/// n-gram source and varied dialogue on eagle.
pub fn draftsrc() -> Result<String> {
    let mut out = String::from(
        "# draftsrc — online draft-source policy convergence per scenario (T=0)\n\n\
         | scenario | repetitiveness | winner | score | depth hint | picks e/c/n/m | switches |\n\
         |---|---|---|---|---|---|---|\n",
    );
    for sc in synthetic_scenarios() {
        let r = prompt_repetitiveness(sc.prompt);
        let sel = SourceSelector::new();
        for _ in 0..200 {
            let k = sel.pick(0.0);
            sel.observe(k, sim_accepted_per_round(k, r));
        }
        let w = sel.best(0.0);
        writeln!(
            out,
            "| {} | {r:.2} | {} | {:.2} | {} | {}/{}/{}/{} | {} |",
            sc.name,
            w.as_str(),
            sel.score(w),
            sel.depth_hint(w),
            sel.picks(SourceKind::Eagle),
            sel.picks(SourceKind::Chain),
            sel.picks(SourceKind::Ngram),
            sel.picks(SourceKind::Medusa),
            sel.switches(),
        )?;
        match sc.name {
            "dialogue" => anyhow::ensure!(
                w == SourceKind::Eagle,
                "dialogue must converge to eagle, got {w:?}"
            ),
            "repetitive-json" => anyhow::ensure!(
                w == SourceKind::Ngram,
                "repetitive JSON must converge to ngram, got {w:?}"
            ),
            _ => {}
        }
    }
    out.push_str(
        "\nEach row runs a fresh selector through 200 requests of one scenario:\n\
         deterministic round-robin probing until every source has 4\n\
         observations, then the best cost-normalized acceptance EWMA\n\
         (accepted tokens per round / relative drafting cost). `score` is the\n\
         winner's converged EWMA over its cost hint; `picks e/c/n/m` counts\n\
         requests routed to eagle/chain/ngram/medusa — the winner dominates\n\
         after the probe phase, so switches stay small. The same selector and\n\
         simulation drive `--draft auto` in the synthetic server, so this\n\
         table is the offline twin of eagle_policy_switches_total and the\n\
         eagle_draft_source_rounds_total family.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dyntree_labels_byte_stable_for_eagle() {
        // regression guard: the default source must not perturb the
        // historical dyntree row labels
        for base in ["static 4/8/8/5", "dynamic (adaptive)", "bs=2 static"] {
            assert_eq!(dyntree_row_label(base, SourceKind::Eagle), base);
        }
        assert_eq!(
            dyntree_row_label("dynamic (adaptive)", SourceKind::Ngram),
            "dynamic (adaptive) [ngram]"
        );
        assert_eq!(dyntree_row_label("bs=2 static", SourceKind::Medusa), "bs=2 static [medusa]");
    }

    #[test]
    fn draftsrc_converges_per_scenario() {
        let table = draftsrc().expect("draftsrc must converge");
        assert!(table.contains("| dialogue |"));
        assert!(table.contains("| repetitive-json |"));
        // winners per the ensure! asserts inside draftsrc(); spot-check
        // the rendered rows as well
        let dialogue_row = table.lines().find(|l| l.starts_with("| dialogue |")).unwrap();
        assert!(dialogue_row.contains("| eagle |"), "{dialogue_row}");
        let json_row = table.lines().find(|l| l.starts_with("| repetitive-json |")).unwrap();
        assert!(json_row.contains("| ngram |"), "{json_row}");
    }
}
