//! # eagle-serve
//!
//! A serving framework reproducing **EAGLE: Speculative Sampling Requires
//! Rethinking Feature Uncertainty** (ICML 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — request router, continuous batcher, KV-slot
//!   manager, the EAGLE draft-tree engine, SpecInfer-style verification,
//!   baselines, metrics, HTTP server, CLI and the paper-table harness.
//!   Draft trees are shaped by a [`spec::dyntree::TreePolicy`]: the
//!   paper's static 4/8/8/5 tree, or the dynamic planner
//!   ([`spec::dyntree`]) that grows confidence-driven trees per round,
//!   globally reranks them to the verify budget, and adapts speculation
//!   depth/width per request from an online acceptance EWMA. The round
//!   loop runs on reusable flat arenas ([`spec::scratch`]) — no host
//!   heap allocation in steady state (tracked by
//!   `GenRecord::round_host_alloc_bytes`).
//! * **L2** — JAX model graphs AOT-lowered to HLO text
//!   (`python/compile/`), executed via the `xla` crate / PJRT.
//! * **L1** — the Pallas tree-attention kernel inside those graphs.
//!
//! Quickstart (after `make artifacts && cargo build --release`):
//!
//! ```no_run
//! use eagle_serve::prelude::*;
//! let rt = Runtime::cpu().unwrap();
//! let man = Manifest::load(&artifacts_dir()).unwrap();
//! let bundle = ModelBundle::load(&rt, &man, "toy-s", &["eagle"], false, false).unwrap();
//! let draft = &bundle.drafts["eagle"];
//! let engine = EagleEngine::new_tree(&bundle.target, draft, &man.constants);
//! let rec = engine.generate(&[1, 2, 3], &GenConfig::default()).unwrap();
//! println!("{} tokens in {:.1} ms", rec.tokens.len(), rec.wall_ns as f64 / 1e6);
//! ```

// Allocator-level verification of the zero-alloc round guarantee: under
// the test-only `count-alloc` feature the whole crate (and every test
// binary linking it) runs on a thread-local counting allocator, and the
// engines record per-round allocation deltas into
// `GenRecord::round_alloc_counted_bytes` (see `util::count_alloc`).
#[cfg(feature = "count-alloc")]
#[global_allocator]
static COUNTING_ALLOC: util::count_alloc::CountingAlloc = util::count_alloc::CountingAlloc;

pub mod baselines;
pub mod coordinator;
pub mod eval;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod server;
pub mod spec;
pub mod text;
pub mod util;

pub mod prelude {
    pub use crate::baselines::{ClassicSpecEngine, LookaheadEngine, MedusaEngine, VanillaEngine};
    pub use crate::metrics::{Aggregate, GenRecord};
    pub use crate::models::{artifacts_dir, EagleDraft, MedusaHeads, ModelBundle, TargetModel};
    pub use crate::runtime::{Manifest, Runtime};
    pub use crate::spec::dyntree::{DynTreeConfig, SpecController, TreePolicy};
    pub use crate::spec::engine::{EagleEngine, GenConfig, PairShift};
    pub use crate::spec::tree::TreeSpec;
    pub use crate::text::bpe::Bpe;
}
