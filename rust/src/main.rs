//! `repro` — the eagle-serve CLI.
//!
//!   repro serve   [--addr 127.0.0.1:8085] [--model toy-s] [--queue 64]
//!                 [--tree static|dynamic] [--verify-width auto|N]
//!                 [--draft eagle|chain|ngram|medusa|auto] [--capacity-file PATH]
//!                 [--batch N] [--linger MS] [--width-grouping]
//!                 [--cost-model PATH] [--edf] [--aging-ms MS]
//!                 [--preempt] [--kv-budget MIB]
//!                 [--synthetic [--round-us US]]
//!   repro loadgen [--addr 127.0.0.1:8085] [--arrivals poisson|bursty|closed|replay]
//!                 [--rps F] [--levels 0.5,1,2] [--duration SECS]
//!                 [--soak SECS] [--compare-edf] [--compare-preempt]
//!                 [--profile chat|mixed] [--draft eagle|...|auto]
//!                 [--target-p99-ttft-ms MS] [--out BENCH_serve.json]
//!   repro generate --prompt "..." [--model toy-s] [--method eagle]
//!                  [--max-tokens 64] [--temperature 0] [--seed 7]
//!                  [--tree static|dynamic] [--draft-depth N] [--frontier K]
//!                  [--branch B] [--no-adapt] [--verify-width auto|N]
//!   repro eval    (--all | --exp fig1) [--n 16] [--max-new 48] [--out results]
//!   repro bench   [--json BENCH_host.json] [--iters 200]  host/exe micro-bench
//!   repro profile [--model toy-s] [--n 4]   step-phase breakdown (§Perf)
//!   repro trace   [--addr 127.0.0.1:8085] [--last N] [--raw]
//!                 summarize a running server's round flight recorder
//!   repro scrape  [--addr 127.0.0.1:8085] [--require fam1,fam2]
//!                 fetch + validate /metrics Prometheus exposition
//!   repro selftest                            losslessness smoke check

use anyhow::Result;
use eagle_serve::coordinator::request::Method;
use eagle_serve::eval::runner::{Runner, RunSpec};
use eagle_serve::eval::tables::EvalCtx;
use eagle_serve::models::{artifacts_dir, ModelBundle};
use eagle_serve::spec::dyntree::{DynTreeConfig, TreePolicy, WidthSelect};
use eagle_serve::spec::engine::GenConfig;
use eagle_serve::text::bpe::Bpe;
use eagle_serve::util::cli::Args;

fn main() {
    let args = Args::parse(
        std::env::args().skip(1),
        &[
            "all",
            "verbose",
            "no-adapt",
            "width-grouping",
            "raw",
            "synthetic",
            "edf",
            "compare-edf",
            "preempt",
            "compare-preempt",
        ],
    );
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "serve" => serve(&args),
        "loadgen" => loadgen(&args),
        "generate" => generate(&args),
        "eval" => eval(&args),
        "bench" => bench(&args),
        "profile" => profile(&args),
        "trace" => trace(&args),
        "scrape" => scrape(&args),
        "selftest" => selftest(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "repro — EAGLE speculative-decoding serving framework\n\n\
         USAGE: repro <serve|loadgen|generate|eval|bench|profile|selftest> [options]\n\n\
         serve     --addr HOST:PORT --model NAME --queue N --tree static|dynamic\n\
         \u{20}          --verify-width auto|N   (auto = cheapest lowered verify_t{{t}} per round)\n\
         \u{20}          --batch N --linger MS   (admission batch size + fill deadline;\n\
         \u{20}           FCFS multi-lane eagle batches run on the batched engine, uncapped)\n\
         \u{20}          --width-grouping        (group lanes by predicted verify width:\n\
         \u{20}           requests carry a \"width_hint\" field; compatible eagle lanes (greedy,\n\
         \u{20}           or sampled sharing a temperature — per-lane RNG streams)\n\
         \u{20}           run as per-width sub-batches so low-acceptance lanes are never\n\
         \u{20}           executed at a hot lane's width. Default: FCFS)\n\
         \u{20}          --cost-model PATH       (calibrate the grouping dispatch overhead\n\
         \u{20}           from a repro bench --json file; default: built-in constant)\n\
         \u{20}          --trace-cap N --stall-ms MS  (flight-recorder ring capacity;\n\
         \u{20}           heartbeat age past which /healthz turns 503)\n\
         \u{20}          --default-deadline-ms MS (deadline for requests without their own\n\
         \u{20}           \"deadline_ms\"; expired requests return partial text with\n\
         \u{20}           \"truncated\":\"deadline\", queue-expired ones 504; 0 = unbounded.\n\
         \u{20}           Overloaded queues shed with 429 + Retry-After.\n\
         \u{20}           POST /admin/drain stops admission and exits after the queue empties)\n\
         \u{20}          --inject SPEC           (fault-injection sites, fault-inject builds\n\
         \u{20}           only: site=panic|degenerate|delay(MS)[@N],… — see docs/robustness.md)\n\
         \u{20}          --edf [--aging-ms MS]   (earliest-deadline-first admission with a\n\
         \u{20}           starvation aging bound; POST /admin/sched flips at runtime)\n\
         \u{20}          --preempt [--kv-budget MIB]  (round-boundary lane preemption:\n\
         \u{20}           deadline/pressure/drain governors suspend lanes into checkpoints\n\
         \u{20}           that resume bit-identically; --kv-budget bounds suspended KV bytes,\n\
         \u{20}           past it lanes re-prefill on resume. POST /admin/preempt flips at\n\
         \u{20}           runtime — see docs/robustness.md)\n\
         \u{20}          --draft eagle|chain|ngram|medusa|auto  (default draft source for\n\
         \u{20}           requests without a \"draft\" field; auto picks per request from the\n\
         \u{20}           online acceptance policy — see docs/drafting.md)\n\
         \u{20}          --capacity-file PATH    (committed-capacity shed seed from a loadgen\n\
         \u{20}           p99_search stanza; default: probe ./BENCH_serve.json)\n\
         \u{20}          --synthetic [--round-us US]  (no-artifact simulated engine: timed\n\
         \u{20}           rounds, deterministic output — the loadgen/CI target)\n\
         loadgen   --addr HOST:PORT --arrivals poisson|bursty|closed|replay --rps F\n\
         \u{20}          --levels 0.5,1,2 --duration SECS   (offered-load sweep ->\n\
         \u{20}           BENCH_serve.json: goodput, p50/p99 TTFT + per-token, shed/miss rates)\n\
         \u{20}          --compare-edf           (replay one workload under FCFS then EDF;\n\
         \u{20}           asserts identical outputs + reports tight-deadline p99)\n\
         \u{20}          --compare-preempt       (replay one workload with preemption off\n\
         \u{20}           then on; asserts identical outputs + tight-cohort p99 both ways)\n\
         \u{20}          --target-p99-ttft-ms MS (closed-loop search: highest offered load\n\
         \u{20}           whose p99 TTFT stays under MS, emitted as a p99_search stanza)\n\
         \u{20}          --soak SECS             (chaos soak: bursty load, /healthz watchdog,\n\
         \u{20}           asserts drain, zero hung slots, zero round-path alloc)\n\
         \u{20}          --tight-deadline-ms MS --tight-frac F --max-retries N --seed N\n\
         \u{20}          --profile chat|mixed    (request mix: chat prompts, or chat +\n\
         \u{20}           repetitive-JSON so --draft auto has something to tell apart)\n\
         \u{20}          --draft eagle|chain|ngram|medusa|auto  (stamp every request's\n\
         \u{20}           \"draft\" field; auto exercises the online source policy)\n\
         generate  --prompt TEXT --model NAME --method eagle|eagle-chain|vanilla|medusa|lookahead|classic-spec\n\
         \u{20}          --max-tokens N --temperature F --seed N\n\
         \u{20}          --tree static|dynamic [--draft-depth N --frontier K --branch B --no-adapt]\n\
         \u{20}          --verify-width auto|N\n\
         eval      --all | --exp ID   (--n PROMPTS --max-new N --out DIR)\n\
         bench     --json PATH --iters N   (host round-scratch vs reference pair +\n\
         \u{20}           per-width exe/verify benches when artifacts exist; the JSON\n\
         \u{20}           output feeds --cost-model)\n\
         profile   --model NAME --n N\n\
         trace     --addr HOST:PORT [--last N] [--raw]   (per-lane round summary of a\n\
         \u{20}           running server's GET /trace flight-recorder dump)\n\
         scrape    --addr HOST:PORT [--require fam1,fam2]   (fetch GET /metrics and\n\
         \u{20}           validate the Prometheus exposition parses; CI smoke check)\n\
         selftest  quick losslessness check (eagle == vanilla at T=0)\n\n\
         Artifacts are read from $EAGLE_ARTIFACTS or ./artifacts (make artifacts)."
    );
}

/// Parse `--tree static|dynamic` (+ dynamic knobs) into a policy.
fn tree_policy(args: &Args) -> Result<TreePolicy> {
    match args.get_or("tree", "static") {
        "static" => Ok(TreePolicy::default_tree()),
        "dynamic" | "dyntree" => {
            let base = DynTreeConfig::default();
            let dc = DynTreeConfig {
                depth: args.usize_or("draft-depth", base.depth),
                frontier_k: args.usize_or("frontier", base.frontier_k),
                branch: args.usize_or("branch", base.branch),
                adaptive: !args.has("no-adapt"),
                ..base
            };
            Ok(TreePolicy::Dynamic(dc))
        }
        other => Err(anyhow::anyhow!("unknown --tree '{other}' (static|dynamic)")),
    }
}

/// Parse `--verify-width auto|N` into a width policy.
fn verify_width(args: &Args) -> Result<WidthSelect> {
    let s = args.get_or("verify-width", "auto");
    WidthSelect::parse(s)
        .ok_or_else(|| anyhow::anyhow!("bad --verify-width '{s}' (auto or an integer >= 2)"))
}

/// Parse `--draft eagle|chain|ngram|medusa|auto` into the server's
/// default draft-source policy.
fn draft_choice(args: &Args) -> Result<eagle_serve::spec::source::DraftChoice> {
    let s = args.get_or("draft", "eagle");
    eagle_serve::spec::source::DraftChoice::parse(s)
        .ok_or_else(|| anyhow::anyhow!("bad --draft '{s}' (eagle|chain|ngram|medusa|auto)"))
}

fn serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:8085");
    let model = args.get_or("model", "toy-s");
    let cfg = eagle_serve::server::ServeConfig {
        queue_cap: args.usize_or("queue", 64),
        default_tree: tree_policy(args)?,
        default_width: verify_width(args)?,
        default_draft: draft_choice(args)?,
        capacity_file: args.get("capacity-file").map(std::path::PathBuf::from),
        max_batch: args.usize_or("batch", 1),
        linger_ms: args.u64_or("linger", 2),
        width_grouping: args.has("width-grouping"),
        cost_model: args.get("cost-model").map(std::path::PathBuf::from),
        trace_cap: args.usize_or("trace-cap", 1024),
        stall_ms: args.u64_or("stall-ms", 30_000),
        default_deadline_ms: args.u64_or("default-deadline-ms", 0),
        inject: args.get("inject").map(String::from),
        synthetic: args.has("synthetic"),
        synthetic_round_us: args.u64_or("round-us", 2_000),
        edf: args.has("edf"),
        aging_ms: args.u64_or("aging-ms", eagle_serve::coordinator::queue::DEFAULT_AGING_MS),
        preempt: args.has("preempt"),
        kv_budget_mib: args.usize_or("kv-budget", 0),
        ..eagle_serve::server::ServeConfig::new(addr, model, &artifacts_dir())
    };
    eagle_serve::server::serve(cfg)
}

/// Closed/open-loop load harness against a live server; writes
/// `BENCH_serve.json`. `--soak SECS` switches to the chaos-soak
/// assertions instead of the level sweep.
fn loadgen(args: &Args) -> Result<()> {
    use eagle_serve::eval::loadgen as lg;
    let soak_secs = args.get("soak").and_then(|s| s.parse::<f64>().ok());
    let soak = soak_secs.is_some() || args.has("soak");
    let duration = soak_secs.unwrap_or_else(|| args.f64_or("duration", 10.0));
    let rps = args.f64_or("rps", 20.0);
    let arrivals = lg::Arrival::parse(
        args.get_or("arrivals", "poisson"),
        rps,
        args.usize_or("clients", 4),
        args.get("trace"),
    )?;
    let levels: Vec<f64> = args
        .get_or("levels", "0.5,1,2")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    anyhow::ensure!(!levels.is_empty(), "--levels parsed to nothing");
    let mixed = match args.get_or("profile", "chat") {
        "chat" => false,
        "mixed" => true,
        other => anyhow::bail!("unknown --profile '{other}' (chat|mixed)"),
    };
    let profile = lg::Profile {
        max_tokens: args.usize_or("max-tokens", 48),
        tight_deadline_ms: args.u64_or("tight-deadline-ms", 300),
        tight_frac: args.f64_or("tight-frac", 0.3),
        sampled_frac: args.f64_or("sampled-frac", 0.25),
        draft: args.get("draft").map(String::from),
        mixed,
    };
    let cfg = lg::LoadgenConfig {
        addr: args.get_or("addr", "127.0.0.1:8085").to_string(),
        arrivals,
        duration_secs: duration,
        levels,
        rps,
        profile,
        max_retries: args.u64_or("max-retries", 4) as u32,
        seed: args.u64_or("seed", 7),
        soak,
        compare_edf: args.has("compare-edf"),
        compare_preempt: args.has("compare-preempt"),
        target_p99_ttft_ms: args.get("target-p99-ttft-ms").and_then(|s| s.parse().ok()),
        out: std::path::PathBuf::from(args.get_or("out", "BENCH_serve.json")),
    };
    lg::run(&cfg)
}

/// Host (and, with artifacts, per-width exe) micro-benches; `--json`
/// writes `BENCH_host.json`, whose `exe/verify_t{t}` curve is fit into
/// a `cost_model` stanza consumable by `repro serve --cost-model`.
fn bench(args: &Args) -> Result<()> {
    use eagle_serve::eval::bench as hb;
    let iters = args.usize_or("iters", 200).max(1);
    let mut results = hb::host_suite(iters);
    if artifacts_dir().join("manifest.json").exists() {
        let runner = Runner::new(&artifacts_dir())?;
        let bundle = ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], false, false)?;
        results.extend(hb::exe_verify_suite(&runner, &bundle, iters.min(30)));
    } else {
        eprintln!("[bench] artifacts not built; exe benches skipped (host suite only)");
    }
    for r in &results {
        println!("{:32} median {:8.4} ms   ({} iters)", r.name, r.median_ms, r.iters);
    }
    let scratch = results.iter().find(|r| r.name == "host/round_scratch");
    let reference = results.iter().find(|r| r.name == "host/round_ref");
    if let (Some(s), Some(r)) = (scratch, reference) {
        println!(
            "round_scratch vs round_ref: {:.2}x ({} alloc-free)",
            r.median_ms / s.median_ms.max(1e-9),
            if s.median_ms <= r.median_ms { "arena path faster," } else { "REGRESSION:" }
        );
    }
    let cost = hb::fit_cost_model(&results);
    if let Some(cm) = cost {
        println!("fitted cost model: dispatch_overhead = {} node units", cm.dispatch_overhead);
    }
    let path = std::path::PathBuf::from(args.get_or("json", "BENCH_host.json"));
    hb::write_json(&path, &results, cost)?;
    println!("wrote {}", path.display());
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let runner = Runner::new(&artifacts_dir())?;
    let bpe = Bpe::load(runner.man.path(&runner.man.tokenizer).to_str().unwrap())?;
    let model = args.get_or("model", "toy-s");
    let method = Method::parse(args.get_or("method", "eagle"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let bundle = ModelBundle::load(&runner.rt, &runner.man, model, &["eagle"], true, true)?;
    let default_prompt =
        "tom has 12 apples. tom buys 5 more and gives away 3. how many apples remain?";
    let prompt = args.get_or("prompt", default_prompt);
    let ids = bpe.encode_prompt(prompt);
    let spec = RunSpec {
        method,
        temperature: args.f32_or("temperature", 0.0),
        max_new: args.usize_or("max-tokens", 64),
        seed: args.u64_or("seed", 7),
        tree: tree_policy(args)?,
        verify_width: verify_width(args)?,
        ..Default::default()
    };
    let cfg = GenConfig {
        max_new: spec.max_new,
        temperature: spec.temperature,
        seed: spec.seed,
        eos: Some(bpe.eos()),
    };
    let rec = runner.run_one(&bundle, &ids, &spec, &cfg)?;
    println!("prompt : {prompt}");
    println!("output : {}", bpe.decode(&rec.tokens));
    println!(
        "stats  : {} tokens, {} target passes, tau {:.2}, {:.1} tok/s ({:.1} ms)",
        rec.tokens.len(),
        rec.target_passes,
        rec.tau(),
        rec.tokens_per_sec(),
        rec.wall_ns as f64 / 1e6
    );
    if rec.mean_tree_nodes() > 0.0 {
        println!("tree   : {:.1} verified draft nodes/round (mean)", rec.mean_tree_nodes());
    }
    if rec.mean_verify_t() > 0.0 {
        println!("verify : {:.1} mean selected width (verify_t family)", rec.mean_verify_t());
    }
    if rec.mean_draft_w() > 0.0 {
        let dw = rec.mean_draft_w();
        println!("draft  : {dw:.1} mean selected step width (draft_widths family)");
    }
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 16);
    let max_new = args.usize_or("max-new", 48);
    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    let ids: Vec<&str> = if args.has("all") {
        EvalCtx::ALL.to_vec()
    } else {
        vec![args.get("exp").ok_or_else(|| anyhow::anyhow!("--exp ID or --all"))?]
    };
    // draftsrc is artifact-free (a pure policy simulation over the
    // synthetic workload scenarios), so `--exp draftsrc` runs before
    // `make artifacts` — the CI smoke invokes it exactly that way
    if ids == ["draftsrc"] {
        let table = eagle_serve::eval::tables::draftsrc()?;
        let path = out_dir.join("draftsrc.md");
        std::fs::write(&path, &table)?;
        println!("{table}");
        return Ok(());
    }
    let ctx = EvalCtx::new(&artifacts_dir(), n, max_new)?;
    for id in ids {
        eprintln!("[eval] running {id} ...");
        let t0 = std::time::Instant::now();
        let table = ctx.run(id)?;
        let path = out_dir.join(format!("{id}.md"));
        std::fs::write(&path, &table)?;
        println!("{table}");
        eprintln!("[eval] {id} done in {:.1}s -> {}", t0.elapsed().as_secs_f64(), path.display());
    }
    Ok(())
}

fn profile(args: &Args) -> Result<()> {
    let runner = Runner::new(&artifacts_dir())?;
    let bpe = Bpe::load(runner.man.path(&runner.man.tokenizer).to_str().unwrap())?;
    let model = args.get_or("model", "toy-s");
    let n = args.usize_or("n", 4);
    let bundle = ModelBundle::load(&runner.rt, &runner.man, model, &["eagle"], false, false)?;
    let p_win = runner.man.constants.prefill_p;
    let wl = eagle_serve::eval::Workload::load(&runner.man, &bpe, "mtbench", p_win)?;
    let spec = RunSpec::default();
    let agg = runner.run_with(&bundle, &wl.take(n), &spec)?;
    let tl = &agg.timeline;
    let tot = tl.total_ns() as f64;
    println!("phase breakdown over {n} eagle generations ({} tokens):", agg.tokens);
    for (name, ns) in [
        ("prefill", tl.prefill_ns),
        ("draft", tl.draft_ns),
        ("verify", tl.verify_ns),
        ("commit", tl.commit_ns),
        ("host", tl.host_ns),
    ] {
        println!("  {name:8} {:8.1} ms  ({:4.1}%)", ns as f64 / 1e6, ns as f64 / tot * 100.0);
    }
    println!("per-executable:");
    for (name, calls, ms) in bundle.target.exes.profile() {
        if calls > 0 {
            println!(
                "  target.{name:14} {calls:5} calls  {ms:8.1} ms  ({:.2} ms/call)",
                ms / calls as f64
            );
        }
    }
    for (name, calls, ms) in bundle.drafts["eagle"].exes.profile() {
        if calls > 0 {
            println!(
                "  draft.{name:15} {calls:5} calls  {ms:8.1} ms  ({:.2} ms/call)",
                ms / calls as f64
            );
        }
    }
    Ok(())
}

/// Fetch `GET /trace` from a running server and print the per-lane
/// round summary from the flight recorder (`--raw` dumps the JSON
/// payload verbatim; `--last N` keeps only the newest N rounds).
fn trace(args: &Args) -> Result<()> {
    use eagle_serve::metrics::trace::{events_from_json, summarize};
    let addr = args.get_or("addr", "127.0.0.1:8085");
    let (code, body) = eagle_serve::server::http::get(addr, "/trace")?;
    anyhow::ensure!(code == 200, "GET /trace returned {code}: {body}");
    if args.has("raw") {
        println!("{body}");
        return Ok(());
    }
    let j = eagle_serve::util::json::Json::parse(&body)?;
    let mut events = events_from_json(&j);
    if let Some(last) = args.get("last").and_then(|s| s.parse::<usize>().ok()) {
        let skip = events.len().saturating_sub(last);
        events.drain(..skip);
    }
    print!("{}", summarize(&events));
    Ok(())
}

/// Scrape `GET /metrics` from a running server and validate that the
/// body parses as Prometheus text exposition (typed families,
/// cumulative buckets, `+Inf` == `_count`, `_sum` present).
/// `--require fam1,fam2` additionally asserts named families exist.
/// This doubles as the CI smoke check for the serving registry.
fn scrape(args: &Args) -> Result<()> {
    use eagle_serve::metrics::registry::parse_exposition;
    let addr = args.get_or("addr", "127.0.0.1:8085");
    let (code, body) = eagle_serve::server::http::get(addr, "/metrics")?;
    anyhow::ensure!(code == 200, "GET /metrics returned {code}");
    let exp = parse_exposition(&body)?;
    if let Some(req) = args.get("require") {
        for name in req.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            anyhow::ensure!(
                exp.family(name).is_some(),
                "required metric family '{name}' missing from /metrics"
            );
        }
    }
    println!(
        "scrape ok: {} families, {} samples",
        exp.families.len(),
        exp.families.values().map(|f| f.samples.len()).sum::<usize>()
    );
    Ok(())
}

fn selftest(_args: &Args) -> Result<()> {
    let runner = Runner::new(&artifacts_dir())?;
    let bpe = Bpe::load(runner.man.path(&runner.man.tokenizer).to_str().unwrap())?;
    let bundle = ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], false, false)?;
    let p_win = runner.man.constants.prefill_p;
    let wl = eagle_serve::eval::Workload::load(&runner.man, &bpe, "mtbench", p_win)?;
    let cfg = GenConfig { max_new: 32, temperature: 0.0, seed: 7, eos: None };
    let mut ok = 0;
    for p in wl.take(4) {
        let vspec = RunSpec { method: Method::Vanilla, ..Default::default() };
        let van = runner.run_one(&bundle, &p.ids, &vspec, &cfg)?;
        let eag = runner.run_one(&bundle, &p.ids, &RunSpec::default(), &cfg)?;
        if van.tokens == eag.tokens {
            ok += 1;
            println!("OK  lossless: {} tokens identical (tau {:.2})", eag.tokens.len(), eag.tau());
        } else {
            println!("FAIL mismatch:\n  vanilla {:?}\n  eagle   {:?}", van.tokens, eag.tokens);
        }
    }
    anyhow::ensure!(ok == 4, "losslessness selftest failed");
    println!("selftest passed");
    Ok(())
}
