//! Lookahead-style baseline (greedy-only, like the original): drafts come
//! for free from an n-gram pool built over the prompt + generated text
//! (the retrieval formulation of lookahead's verification branch — see
//! DESIGN.md §Substitutions). When the pool has no continuation the round
//! degenerates to a single-token verify (vanilla step + pool update).
//!
//! Since PR 10 the pool + retrieval live in
//! [`crate::spec::source::NgramSource`] (fixed-capacity, allocation-free
//! table instead of a growing `HashMap`) behind the `DraftSource` trait,
//! and this engine is a thin facade over the generic
//! [`crate::spec::source::SourceEngine`] round loop. The source itself is
//! lossless at any temperature (one-hot q rows); this facade keeps the
//! paper's greedy-only setting.

use anyhow::Result;

use crate::metrics::GenRecord;
use crate::models::TargetModel;
use crate::spec::engine::GenConfig;
use crate::spec::source::{NgramSource, SourceEngine};

pub struct LookaheadEngine<'a> {
    pub target: &'a TargetModel,
    pub n: usize,     // n-gram context length
    pub gamma: usize, // draft length
    pub verify_t: usize,
    pub accept_a: usize,
}

impl<'a> LookaheadEngine<'a> {
    pub fn new(target: &'a TargetModel, c: &crate::runtime::manifest::Constants) -> Self {
        LookaheadEngine { target, n: 2, gamma: 5, verify_t: c.chain_t, accept_a: c.accept_a }
    }

    pub fn generate(&self, prompt: &[u32], cfg: &GenConfig) -> Result<GenRecord> {
        assert!(cfg.temperature <= 0.0, "lookahead baseline is greedy-only (paper setting)");
        assert_eq!(self.n, NgramSource::N, "the n-gram source is fixed at 2-gram contexts");
        let mut src = NgramSource::new(self.gamma, self.verify_t, self.target.vocab);
        let eng = SourceEngine::new(self.target, self.accept_a);
        eng.generate(&mut src, prompt, cfg)
    }
}
