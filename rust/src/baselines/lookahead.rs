//! Lookahead-style baseline (greedy-only, like the original): drafts come
//! for free from an n-gram pool built over the prompt + generated text
//! (the retrieval formulation of lookahead's verification branch — see
//! DESIGN.md §Substitutions). When the pool has no continuation the round
//! degenerates to a single-token verify (vanilla step + pool update).

use anyhow::Result;
use std::collections::HashMap;
use std::time::Instant;

use crate::metrics::GenRecord;
use crate::models::TargetModel;
use crate::spec::engine::GenConfig;
use crate::spec::sampling::argmax;
use crate::spec::tree::DraftTree;

pub struct LookaheadEngine<'a> {
    pub target: &'a TargetModel,
    pub n: usize,     // n-gram context length
    pub gamma: usize, // draft length
    pub verify_t: usize,
    pub accept_a: usize,
}

impl<'a> LookaheadEngine<'a> {
    pub fn new(target: &'a TargetModel, c: &crate::runtime::manifest::Constants) -> Self {
        LookaheadEngine { target, n: 2, gamma: 5, verify_t: c.chain_t, accept_a: c.accept_a }
    }

    pub fn generate(&self, prompt: &[u32], cfg: &GenConfig) -> Result<GenRecord> {
        assert!(cfg.temperature <= 0.0, "lookahead baseline is greedy-only (paper setting)");
        let t_all = Instant::now();
        let mut rec = GenRecord::new(prompt.len());
        let tgt = self.target;
        let vocab = tgt.vocab;
        let s_tot = tgt.max_len;

        // n-gram pool: [t_{i-n+1..i}] -> most recent following token
        let mut pool: HashMap<Vec<u32>, u32> = HashMap::new();
        let index = |pool: &mut HashMap<Vec<u32>, u32>, seq: &[u32], n: usize| {
            if seq.len() > n {
                for i in 0..seq.len() - n {
                    pool.insert(seq[i..i + n].to_vec(), seq[i + n]);
                }
            }
        };
        index(&mut pool, prompt, self.n);

        let mut cache = tgt.new_cache(1);
        let t0 = Instant::now();
        let (out, plen) = tgt.prefill(prompt, &mut cache)?;
        rec.timeline.prefill_ns += t0.elapsed().as_nanos() as u64;
        rec.target_passes += 1;
        let root = argmax(tgt.row(&out.logits, tgt.prefill_p, 0, plen - 1, vocab)) as u32;
        let mut committed: Vec<u32> = prompt.to_vec();
        committed.push(root);
        rec.tokens.push(root);
        let mut m = plen;
        let mut pending_old_m = m;
        let mut pending_idx = vec![0i32; self.accept_a];
        let mut pending_n = 0i32;

        if cfg.eos == Some(root) {
            rec.wall_ns = t_all.elapsed().as_nanos() as u64;
            return Ok(rec);
        }

        while rec.tokens.len() < cfg.max_new {
            if m + self.verify_t + 1 >= s_tot {
                break;
            }
            // --- retrieve a draft continuation from the pool ----------------
            let th = Instant::now();
            let mut draft: Vec<u32> = Vec::new();
            let mut ctx: Vec<u32> = committed[committed.len().saturating_sub(self.n)..].to_vec();
            for _ in 0..self.gamma {
                match pool.get(&ctx) {
                    Some(&nxt) => {
                        draft.push(nxt);
                        ctx.push(nxt);
                        ctx.remove(0);
                    }
                    None => break,
                }
            }
            rec.drafted += draft.len();
            rec.timeline.host_ns += th.elapsed().as_nanos() as u64;

            // --- verify [root, draft...] ------------------------------------
            let mut tree = DraftTree::with_root(committed[m]);
            let mut parent = 0usize;
            for &tok in &draft {
                parent = tree.add(parent, tok, 0.0, None);
            }
            let (tokens, pos, bias) = tree.verify_inputs(self.verify_t, m, s_tot);
            let t0 = Instant::now();
            let vout = tgt.verify(
                self.verify_t, &mut cache, &[pending_old_m as i32], &pending_idx,
                &[pending_n], &tokens, &pos, &bias, self.accept_a,
            )?;
            rec.timeline.verify_ns += t0.elapsed().as_nanos() as u64;
            rec.target_passes += 1;

            let path =
                tree.greedy_walk(|i| argmax(tgt.row(&vout.logits, self.verify_t, 0, i, vocab)));
            let deepest = *path.last().unwrap();
            let bonus = argmax(tgt.row(&vout.logits, self.verify_t, 0, deepest, vocab)) as u32;

            let n_commit = path.len();
            pending_old_m = m;
            pending_idx = vec![0i32; self.accept_a];
            for (j, &ni) in path.iter().enumerate() {
                pending_idx[j] = ni as i32;
            }
            pending_n = n_commit as i32;

            let round: Vec<u32> = path[1..]
                .iter()
                .map(|&ni| tree.nodes[ni].token)
                .chain(std::iter::once(bonus))
                .collect();
            rec.round_accepts.push(round.len());
            let mut stop = false;
            for &t in &round {
                committed.push(t);
                rec.tokens.push(t);
                if cfg.eos == Some(t) || rec.tokens.len() >= cfg.max_new {
                    stop = true;
                    break;
                }
            }
            m += n_commit;
            // refresh the pool with the newly committed suffix
            let tail_start = committed.len().saturating_sub(n_commit + self.n);
            index(&mut pool, &committed[tail_start..], self.n);
            if stop {
                break;
            }
        }
        rec.wall_ns = t_all.elapsed().as_nanos() as u64;
        Ok(rec)
    }
}
