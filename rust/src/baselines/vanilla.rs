//! Vanilla auto-regressive decoding: one target forward per token. The
//! reference everything else's speedup ratio is measured against, and the
//! oracle for the T=0 losslessness integration test.

use anyhow::Result;
use std::time::Instant;

use crate::metrics::GenRecord;
use crate::models::TargetModel;
use crate::spec::engine::GenConfig;
use crate::spec::sampling::{argmax, sample, softmax};
use crate::util::rng::Rng;

pub struct VanillaEngine<'a> {
    pub target: &'a TargetModel,
}

impl<'a> VanillaEngine<'a> {
    pub fn new(target: &'a TargetModel) -> Self {
        VanillaEngine { target }
    }

    pub fn generate(&self, prompt: &[u32], cfg: &GenConfig) -> Result<GenRecord> {
        let t_all = Instant::now();
        let mut rec = GenRecord::new(prompt.len());
        let mut rng = Rng::new(cfg.seed);
        let tgt = self.target;
        let vocab = tgt.vocab;

        let mut cache = tgt.new_cache(1);
        let t0 = Instant::now();
        let (out, plen) = tgt.prefill(prompt, &mut cache)?;
        rec.timeline.prefill_ns += t0.elapsed().as_nanos() as u64;
        rec.target_passes += 1;
        let mut logits = tgt.row(&out.logits, tgt.prefill_p, 0, plen - 1, vocab).to_vec();
        let mut pos = plen;

        while rec.tokens.len() < cfg.max_new && pos + 1 < tgt.max_len {
            let tok = if cfg.temperature <= 0.0 {
                argmax(&logits) as u32
            } else {
                sample(&softmax(&logits, cfg.temperature), &mut rng) as u32
            };
            rec.tokens.push(tok);
            if cfg.eos == Some(tok) || rec.tokens.len() >= cfg.max_new {
                break;
            }
            let t0 = Instant::now();
            let out = tgt.decode(&mut cache, &[pos as i32], &[tok as i32])?;
            rec.timeline.verify_ns += t0.elapsed().as_nanos() as u64;
            rec.target_passes += 1;
            rec.round_accepts.push(1);
            logits = out.logits;
            pos += 1;
        }
        rec.wall_ns = t_all.elapsed().as_nanos() as u64;
        Ok(rec)
    }
}
