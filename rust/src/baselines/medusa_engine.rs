//! Medusa-style baseline (greedy-only, as in the paper's comparison):
//! K independent residual-MLP heads predict tokens at offsets +2..+K+1
//! from the last verified feature; the chain [root, h_0..h_{K-1}] is
//! verified in one target pass. No sampled-token feedback — exactly the
//! uncertainty limitation EAGLE's shifted token removes (paper §3.2).
//!
//! Since PR 10 the head proposals + feature recycling live in
//! [`crate::spec::source::MedusaSource`] behind the `DraftSource` trait
//! and this engine is a thin facade over the generic
//! [`crate::spec::source::SourceEngine`] round loop. The source itself is
//! lossless at any temperature (one-hot q rows); this facade keeps the
//! paper's greedy-only setting.

use anyhow::Result;

use crate::metrics::GenRecord;
use crate::models::{MedusaHeads, TargetModel};
use crate::spec::engine::GenConfig;
use crate::spec::source::{MedusaSource, SourceEngine};

pub struct MedusaEngine<'a> {
    pub target: &'a TargetModel,
    pub heads: &'a MedusaHeads,
    pub verify_t: usize,
    pub accept_a: usize,
    pub k: usize,
}

impl<'a> MedusaEngine<'a> {
    pub fn new(
        target: &'a TargetModel,
        heads: &'a MedusaHeads,
        c: &crate::runtime::manifest::Constants,
    ) -> Self {
        MedusaEngine { target, heads, verify_t: c.chain_t, accept_a: c.accept_a, k: 4 }
    }

    pub fn generate(&self, prompt: &[u32], cfg: &GenConfig) -> Result<GenRecord> {
        assert!(cfg.temperature <= 0.0, "medusa baseline is greedy-only (paper setting)");
        let mut src = MedusaSource::new(
            self.heads,
            self.k,
            self.target.d,
            self.target.vocab,
            self.verify_t,
        );
        let eng = SourceEngine::new(self.target, self.accept_a);
        eng.generate(&mut src, prompt, cfg)
    }
}
