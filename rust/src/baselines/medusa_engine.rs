//! Medusa-style baseline (greedy-only, as in the paper's comparison):
//! K independent residual-MLP heads predict tokens at offsets +2..+K+1
//! from the last verified feature; the chain [root, h_0..h_{K-1}] is
//! verified in one target pass. No sampled-token feedback — exactly the
//! uncertainty limitation EAGLE's shifted token removes (paper §3.2).

use anyhow::Result;
use std::time::Instant;

use crate::metrics::GenRecord;
use crate::models::{MedusaHeads, TargetModel};
use crate::spec::engine::GenConfig;
use crate::spec::sampling::argmax;
use crate::spec::tree::DraftTree;

pub struct MedusaEngine<'a> {
    pub target: &'a TargetModel,
    pub heads: &'a MedusaHeads,
    pub verify_t: usize,
    pub accept_a: usize,
    pub k: usize,
}

impl<'a> MedusaEngine<'a> {
    pub fn new(
        target: &'a TargetModel,
        heads: &'a MedusaHeads,
        c: &crate::runtime::manifest::Constants,
    ) -> Self {
        MedusaEngine { target, heads, verify_t: c.chain_t, accept_a: c.accept_a, k: 4 }
    }

    pub fn generate(&self, prompt: &[u32], cfg: &GenConfig) -> Result<GenRecord> {
        assert!(cfg.temperature <= 0.0, "medusa baseline is greedy-only (paper setting)");
        let t_all = Instant::now();
        let mut rec = GenRecord::new(prompt.len());
        let tgt = self.target;
        let vocab = tgt.vocab;
        let d = tgt.d;
        let s_tot = tgt.max_len;

        let mut cache = tgt.new_cache(1);
        let t0 = Instant::now();
        let (out, plen) = tgt.prefill(prompt, &mut cache)?;
        rec.timeline.prefill_ns += t0.elapsed().as_nanos() as u64;
        rec.target_passes += 1;
        let root = argmax(tgt.row(&out.logits, tgt.prefill_p, 0, plen - 1, vocab)) as u32;
        let mut committed: Vec<u32> = prompt.to_vec();
        committed.push(root);
        rec.tokens.push(root);
        let mut m = plen;
        let mut pending_old_m = m;
        let mut pending_idx = vec![0i32; self.accept_a];
        let mut pending_n = 0i32;
        // feature at the position whose LM-head dist produced `root`
        let mut feat: Vec<f32> = tgt.row(&out.feats, tgt.prefill_p, 0, plen - 1, d).to_vec();

        if cfg.eos == Some(root) {
            rec.wall_ns = t_all.elapsed().as_nanos() as u64;
            return Ok(rec);
        }

        while rec.tokens.len() < cfg.max_new {
            if m + self.verify_t + 1 >= s_tot {
                break;
            }
            // --- heads propose offsets +2..+K+1 from `feat` (position m-1):
            //     candidates for absolute positions m+1 .. m+K
            let t0 = Instant::now();
            let hl = self.heads.heads(&feat)?; // [K, V]
            rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
            rec.draft_passes += 1;
            let mut tree = DraftTree::with_root(committed[m]);
            let mut parent = 0usize;
            for kk in 0..self.k {
                let tok = argmax(&hl[kk * vocab..(kk + 1) * vocab]) as u32;
                parent = tree.add(parent, tok, 0.0, None);
                rec.drafted += 1;
            }

            // --- verify -----------------------------------------------------
            let (tokens, pos, bias) = tree.verify_inputs(self.verify_t, m, s_tot);
            let t0 = Instant::now();
            let vout = tgt.verify(
                self.verify_t, &mut cache, &[pending_old_m as i32], &pending_idx,
                &[pending_n], &tokens, &pos, &bias, self.accept_a,
            )?;
            rec.timeline.verify_ns += t0.elapsed().as_nanos() as u64;
            rec.target_passes += 1;

            let path =
                tree.greedy_walk(|i| argmax(tgt.row(&vout.logits, self.verify_t, 0, i, vocab)));
            for (gidx, _) in path[1..].iter().enumerate() {
                if gidx < rec.alpha.len() {
                    rec.alpha[gidx].0 += 1;
                    rec.alpha[gidx].1 += 1;
                }
            }
            if path.len() - 1 < self.k && path.len() - 1 < rec.alpha.len() {
                rec.alpha[path.len() - 1].1 += 1;
            }
            let deepest = *path.last().unwrap();
            let bonus = argmax(tgt.row(&vout.logits, self.verify_t, 0, deepest, vocab)) as u32;
            // next round's feature: at the deepest accepted position
            feat = tgt.row(&vout.feats, self.verify_t, 0, deepest, d).to_vec();

            let n_commit = path.len();
            pending_old_m = m;
            pending_idx = vec![0i32; self.accept_a];
            for (j, &ni) in path.iter().enumerate() {
                pending_idx[j] = ni as i32;
            }
            pending_n = n_commit as i32;

            let round: Vec<u32> = path[1..]
                .iter()
                .map(|&ni| tree.nodes[ni].token)
                .chain(std::iter::once(bonus))
                .collect();
            rec.round_accepts.push(round.len());
            let mut stop = false;
            for &t in &round {
                committed.push(t);
                rec.tokens.push(t);
                if cfg.eos == Some(t) || rec.tokens.len() >= cfg.max_new {
                    stop = true;
                    break;
                }
            }
            m += n_commit;
            if stop {
                break;
            }
        }
        rec.wall_ns = t_all.elapsed().as_nanos() as u64;
        Ok(rec)
    }
}
