//! Baseline decoders (S14): vanilla auto-regression, classic two-model
//! speculative sampling (token-level draft LM), Medusa-style independent
//! heads, and a Lookahead-style n-gram drafter. All share the target
//! wrapper and the verification machinery, so comparisons isolate the
//! *drafting* strategy — the paper's Figure 1/2 axis.

pub mod chain_spec;
pub mod lookahead;
pub mod medusa_engine;
pub mod vanilla;

pub use chain_spec::ClassicSpecEngine;
pub use lookahead::LookaheadEngine;
pub use medusa_engine::MedusaEngine;
pub use vanilla::VanillaEngine;
