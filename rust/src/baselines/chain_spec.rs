//! Classic speculative sampling (Leviathan et al. / Chen et al.): a small
//! token-level draft LM proposes γ tokens auto-regressively; the target
//! verifies the chain in one pass; accept/resample preserves the target
//! distribution. The draft LM replays committed tokens it missed — the
//! overhead the paper cites for small-model drafting.
//!
//! Since PR 10 the drafting logic lives in
//! [`crate::spec::source::ChainLmSource`] behind the `DraftSource` trait
//! and this engine is a thin facade over the generic
//! [`crate::spec::source::SourceEngine`] round loop — same proposals,
//! same SpecInfer chain acceptance (a chain is a single-child tree), now
//! servable next to the other sources.

use anyhow::Result;

use crate::metrics::GenRecord;
use crate::models::TargetModel;
use crate::spec::engine::GenConfig;
use crate::spec::source::{ChainLmSource, SourceEngine};

pub struct ClassicSpecEngine<'a> {
    pub target: &'a TargetModel,
    /// token-level draft LM (same wrapper type, smaller config)
    pub draft: &'a TargetModel,
    pub gamma: usize,
    pub verify_t: usize,
    pub accept_a: usize,
}

impl<'a> ClassicSpecEngine<'a> {
    pub fn new(
        target: &'a TargetModel,
        draft: &'a TargetModel,
        c: &crate::runtime::manifest::Constants,
        gamma: usize,
    ) -> Self {
        assert!(gamma + 1 <= c.chain_t);
        ClassicSpecEngine { target, draft, gamma, verify_t: c.chain_t, accept_a: c.accept_a }
    }

    pub fn generate(&self, prompt: &[u32], cfg: &GenConfig) -> Result<GenRecord> {
        let mut src = ChainLmSource::new(self.draft, self.gamma, self.verify_t);
        let eng = SourceEngine::new(self.target, self.accept_a);
        eng.generate(&mut src, prompt, cfg)
    }
}
