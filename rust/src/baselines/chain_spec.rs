//! Classic speculative sampling (Leviathan et al. / Chen et al.): a small
//! token-level draft LM proposes γ tokens auto-regressively; the target
//! verifies the chain in one pass; accept/resample preserves the target
//! distribution. The draft LM replays committed tokens it missed — the
//! overhead the paper cites for small-model drafting.

use anyhow::Result;
use std::time::Instant;

use crate::metrics::GenRecord;
use crate::models::TargetModel;
use crate::spec::engine::GenConfig;
use crate::spec::sampling::{argmax, chain_accept_into, sample, softmax, Verdict};
use crate::spec::tree::DraftTree;
use crate::util::rng::Rng;

pub struct ClassicSpecEngine<'a> {
    pub target: &'a TargetModel,
    /// token-level draft LM (same wrapper type, smaller config)
    pub draft: &'a TargetModel,
    pub gamma: usize,
    pub verify_t: usize,
    pub accept_a: usize,
}

impl<'a> ClassicSpecEngine<'a> {
    pub fn new(
        target: &'a TargetModel,
        draft: &'a TargetModel,
        c: &crate::runtime::manifest::Constants,
        gamma: usize,
    ) -> Self {
        assert!(gamma + 1 <= c.chain_t);
        ClassicSpecEngine { target, draft, gamma, verify_t: c.chain_t, accept_a: c.accept_a }
    }

    pub fn generate(&self, prompt: &[u32], cfg: &GenConfig) -> Result<GenRecord> {
        let t_all = Instant::now();
        let mut rec = GenRecord::new(prompt.len());
        let mut rng = Rng::new(cfg.seed);
        let tgt = self.target;
        let vocab = tgt.vocab;
        let s_tot = tgt.max_len;

        // target prefill
        let mut cache = tgt.new_cache(1);
        let t0 = Instant::now();
        let (out, plen) = tgt.prefill(prompt, &mut cache)?;
        rec.timeline.prefill_ns += t0.elapsed().as_nanos() as u64;
        rec.target_passes += 1;
        let root_logits = tgt.row(&out.logits, tgt.prefill_p, 0, plen - 1, vocab);
        let root = self.pick(root_logits, cfg, &mut rng);
        let mut committed: Vec<u32> = prompt.to_vec();
        committed.push(root);
        rec.tokens.push(root);
        let mut m = plen;
        let mut pending_old_m = m;
        let mut pending_idx = vec![0i32; self.accept_a];
        let mut pending_n = 0i32;

        // draft LM prefill
        let mut dcache = self.draft.new_cache(1);
        let t0 = Instant::now();
        let (_, _) = self.draft.prefill(prompt, &mut dcache)?;
        rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
        rec.draft_passes += 1;
        let mut draft_pos = plen; // committed rows in the draft LM cache

        if cfg.eos == Some(root) {
            rec.wall_ns = t_all.elapsed().as_nanos() as u64;
            return Ok(rec);
        }

        // reused rejection-residual buffer for the T>0 accept rule
        let mut residual: Vec<f32> = Vec::new();
        while rec.tokens.len() < cfg.max_new {
            if m + self.verify_t + 1 >= s_tot || m + self.verify_t + 1 >= self.draft.max_len {
                break;
            }
            // --- draft γ tokens, replaying any missed committed tokens -----
            // (the draft LM consumes committed[draft_pos..=m] one at a time)
            let mut dlogits: Vec<f32> = Vec::new();
            let t0 = Instant::now();
            while draft_pos <= m {
                let out = self.draft.decode(
                    &mut dcache,
                    &[draft_pos as i32],
                    &[committed[draft_pos] as i32],
                )?;
                rec.draft_passes += 1;
                dlogits = out.logits;
                draft_pos += 1;
            }
            let mut qs: Vec<Vec<f32>> = Vec::with_capacity(self.gamma);
            let mut proposal: Vec<u32> = Vec::with_capacity(self.gamma);
            for g in 0..self.gamma {
                let temp = if cfg.temperature > 0.0 { cfg.temperature } else { 1.0 };
                let q = softmax(&dlogits, temp);
                let tok = if cfg.temperature <= 0.0 {
                    argmax(&dlogits) as u32
                } else {
                    sample(&q, &mut rng) as u32
                };
                qs.push(q);
                proposal.push(tok);
                rec.drafted += 1;
                if g + 1 < self.gamma {
                    let out = self.draft.decode(
                        &mut dcache,
                        &[draft_pos as i32],
                        &[tok as i32],
                    )?;
                    rec.draft_passes += 1;
                    dlogits = out.logits;
                    draft_pos += 1;
                }
            }
            rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;

            // --- verify chain [root, proposal...] ---------------------------
            let mut tree = DraftTree::with_root(committed[m]);
            let mut parent = 0usize;
            for &tok in &proposal {
                parent = tree.add(parent, tok, 0.0, None);
            }
            let (tokens, pos, bias) = tree.verify_inputs(self.verify_t, m, s_tot);
            let t0 = Instant::now();
            let vout = tgt.verify(
                self.verify_t, &mut cache, &[pending_old_m as i32], &pending_idx,
                &[pending_n], &tokens, &pos, &bias, self.accept_a,
            )?;
            rec.timeline.verify_ns += t0.elapsed().as_nanos() as u64;
            rec.target_passes += 1;

            // --- accept/resample --------------------------------------------
            let mut n_acc = 0usize; // accepted proposal tokens
            let mut bonus: Option<u32> = None;
            for g in 0..self.gamma {
                let p_row = tgt.row(&vout.logits, self.verify_t, 0, g, vocab);
                if g < rec.alpha.len() {
                    rec.alpha[g].1 += 1;
                }
                if cfg.temperature <= 0.0 {
                    if argmax(p_row) == proposal[g] as usize {
                        n_acc += 1;
                        if g < rec.alpha.len() {
                            rec.alpha[g].0 += 1;
                        }
                    } else {
                        bonus = Some(argmax(p_row) as u32);
                        break;
                    }
                } else {
                    let p = softmax(p_row, cfg.temperature);
                    let tok = proposal[g] as usize;
                    match chain_accept_into(&p, &qs[g], tok, &mut residual, &mut rng) {
                        Verdict::Accept => {
                            n_acc += 1;
                            if g < rec.alpha.len() {
                                rec.alpha[g].0 += 1;
                            }
                        }
                        Verdict::Resample(t) => {
                            bonus = Some(t as u32);
                            break;
                        }
                    }
                }
            }
            let bonus = match bonus {
                Some(b) => b,
                None => {
                    // all γ accepted: bonus from the target dist at the leaf
                    let p_row = tgt.row(&vout.logits, self.verify_t, 0, self.gamma, vocab);
                    self.pick(p_row, cfg, &mut rng)
                }
            };

            // --- record acceptance (fused commit on next verify) -------------
            let n_commit = 1 + n_acc;
            pending_old_m = m;
            pending_idx = vec![0i32; self.accept_a];
            for j in 0..n_commit {
                pending_idx[j] = j as i32;
            }
            pending_n = n_commit as i32;

            let round: Vec<u32> =
                proposal[..n_acc].iter().copied().chain(std::iter::once(bonus)).collect();
            rec.round_accepts.push(round.len());
            let mut stop = false;
            for &t in &round {
                committed.push(t);
                rec.tokens.push(t);
                if cfg.eos == Some(t) || rec.tokens.len() >= cfg.max_new {
                    stop = true;
                    break;
                }
            }
            m += n_commit;
            // rewind the draft LM onto the committed stream: its cache holds
            // [0, draft_pos) rows of a now partially-discarded branch; roll
            // back to the last row that is still on the committed prefix.
            draft_pos = draft_pos.min(m);
            if stop {
                break;
            }
        }
        rec.wall_ns = t_all.elapsed().as_nanos() as u64;
        Ok(rec)
    }

    fn pick(&self, logits: &[f32], cfg: &GenConfig, rng: &mut Rng) -> u32 {
        if cfg.temperature <= 0.0 {
            argmax(logits) as u32
        } else {
            sample(&softmax(logits, cfg.temperature), rng) as u32
        }
    }
}
