//! EAGLE Auto-regression-Head wrapper: draft prefill over the committed
//! prefix + per-tree-level `step` calls. The head reuses the *target's*
//! `tok_emb`/`lm_head` device buffers (paper Fig. 7: frozen Embedding and
//! LM Head) — they are appended positionally after the head's own leaves.

use anyhow::Result;
use std::rc::Rc;

use super::target::KvCache;
use super::ExeSet;
use crate::runtime::{lit_f32, manifest::{DraftEntry, ModelEntry}, Manifest, Runtime};

pub struct EagleDraft {
    pub name: String,
    pub exes: ExeSet,
    /// Index of tok_emb / lm_head in the *target* param set.
    tok_emb_idx: usize,
    lm_head_idx: usize,
    target_weights: crate::runtime::ParamSet,
    pub d: usize,
    pub vocab: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_len: usize,
    pub prefill_p: usize,
    pub accuracy: f64,
}

pub struct DraftOut {
    /// Predicted features [B, W, D]
    pub feats: Vec<f32>,
    /// Children logits [B, W, V]
    pub logits: Vec<f32>,
}

impl EagleDraft {
    pub fn load(
        rt: &Rc<Runtime>,
        man: &Manifest,
        target_entry: &ModelEntry,
        entry: &DraftEntry,
        name: &str,
    ) -> Result<EagleDraft> {
        let exes =
            ExeSet::load(rt, man, &entry.weights, &entry.param_names, &entry.executables, name)?;
        // the head borrows the target's embedding + LM head buffers; load a
        // private copy of the target params (cheap: uploaded once)
        let target_weights = crate::runtime::ParamSet::load(
            rt,
            &man.path(&target_entry.weights),
            &target_entry.param_names,
        )?;
        let tok_emb_idx = target_weights.names.iter().position(|n| n == "tok_emb")
            .ok_or_else(|| anyhow::anyhow!("target has no tok_emb leaf"))?;
        let lm_head_idx = target_weights.names.iter().position(|n| n == "lm_head")
            .ok_or_else(|| anyhow::anyhow!("target has no lm_head leaf"))?;
        let c = &target_entry.config;
        Ok(EagleDraft {
            name: name.to_string(),
            exes,
            tok_emb_idx,
            lm_head_idx,
            target_weights,
            d: c.d,
            vocab: c.vocab,
            n_heads: c.n_heads,
            head_dim: c.head_dim,
            max_len: c.max_len,
            prefill_p: man.constants.prefill_p,
            accuracy: entry.accuracy,
        })
    }

    pub fn new_cache(&self, batch: usize) -> KvCache {
        // draft cache layout [2, B, S, H, dh] — reuse KvCache with L folded
        let dims = [2, 1, batch, self.max_len, self.n_heads, self.head_dim];
        KvCache { data: vec![0.0; dims.iter().product()], dims }
    }

    fn cache_dims(&self, batch: usize) -> Vec<usize> {
        vec![2, batch, self.max_len, self.n_heads, self.head_dim]
    }

    /// Draft prefill over the prompt: teacher features [1,P,D] + tokens
    /// (already shifted for the eagle variant by the caller). Returns the
    /// first draft (f̂ at the last valid position, children logits).
    pub fn prefill(
        &self,
        feats: &[f32],
        tokens: &[i32],
        len: usize,
        cache: &mut KvCache,
    ) -> Result<DraftOut> {
        // device-call staging is the documented exception to the
        // zero-alloc round guarantee (see util::count_alloc)
        #[cfg(feature = "count-alloc")]
        let _device_pause = crate::util::count_alloc::pause();
        let p = self.prefill_p;
        assert_eq!(tokens.len(), p);
        assert_eq!(feats.len(), p * self.d);
        let rt = &self.exes.rt;
        let f_buf = rt.upload_f32(feats, &[1, p, self.d])?;
        let t_buf = rt.upload_i32(tokens, &[1, p])?;
        let l_buf = rt.upload_i32(&[len as i32], &[1])?;
        let mut args = self.exes.params.refs();
        args.push(&self.target_weights.bufs[self.tok_emb_idx]);
        args.push(&self.target_weights.bufs[self.lm_head_idx]);
        args.push(&f_buf);
        args.push(&t_buf);
        args.push(&l_buf);
        let out = self.exes.exe("prefill")?.run(&args)?;
        let f_hat = lit_f32(&out[0])?; // [1, D]
        let logits = lit_f32(&out[1])?; // [1, V]
        cache.data = lit_f32(&out[2])?;
        Ok(DraftOut { feats: f_hat, logits })
    }

    /// Whether a `step_w{w}` executable is lowered for batch size `b` —
    /// the probe behind the draft-step [`WidthFamily`]
    /// (`crate::spec::dyntree::WidthFamily::filtered` over the
    /// `"draft_widths"` manifest constant).
    pub fn has_step(&self, w: usize, b: usize) -> bool {
        self.exes.has(&step_exe_name(w, b))
    }

    /// One draft level over `w` nodes. K/V rows land at
    /// [write_base, write_base + w); the caller owns slot bookkeeping.
    /// `w` may be any width of the lowered `step_w{w}` family — callers
    /// pick the narrowest one holding the level's frontier.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        w: usize,
        cache: &mut KvCache,
        write_base: &[i32],
        feats: &[f32],
        tokens: &[i32],
        pos: &[i32],
        bias: &[f32],
    ) -> Result<DraftOut> {
        #[cfg(feature = "count-alloc")]
        let _device_pause = crate::util::count_alloc::pause();
        let b = write_base.len();
        let exe_name = step_exe_name(w, b);
        let rt = &self.exes.rt;
        let cache_buf = rt.upload_f32(&cache.data, &self.cache_dims(b))?;
        let wb_buf = rt.upload_i32(write_base, &[b])?;
        let f_buf = rt.upload_f32(feats, &[b, w, self.d])?;
        let t_buf = rt.upload_i32(tokens, &[b, w])?;
        let p_buf = rt.upload_i32(pos, &[b, w])?;
        let m_buf = rt.upload_f32(bias, &[b, w, self.max_len])?;
        let mut args = self.exes.params.refs();
        args.push(&self.target_weights.bufs[self.tok_emb_idx]);
        args.push(&self.target_weights.bufs[self.lm_head_idx]);
        args.push(&cache_buf);
        args.push(&wb_buf);
        args.push(&f_buf);
        args.push(&t_buf);
        args.push(&p_buf);
        args.push(&m_buf);
        let out = self.exes.exe(&exe_name)?.run(&args)?;
        let f_hat = lit_f32(&out[0])?;
        let logits = lit_f32(&out[1])?;
        cache.data = lit_f32(&out[2])?;
        Ok(DraftOut { feats: f_hat, logits })
    }
}

/// Manifest/executable name of the draft step at width `w`, batch `b`.
pub fn step_exe_name(w: usize, b: usize) -> String {
    if b == 1 {
        format!("step_w{w}")
    } else {
        format!("step_w{w}_bs{b}")
    }
}
