//! Target-model wrapper: prefill / decode / tree-verify / commit, plus
//! batched (bs>1) variants. Owns nothing mutable — KV caches are passed
//! by the caller (`KvCache`), keeping the wrapper shareable across
//! sequences (vLLM-style separation of model and sequence state).

use anyhow::{bail, Result};
use std::rc::Rc;

use super::{ExeSet, NEG};
use crate::runtime::{lit_f32, manifest::ModelEntry, Manifest, Runtime};

/// Host-side KV cache for one (batched) sequence group.
/// Layout mirrors the artifact: [2, L, B, S, H, dh].
pub struct KvCache {
    pub data: Vec<f32>,
    pub dims: [usize; 6],
}

impl KvCache {
    pub fn new(
        n_layers: usize,
        batch: usize,
        max_len: usize,
        n_heads: usize,
        head_dim: usize,
    ) -> KvCache {
        let dims = [2, n_layers, batch, max_len, n_heads, head_dim];
        KvCache { data: vec![0.0; dims.iter().product()], dims }
    }
    pub fn dims_usize(&self) -> Vec<usize> {
        self.dims.to_vec()
    }
}

/// Result of a forward over T positions.
pub struct ForwardOut {
    /// [B, T, V]
    pub logits: Vec<f32>,
    /// [B, T, D]
    pub feats: Vec<f32>,
}

pub struct TargetModel {
    pub name: String,
    pub exes: ExeSet,
    pub vocab: usize,
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_len: usize,
    pub prefill_p: usize,
    pub is_moe: bool,
}

impl TargetModel {
    pub fn load(
        rt: &Rc<Runtime>,
        man: &Manifest,
        name: &str,
        entry: &ModelEntry,
    ) -> Result<TargetModel> {
        let exes =
            ExeSet::load(rt, man, &entry.weights, &entry.param_names, &entry.executables, name)?;
        let c = &entry.config;
        Ok(TargetModel {
            name: name.to_string(),
            exes,
            vocab: c.vocab,
            d: c.d,
            n_layers: c.n_layers,
            n_heads: c.n_heads,
            head_dim: c.head_dim,
            max_len: c.max_len,
            prefill_p: man.constants.prefill_p,
            is_moe: c.n_experts > 0,
        })
    }

    pub fn new_cache(&self, batch: usize) -> KvCache {
        KvCache::new(self.n_layers, batch, self.max_len, self.n_heads, self.head_dim)
    }

    /// Prefill (bs=1): pad/truncate `prompt` to P; returns logits/feats for
    /// all P positions and fills `cache`. Returns the used prompt length.
    pub fn prefill(&self, prompt: &[u32], cache: &mut KvCache) -> Result<(ForwardOut, usize)> {
        // device-call staging is the documented exception to the
        // zero-alloc round guarantee (see util::count_alloc)
        #[cfg(feature = "count-alloc")]
        let _device_pause = crate::util::count_alloc::pause();
        let p = self.prefill_p;
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() > p {
            bail!("prompt length {} exceeds prefill window {p}", prompt.len());
        }
        let len = prompt.len();
        let mut toks = vec![0i32; p];
        for (i, &t) in prompt.iter().enumerate() {
            toks[i] = t as i32;
        }
        let rt = &self.exes.rt;
        let tok_buf = rt.upload_i32(&toks, &[1, p])?;
        let len_buf = rt.upload_i32(&[len as i32], &[1])?;
        let mut args = self.exes.params.refs();
        args.push(&tok_buf);
        args.push(&len_buf);
        let out = self.exes.exe("prefill")?.run(&args)?;
        let logits = lit_f32(&out[0])?;
        let feats = lit_f32(&out[1])?;
        cache.data = lit_f32(&out[2])?;
        Ok((ForwardOut { logits, feats }, len))
    }

    /// Single-token decode (bs=1 or batched): `tokens` is one id per lane.
    pub fn decode(
        &self,
        cache: &mut KvCache,
        cache_lens: &[i32],
        tokens: &[i32],
    ) -> Result<ForwardOut> {
        #[cfg(feature = "count-alloc")]
        let _device_pause = crate::util::count_alloc::pause();
        let b = cache_lens.len();
        let exe_name = if b == 1 { "decode".to_string() } else { format!("decode_bs{b}") };
        let rt = &self.exes.rt;
        let cache_buf = rt.upload_f32(&cache.data, &cache.dims_usize())?;
        let len_buf = rt.upload_i32(cache_lens, &[b])?;
        let tok_buf = rt.upload_i32(tokens, &[b, 1])?;
        let mut args = self.exes.params.refs();
        args.push(&cache_buf);
        args.push(&len_buf);
        args.push(&tok_buf);
        let out = self.exes.exe(&exe_name)?.run(&args)?;
        let logits = lit_f32(&out[0])?;
        let feats = lit_f32(&out[1])?;
        cache.data = lit_f32(&out[2])?;
        Ok(ForwardOut { logits, feats })
    }

    /// Whether a `verify_t{t}` executable is lowered for batch size `b`
    /// — the probe behind [`WidthFamily::from_available`]
    /// (`crate::spec::dyntree::WidthFamily`).
    pub fn has_verify(&self, t: usize, b: usize) -> bool {
        self.exes.has(&verify_exe_name(t, b))
    }

    /// Fused commit+verify over `t` tree nodes (§Perf iteration 1): the
    /// PREVIOUS round's acceptance (`prev_idx`/`prev_n`, vs boundary
    /// `old_lens`) is compacted in-graph, then the new tree (built against
    /// `old_lens + prev_n`) is processed. `bias` is the additive mask
    /// [B, t, S] built by the tree module. `t` may be any width of the
    /// lowered `verify_t{t}` family — callers pick the cheapest one that
    /// holds the round's tree (see `spec/dyntree/widths.rs`).
    #[allow(clippy::too_many_arguments)]
    pub fn verify(
        &self,
        t: usize,
        cache: &mut KvCache,
        old_lens: &[i32],
        prev_idx: &[i32],
        prev_n: &[i32],
        tokens: &[i32],
        pos: &[i32],
        bias: &[f32],
        accept_a: usize,
    ) -> Result<ForwardOut> {
        #[cfg(feature = "count-alloc")]
        let _device_pause = crate::util::count_alloc::pause();
        let b = old_lens.len();
        let exe_name = verify_exe_name(t, b);
        let rt = &self.exes.rt;
        let cache_buf = rt.upload_f32(&cache.data, &cache.dims_usize())?;
        let len_buf = rt.upload_i32(old_lens, &[b])?;
        let pidx_buf = rt.upload_i32(prev_idx, &[b, accept_a])?;
        let pn_buf = rt.upload_i32(prev_n, &[b])?;
        let tok_buf = rt.upload_i32(tokens, &[b, t])?;
        let pos_buf = rt.upload_i32(pos, &[b, t])?;
        let bias_buf = rt.upload_f32(bias, &[b, t, self.max_len])?;
        let mut args = self.exes.params.refs();
        args.push(&cache_buf);
        args.push(&len_buf);
        args.push(&pidx_buf);
        args.push(&pn_buf);
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(&bias_buf);
        let out = self.exes.exe(&exe_name)?.run(&args)?;
        let logits = lit_f32(&out[0])?;
        let feats = lit_f32(&out[1])?;
        cache.data = lit_f32(&out[2])?;
        Ok(ForwardOut { logits, feats })
    }

    /// Batched prefill into one slot of a batch cache (bs>1 engines).
    pub fn prefill_slot(
        &self,
        batch: usize,
        cache: &mut KvCache,
        slot: usize,
        prompt: &[u32],
    ) -> Result<(ForwardOut, usize)> {
        #[cfg(feature = "count-alloc")]
        let _device_pause = crate::util::count_alloc::pause();
        let p = self.prefill_p;
        if prompt.len() > p {
            bail!("prompt too long");
        }
        let len = prompt.len();
        let mut toks = vec![0i32; p];
        for (i, &t) in prompt.iter().enumerate() {
            toks[i] = t as i32;
        }
        let rt = &self.exes.rt;
        let cache_buf = rt.upload_f32(&cache.data, &cache.dims_usize())?;
        let slot_buf = rt.upload_i32(&[slot as i32], &[])?;
        let tok_buf = rt.upload_i32(&toks, &[1, p])?;
        let len_buf = rt.upload_i32(&[len as i32], &[1])?;
        let mut args = self.exes.params.refs();
        args.push(&cache_buf);
        args.push(&slot_buf);
        args.push(&tok_buf);
        args.push(&len_buf);
        let out = self.exes.exe(&format!("prefill_slot_bs{batch}"))?.run(&args)?;
        let logits = lit_f32(&out[0])?;
        let feats = lit_f32(&out[1])?;
        cache.data = lit_f32(&out[2])?;
        Ok((ForwardOut { logits, feats }, len))
    }

    /// Slice [b, t, :] out of a [B, T, V]-flattened vector.
    pub fn row<'a>(
        &self,
        flat: &'a [f32],
        nt: usize,
        b: usize,
        t: usize,
        width: usize,
    ) -> &'a [f32] {
        let off = (b * nt + t) * width;
        &flat[off..off + width]
    }
}

/// Manifest/executable name of the fused verify at width `t`, batch `b`.
pub fn verify_exe_name(t: usize, b: usize) -> String {
    if b == 1 {
        format!("verify_t{t}")
    } else {
        format!("verify_t{t}_bs{b}")
    }
}

/// Build a single-row causal decode bias (testing/diagnostics helper).
pub fn causal_bias_row(cache_len: usize, s: usize) -> Vec<f32> {
    (0..s).map(|j| if j <= cache_len { 0.0 } else { NEG }).collect()
}
