//! Medusa-heads wrapper (baseline S6/S14): K residual-MLP heads that map
//! one feature vector to K token distributions at offsets +2..+K+1.

use anyhow::Result;
use std::rc::Rc;

use super::ExeSet;
use crate::runtime::{lit_f32, manifest::DraftEntry, Manifest, Runtime};

pub struct MedusaHeads {
    pub exes: ExeSet,
    pub k: usize,
    pub d: usize,
    pub vocab: usize,
}

impl MedusaHeads {
    pub fn load(
        rt: &Rc<Runtime>,
        man: &Manifest,
        entry: &DraftEntry,
        name: &str,
    ) -> Result<MedusaHeads> {
        let exes =
            ExeSet::load(rt, man, &entry.weights, &entry.param_names, &entry.executables, name)?;
        Ok(MedusaHeads { exes, k: 4, d: 0, vocab: 0 })
    }

    /// feat [D] -> logits [K, V].
    pub fn heads(&self, feat: &[f32]) -> Result<Vec<f32>> {
        let rt = &self.exes.rt;
        let f_buf = rt.upload_f32(feat, &[1, feat.len()])?;
        let mut args = self.exes.params.refs();
        args.push(&f_buf);
        let out = self.exes.exe("heads")?.run(&args)?;
        lit_f32(&out[0])
    }
}
