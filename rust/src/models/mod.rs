//! Typed model wrappers (S10) over AOT executables.
//!
//! Calling convention (see `python/compile/aot.py`):
//!   target exe: [param leaves] + call inputs
//!   draft  exe: [draft leaves] + [tok_emb, lm_head] + call inputs
//!
//! KV caches live as host `Vec<f32>` between calls (executables return the
//! updated cache; outputs arrive as host literals anyway — see
//! `runtime/mod.rs`) and are re-uploaded per call. All methods pay the
//! same cost, so paper speedup *ratios* are preserved; absolute overhead
//! is tracked by the step profiler and discussed in EXPERIMENTS.md §Perf.

pub mod eagle;
pub mod medusa;
pub mod target;

pub use eagle::EagleDraft;
pub use medusa::MedusaHeads;
pub use target::TargetModel;

use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

use crate::runtime::{manifest::ModelEntry, Exe, Manifest, ParamSet, Runtime};

/// Additive-mask "minus infinity" (matches python model.NEG).
pub const NEG: f32 = -1e30;

/// Loads + caches compiled executables for one weights/manifest entry.
pub struct ExeSet {
    pub rt: Rc<Runtime>,
    pub params: ParamSet,
    exes: BTreeMap<String, Exe>,
}

impl ExeSet {
    pub fn load(
        rt: &Rc<Runtime>,
        man: &Manifest,
        weights_rel: &str,
        param_names: &[String],
        exes: &BTreeMap<String, crate::runtime::manifest::ExeEntry>,
        prefix: &str,
    ) -> Result<ExeSet> {
        let params = ParamSet::load(rt, &man.path(weights_rel), param_names)?;
        let mut out = BTreeMap::new();
        for (name, entry) in exes {
            let exe = Exe::load(rt, &format!("{prefix}.{name}"), &man.path(&entry.hlo))?;
            out.insert(name.clone(), exe);
        }
        Ok(ExeSet { rt: rt.clone(), params, exes: out })
    }

    pub fn exe(&self, name: &str) -> Result<&Exe> {
        self.exes
            .get(name)
            .ok_or_else(|| {
                let have: Vec<_> = self.exes.keys().collect();
                anyhow::anyhow!("executable '{name}' not loaded (have {have:?})")
            })
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// (calls, total_ms) per executable — profiler hook.
    pub fn profile(&self) -> Vec<(String, u64, f64)> {
        self.exes
            .iter()
            .map(|(n, e)| (n.clone(), e.calls.get(), e.nanos.get() as f64 / 1e6))
            .collect()
    }
}

/// Convenience: load an entire model family (target + drafts + medusa +
/// tdlm) from the manifest.
pub struct ModelBundle {
    pub name: String,
    pub target: TargetModel,
    pub drafts: BTreeMap<String, EagleDraft>,
    pub medusa: Option<MedusaHeads>,
    pub tdlm: Option<TargetModel>,
}

impl ModelBundle {
    pub fn load(
        rt: &Rc<Runtime>,
        man: &Manifest,
        model_name: &str,
        draft_names: &[&str],
        with_medusa: bool,
        with_tdlm: bool,
    ) -> Result<ModelBundle> {
        let entry: &ModelEntry = man.model(model_name)?;
        let target = TargetModel::load(rt, man, model_name, entry)?;
        let mut drafts = BTreeMap::new();
        for dn in draft_names {
            if let Some(de) = entry.drafts.get(*dn) {
                drafts.insert(
                    dn.to_string(),
                    EagleDraft::load(rt, man, entry, de, &format!("{model_name}.{dn}"))?,
                );
            }
        }
        let medusa = if with_medusa {
            match &entry.medusa {
                Some(me) => Some(MedusaHeads::load(rt, man, me, &format!("{model_name}.medusa"))?),
                None => None,
            }
        } else {
            None
        };
        let tdlm = if with_tdlm {
            match &entry.tdlm {
                Some(te) => Some(TargetModel::load(rt, man, &format!("{model_name}.tdlm"), te)?),
                None => None,
            }
        } else {
            None
        };
        Ok(ModelBundle { name: model_name.to_string(), target, drafts, medusa, tdlm })
    }
}

/// Locate the artifacts directory: $EAGLE_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("EAGLE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| Path::new("artifacts").to_path_buf())
}
