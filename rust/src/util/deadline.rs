//! Per-request deadlines as a `Copy`, allocation-free clock.
//!
//! A [`DeadlineClock`] wraps an optional absolute [`Instant`]; checking
//! it is a single monotonic-clock read and a comparison — no heap, no
//! locks — so the engines can poll it at the top of every speculation
//! round without breaking the S22 zero-allocation guarantee. The
//! default clock is unbounded (never expires), which keeps every
//! existing call path (`RunSpec::default()`, eval, benches) behaviour-
//! identical.

use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, Default)]
pub struct DeadlineClock {
    at: Option<Instant>,
}

impl DeadlineClock {
    /// A clock that never expires (the default).
    pub fn unbounded() -> DeadlineClock {
        DeadlineClock { at: None }
    }

    /// Expire at an absolute instant.
    pub fn at(t: Instant) -> DeadlineClock {
        DeadlineClock { at: Some(t) }
    }

    /// Expire `ms` milliseconds after `start` (a request's arrival).
    pub fn after_ms(start: Instant, ms: u64) -> DeadlineClock {
        DeadlineClock { at: Some(start + Duration::from_millis(ms)) }
    }

    /// Build from an optional request budget: `None` or `0` means
    /// unbounded (the serve-flag convention: `--default-deadline-ms 0`
    /// disables deadlines).
    pub fn from_ms(ms: Option<u64>, start: Instant) -> DeadlineClock {
        match ms {
            Some(m) if m > 0 => DeadlineClock::after_ms(start, m),
            _ => DeadlineClock::unbounded(),
        }
    }

    pub fn is_unbounded(&self) -> bool {
        self.at.is_none()
    }

    /// The absolute expiry instant, if bounded.
    pub fn instant(&self) -> Option<Instant> {
        self.at
    }

    /// Has the deadline passed? Unbounded clocks never expire.
    /// Stack-only: safe inside the zero-alloc round loop.
    #[inline]
    pub fn expired(&self) -> bool {
        match self.at {
            Some(t) => Instant::now() >= t,
            None => false,
        }
    }

    /// Time left before expiry; `None` when unbounded, zero once expired.
    pub fn remaining(&self) -> Option<Duration> {
        self.at.map(|t| t.saturating_duration_since(Instant::now()))
    }

    /// Remaining budget in seconds, or `None` when unbounded. Used by
    /// the server's shed decision (estimated queue wait vs budget).
    pub fn budget_secs(&self) -> Option<f64> {
        self.remaining().map(|d| d.as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbounded_never_expires() {
        let c = DeadlineClock::default();
        assert!(c.is_unbounded());
        assert!(!c.expired());
        assert!(c.remaining().is_none());
        assert!(DeadlineClock::from_ms(None, Instant::now()).is_unbounded());
        assert!(DeadlineClock::from_ms(Some(0), Instant::now()).is_unbounded());
    }

    #[test]
    fn expiry_is_monotonic() {
        let past = Instant::now() - Duration::from_millis(5);
        assert!(DeadlineClock::at(past).expired());
        let c = DeadlineClock::after_ms(Instant::now(), 60_000);
        assert!(!c.expired());
        assert!(c.remaining().unwrap() > Duration::from_secs(1));
        assert!(c.budget_secs().unwrap() > 1.0);
    }

    #[test]
    fn from_ms_bounds() {
        let start = Instant::now();
        let c = DeadlineClock::from_ms(Some(10), start);
        assert!(!c.is_unbounded());
        assert!(c.instant().unwrap() <= start + Duration::from_millis(10));
    }
}
