//! Fault-injection harness ("failpoints") for chaos-testing the
//! serving stack, compiled only under the `fault-inject` feature.
//!
//! Hot paths mark named sites with the [`failpoint!`] macro:
//!
//! ```ignore
//! let degenerate = crate::failpoint!("verify");
//! ```
//!
//! Without the feature the macro is the constant `false` — zero code,
//! zero cost, so the S22 zero-allocation guarantee is untouched in
//! production and `count-alloc` builds. With the feature, each pass
//! through a site bumps its hit counter and, on the configured Nth hit,
//! performs the injected action:
//!
//! - `panic`          — `panic!` at the site (exercises worker supervision)
//! - `delay(MS)`      — sleep `MS` milliseconds (exercises deadlines/stall)
//! - `degenerate`     — return `true`; the site substitutes degenerate
//!   (all-NaN) logits (exercises the `total_cmp` NaN hardening)
//!
//! Actions are one-shot: they fire on the Nth hit only, so "survive the
//! panic, serve the next request" is the natural test shape. Sites are
//! configured programmatically ([`set`]/[`configure`]) or from the
//! environment (`EAGLE_FAILPOINTS`, also fed by `repro serve --inject`)
//! with the grammar `site=action[@N],site=action[@N],…`, e.g.
//! `verify=panic@2,draft-step=delay(50)`.
//!
//! Site catalogue (see docs/robustness.md): `draft-step`, `verify`,
//! `accept-walk` (both engines), `sched-dispatch` (scheduler group
//! formation), `deliver` (server slot delivery), `checkpoint` (lane
//! suspension — degenerate drops the suspension request, the lane runs
//! on), `resume` (checkpoint re-entry — degenerate evicts the parked KV
//! so the lane takes the slow prefix re-prefill path).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Panic at the site on the Nth hit.
    Panic,
    /// Sleep this many milliseconds on the Nth hit.
    Delay(u64),
    /// Tell the site to substitute degenerate (NaN) outputs on the Nth hit.
    Degenerate,
}

struct Site {
    name: String,
    action: Action,
    /// Fire on this hit count (1-based, one-shot).
    nth: u64,
    hits: AtomicU64,
}

fn registry() -> &'static Mutex<Vec<Site>> {
    static REG: OnceLock<Mutex<Vec<Site>>> = OnceLock::new();
    REG.get_or_init(|| {
        let reg = Mutex::new(Vec::new());
        if let Ok(spec) = std::env::var("EAGLE_FAILPOINTS") {
            if let Ok(sites) = parse_spec(&spec) {
                *reg.lock().unwrap() = sites;
            }
        }
        reg
    })
}

/// Arm `site` with `action`, firing on the `nth` hit (1-based).
/// Re-arming an existing site resets its hit counter.
pub fn set(site: &str, action: Action, nth: u64) {
    let mut reg = registry().lock().unwrap();
    reg.retain(|s| s.name != site);
    reg.push(Site { name: site.into(), action, nth: nth.max(1), hits: AtomicU64::new(0) });
}

/// Disarm every site and zero all hit counters.
pub fn clear_all() {
    registry().lock().unwrap().clear();
}

/// Total hits recorded at `site` since it was last armed (0 if unarmed).
pub fn hits(site: &str) -> u64 {
    let reg = registry().lock().unwrap();
    reg.iter().find(|s| s.name == site).map(|s| s.hits.load(Ordering::Relaxed)).unwrap_or(0)
}

/// Parse and install a `site=action[@N],…` spec (see module docs).
pub fn configure(spec: &str) -> anyhow::Result<()> {
    let sites = parse_spec(spec)?;
    let mut reg = registry().lock().unwrap();
    for s in sites {
        reg.retain(|e| e.name != s.name);
        reg.push(s);
    }
    Ok(())
}

fn parse_spec(spec: &str) -> anyhow::Result<Vec<Site>> {
    let mut out = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (name, rest) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("failpoint spec `{part}`: expected site=action"))?;
        let (act, nth) = match rest.split_once('@') {
            Some((a, n)) => {
                (a, n.parse::<u64>().map_err(|_| anyhow::anyhow!("bad hit count in `{part}`"))?)
            }
            None => (rest, 1),
        };
        let action = if act == "panic" {
            Action::Panic
        } else if act == "degenerate" {
            Action::Degenerate
        } else if let Some(ms) = act.strip_prefix("delay(").and_then(|r| r.strip_suffix(')')) {
            Action::Delay(ms.parse().map_err(|_| anyhow::anyhow!("bad delay ms in `{part}`"))?)
        } else {
            anyhow::bail!("failpoint spec `{part}`: unknown action `{act}`");
        };
        out.push(Site { name: name.trim().into(), action, nth: nth.max(1), hits: AtomicU64::new(0) });
    }
    Ok(out)
}

/// Record a pass through `site`; perform the armed action if this is the
/// Nth hit. Returns `true` when the site should substitute degenerate
/// outputs. Called only through the [`failpoint!`] macro.
pub fn hit(site: &str) -> bool {
    let action = {
        let reg = registry().lock().unwrap();
        match reg.iter().find(|s| s.name == site) {
            Some(s) => {
                let n = s.hits.fetch_add(1, Ordering::Relaxed) + 1;
                if n == s.nth {
                    Some(s.action)
                } else {
                    None
                }
            }
            None => None,
        }
    };
    // act outside the registry lock so a panic cannot poison it
    match action {
        Some(Action::Panic) => panic!("failpoint `{site}`: injected panic"),
        Some(Action::Delay(ms)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            false
        }
        Some(Action::Degenerate) => true,
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Sites are process-global; each test uses its own site names so
    // parallel test threads cannot interfere.

    #[test]
    fn unarmed_site_is_inert() {
        assert!(!hit("fp-test-inert"));
        assert_eq!(hits("fp-test-inert"), 0, "unarmed sites do not track hits");
    }

    #[test]
    fn fires_on_nth_hit_once() {
        set("fp-test-nth", Action::Degenerate, 2);
        assert!(!hit("fp-test-nth"), "first hit passes");
        assert!(hit("fp-test-nth"), "second hit fires");
        assert!(!hit("fp-test-nth"), "one-shot: third hit passes");
        assert_eq!(hits("fp-test-nth"), 3);
        set("fp-test-nth", Action::Degenerate, 1);
        assert_eq!(hits("fp-test-nth"), 0, "re-arming resets the counter");
        assert!(hit("fp-test-nth"));
    }

    #[test]
    fn injected_panic_carries_site_name() {
        set("fp-test-panic", Action::Panic, 1);
        let err = std::panic::catch_unwind(|| hit("fp-test-panic")).unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("fp-test-panic"), "panic message names the site: {msg}");
        assert!(!hit("fp-test-panic"), "registry survives the panic unpoisoned");
    }

    #[test]
    fn spec_grammar_roundtrip() {
        let sites = parse_spec("verify=panic@2, draft-step=delay(50), accept-walk=degenerate")
            .unwrap();
        assert_eq!(sites.len(), 3);
        assert_eq!(sites[0].action, Action::Panic);
        assert_eq!(sites[0].nth, 2);
        assert_eq!(sites[1].action, Action::Delay(50));
        assert_eq!(sites[1].nth, 1);
        assert_eq!(sites[2].action, Action::Degenerate);
        assert!(parse_spec("verify").is_err(), "missing action");
        assert!(parse_spec("verify=explode").is_err(), "unknown action");
        assert!(parse_spec("verify=panic@x").is_err(), "bad count");
        assert!(parse_spec("verify=delay(abc)").is_err(), "bad delay");
    }
}
