//! Dependency-free substrates: JSON, RNG, property-test harness, CLI args,
//! request deadlines, the test-only counting allocator (`count-alloc`
//! feature), and the fault-injection harness (`fault-inject` feature).

pub mod cli;
#[cfg(feature = "count-alloc")]
pub mod count_alloc;
pub mod deadline;
#[cfg(feature = "fault-inject")]
pub mod failpoint;
pub mod json;
pub mod prop;
pub mod rng;

/// Mark a named fault-injection site (see `util/failpoint.rs`).
///
/// Evaluates to a `bool`: `true` when an armed `degenerate` action fired
/// at this site (the caller substitutes degenerate outputs); `panic` and
/// `delay` actions are performed inside the macro. Without the
/// `fault-inject` feature this is the constant `false` — no code is
/// generated, so production and `count-alloc` builds are untouched.
#[macro_export]
macro_rules! failpoint {
    ($site:expr) => {{
        #[cfg(feature = "fault-inject")]
        let __fp_degenerate = $crate::util::failpoint::hit($site);
        #[cfg(not(feature = "fault-inject"))]
        let __fp_degenerate = false;
        __fp_degenerate
    }};
}
