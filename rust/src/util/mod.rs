//! Dependency-free substrates: JSON, RNG, property-test harness, CLI args,
//! and the test-only counting allocator (`count-alloc` feature).

pub mod cli;
#[cfg(feature = "count-alloc")]
pub mod count_alloc;
pub mod json;
pub mod prop;
pub mod rng;
