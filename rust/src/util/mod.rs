//! Dependency-free substrates: JSON, RNG, property-test harness, CLI args.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
