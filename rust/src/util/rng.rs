//! SplitMix64 PRNG (S19 substrate) — deterministic, seedable, dependency-
//! free. Used by sampling (temperature decoding), workload shuffling, and
//! the property-test harness.
//!
//! Every derived draw (`f64`, `below`, `weighted`, `fork`, …) routes
//! through [`Rng::next_u64`], so the stream position is fully described
//! by the number of `next_u64` calls made since seeding. The counter is
//! what makes lane checkpoints replayable: SplitMix64's state after `n`
//! draws is `seed + (n + 1) * GAMMA`, so [`Rng::resume`] rebuilds the
//! exact stream position in O(1) without replaying the draws.

const GAMMA: u64 = 0x9E3779B97F4A7C15;

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    draws: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(GAMMA), draws: 0 }
    }

    /// Rebuild the stream of `Rng::new(seed)` positioned just after its
    /// first `draws` calls to [`Rng::next_u64`] — bit-identical to
    /// seeding fresh and discarding `draws` values, in O(1).
    pub fn resume(seed: u64, draws: u64) -> Self {
        Rng { state: seed.wrapping_add(GAMMA.wrapping_mul(draws.wrapping_add(1))), draws }
    }

    /// Number of `next_u64` draws consumed since seeding — the stream
    /// position a [`Rng::resume`] needs alongside the original seed.
    pub fn draws(&self) -> u64 {
        self.draws
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GAMMA);
        self.draws = self.draws.wrapping_add(1);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi].
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + (self.next_u64() % (hi - lo + 1) as u64) as i64
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|w| *w as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= *w as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn resume_replays_stream_bit_identically() {
        for seed in [0u64, 7, 42, u64::MAX] {
            let mut full = Rng::new(seed);
            for cut in [0u64, 1, 3, 17, 100] {
                let mut a = Rng::new(seed);
                for _ in 0..cut {
                    a.next_u64();
                }
                assert_eq!(a.draws(), cut);
                let mut b = Rng::resume(seed, cut);
                assert_eq!(b.draws(), cut);
                for _ in 0..50 {
                    assert_eq!(a.next_u64(), b.next_u64(), "seed {seed} cut {cut}");
                }
            }
            // derived draws advance the counter too (they all route
            // through next_u64), so counting next_u64 calls suffices
            let before = full.draws();
            full.f64();
            full.below(9);
            full.weighted(&[1.0, 2.0]);
            assert!(full.draws() > before);
            let mut resumed = Rng::resume(seed, full.draws());
            assert_eq!(resumed.next_u64(), full.next_u64());
        }
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(3);
        let w = [0.0f32, 0.0, 1.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.weighted(&w), 2);
        }
        // rough frequency check
        let w = [1.0f32, 3.0];
        let mut c = [0usize; 2];
        for _ in 0..4000 {
            c[r.weighted(&w)] += 1;
        }
        let frac = c[1] as f64 / 4000.0;
        assert!((0.70..0.80).contains(&frac), "got {frac}");
    }
}
