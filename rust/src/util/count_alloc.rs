//! Allocator-level verification of the zero-allocation guarantee
//! (test-only, behind the `count-alloc` feature).
//!
//! `GenRecord::round_host_alloc_bytes` tracks the capacity growth of the
//! buffers the S22 scratch subsystem KNOWS about; an allocation smuggled
//! in anywhere else (a stray `Vec::new` in a walk, a `format!` on the
//! hot path, an `Rc` clone) would be invisible to it. This module closes
//! that gap: a counting [`std::alloc::GlobalAlloc`] wrapper over the
//! system allocator records every byte the CURRENT THREAD allocates, and
//! the engines record the per-round delta as
//! `GenRecord::round_alloc_counted_bytes` — asserted to be 0 for every
//! steady-state round (T=0 and T>0) in `rust/tests/count_alloc.rs`.
//!
//! Counting is **thread-local**, so concurrent test threads cannot
//! pollute each other's deltas and the suite needs no serial runner.
//!
//! One scoped exception: executable calls still stage inputs/outputs
//! through PJRT literals (uploads, `lit_f32` copies, exe-name
//! `format!`s), which the device-buffer-residency ROADMAP item will
//! remove. The model wrappers suspend counting around the device call
//! boundary with [`pause`], so the assertion measures exactly the host
//! round loop the scratch subsystem is responsible for.
//!
//! Registered as the global allocator by `lib.rs` when the feature is
//! on; the wrapper delegates straight to [`std::alloc::System`] either
//! way, so behavior (addresses, alignment, zeroing) is unchanged.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCATED: Cell<u64> = const { Cell::new(0) };
    static PAUSED: Cell<bool> = const { Cell::new(false) };
}

/// Counting wrapper over the system allocator (see module doc).
pub struct CountingAlloc;

#[inline]
fn record(bytes: usize) {
    // try_with: never panic inside the allocator (TLS teardown can
    // re-enter during thread exit)
    let _ = PAUSED.try_with(|p| {
        if !p.get() {
            let _ = ALLOCATED.try_with(|a| a.set(a.get() + bytes as u64));
        }
    });
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // only growth counts: shrinking (or in-place no-ops) acquires no
        // new capacity
        if new_size > layout.size() {
            record(new_size - layout.size());
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Total bytes the current thread has allocated while counting was not
/// paused (monotonic; callers measure deltas).
pub fn thread_allocated_bytes() -> u64 {
    ALLOCATED.with(|a| a.get())
}

/// Suspend counting on this thread until the guard drops — the model
/// wrappers hold one across each executable call so PJRT staging (the
/// documented device-boundary exception) stays out of the round deltas.
pub fn pause() -> PauseGuard {
    let prev = PAUSED.with(|p| p.replace(true));
    PauseGuard { prev }
}

pub struct PauseGuard {
    prev: bool,
}

impl Drop for PauseGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        let _ = PAUSED.try_with(|p| p.set(prev));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_this_threads_allocations_and_pauses() {
        let a0 = thread_allocated_bytes();
        let v: Vec<u8> = Vec::with_capacity(4096);
        let a1 = thread_allocated_bytes();
        assert!(a1 - a0 >= 4096, "allocation not counted: {} -> {a1}", a0);
        drop(v);
        {
            let _g = pause();
            let _w: Vec<u8> = Vec::with_capacity(8192);
            assert_eq!(thread_allocated_bytes(), a1, "paused allocations must not count");
        }
        let _x: Vec<u8> = Vec::with_capacity(64);
        assert!(thread_allocated_bytes() > a1, "counting resumes after the guard drops");
    }

    #[test]
    fn warm_vec_reuse_counts_zero() {
        let mut v: Vec<u64> = Vec::with_capacity(512);
        let a0 = thread_allocated_bytes();
        for round in 0..5 {
            v.clear();
            v.resize(512, round);
        }
        assert_eq!(thread_allocated_bytes(), a0, "clear/resize within capacity allocates");
    }
}
