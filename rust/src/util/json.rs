//! Minimal JSON parser/serializer (S16 substrate).
//!
//! serde/serde_json are not in the offline crate set, so the coordinator
//! carries its own implementation: a recursive-descent parser and a
//! writer, sufficient for the manifest/vocab/workload/API payloads.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `get` chained with a typed accessor, with an error path.
    pub fn req<'a>(&'a self, key: &str) -> anyhow::Result<&'a Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("short \\u"))?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| self.err("short low surrogate"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad \\u"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad \\u"))?;
                                    self.i += 6;
                                    char::from_u32(
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                    )
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("invalid codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // collect the full utf-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn display_roundtrip() {
        let src = r#"{"a":[1,2.5,"x\ny"],"b":{"c":true}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }
}
