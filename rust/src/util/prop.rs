//! Mini property-based testing harness (S19).
//!
//! proptest is not in the offline crate set; this provides the same core
//! workflow — run a property over many seeded random cases, report the
//! first failing seed so it can be replayed deterministically.

use super::rng::Rng;

/// Run `prop(rng, case_index)` for `cases` seeded cases; panic with the
/// failing seed on the first failure (replay with `check_seeded`).
pub fn check<F: FnMut(&mut Rng, usize)>(name: &str, cases: usize, mut prop: F) {
    for i in 0..cases {
        let seed = 0xEA61E_u64.wrapping_mul(i as u64 + 1);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, i)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed on case {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single case by seed (debugging helper).
pub fn check_seeded<F: FnOnce(&mut Rng)>(seed: u64, prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

/// Random probability vector of dimension `n` (sums to 1), possibly sparse.
pub fn random_dist(rng: &mut Rng, n: usize) -> Vec<f32> {
    let sparsity = rng.f32();
    let mut w: Vec<f32> = (0..n)
        .map(|_| if rng.f32() < sparsity { 0.0 } else { rng.f32() + 1e-4 })
        .collect();
    let sum: f32 = w.iter().sum();
    if sum <= 0.0 {
        w[rng.below(n)] = 1.0;
        return w;
    }
    for x in &mut w {
        *x /= sum;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check("counter", 25, |_, _| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 10, |rng, _| {
            assert!(rng.f64() < 0.5, "too big");
        });
    }

    #[test]
    fn random_dist_sums_to_one() {
        check("dist", 50, |rng, _| {
            let n = 1 + rng.below(40);
            let d = random_dist(rng, n);
            let s: f32 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
            assert!(d.iter().all(|&x| x >= 0.0));
        });
    }
}
