//! Tiny CLI argument parser (clap is not in the offline crate set).
//! Supports `--flag`, `--key value`, and positional arguments.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.push(name.to_string());
                    } else {
                        out.options.insert(name.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], flags: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn mixed_args() {
        let a = parse(&["eval", "--exp", "fig1", "--all", "--n", "8"], &["all"]);
        assert_eq!(a.positional, vec!["eval"]);
        assert_eq!(a.get("exp"), Some("fig1"));
        assert!(a.has("all"));
        assert_eq!(a.usize_or("n", 0), 8);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--verbose"], &[]);
        assert!(a.has("verbose"));
    }
}
