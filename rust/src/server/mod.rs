//! HTTP serving layer (S16): a hand-rolled HTTP/1.1 server over
//! `std::net` (tokio/hyper are not in the offline crate set) with a
//! single inference worker draining the request queue — Python never
//! touches the request path.
//!
//! Endpoints:
//!   POST /v1/generate   {"prompt", "max_tokens"?, "temperature"?, "method"?}
//!   GET  /healthz
//!   GET  /metrics       prometheus-style text

pub mod http;

use anyhow::Result;
use std::io::Write as _;
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::request::{Method, Request, Response, TreeChoice};
use crate::coordinator::{queue::PushError, RequestQueue, Scheduler};
use crate::eval::runner::{Runner, RunSpec};
use crate::models::ModelBundle;
use crate::spec::dyntree::{TreePolicy, WidthSelect};
use crate::spec::engine::GenConfig;
use crate::text::bpe::Bpe;
use crate::util::json::Json;
use http::{HttpRequest, HttpResponse};

pub struct ServerStats {
    pub requests: AtomicU64,
    pub tokens: AtomicU64,
    pub errors: AtomicU64,
    pub rejected: AtomicU64,
    pub gen_ns: AtomicU64,
}

/// Run the server (blocking). The inference worker owns the PJRT client
/// (single accelerator, single worker — CPU testbed); HTTP I/O threads
/// hand requests over through the bounded queue (backpressure -> 429).
/// `default_tree` is the draft-tree policy applied when a request does
/// not pick one via its `"tree"` field; `default_width` is the
/// verify-width policy (`--verify-width auto|N`) applied when a request
/// does not pin one via its `"verify_width"` field.
pub fn serve(
    addr: &str,
    model: &str,
    artifacts: &std::path::Path,
    queue_cap: usize,
    default_tree: TreePolicy,
    default_width: WidthSelect,
) -> Result<()> {
    let queue = Arc::new(RequestQueue::new(queue_cap));
    let stats = Arc::new(ServerStats {
        requests: AtomicU64::new(0),
        tokens: AtomicU64::new(0),
        errors: AtomicU64::new(0),
        rejected: AtomicU64::new(0),
        gen_ns: AtomicU64::new(0),
    });
    // response slots keyed by request id
    type Slot = Arc<(Mutex<Option<Response>>, std::sync::Condvar)>;
    let pending: Arc<Mutex<std::collections::HashMap<u64, Slot>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));

    // ---- inference worker --------------------------------------------------
    {
        let queue = queue.clone();
        let pending = pending.clone();
        let stats = stats.clone();
        let artifacts = artifacts.to_path_buf();
        let model = model.to_string();
        std::thread::Builder::new().name("inference".into()).spawn(move || {
            let runner = Runner::new(&artifacts).expect("loading artifacts");
            let bpe = Bpe::load(runner.man.path(&runner.man.tokenizer).to_str().unwrap())
                .expect("loading vocab");
            let bundle = ModelBundle::load(
                &runner.rt, &runner.man, &model, &["eagle"], true, true,
            )
            .expect("loading model bundle");
            eprintln!(
                "[server] model '{model}' loaded; serving (tree policy: {}, verify width: {})",
                default_tree.name(),
                default_width.describe()
            );
            let sched = Scheduler::new(1, 0);
            loop {
                let batch = sched.next_batch(&queue);
                if batch.is_empty() {
                    break; // queue closed
                }
                for req in batch {
                    let t0 = std::time::Instant::now();
                    let ids = bpe.encode_prompt(&req.prompt);
                    let spec = RunSpec {
                        method: req.method,
                        temperature: req.temperature,
                        max_new: req.max_tokens,
                        seed: req.seed,
                        tree: match (req.tree, &default_tree) {
                            (TreeChoice::Static, _) => TreePolicy::default_tree(),
                            // explicit "dynamic" keeps the server's configured
                            // dynamic knobs when it already runs dynamic
                            (TreeChoice::Dynamic, TreePolicy::Dynamic(_)) => default_tree.clone(),
                            (TreeChoice::Dynamic, _) => TreePolicy::dynamic_default(),
                            (TreeChoice::Default, _) => default_tree.clone(),
                        },
                        verify_width: match req.verify_width {
                            Some(t) => WidthSelect::Fixed(t),
                            None => default_width,
                        },
                        ..Default::default()
                    };
                    let cfg = GenConfig {
                        max_new: req.max_tokens,
                        temperature: req.temperature,
                        seed: req.seed,
                        eos: Some(bpe.eos()),
                    };
                    let resp = match runner.run_one(&bundle, &ids, &spec, &cfg) {
                        Ok(rec) => {
                            stats.tokens.fetch_add(rec.tokens.len() as u64, Ordering::Relaxed);
                            stats.gen_ns.fetch_add(rec.wall_ns, Ordering::Relaxed);
                            Response {
                                id: req.id,
                                text: bpe.decode(&rec.tokens),
                                tokens: rec.tokens.len(),
                                target_passes: rec.target_passes,
                                tau: rec.tau(),
                                latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                                queue_ms: req.arrival.elapsed().as_secs_f64() * 1e3
                                    - t0.elapsed().as_secs_f64() * 1e3,
                            }
                        }
                        Err(e) => {
                            stats.errors.fetch_add(1, Ordering::Relaxed);
                            Response {
                                id: req.id,
                                text: format!("error: {e}"),
                                tokens: 0,
                                target_passes: 0,
                                tau: 0.0,
                                latency_ms: 0.0,
                                queue_ms: 0.0,
                            }
                        }
                    };
                    if let Some(slot) = pending.lock().unwrap().get(&req.id).cloned() {
                        *slot.0.lock().unwrap() = Some(resp);
                        slot.1.notify_all();
                    }
                }
            }
        })?;
    }

    // ---- accept loop ---------------------------------------------------------
    let listener = TcpListener::bind(addr)?;
    eprintln!("[server] listening on http://{addr}");
    let next_id = Arc::new(AtomicU64::new(1));
    for stream in listener.incoming() {
        let mut stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let queue = queue.clone();
        let pending = pending.clone();
        let stats = stats.clone();
        let next_id = next_id.clone();
        std::thread::spawn(move || {
            let req = match HttpRequest::read_from(&mut stream) {
                Ok(r) => r,
                Err(_) => return,
            };
            let resp = route(&req, &queue, &pending, &stats, &next_id);
            let _ = stream.write_all(resp.to_bytes().as_slice());
        });
    }
    Ok(())
}

type PendingMap =
    Mutex<std::collections::HashMap<u64, Arc<(Mutex<Option<Response>>, std::sync::Condvar)>>>;

fn route(
    req: &HttpRequest,
    queue: &RequestQueue,
    pending: &PendingMap,
    stats: &ServerStats,
    next_id: &AtomicU64,
) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => HttpResponse::ok("application/json", b"{\"ok\":true}".to_vec()),
        ("GET", "/metrics") => {
            let body = format!(
                "eagle_requests_total {}\neagle_tokens_total {}\neagle_errors_total {}\neagle_rejected_total {}\neagle_queue_depth {}\neagle_gen_seconds_total {:.3}\n",
                stats.requests.load(Ordering::Relaxed),
                stats.tokens.load(Ordering::Relaxed),
                stats.errors.load(Ordering::Relaxed),
                stats.rejected.load(Ordering::Relaxed),
                queue.len(),
                stats.gen_ns.load(Ordering::Relaxed) as f64 / 1e9,
            );
            HttpResponse::ok("text/plain", body.into_bytes())
        }
        ("POST", "/v1/generate") => {
            stats.requests.fetch_add(1, Ordering::Relaxed);
            let body = match std::str::from_utf8(&req.body).ok().and_then(|s| Json::parse(s).ok())
            {
                Some(v) => v,
                None => return HttpResponse::status(400, "bad json"),
            };
            let id = next_id.fetch_add(1, Ordering::Relaxed);
            let r = match Request::from_json(id, &body) {
                Ok(r) => r,
                Err(e) => return HttpResponse::status(400, &format!("{e}")),
            };
            if r.method == Method::Medusa && r.temperature > 0.0 {
                return HttpResponse::status(400, "medusa is greedy-only");
            }
            let slot = Arc::new((Mutex::new(None), std::sync::Condvar::new()));
            pending.lock().unwrap().insert(id, slot.clone());
            match queue.push(r) {
                Ok(()) => {}
                Err(PushError::Full) => {
                    pending.lock().unwrap().remove(&id);
                    stats.rejected.fetch_add(1, Ordering::Relaxed);
                    return HttpResponse::status(429, "queue full");
                }
                Err(PushError::Closed) => {
                    pending.lock().unwrap().remove(&id);
                    return HttpResponse::status(503, "shutting down");
                }
            }
            // wait for the worker
            let (lock, cv) = &*slot;
            let mut g = lock.lock().unwrap();
            while g.is_none() {
                let (ng, _t) = cv
                    .wait_timeout(g, std::time::Duration::from_secs(120))
                    .unwrap();
                g = ng;
                if g.is_none() {
                    pending.lock().unwrap().remove(&id);
                    return HttpResponse::status(504, "generation timeout");
                }
            }
            let resp = g.take().unwrap();
            pending.lock().unwrap().remove(&id);
            HttpResponse::ok("application/json", resp.to_json().to_string().into_bytes())
        }
        _ => HttpResponse::status(404, "not found"),
    }
}
