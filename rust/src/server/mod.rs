//! HTTP serving layer (S16): a hand-rolled HTTP/1.1 server over
//! `std::net` (tokio/hyper are not in the offline crate set) with a
//! single inference worker draining the request queue — Python never
//! touches the request path.
//!
//! Endpoints:
//!   POST /v1/generate   {"prompt", "max_tokens"?, "temperature"?, "method"?}
//!   GET  /healthz
//!   GET  /metrics       prometheus-style text
//!
//! The worker admits requests through the [`Scheduler`]: per-request
//! FCFS by default, or — with `--batch N --width-grouping` — width-aware
//! sub-batches where EAGLE lanes are grouped by their predicted
//! verify width (`"width_hint"` request field, falling back to the
//! `"verify_width"` pin) and executed on the batched engine with the
//! group's width cap, so a low-acceptance group never runs at a hot
//! lane's width. With `--batch N` alone (FCFS), an admitted multi-lane
//! batch of compatible EAGLE requests still executes on the batched
//! engine — uncapped, at the max over lane fits — so the serve-time
//! FCFS-vs-grouped A/B matches the engine-level
//! `repro eval --exp widthsched` comparison. Sampled (T>0) requests
//! batch too: lanes sharing a temperature co-execute with per-request
//! RNG seeds (`generate_pooled_seeded`), so a sampled response never
//! depends on which other lanes shared its batch, and stays
//! distribution-preserving; it is bit-identical to the equal-seed bs=1
//! run when the per-round tree plans match (static trees, or matching
//! width families with the adaptive controller off — see the
//! batch-engine module doc). Groups the batched engine cannot take
//! (other methods,
//! mixed max_tokens/tree/temperature classes, verify-width pins,
//! missing `_bs{b}` executables) fall back to the bs=1 path. The worker
//! owns one [`ScratchPool`] for its lifetime, so batched groups reuse
//! warm per-lane round state across admissions (keyed by KV slot). The
//! width-grouping cost model can be calibrated with `--cost-model
//! path` (a JSON file from `repro bench --json`; see
//! [`crate::coordinator::CostModel`]).

pub mod http;

use anyhow::Result;
use std::io::Write as _;
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::request::{Method, Request, Response, TreeChoice};
use crate::coordinator::{
    queue::PushError, AdmissionPolicy, AdmittedGroup, BatchEagleEngine, CostModel, RequestQueue,
    Scheduler,
};
use crate::eval::runner::{Runner, RunSpec};
use crate::models::ModelBundle;
use crate::spec::dyntree::{TreePolicy, WidthSelect};
use crate::spec::engine::GenConfig;
use crate::spec::scratch::ScratchPool;
use crate::text::bpe::Bpe;
use crate::util::json::Json;
use http::{HttpRequest, HttpResponse};

pub struct ServerStats {
    pub requests: AtomicU64,
    pub tokens: AtomicU64,
    pub errors: AtomicU64,
    pub rejected: AtomicU64,
    pub gen_ns: AtomicU64,
    pub batched: AtomicU64,
}

/// Server configuration (see `repro serve --help`).
pub struct ServeConfig {
    pub addr: String,
    pub model: String,
    pub artifacts: std::path::PathBuf,
    pub queue_cap: usize,
    /// Draft-tree policy applied when a request does not pick one via
    /// its `"tree"` field.
    pub default_tree: TreePolicy,
    /// Verify-width policy (`--verify-width auto|N`) applied when a
    /// request does not pin one via its `"verify_width"` field.
    pub default_width: WidthSelect,
    /// Admission batch size (`--batch`); 1 = per-request serving.
    pub max_batch: usize,
    /// Linger for batch fill (`--linger`), in milliseconds.
    pub linger_ms: u64,
    /// Width-aware group admission (`--width-grouping`); FCFS otherwise.
    pub width_grouping: bool,
    /// Optional dispatch-cost calibration file (`--cost-model`); the
    /// default keeps `scheduler::DISPATCH_OVERHEAD`.
    pub cost_model: Option<std::path::PathBuf>,
}

impl ServeConfig {
    pub fn new(addr: &str, model: &str, artifacts: &std::path::Path) -> ServeConfig {
        ServeConfig {
            addr: addr.to_string(),
            model: model.to_string(),
            artifacts: artifacts.to_path_buf(),
            queue_cap: 64,
            default_tree: TreePolicy::default_tree(),
            default_width: WidthSelect::Auto,
            max_batch: 1,
            linger_ms: 2,
            width_grouping: false,
            cost_model: None,
        }
    }
}

type Slot = Arc<(Mutex<Option<Response>>, std::sync::Condvar)>;
type PendingMap = Mutex<std::collections::HashMap<u64, Slot>>;

fn deliver(pending: &PendingMap, id: u64, resp: Response) {
    if let Some(slot) = pending.lock().unwrap().get(&id).cloned() {
        *slot.0.lock().unwrap() = Some(resp);
        slot.1.notify_all();
    }
}

fn error_response(id: u64, e: &anyhow::Error) -> Response {
    Response {
        id,
        text: format!("error: {e}"),
        tokens: 0,
        target_passes: 0,
        tau: 0.0,
        latency_ms: 0.0,
        queue_ms: 0.0,
    }
}

/// Resolve a request's tree choice against the server default.
fn resolve_tree(choice: TreeChoice, default_tree: &TreePolicy) -> TreePolicy {
    match (choice, default_tree) {
        (TreeChoice::Static, _) => TreePolicy::default_tree(),
        // explicit "dynamic" keeps the server's configured dynamic knobs
        // when it already runs dynamic
        (TreeChoice::Dynamic, TreePolicy::Dynamic(_)) => default_tree.clone(),
        (TreeChoice::Dynamic, _) => TreePolicy::dynamic_default(),
        (TreeChoice::Default, _) => default_tree.clone(),
    }
}

/// Run the server (blocking). The inference worker owns the PJRT client
/// (single accelerator, single worker — CPU testbed); HTTP I/O threads
/// hand requests over through the bounded queue (backpressure -> 429).
pub fn serve(cfg: ServeConfig) -> Result<()> {
    let queue = Arc::new(RequestQueue::new(cfg.queue_cap));
    let stats = Arc::new(ServerStats {
        requests: AtomicU64::new(0),
        tokens: AtomicU64::new(0),
        errors: AtomicU64::new(0),
        rejected: AtomicU64::new(0),
        gen_ns: AtomicU64::new(0),
        batched: AtomicU64::new(0),
    });
    let pending: Arc<PendingMap> = Arc::new(Mutex::new(std::collections::HashMap::new()));

    // ---- inference worker --------------------------------------------------
    {
        let queue = queue.clone();
        let pending = pending.clone();
        let stats = stats.clone();
        let artifacts = cfg.artifacts.clone();
        let model = cfg.model.clone();
        let default_tree = cfg.default_tree.clone();
        let default_width = cfg.default_width;
        let (max_batch, linger_ms) = (cfg.max_batch, cfg.linger_ms);
        let grouping = cfg.width_grouping;
        let cost_model = cfg.cost_model.clone();
        std::thread::Builder::new().name("inference".into()).spawn(move || {
            let runner = Runner::new(&artifacts).expect("loading artifacts");
            let bpe = Bpe::load(runner.man.path(&runner.man.tokenizer).to_str().unwrap())
                .expect("loading vocab");
            let bundle = ModelBundle::load(
                &runner.rt, &runner.man, &model, &["eagle"], true, true,
            )
            .expect("loading model bundle");
            let c = runner.man.constants.clone();
            eprintln!(
                "[server] model '{model}' loaded; serving (tree: {}, verify width: {}, \
                 batch: {max_batch}, admission: {})",
                default_tree.name(),
                default_width.describe(),
                if grouping { "width-grouped" } else { "fcfs" }
            );
            let policy = if grouping {
                AdmissionPolicy::WidthGrouped {
                    verify_widths: c.verify_widths.clone(),
                    max_t: c.tree_t,
                }
            } else {
                AdmissionPolicy::Fcfs
            };
            let cost = match &cost_model {
                Some(path) => match CostModel::load(path) {
                    Ok(cm) => {
                        eprintln!(
                            "[server] cost model calibrated: dispatch overhead {} node units \
                             (from {})",
                            cm.dispatch_overhead,
                            path.display()
                        );
                        cm
                    }
                    Err(e) => {
                        eprintln!("[server] cost model load failed ({e}); using default");
                        CostModel::default()
                    }
                },
                None => CostModel::default(),
            };
            let sched =
                Scheduler::new(max_batch, linger_ms).with_policy(policy).with_cost_model(cost);
            // one warm scratch pool for the worker's lifetime: batched
            // groups reuse per-lane round state across admissions
            let mut pool = ScratchPool::new();
            loop {
                let groups = sched.next_groups(&queue);
                if groups.is_empty() {
                    break; // queue closed
                }
                for group in groups {
                    run_group(
                        group, &runner, &bundle, &bpe, &c, &default_tree, default_width,
                        &pending, &stats, &mut pool,
                    );
                }
            }
        })?;
    }

    // ---- accept loop ---------------------------------------------------------
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!("[server] listening on http://{}", cfg.addr);
    let next_id = Arc::new(AtomicU64::new(1));
    for stream in listener.incoming() {
        let mut stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let queue = queue.clone();
        let pending = pending.clone();
        let stats = stats.clone();
        let next_id = next_id.clone();
        std::thread::spawn(move || {
            let req = match HttpRequest::read_from(&mut stream) {
                Ok(r) => r,
                Err(_) => return,
            };
            let resp = route(&req, &queue, &pending, &stats, &next_id);
            let _ = stream.write_all(resp.to_bytes().as_slice());
        });
    }
    Ok(())
}

/// Execute one admitted group: the batched engine when it qualifies —
/// with the group's width cap under width-grouped admission, uncapped
/// (max over lane fits) for a compatible FCFS batch — the bs=1 path per
/// request otherwise.
#[allow(clippy::too_many_arguments)]
fn run_group(
    group: AdmittedGroup,
    runner: &Runner,
    bundle: &ModelBundle,
    bpe: &Bpe,
    c: &crate::runtime::manifest::Constants,
    default_tree: &TreePolicy,
    default_width: WidthSelect,
    pending: &PendingMap,
    stats: &ServerStats,
    pool: &mut ScratchPool,
) {
    let reqs = &group.requests;
    let b = reqs.len();
    // the batched engine can take the group iff it is a multi-lane group
    // of batchable requests (`Request::width_batchable`, the same
    // predicate the scheduler groups by), the server is not pinned to a
    // fixed verify width (only the bs=1 path honors `--verify-width N`),
    // and the bs{b} executables are lowered. Width-planned groups arrive
    // pre-classed by the scheduler; an FCFS admission may mix classes,
    // so the batched FCFS baseline additionally requires one shared
    // (max_tokens, tree, temperature) class — the lock-step engine runs
    // every lane under one GenConfig (seeds stay per-lane).
    let same_class = reqs.windows(2).all(|p| {
        p[0].max_tokens == p[1].max_tokens
            && p[0].tree == p[1].tree
            && p[0].temperature_class() == p[1].temperature_class()
    });
    let batchable = b >= 2
        && default_width == WidthSelect::Auto
        && same_class
        && reqs.iter().all(Request::width_batchable)
        && bundle.target.exes.has(&format!("prefill_slot_bs{b}"))
        && bundle.drafts.contains_key("eagle");
    if batchable {
        let t0 = std::time::Instant::now();
        let prompts: Vec<Vec<u32>> = reqs.iter().map(|r| bpe.encode_prompt(&r.prompt)).collect();
        let policy = resolve_tree(reqs[0].tree, default_tree);
        let mut engine = BatchEagleEngine::new(&bundle.target, &bundle.drafts["eagle"], c)
            .with_policy(policy.clone());
        // the group's width cap only applies under the dynamic planner,
        // which shrinks each lane's node budget to fit it; a static tree
        // is a fixed shape that no narrow cap can hold, so a static
        // group runs batched but uncapped (max over lane fits). FCFS
        // groups carry no cap at all — the uncapped batched baseline.
        if policy.is_dynamic() {
            if let Some(cap) = group.verify_cap {
                engine = engine.with_verify_cap(cap);
            }
        }
        let gen = GenConfig {
            max_new: reqs[0].max_tokens,
            temperature: reqs[0].temperature.max(0.0),
            seed: reqs[0].seed,
            eos: Some(bpe.eos()),
        };
        // per-request seeds: a lane's sampled stream is its own, so the
        // response matches the request's equal-seed bs=1 run no matter
        // which other lanes share the batch
        let seeds: Vec<u64> = reqs.iter().map(|r| r.seed).collect();
        match engine.generate_pooled_seeded(&prompts, &seeds, &gen, pool) {
            Ok(recs) => {
                stats.batched.fetch_add(b as u64, Ordering::Relaxed);
                let lat_ms = t0.elapsed().as_secs_f64() * 1e3;
                for (req, rec) in reqs.iter().zip(recs) {
                    stats.tokens.fetch_add(rec.tokens.len() as u64, Ordering::Relaxed);
                    stats.gen_ns.fetch_add(rec.wall_ns / b as u64, Ordering::Relaxed);
                    deliver(
                        pending,
                        req.id,
                        Response {
                            id: req.id,
                            text: bpe.decode(&rec.tokens),
                            tokens: rec.tokens.len(),
                            target_passes: rec.target_passes,
                            tau: rec.tau(),
                            latency_ms: lat_ms,
                            queue_ms: req.arrival.elapsed().as_secs_f64() * 1e3 - lat_ms,
                        },
                    );
                }
            }
            Err(e) => {
                stats.errors.fetch_add(b as u64, Ordering::Relaxed);
                let e = anyhow::anyhow!("{e}");
                for req in reqs {
                    deliver(pending, req.id, error_response(req.id, &e));
                }
            }
        }
        return;
    }
    // bs=1 fallback: the latency path, one request at a time
    for req in reqs {
        let t0 = std::time::Instant::now();
        let ids = bpe.encode_prompt(&req.prompt);
        let spec = RunSpec {
            method: req.method,
            temperature: req.temperature,
            max_new: req.max_tokens,
            seed: req.seed,
            tree: resolve_tree(req.tree, default_tree),
            verify_width: match req.verify_width {
                Some(t) => WidthSelect::Fixed(t),
                None => default_width,
            },
            ..Default::default()
        };
        let gen = GenConfig {
            max_new: req.max_tokens,
            temperature: req.temperature,
            seed: req.seed,
            eos: Some(bpe.eos()),
        };
        let resp = match runner.run_one(bundle, &ids, &spec, &gen) {
            Ok(rec) => {
                stats.tokens.fetch_add(rec.tokens.len() as u64, Ordering::Relaxed);
                stats.gen_ns.fetch_add(rec.wall_ns, Ordering::Relaxed);
                Response {
                    id: req.id,
                    text: bpe.decode(&rec.tokens),
                    tokens: rec.tokens.len(),
                    target_passes: rec.target_passes,
                    tau: rec.tau(),
                    latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                    queue_ms: req.arrival.elapsed().as_secs_f64() * 1e3
                        - t0.elapsed().as_secs_f64() * 1e3,
                }
            }
            Err(e) => {
                stats.errors.fetch_add(1, Ordering::Relaxed);
                error_response(req.id, &e)
            }
        };
        deliver(pending, req.id, resp);
    }
}

fn route(
    req: &HttpRequest,
    queue: &RequestQueue,
    pending: &PendingMap,
    stats: &ServerStats,
    next_id: &AtomicU64,
) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => HttpResponse::ok("application/json", b"{\"ok\":true}".to_vec()),
        ("GET", "/metrics") => {
            let body = format!(
                "eagle_requests_total {}\neagle_tokens_total {}\neagle_errors_total {}\neagle_rejected_total {}\neagle_batched_total {}\neagle_queue_depth {}\neagle_gen_seconds_total {:.3}\n",
                stats.requests.load(Ordering::Relaxed),
                stats.tokens.load(Ordering::Relaxed),
                stats.errors.load(Ordering::Relaxed),
                stats.rejected.load(Ordering::Relaxed),
                stats.batched.load(Ordering::Relaxed),
                queue.len(),
                stats.gen_ns.load(Ordering::Relaxed) as f64 / 1e9,
            );
            HttpResponse::ok("text/plain", body.into_bytes())
        }
        ("POST", "/v1/generate") => {
            stats.requests.fetch_add(1, Ordering::Relaxed);
            let body = match std::str::from_utf8(&req.body).ok().and_then(|s| Json::parse(s).ok())
            {
                Some(v) => v,
                None => return HttpResponse::status(400, "bad json"),
            };
            let id = next_id.fetch_add(1, Ordering::Relaxed);
            let r = match Request::from_json(id, &body) {
                Ok(r) => r,
                Err(e) => return HttpResponse::status(400, &format!("{e}")),
            };
            if r.method == Method::Medusa && r.temperature > 0.0 {
                return HttpResponse::status(400, "medusa is greedy-only");
            }
            let slot: Slot = Arc::new((Mutex::new(None), std::sync::Condvar::new()));
            pending.lock().unwrap().insert(id, slot.clone());
            match queue.push(r) {
                Ok(()) => {}
                Err(PushError::Full) => {
                    pending.lock().unwrap().remove(&id);
                    stats.rejected.fetch_add(1, Ordering::Relaxed);
                    return HttpResponse::status(429, "queue full");
                }
                Err(PushError::Closed) => {
                    pending.lock().unwrap().remove(&id);
                    return HttpResponse::status(503, "shutting down");
                }
            }
            // wait for the worker
            let (lock, cv) = &*slot;
            let mut g = lock.lock().unwrap();
            while g.is_none() {
                let (ng, _t) = cv
                    .wait_timeout(g, std::time::Duration::from_secs(120))
                    .unwrap();
                g = ng;
                if g.is_none() {
                    pending.lock().unwrap().remove(&id);
                    return HttpResponse::status(504, "generation timeout");
                }
            }
            let resp = g.take().unwrap();
            pending.lock().unwrap().remove(&id);
            HttpResponse::ok("application/json", resp.to_json().to_string().into_bytes())
        }
        _ => HttpResponse::status(404, "not found"),
    }
}
