//! HTTP serving layer (S16): a hand-rolled HTTP/1.1 server over
//! `std::net` (tokio/hyper are not in the offline crate set) with a
//! single inference worker draining the request queue — Python never
//! touches the request path.
//!
//! Endpoints:
//!   POST /v1/generate   {"prompt", "max_tokens"?, "temperature"?, "deadline_ms"?, ...}
//!   GET  /healthz       worker liveness JSON; 503 when stalled or draining
//!   GET  /metrics       Prometheus text exposition (see [`ServerMetrics`])
//!   GET  /trace         round flight-recorder dump (see `metrics::trace`)
//!   POST /admin/drain   close the queue, finish in flight, exit cleanly
//!   POST /admin/preempt {"enabled": bool} — flip lane preemption at runtime
//!
//! The worker admits requests through the [`Scheduler`]: per-request
//! FCFS by default, or — with `--batch N --width-grouping` — width-aware
//! sub-batches where EAGLE lanes are grouped by their predicted
//! verify width (`"width_hint"` request field, falling back to the
//! `"verify_width"` pin) and executed on the batched engine with the
//! group's width cap, so a low-acceptance group never runs at a hot
//! lane's width. With `--batch N` alone (FCFS), an admitted multi-lane
//! batch of compatible EAGLE requests still executes on the batched
//! engine — uncapped, at the max over lane fits — so the serve-time
//! FCFS-vs-grouped A/B matches the engine-level
//! `repro eval --exp widthsched` comparison. Sampled (T>0) requests
//! batch too: lanes sharing a temperature co-execute with per-request
//! RNG seeds (`generate_pooled_seeded`), so a sampled response never
//! depends on which other lanes shared its batch, and stays
//! distribution-preserving; it is bit-identical to the equal-seed bs=1
//! run when the per-round tree plans match (static trees, or matching
//! width families with the adaptive controller off — see the
//! batch-engine module doc). Groups the batched engine cannot take
//! (other methods,
//! mixed max_tokens/tree/temperature classes, verify-width pins,
//! missing `_bs{b}` executables) fall back to the bs=1 path. The worker
//! owns one [`ScratchPool`] for its lifetime, so batched groups reuse
//! warm per-lane round state across admissions (keyed by KV slot). The
//! width-grouping cost model can be calibrated with `--cost-model
//! path` (a JSON file from `repro bench --json`; see
//! [`crate::coordinator::CostModel`]).
//!
//! Observability: the worker threads a [`RoundObserver`] through both
//! engines — every speculation round lands in the [`FlightRecorder`]
//! ring and the round histograms, and beats the [`Health`] heartbeat.
//! The whole record path is store/fetch-add only, so serving with full
//! observability attached stays inside the S22 zero-allocation round
//! guarantee (asserted in `rust/tests/count_alloc.rs`). The full metric
//! catalogue lives in `docs/observability.md`.
//!
//! Fault tolerance (`docs/robustness.md`): [`worker_loop`] wraps every
//! admitted group in `catch_unwind`, so a panic fails only its own
//! lanes with a 500 and the worker rebuilds its scratch and serves the
//! next group; repeat offenders are refused by content-fingerprint
//! [`Quarantine`]. Per-request deadlines (`"deadline_ms"` /
//! `--default-deadline-ms`) drop queue-expired work with 504 and
//! truncate in-flight generations to partial text; admission sheds with
//! 429 + Retry-After when queue depth x EWMA service time exceeds the
//! request's budget (seeded from the live cost model's prediction on a
//! cold server, so a burst right after restart still sheds).
//!
//! SLA-aware scheduling (`docs/load.md`, `docs/robustness.md`): the
//! queue's admission order is runtime-switchable between FCFS and EDF
//! (`--edf` / `POST /admin/sched`), the scheduler's linger is capped by
//! the tightest queued deadline minus the estimated service time, and
//! the dispatch cost model is re-fit online from the server's own
//! per-round verify timings ([`OnlineCostModel`]). `--synthetic` swaps
//! the engine worker for a deterministic simulated one so the whole
//! stack — queue, scheduler, shedding, drain, metrics, failpoints — runs
//! end to end without artifacts (the `repro loadgen` harness and the CI
//! smoke drive exactly this mode).
//!
//! Heterogeneous draft sources (`docs/drafting.md`): a request pins a
//! drafting strategy with its `"draft"` field (`eagle | chain | ngram |
//! medusa`), or asks for the online policy with `"draft": "auto"`; the
//! server default is `--draft`. The source is resolved at admission —
//! auto picks from a per-source acceptance [`SourceSelector`] fed by
//! every finished generation (simulated acceptance curves in synthetic
//! mode, so `--draft auto` converges without artifacts) — and becomes
//! part of the scheduler's compat class (groups never mix sources), the
//! quarantine [`fingerprint`], and the dispatch decision (non-eagle
//! sources run their engine facades on the bs=1 path). Rounds are
//! counted per source in `eagle_draft_source_rounds_total{source}`;
//! auto-policy source changes in `eagle_policy_switches_total`.
//!
//! Checkpointable lanes (`--preempt`, `docs/robustness.md`): every lane
//! is suspendable at round boundaries and resumes **bit-identically**.
//! A [`PreemptCtl`] bundles the lane [`PreemptSignal`], the
//! [`CheckpointStore`] (with a `--kv-budget` eviction watermark), and a
//! runtime enable switch (`POST /admin/preempt`). Suspension requests
//! come from three governors — the EDF head's deadline beating the
//! running group's slack (per-round, via [`WorkerObserver`]), store
//! memory pressure, and drain — counted by
//! `eagle_preempt_total{reason}`. A suspended lane's checkpoint parks in
//! the store while its request re-enters the queue via `push_resume`
//! (original arrival/deadline, width hint refreshed from the
//! controller's current EWMA); the next dispatch resumes it, re-
//! prefilling first if its KV was evicted (`eagle_kv_evictions_total`,
//! `eagle_resume_refill_rounds_total`). Preemption never touches the
//! quarantine ledger, a deadline expiring while suspended delivers the
//! partial text with `"truncated":"deadline"`, and drain resumes and
//! completes every suspended lane before the worker exits.

pub mod http;

use anyhow::Result;
use std::io::Write as _;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::coordinator::costfit::load_committed_capacity;
use crate::coordinator::request::{Method, Request, Response, TreeChoice};
use crate::coordinator::{
    queue::PushError, verify_curve_points, AdmissionPolicy, AdmittedGroup, BatchEagleEngine,
    CheckpointStore, CostModel, LaneCheckpoint, LaneInput, LaneOutcome, OnlineCostModel,
    PreemptSignal, RequestQueue, Scheduler,
};
use crate::eval::runner::{Runner, RunSpec};
use crate::metrics::registry::{
    log_buckets, CounterId, GaugeId, HistId, MetricsRegistry, RegistryBuilder,
};
use crate::metrics::trace::{FlightRecorder, RoundEvent, RoundObserver};
use crate::metrics::{Aggregate, GenRecord};
use crate::models::ModelBundle;
use crate::spec::dyntree::{SourceSelector, TreePolicy, WidthSelect};
use crate::spec::engine::{EagleEngine, GenConfig};
use crate::spec::scratch::ScratchPool;
use crate::spec::source::{prompt_repetitiveness, sim_accepted_per_round, DraftChoice, SourceKind};
use crate::text::bpe::Bpe;
use crate::util::json::Json;
use http::{HttpRequest, HttpResponse};

/// The server's full metric surface: a pre-sized lock-free registry
/// (request lifecycle histograms, scheduler gauges, dispatch/drag
/// counters, per-phase time totals) plus the round flight recorder.
/// Constructed once at startup; every record method is store/fetch-add
/// only. Constructable without artifacts, so the exposition tests in
/// `rust/tests/observability.rs` exercise the exact serving registry.
pub struct ServerMetrics {
    pub registry: MetricsRegistry,
    pub trace: FlightRecorder,
    // counters
    c_requests: CounterId,
    c_tokens: CounterId,
    c_errors: CounterId,
    c_rejected: CounterId,
    c_shed: CounterId,
    c_worker_panics: CounterId,
    c_lane_failures: CounterId,
    c_deadline_queue: CounterId,
    c_deadline_generate: CounterId,
    c_dispatch_batched: CounterId,
    c_dispatch_bs1: CounterId,
    c_dragged: CounterId,
    c_rounds: CounterId,
    c_gen_ns: CounterId,
    c_phase: [CounterId; 5],
    c_round_alloc: CounterId,
    // scheduler counters mirrored at scrape time (the queue/scheduler
    // own the live atomics; see `refresh_sched`)
    c_edf_aged: CounterId,
    c_edf_reordered: CounterId,
    c_linger_capped: CounterId,
    c_cost_refits: CounterId,
    /// Preemption requests by reason, indexed by [`PreemptReason`].
    c_preempt: [CounterId; 3],
    c_kv_evictions: CounterId,
    c_resumes: CounterId,
    c_resume_refill: CounterId,
    /// Speculation rounds by draft source, indexed by [`SourceKind::idx`].
    c_draft_source: [CounterId; 4],
    c_policy_switches: CounterId,
    // gauges
    g_queue_depth: GaugeId,
    g_inflight: GaugeId,
    g_last_group: GaugeId,
    g_tau: GaugeId,
    g_mean_verify_t: GaugeId,
    g_mean_draft_w: GaugeId,
    g_p50: GaugeId,
    g_p99: GaugeId,
    g_shed_rate: GaugeId,
    g_deadline_miss_rate: GaugeId,
    g_worker_restarts: GaugeId,
    g_est_service: GaugeId,
    g_edf_enabled: GaugeId,
    g_cost_overhead: GaugeId,
    g_predicted_service: GaugeId,
    g_suspended: GaugeId,
    /// EWMA of per-request engine service time (seconds, f64 bits;
    /// 0.0 = no generation served yet). Single writer (the worker, via
    /// [`ServerMetrics::record_gen`]); route threads read it for the
    /// shed decision. Not a registry metric itself — the registry
    /// exposes it through `eagle_est_service_seconds` at scrape time.
    ewma_service: AtomicU64,
    // histograms
    h_request: HistId,
    h_ttft: HistId,
    h_queue_wait: HistId,
    h_token: HistId,
    h_round_accepted: HistId,
    h_round_verify: HistId,
}

impl ServerMetrics {
    /// Build the serving registry and a flight recorder ring of
    /// `trace_cap` events. All allocation happens here.
    pub fn new(trace_cap: usize) -> ServerMetrics {
        let mut b = RegistryBuilder::new();
        let lat = log_buckets(0.001, 2.0, 16); // 1 ms .. ~32.8 s
        let tok = log_buckets(0.0001, 2.0, 14); // 0.1 ms .. ~0.8 s
        let c_requests = b.counter("eagle_requests_total", "Requests admitted to the queue.");
        let c_tokens = b.counter("eagle_tokens_total", "Tokens generated across all requests.");
        let c_errors = b.counter("eagle_errors_total", "Requests that failed in the engine.");
        let c_rejected =
            b.counter("eagle_rejected_total", "Requests rejected with 429 (queue full).");
        let c_shed = b.counter(
            "eagle_shed_total",
            "Requests shed with 429: estimated queue wait exceeded the deadline budget.",
        );
        let c_worker_panics = b.counter(
            "eagle_worker_panics_total",
            "Panics caught by worker supervision (each rebuilds the round state).",
        );
        let c_lane_failures = b.counter(
            "eagle_lane_failures_total",
            "Lanes failed with 500: panicked group members and quarantined requests.",
        );
        let c_deadline_queue = b.counter_with(
            "eagle_deadline_expired_total",
            "Requests whose deadline expired, by stage.",
            &[("stage", "queue")],
        );
        let c_deadline_generate = b.counter_with(
            "eagle_deadline_expired_total",
            "Requests whose deadline expired, by stage.",
            &[("stage", "generate")],
        );
        let c_dispatch_batched = b.counter(
            "eagle_dispatch_batched_total",
            "Lanes dispatched on the batched engine.",
        );
        let c_dispatch_bs1 =
            b.counter("eagle_dispatch_bs1_total", "Requests dispatched on the bs=1 path.");
        let c_dragged = b.counter(
            "eagle_dragged_rounds_total",
            "Rounds where a lane verified wider than its own tree's fit.",
        );
        let c_rounds = b.counter("eagle_rounds_total", "Speculation rounds executed.");
        let c_gen_ns = b.counter_scaled(
            "eagle_gen_seconds_total",
            "Engine generation time (batched lanes share their group's wall).",
            &[],
            1e-9,
        );
        let c_phase = ["prefill", "draft", "verify", "commit", "host"].map(|phase| {
            b.counter_scaled(
                "eagle_phase_seconds_total",
                "Engine time by phase.",
                &[("phase", phase)],
                1e-9,
            )
        });
        let c_round_alloc = b.counter(
            "eagle_round_alloc_bytes_total",
            "Host round-state capacity growth across all rounds (0-drift once warm — the \
             soak harness asserts it).",
        );
        let c_edf_aged = b.counter(
            "eagle_edf_aged_pops_total",
            "EDF pops ordered by the aging bound rather than a real deadline.",
        );
        let c_edf_reordered = b.counter(
            "eagle_edf_reordered_pops_total",
            "EDF pops that deviated from arrival (FCFS) order.",
        );
        let c_linger_capped = b.counter(
            "eagle_linger_capped_total",
            "Admissions whose linger window was shortened by a queued deadline.",
        );
        let c_cost_refits = b.counter(
            "eagle_cost_refits_total",
            "Successful online re-fits of the dispatch cost model.",
        );
        let c_preempt = ["deadline", "pressure", "drain"].map(|reason| {
            b.counter_with(
                "eagle_preempt_total",
                "Lane suspension requests at round boundaries, by reason.",
                &[("reason", reason)],
            )
        });
        let c_kv_evictions = b.counter(
            "eagle_kv_evictions_total",
            "Suspended-lane KV payloads evicted under the checkpoint store's budget/pressure \
             watermark (reconstructed by prefix re-prefill on resume).",
        );
        let c_resumes =
            b.counter("eagle_resumes_total", "Suspended lanes re-dispatched from a checkpoint.");
        let c_resume_refill = b.counter(
            "eagle_resume_refill_rounds_total",
            "Prefill passes spent reconstructing evicted KV on resume.",
        );
        let c_draft_source = SourceKind::ALL.map(|k| {
            b.counter_with(
                "eagle_draft_source_rounds_total",
                "Speculation rounds executed, by draft source.",
                &[("source", k.as_str())],
            )
        });
        let c_policy_switches = b.counter(
            "eagle_policy_switches_total",
            "Auto draft-policy picks that changed source relative to the previous pick.",
        );
        let g_queue_depth = b.gauge("eagle_queue_depth", "Requests waiting in the queue.");
        let g_inflight = b.gauge("eagle_inflight_lanes", "Lanes currently generating.");
        let g_last_group =
            b.gauge("eagle_last_group_lanes", "Lane count of the most recent admitted group.");
        let g_tau = b.gauge("eagle_tau", "Mean accepted tokens per target pass (served so far).");
        let g_mean_verify_t =
            b.gauge("eagle_mean_verify_t", "Mean dispatched verify width per round.");
        let g_mean_draft_w =
            b.gauge("eagle_mean_draft_w", "Mean dispatched draft-step width per call.");
        let g_p50 =
            b.gauge("eagle_latency_p50_seconds", "p50 engine latency over served requests.");
        let g_p99 =
            b.gauge("eagle_latency_p99_seconds", "p99 engine latency over served requests.");
        let g_shed_rate =
            b.gauge("eagle_shed_rate", "Shed requests over admitted requests (lifetime ratio).");
        let g_deadline_miss_rate = b.gauge(
            "eagle_deadline_miss_rate",
            "Deadline-expired requests (queue + generate) over admitted requests.",
        );
        let g_worker_restarts = b.gauge(
            "eagle_worker_restarts",
            "Times the worker rebuilt its round state after a supervised panic.",
        );
        let g_est_service = b.gauge(
            "eagle_est_service_seconds",
            "EWMA per-request engine service time feeding the shed decision.",
        );
        let g_edf_enabled = b.gauge(
            "eagle_edf_enabled",
            "1 when admission order is EDF, 0 for FCFS (runtime-togglable).",
        );
        let g_cost_overhead = b.gauge(
            "eagle_cost_dispatch_overhead",
            "Current dispatch overhead (node units) of the live cost model.",
        );
        let g_predicted_service = b.gauge(
            "eagle_predicted_service_seconds",
            "Live cost model's predicted service time for a default (64-token) request.",
        );
        let g_suspended =
            b.gauge("eagle_suspended_lanes", "Lanes currently parked in the checkpoint store.");
        let h_request = b.histogram(
            "eagle_request_seconds",
            "End-to-end request latency (admission to delivery).",
            &lat,
        );
        let h_ttft = b.histogram(
            "eagle_ttft_seconds",
            "Time to first committed token (queue wait + prefill + root sample).",
            &lat,
        );
        let h_queue_wait =
            b.histogram("eagle_queue_wait_seconds", "Time spent queued before dispatch.", &lat);
        let h_token =
            b.histogram("eagle_token_seconds", "Mean per-token engine latency per request.", &tok);
        let h_round_accepted = b.histogram(
            "eagle_round_accepted_tokens",
            "Tokens committed per speculation round (bonus included).",
            &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 12.0],
        );
        let h_round_verify = b.histogram(
            "eagle_round_verify_seconds",
            "Target verify time per speculation round.",
            &log_buckets(0.0001, 2.0, 12),
        );
        ServerMetrics {
            registry: b.build(),
            trace: FlightRecorder::new(trace_cap),
            c_requests,
            c_tokens,
            c_errors,
            c_rejected,
            c_shed,
            c_worker_panics,
            c_lane_failures,
            c_deadline_queue,
            c_deadline_generate,
            c_dispatch_batched,
            c_dispatch_bs1,
            c_dragged,
            c_rounds,
            c_gen_ns,
            c_phase,
            c_round_alloc,
            c_edf_aged,
            c_edf_reordered,
            c_linger_capped,
            c_cost_refits,
            c_preempt,
            c_kv_evictions,
            c_resumes,
            c_resume_refill,
            c_draft_source,
            c_policy_switches,
            g_queue_depth,
            g_inflight,
            g_last_group,
            g_tau,
            g_mean_verify_t,
            g_mean_draft_w,
            g_p50,
            g_p99,
            g_shed_rate,
            g_deadline_miss_rate,
            g_worker_restarts,
            g_est_service,
            g_edf_enabled,
            g_cost_overhead,
            g_predicted_service,
            g_suspended,
            ewma_service: AtomicU64::new(0),
            h_request,
            h_ttft,
            h_queue_wait,
            h_token,
            h_round_accepted,
            h_round_verify,
        }
    }

    pub fn on_request(&self) {
        self.registry.inc(self.c_requests);
    }

    pub fn on_rejected(&self) {
        self.registry.inc(self.c_rejected);
    }

    pub fn on_errors(&self, n: u64) {
        self.registry.add(self.c_errors, n);
    }

    /// A request was shed at admission: its deadline budget cannot
    /// survive the estimated queue wait.
    pub fn on_shed(&self) {
        self.registry.inc(self.c_shed);
    }

    /// Supervision caught a panic that failed `lanes` in-flight lanes.
    pub fn on_worker_panic(&self, lanes: u64) {
        self.registry.inc(self.c_worker_panics);
        self.registry.add(self.c_lane_failures, lanes);
    }

    /// `rounds` speculation rounds ran under draft source `kind`.
    pub fn on_draft_source_rounds(&self, kind: SourceKind, rounds: u64) {
        self.registry.add(self.c_draft_source[kind.idx()], rounds);
    }

    /// The auto draft policy picked a different source than its
    /// previous pick.
    pub fn on_policy_switch(&self) {
        self.registry.inc(self.c_policy_switches);
    }

    /// Lanes failed with 500 outside a panic (e.g. quarantine refusals).
    pub fn on_lane_failures(&self, lanes: u64) {
        self.registry.add(self.c_lane_failures, lanes);
    }

    /// A request's deadline expired while it was still queued.
    pub fn on_deadline_queue(&self) {
        self.registry.inc(self.c_deadline_queue);
    }

    /// A governor requested suspension of `lanes` running lanes.
    pub fn on_preempt(&self, reason: PreemptReason, lanes: u64) {
        self.registry.add(self.c_preempt[reason as usize], lanes);
    }

    /// A suspended lane was re-dispatched from its checkpoint.
    pub fn on_resumes(&self, lanes: u64) {
        self.registry.add(self.c_resumes, lanes);
    }

    /// The checkpoint store evicted `n` suspended lanes' KV payloads.
    pub fn on_kv_evictions(&self, n: u64) {
        self.registry.add(self.c_kv_evictions, n);
    }

    pub fn set_suspended(&self, lanes: usize) {
        self.registry.set_gauge(self.g_suspended, lanes as f64);
    }

    /// A group left the queue for an engine: count the dispatch class
    /// and remember the group size.
    pub fn on_dispatch(&self, batched: bool, lanes: u64) {
        let id = if batched { self.c_dispatch_batched } else { self.c_dispatch_bs1 };
        self.registry.add(id, lanes);
        self.registry.set_gauge(self.g_last_group, lanes as f64);
    }

    pub fn set_queue_depth(&self, n: usize) {
        self.registry.set_gauge(self.g_queue_depth, n as f64);
    }

    pub fn set_inflight(&self, lanes: u64) {
        self.registry.set_gauge(self.g_inflight, lanes as f64);
    }

    /// Record one finished generation: request lifecycle histograms
    /// (e2e, queue wait, TTFT, per-token) and the per-phase/drag
    /// counters. `lanes_sharing` is the batch width the record's wall
    /// time was shared across (1 on the bs=1 path), so
    /// `eagle_gen_seconds_total` never double-counts a group's wall.
    pub fn record_gen(&self, rec: &GenRecord, queue_wait_s: f64, e2e_s: f64, lanes_sharing: u64) {
        self.registry.observe(self.h_request, e2e_s);
        self.registry.observe(self.h_queue_wait, queue_wait_s);
        // engines that predate ttft_ns report 0: fall back to e2e
        let ttft =
            if rec.ttft_ns > 0 { queue_wait_s + rec.ttft_ns as f64 / 1e9 } else { e2e_s };
        self.registry.observe(self.h_ttft, ttft);
        let tokens = rec.tokens.len().max(1);
        self.registry.observe(self.h_token, rec.wall_ns as f64 / 1e9 / tokens as f64);
        self.registry.add(self.c_tokens, rec.tokens.len() as u64);
        self.registry.add(self.c_gen_ns, rec.wall_ns / lanes_sharing.max(1));
        self.registry.add(self.c_dragged, rec.dragged_rounds as u64);
        let tl = &rec.timeline;
        let phase_ns = [tl.prefill_ns, tl.draft_ns, tl.verify_ns, tl.commit_ns, tl.host_ns];
        for (id, ns) in self.c_phase.iter().zip(phase_ns) {
            self.registry.add(*id, ns);
        }
        if rec.truncated.is_some() {
            // the engine stopped this generation at its deadline and
            // returned partial text (engines stay metrics-free; the
            // record carries the marker here)
            self.registry.inc(self.c_deadline_generate);
        }
        self.registry.add(self.c_resume_refill, rec.resume_refill_rounds);
        self.note_service(rec.wall_ns as f64 / 1e9 / lanes_sharing.max(1) as f64);
    }

    /// Fold one request's engine service time into the shed estimator's
    /// EWMA (α = 0.2; the first sample seeds it). Single writer — the
    /// worker — so a relaxed load/store pair is race-free; route threads
    /// only read.
    fn note_service(&self, secs: f64) {
        let prev = f64::from_bits(self.ewma_service.load(Ordering::Relaxed));
        let next = if prev == 0.0 { secs } else { 0.8 * prev + 0.2 * secs };
        self.ewma_service.store(next.to_bits(), Ordering::Relaxed);
    }

    /// EWMA per-request service time in seconds (0.0 until the first
    /// generation completes — a cold server never deadline-sheds).
    pub fn est_service_secs(&self) -> f64 {
        f64::from_bits(self.ewma_service.load(Ordering::Relaxed))
    }

    /// Refresh the derived robustness gauges (shed rate, deadline-miss
    /// rate, worker restarts, service-time estimate) from the lifetime
    /// counters. Called at scrape time, like the queue-depth gauge.
    pub fn refresh_derived(&self) {
        let admitted = self.registry.counter_value(self.c_requests).max(1) as f64;
        let shed = self.registry.counter_value(self.c_shed) as f64;
        let missed = self.registry.counter_value(self.c_deadline_queue)
            + self.registry.counter_value(self.c_deadline_generate);
        self.registry.set_gauge(self.g_shed_rate, shed / admitted);
        self.registry.set_gauge(self.g_deadline_miss_rate, missed as f64 / admitted);
        self.registry.set_gauge(
            self.g_worker_restarts,
            self.registry.counter_value(self.c_worker_panics) as f64,
        );
        self.registry.set_gauge(self.g_est_service, self.est_service_secs());
    }

    /// Raise a mirrored counter to `target` (the live atomic owned by
    /// the queue/scheduler/cost model). Counters are monotonic, so the
    /// mirror only ever adds the delta; concurrent scrapes can split the
    /// delta between them but never double-count past the target.
    fn mirror_counter(&self, id: CounterId, target: u64) {
        let cur = self.registry.counter_value(id);
        if target > cur {
            self.registry.add(id, target - cur);
        }
    }

    /// Refresh the scheduling metric families from the live atomics at
    /// scrape time: EDF order/counters from the queue, the linger cap
    /// counter from the scheduler, and the online cost-model fit.
    pub fn refresh_sched(
        &self,
        queue: &RequestQueue,
        sched: Option<&Scheduler>,
        live: Option<&OnlineCostModel>,
    ) {
        self.registry.set_gauge(self.g_edf_enabled, queue.edf_enabled() as u64 as f64);
        self.mirror_counter(self.c_edf_aged, queue.aged_pops());
        self.mirror_counter(self.c_edf_reordered, queue.reordered_pops());
        if let Some(s) = sched {
            self.mirror_counter(self.c_linger_capped, s.linger_capped.load(Ordering::Relaxed));
        }
        if let Some(l) = live {
            self.mirror_counter(self.c_cost_refits, l.refits());
            self.registry.set_gauge(self.g_cost_overhead, l.dispatch_overhead() as f64);
            self.registry.set_gauge(self.g_predicted_service, l.predicted_service_secs(64));
        }
    }

    /// Refresh the derived gauges from the worker's running aggregate
    /// (τ, mean widths, latency percentiles from the sorted cache).
    pub fn update_aggregate(&self, agg: &Aggregate) {
        self.registry.set_gauge(self.g_tau, agg.tau());
        self.registry.set_gauge(self.g_mean_verify_t, agg.mean_verify_t());
        self.registry.set_gauge(self.g_mean_draft_w, agg.mean_draft_w());
        self.registry.set_gauge(self.g_p50, agg.latency_p50_ms() / 1e3);
        self.registry.set_gauge(self.g_p99, agg.latency_p99_ms() / 1e3);
    }

    pub fn render(&self) -> String {
        self.registry.render()
    }
}

impl RoundObserver for ServerMetrics {
    /// Per-round hook: ring-buffer slot claim + three histogram/counter
    /// fetch-adds. Runs inside the engine round loop — must not (and
    /// does not) allocate.
    #[inline]
    fn on_round(&self, ev: &RoundEvent) {
        self.trace.record(ev);
        self.registry.inc(self.c_rounds);
        self.registry.add(self.c_round_alloc, ev.alloc_bytes);
        self.registry.observe(self.h_round_accepted, ev.accepted as f64);
        self.registry.observe(self.h_round_verify, ev.verify_ns as f64 / 1e9);
    }
}

/// Worker liveness for `GET /healthz`: a heartbeat the worker stores on
/// every busy/idle transition — and on every speculation round, via
/// [`WorkerObserver`] — so a wedged generation is distinguishable from
/// an idle worker blocking on the queue. Stall = busy AND heartbeat
/// older than `stall_ms`.
pub struct Health {
    start: Instant,
    stall_ms: u64,
    busy: AtomicU64,
    inflight: AtomicU64,
    heartbeat_ms: AtomicU64,
    /// Set by `POST /admin/drain`: the queue is closed, in-flight and
    /// already-queued work finishes, then the worker exits. `/healthz`
    /// reports 503 so load balancers stop routing here.
    draining: AtomicU64,
}

impl Health {
    /// Starts busy so a worker that panics while loading artifacts
    /// (before its first idle transition) reads as stalled, not healthy.
    pub fn new(stall_ms: u64) -> Health {
        Health {
            start: Instant::now(),
            stall_ms,
            busy: AtomicU64::new(1),
            inflight: AtomicU64::new(0),
            heartbeat_ms: AtomicU64::new(0),
            draining: AtomicU64::new(0),
        }
    }

    pub fn set_draining(&self) {
        self.draining.store(1, Ordering::Relaxed);
    }

    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed) == 1
    }

    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    /// Store a fresh heartbeat (allocation-free; called per round).
    #[inline]
    pub fn beat(&self) {
        self.heartbeat_ms.store(self.now_ms(), Ordering::Relaxed);
    }

    pub fn set_busy(&self, busy: bool) {
        self.beat();
        self.busy.store(busy as u64, Ordering::Relaxed);
    }

    pub fn set_inflight(&self, lanes: u64) {
        self.inflight.store(lanes, Ordering::Relaxed);
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn heartbeat_age_ms(&self) -> u64 {
        self.now_ms().saturating_sub(self.heartbeat_ms.load(Ordering::Relaxed))
    }

    pub fn stalled(&self) -> bool {
        self.busy.load(Ordering::Relaxed) == 1 && self.heartbeat_age_ms() > self.stall_ms
    }

    pub fn to_json(&self, queue_depth: usize) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(!self.stalled() && !self.draining())),
            ("busy", Json::Bool(self.busy.load(Ordering::Relaxed) == 1)),
            ("draining", Json::Bool(self.draining())),
            ("queue_depth", Json::Num(queue_depth as f64)),
            ("inflight_lanes", Json::Num(self.inflight() as f64)),
            ("heartbeat_age_ms", Json::Num(self.heartbeat_age_ms() as f64)),
            ("uptime_seconds", Json::Num(self.start.elapsed().as_secs_f64())),
        ])
    }
}

/// The observer the worker attaches to both engines: fans each round
/// event into [`ServerMetrics`] (ring + histograms), feeds the online
/// cost model's moments, and beats the [`Health`] heartbeat. Stores and
/// fetch-adds only.
struct WorkerObserver<'a> {
    metrics: &'a ServerMetrics,
    health: &'a Health,
    /// Live dispatch-cost re-fit; every round's `(verify_t, verify_ns)`
    /// lands in its EWMA moments (atomics only).
    live: Option<&'a OnlineCostModel>,
    /// Preemption governors, polled once per round (`None` on paths
    /// without the preempt stack — bs=1 fresh runs, unit fixtures).
    preempt: Option<&'a PreemptCtl>,
    queue: Option<&'a RequestQueue>,
}

impl RoundObserver for WorkerObserver<'_> {
    #[inline]
    fn on_round(&self, ev: &RoundEvent) {
        self.metrics.on_round(ev);
        if let Some(live) = self.live {
            live.observe(
                ev.verify_t,
                ev.verify_ns as f64 / 1e9,
                (ev.draft_ns + ev.verify_ns + ev.host_ns) as f64 / 1e9,
                ev.accepted,
            );
        }
        // governor poll: atomics + one mutex lock, no allocation, so
        // the round loop's zero-alloc guarantee holds with it attached
        if let (Some(p), Some(q)) = (self.preempt, self.queue) {
            let lanes = self.health.inflight().max(1);
            if let Some(live) = self.live {
                if p.poll_deadline(q, live) {
                    self.metrics.on_preempt(PreemptReason::Deadline, lanes);
                }
            }
            if p.poll_pressure(!q.is_empty()) {
                self.metrics.on_preempt(PreemptReason::Pressure, lanes);
            }
        }
        self.health.beat();
    }
}

/// Server configuration (see `repro serve --help`).
pub struct ServeConfig {
    pub addr: String,
    pub model: String,
    pub artifacts: std::path::PathBuf,
    pub queue_cap: usize,
    /// Draft-tree policy applied when a request does not pick one via
    /// its `"tree"` field.
    pub default_tree: TreePolicy,
    /// Verify-width policy (`--verify-width auto|N`) applied when a
    /// request does not pin one via its `"verify_width"` field.
    pub default_width: WidthSelect,
    /// Draft-source policy (`--draft eagle|chain|ngram|medusa|auto`)
    /// applied when a request does not pick one via its `"draft"` field.
    pub default_draft: DraftChoice,
    /// Committed-capacity file for the shed estimator
    /// (`--capacity-file`; defaults to probing `BENCH_serve.json` in the
    /// working directory). A feasible `p99_search` stanza pins the
    /// cold-start per-request service estimate to the committed
    /// operating point; absent or infeasible, the estimate falls back to
    /// the live cost model's prediction. The warm EWMA always wins.
    pub capacity_file: Option<std::path::PathBuf>,
    /// Admission batch size (`--batch`); 1 = per-request serving.
    pub max_batch: usize,
    /// Linger for batch fill (`--linger`), in milliseconds.
    pub linger_ms: u64,
    /// Width-aware group admission (`--width-grouping`); FCFS otherwise.
    pub width_grouping: bool,
    /// Optional dispatch-cost calibration file (`--cost-model`); the
    /// default keeps `scheduler::DISPATCH_OVERHEAD`.
    pub cost_model: Option<std::path::PathBuf>,
    /// Flight-recorder ring capacity (`--trace-cap`), in round events.
    pub trace_cap: usize,
    /// Heartbeat age (`--stall-ms`) past which a busy worker reads as
    /// stalled and `/healthz` turns 503. The observer beats every
    /// round, so this only needs to exceed one speculation round (plus
    /// prefill and artifact loading).
    pub stall_ms: u64,
    /// Deadline (`--default-deadline-ms`) for requests that do not set
    /// `"deadline_ms"` themselves; 0 (the default) = unbounded.
    pub default_deadline_ms: u64,
    /// Fault-injection spec (`--inject site=action[@N],…`), applied at
    /// startup. Only honored in `fault-inject` builds; ignored (with a
    /// warning) otherwise.
    pub inject: Option<String>,
    /// Serve with the synthetic worker (`--synthetic`): no artifacts,
    /// deterministic simulated rounds through the real scheduling/
    /// shedding/drain/metrics stack. The load harness and CI smoke
    /// drive this mode.
    pub synthetic: bool,
    /// Simulated round wall time in microseconds (`--round-us`),
    /// synthetic mode only.
    pub synthetic_round_us: u64,
    /// Start with EDF admission ordering (`--edf`); runtime-togglable
    /// via `POST /admin/sched` either way.
    pub edf: bool,
    /// EDF aging bound in milliseconds (`--aging-ms`): the longest an
    /// unbounded-deadline request can be outranked by tighter arrivals.
    pub aging_ms: u64,
    /// Start with lane preemption enabled (`--preempt`); runtime-
    /// togglable via `POST /admin/preempt` either way.
    pub preempt: bool,
    /// Checkpoint-store KV budget in MiB (`--kv-budget`); suspended
    /// lanes past it lose their KV payload and re-prefill on resume.
    /// 0 (the default) = unbounded.
    pub kv_budget_mib: usize,
}

impl ServeConfig {
    pub fn new(addr: &str, model: &str, artifacts: &std::path::Path) -> ServeConfig {
        ServeConfig {
            addr: addr.to_string(),
            model: model.to_string(),
            artifacts: artifacts.to_path_buf(),
            queue_cap: 64,
            default_tree: TreePolicy::default_tree(),
            default_width: WidthSelect::Auto,
            default_draft: DraftChoice::Fixed(SourceKind::Eagle),
            capacity_file: None,
            max_batch: 1,
            linger_ms: 2,
            width_grouping: false,
            cost_model: None,
            trace_cap: 1024,
            stall_ms: 30_000,
            default_deadline_ms: 0,
            inject: None,
            synthetic: false,
            synthetic_round_us: 2_000,
            edf: false,
            aging_ms: crate::coordinator::queue::DEFAULT_AGING_MS,
            preempt: false,
            kv_budget_mib: 0,
        }
    }
}

pub type Slot = Arc<(Mutex<Option<Response>>, std::sync::Condvar)>;
pub type PendingMap = Mutex<std::collections::HashMap<u64, Slot>>;

/// Deliver a response to a pending slot, retiring the slot from the map
/// in the same critical section that finds it. Removal-at-delivery (not
/// `get`) makes delivery idempotent — a supervised retry or a late
/// worker answer after the route thread already gave up and removed its
/// slot is a no-op — so a slot can never leak from the worker side.
/// Lock discipline: the `pending` guard is dropped BEFORE the slot
/// mutex is taken; route threads take the locks in the opposite order
/// (slot first, then `pending` to clean up), so holding both here could
/// deadlock. Returns whether a waiter was still listening.
pub fn deliver(pending: &PendingMap, id: u64, resp: Response) -> bool {
    // fault-inject site: a panic here (worker thread, inside the
    // supervised group closure) checks that delivery failures fail only
    // their own group
    let _ = crate::failpoint!("deliver");
    let slot = pending.lock().unwrap().remove(&id);
    match slot {
        Some(slot) => {
            *slot.0.lock().unwrap() = Some(resp);
            slot.1.notify_all();
            true
        }
        None => false,
    }
}

fn error_response(id: u64, e: &anyhow::Error) -> Response {
    Response {
        id,
        text: format!("error: {e}"),
        tokens: 0,
        target_passes: 0,
        tau: 0.0,
        latency_ms: 0.0,
        queue_ms: 0.0,
        status: 500,
        truncated: None,
    }
}

/// 500 delivered to every lane of a group whose execution panicked.
fn panic_response(id: u64) -> Response {
    Response {
        id,
        text: "error: worker panic failed this request's group".into(),
        tokens: 0,
        target_passes: 0,
        tau: 0.0,
        latency_ms: 0.0,
        queue_ms: 0.0,
        status: 500,
        truncated: None,
    }
}

/// 500 delivered to a request refused because its fingerprint already
/// failed [`QUARANTINE_AFTER`] supervised executions.
fn quarantine_response(id: u64) -> Response {
    Response {
        id,
        text: "error: request quarantined after repeated worker panics".into(),
        tokens: 0,
        target_passes: 0,
        tau: 0.0,
        latency_ms: 0.0,
        queue_ms: 0.0,
        status: 500,
        truncated: None,
    }
}

/// Partial delivery for a suspended lane that will not be resumed:
/// its deadline expired while it was parked (`reason = "deadline"`), or
/// a drain found its checkpoint orphaned after the queue emptied
/// (`reason = "drain"`, the safety net behind `push_resume`). The
/// tokens generated before suspension were already paid for, so they
/// ship as a 200 with a truncation marker instead of a bare 504.
fn suspended_partial_response(
    id: u64,
    ck: &LaneCheckpoint,
    queue_ms: f64,
    reason: &'static str,
) -> Response {
    Response {
        id,
        text: format!("partial: {} tokens generated before suspension", ck.rec.tokens.len()),
        tokens: ck.rec.tokens.len(),
        target_passes: ck.rec.target_passes,
        tau: ck.rec.tau(),
        latency_ms: ck.rec.wall_ns as f64 / 1e6,
        queue_ms,
        status: 200,
        truncated: Some(reason),
    }
}

/// Park a suspended lane's checkpoint in the store and re-enqueue its
/// request as a resumable entry. The requeued request carries the
/// controller's width hint captured at suspension, so width-grouped
/// admission migrates the lane into a group matching its adapted width
/// rather than its cold-start class. Insertions that push the store
/// past its byte budget evict the coldest resident KV payloads
/// (`eagle_kv_evictions_total`); those lanes resume via prefix
/// re-prefill instead of a KV copy-in.
fn suspend_to_store(
    mut ck: Box<LaneCheckpoint>,
    req: &Request,
    preempt: Option<&PreemptCtl>,
    queue: &RequestQueue,
    metrics: &ServerMetrics,
) {
    let p = preempt.expect("suspended lane without a preempt controller");
    ck.id = req.id;
    let mut rq = req.clone();
    if let Some(h) = ck.width_hint {
        rq.width_hint = Some(h);
    }
    let evicted = p.store.insert(ck);
    if evicted > 0 {
        metrics.on_kv_evictions(evicted as u64);
    }
    metrics.set_suspended(p.store.len());
    queue.push_resume(rq);
}

/// 504 delivered to a request whose deadline expired while queued.
fn queue_expired_response(id: u64, queue_ms: f64) -> Response {
    Response {
        id,
        text: "error: deadline expired before dispatch".into(),
        tokens: 0,
        target_passes: 0,
        tau: 0.0,
        latency_ms: 0.0,
        queue_ms,
        status: 504,
        truncated: Some("deadline"),
    }
}

/// Shed decision for an incoming request: estimated queue wait — depth ×
/// per-request service time — against the request's remaining deadline
/// budget. Returns the estimated wait in seconds when the request cannot
/// make its deadline. Unbounded requests are never deadline-shed. The
/// caller supplies a non-zero estimate even on a cold server (the EWMA
/// seeded from the live cost model's prediction — see the shed block in
/// `route`), so a burst right after drain/restart sheds instead of
/// queueing doomed work.
pub fn should_shed(
    queue_depth: usize,
    est_service_secs: f64,
    budget_secs: Option<f64>,
) -> Option<f64> {
    let budget = budget_secs?;
    let est_wait = queue_depth as f64 * est_service_secs;
    (est_wait > budget).then_some(est_wait)
}

/// `Retry-After` seconds for a shed 429: how long until the predicted
/// queue wait decays back under the request's budget, assuming the
/// queue drains in real time (one second of wall clock retires one
/// second of estimated work). Never less than 1 s — the header is an
/// integer and "retry immediately" would re-shed.
pub fn retry_after_secs(est_wait_secs: f64, budget_secs: f64) -> u64 {
    ((est_wait_secs - budget_secs.max(0.0)).ceil() as u64).max(1)
}

/// Consecutive supervised failures before a request fingerprint is
/// refused on sight (500, no execution). Keyed by content fingerprint —
/// server-assigned ids are unique per HTTP request, so a resubmitted
/// poison request must be recognized by what it asks for, not its id.
pub const QUARANTINE_AFTER: u32 = 3;

/// Content fingerprint for quarantine bookkeeping (FNV-1a over the
/// fields that determine the engine's execution path).
pub fn fingerprint(r: &Request) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x1_0000_01b3);
        }
    };
    eat(r.prompt.as_bytes());
    eat(&r.max_tokens.to_le_bytes());
    eat(&r.temperature.to_bits().to_le_bytes());
    eat(&r.seed.to_le_bytes());
    eat(&[r.method as u8, r.tree as u8, r.source as u8]);
    h
}

/// The quarantine ledger the worker loop keeps across supervised
/// executions: fingerprints of requests whose groups panicked, with
/// their consecutive-failure counts. A successful execution clears its
/// members (panics must be *consecutive* to quarantine — a request that
/// merely shared a group with a poison peer recovers on its next run).
pub struct Quarantine {
    failures: std::collections::HashMap<u64, u32>,
    after: u32,
}

impl Quarantine {
    pub fn new(after: u32) -> Quarantine {
        Quarantine { failures: std::collections::HashMap::new(), after: after.max(1) }
    }

    pub fn is_quarantined(&self, r: &Request) -> bool {
        self.failures.get(&fingerprint(r)).is_some_and(|&n| n >= self.after)
    }

    pub fn note_failure(&mut self, fp: u64) {
        *self.failures.entry(fp).or_insert(0) += 1;
    }

    pub fn note_success(&mut self, fp: u64) {
        self.failures.remove(&fp);
    }
}

/// Why a governor asked the running group to suspend (the `reason`
/// label on `eagle_preempt_total`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptReason {
    /// The EDF head's deadline cannot survive the running group's
    /// predicted remaining service.
    Deadline = 0,
    /// The checkpoint store crossed its memory watermark while work was
    /// still queued.
    Pressure = 1,
    /// `POST /admin/drain` suspending in-flight lanes so the drain
    /// completes within one round boundary.
    Drain = 2,
}

/// The preemption control surface shared by the worker, the per-round
/// deadline governor ([`WorkerObserver`]), and the admin routes: the
/// lane [`PreemptSignal`], the [`CheckpointStore`] parking suspended
/// lanes, and the runtime enable switch (`--preempt` at boot,
/// `POST /admin/preempt` live). The governors fire at most once per
/// running group — `begin_group`/`end_group` bracket every dispatch, and
/// `end_group` clears any unconsumed signal bits so a request aimed at a
/// finished group can never suspend its successor.
pub struct PreemptCtl {
    pub signal: Arc<PreemptSignal>,
    pub store: CheckpointStore,
    enabled: AtomicBool,
    /// Whether a governor already fired for the current group.
    fired: AtomicBool,
    /// Tightest real deadline among the running group's lanes, as
    /// nanoseconds of remaining budget at dispatch plus the dispatch
    /// `Instant` — kept as a Mutex'd pair (lock-only, no allocation, so
    /// the per-round governor stays inside the zero-alloc guarantee).
    group_deadline: Mutex<Option<Instant>>,
    /// Largest `max_tokens` in the running group (service predictor
    /// input); 0 = no group running.
    group_max_tokens: AtomicU64,
}

impl PreemptCtl {
    pub fn new(enabled: bool, store: CheckpointStore) -> PreemptCtl {
        PreemptCtl {
            signal: Arc::new(PreemptSignal::new()),
            store,
            enabled: AtomicBool::new(enabled),
            fired: AtomicBool::new(false),
            group_deadline: Mutex::new(None),
            group_max_tokens: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// A group is entering the engines: arm the governors with its
    /// tightest lane deadline and its service-prediction input.
    pub fn begin_group(&self, tightest: Option<Instant>, max_tokens: usize) {
        *self.group_deadline.lock().unwrap() = tightest;
        self.group_max_tokens.store(max_tokens as u64, Ordering::Relaxed);
        self.fired.store(false, Ordering::Relaxed);
    }

    /// The group left the engines (finished, panicked, or suspended):
    /// disarm the governors and drop any unconsumed suspension bits.
    pub fn end_group(&self) {
        *self.group_deadline.lock().unwrap() = None;
        self.group_max_tokens.store(0, Ordering::Relaxed);
        self.signal.clear();
    }

    /// Deadline governor, polled once per speculation round: when the
    /// tightest queued deadline is tighter than every running lane's AND
    /// its remaining budget is smaller than the predicted service left
    /// in the running group, request suspension of the whole group at
    /// its next round boundary. Returns whether it fired (the caller
    /// counts `eagle_preempt_total{reason="deadline"}`).
    pub fn poll_deadline(&self, queue: &RequestQueue, live: &OnlineCostModel) -> bool {
        if !self.enabled() || self.fired.load(Ordering::Relaxed) {
            return false;
        }
        let Some(head) = queue.earliest_deadline() else { return false };
        let running = *self.group_deadline.lock().unwrap();
        let head_tighter = running.is_none_or(|g| head < g);
        if !head_tighter {
            return false;
        }
        let max_tok = self.group_max_tokens.load(Ordering::Relaxed).max(1) as usize;
        let remaining = head.saturating_duration_since(Instant::now()).as_secs_f64();
        let predicted = live.predicted_service_secs(max_tok);
        if remaining < predicted && !self.fired.swap(true, Ordering::Relaxed) {
            self.signal.request_all();
            return true;
        }
        false
    }

    /// Memory-pressure governor: the checkpoint store is past its
    /// watermark while work is still queued — suspending the running
    /// group frees its lanes for the backlog and lets the store evict
    /// cold KV payloads. Same once-per-group latch as the deadline
    /// governor.
    pub fn poll_pressure(&self, queue_nonempty: bool) -> bool {
        if !self.enabled()
            || !queue_nonempty
            || !self.store.under_pressure()
            || self.fired.swap(true, Ordering::Relaxed)
        {
            return false;
        }
        self.signal.request_all();
        true
    }
}

/// The state the supervisor owns on the worker's behalf: how to run one
/// healthy admitted group, and how to rebuild after a panicked one. The
/// production implementation ([`EngineWorker`]) wraps the engines; chaos
/// tests substitute synthetic executors so the supervision/deadline/
/// drain paths are testable without artifacts.
pub trait GroupWorker {
    /// Run one admitted group to completion, delivering every member's
    /// pending slot. May panic — the supervisor catches it.
    fn run(&mut self, group: AdmittedGroup);

    /// Tear down and rebuild whatever `run` may have left poisoned
    /// after a panic (scratch pool, staged KV state). Must not panic.
    fn rebuild(&mut self);
}

/// The supervised worker loop: drains the queue through the scheduler
/// until it closes (drain), dropping queue-expired requests with 504,
/// refusing quarantined fingerprints with 500, and running every
/// surviving group under `catch_unwind` so a panicking generation fails
/// only its own lanes — each failed lane's slot gets a 500 instead of
/// hanging, the worker's round state is rebuilt, and the next group is
/// served by the same thread.
///
/// With a [`PreemptCtl`] attached, every dispatch is bracketed by
/// `begin_group`/`end_group` (arming the deadline governor, clearing
/// stale suspension bits), a resumed request whose deadline expired
/// while suspended gets its partial text delivered instead of a bare
/// 504, and — after the queue closes and empties — any checkpoints
/// still parked (a suspension whose requeue was lost to fault
/// injection) are delivered as partials so a drain never strands a
/// lane. Preempted groups return through the `Ok` arm: suspension is
/// not a failure, and never advances a fingerprint's quarantine streak.
pub fn worker_loop(
    queue: &RequestQueue,
    sched: &Scheduler,
    pending: &PendingMap,
    metrics: &ServerMetrics,
    health: &Health,
    default_deadline_ms: u64,
    preempt: Option<&PreemptCtl>,
    worker: &mut dyn GroupWorker,
) {
    let mut quarantine = Quarantine::new(QUARANTINE_AFTER);
    loop {
        // idle while blocking on the queue, so an empty server never
        // reads as a stall
        health.set_busy(false);
        // publish the EWMA service estimate so the next collect()'s
        // deadline-aware linger cap reflects the latest service times
        sched.note_service_estimate(metrics.est_service_secs());
        let groups = sched.next_groups(queue);
        health.set_busy(true);
        if groups.is_empty() {
            health.set_busy(false);
            break; // queue closed and drained
        }
        for group in groups {
            let AdmittedGroup { verify_cap, requests } = group;
            let mut live = Vec::with_capacity(requests.len());
            for r in requests {
                if r.deadline(default_deadline_ms).expired() {
                    // the budget is already blown: running this lane
                    // would only slow the group it joined
                    metrics.on_deadline_queue();
                    let qms = r.arrival.elapsed().as_secs_f64() * 1e3;
                    let parked = match preempt {
                        Some(p) if r.resume => p.store.take(r.id),
                        _ => None,
                    };
                    let resp = match &parked {
                        // a deadline expiring while suspended delivers
                        // the tokens generated before suspension, not a
                        // bare 504
                        Some(ck) => suspended_partial_response(r.id, ck, qms, "deadline"),
                        None => queue_expired_response(r.id, qms),
                    };
                    if let Some(p) = preempt {
                        metrics.set_suspended(p.store.len());
                    }
                    deliver(pending, r.id, resp);
                } else if quarantine.is_quarantined(&r) {
                    metrics.on_lane_failures(1);
                    deliver(pending, r.id, quarantine_response(r.id));
                } else {
                    live.push(r);
                }
            }
            if live.is_empty() {
                continue;
            }
            let members: Vec<(u64, u64)> = live.iter().map(|r| (r.id, fingerprint(r))).collect();
            if let Some(p) = preempt {
                let tightest = live
                    .iter()
                    .filter_map(|r| r.deadline(default_deadline_ms).instant())
                    .min();
                let max_tok = live.iter().map(|r| r.max_tokens).max().unwrap_or(1);
                p.begin_group(tightest, max_tok);
            }
            let group = AdmittedGroup { verify_cap, requests: live };
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // fault-inject site: a panic between admission and the
                // engines exercises supervision without touching a model
                let _ = crate::failpoint!("sched-dispatch");
                worker.run(group);
            }));
            if let Some(p) = preempt {
                p.end_group();
            }
            match run {
                Ok(()) => {
                    // suspended members pass through here too: preemption
                    // is not a failure and must not advance a streak
                    for &(_, fp) in &members {
                        quarantine.note_success(fp);
                    }
                }
                Err(_) => {
                    // the panic unwound out of the engines: fail exactly
                    // this group's lanes, rebuild the worker's round
                    // state, and keep serving
                    metrics.on_worker_panic(members.len() as u64);
                    for &(id, fp) in &members {
                        quarantine.note_failure(fp);
                        deliver(pending, id, panic_response(id));
                    }
                    worker.rebuild();
                    health.set_inflight(0);
                    metrics.set_inflight(0);
                    health.beat();
                }
            }
        }
    }
    // drain safety net: the queue closed and emptied, but a checkpoint
    // can still be parked if fault injection ate its requeue. Deliver
    // the partial rather than strand the lane's waiter.
    if let Some(p) = preempt {
        for ck in p.store.drain_all() {
            deliver(pending, ck.id, suspended_partial_response(ck.id, &ck, 0.0, "drain"));
        }
        metrics.set_suspended(0);
    }
}

/// Resolve a request's tree choice against the server default.
fn resolve_tree(choice: TreeChoice, default_tree: &TreePolicy) -> TreePolicy {
    match (choice, default_tree) {
        (TreeChoice::Static, _) => TreePolicy::default_tree(),
        // explicit "dynamic" keeps the server's configured dynamic knobs
        // when it already runs dynamic
        (TreeChoice::Dynamic, TreePolicy::Dynamic(_)) => default_tree.clone(),
        (TreeChoice::Dynamic, _) => TreePolicy::dynamic_default(),
        (TreeChoice::Default, _) => default_tree.clone(),
    }
}

/// The production [`GroupWorker`]: wraps the loaded engines, owns the
/// worker's warm scratch pool and running aggregate. On a supervised
/// panic the pool is rebuilt from scratch — a panic mid-round can leave
/// partially-written arenas/slabs, and the engines' KV caches are
/// per-call (dropped by the unwind), so a fresh pool is a full round-
/// state reset.
struct EngineWorker<'a> {
    runner: &'a Runner,
    bundle: &'a ModelBundle,
    bpe: &'a Bpe,
    c: &'a crate::runtime::manifest::Constants,
    default_tree: &'a TreePolicy,
    default_width: WidthSelect,
    default_deadline_ms: u64,
    pending: &'a PendingMap,
    metrics: &'a ServerMetrics,
    health: &'a Health,
    live: Option<&'a OnlineCostModel>,
    queue: &'a RequestQueue,
    preempt: Option<&'a PreemptCtl>,
    selector: Option<&'a SourceSelector>,
    pool: ScratchPool,
    agg: Aggregate,
}

impl GroupWorker for EngineWorker<'_> {
    fn run(&mut self, group: AdmittedGroup) {
        run_group(
            group,
            self.runner,
            self.bundle,
            self.bpe,
            self.c,
            self.default_tree,
            self.default_width,
            self.default_deadline_ms,
            self.pending,
            self.metrics,
            self.health,
            self.live,
            self.queue,
            self.preempt,
            self.selector,
            &mut self.pool,
            &mut self.agg,
        );
    }

    fn rebuild(&mut self) {
        self.pool = ScratchPool::new();
    }
}

/// Run the server (blocking). The inference worker owns the PJRT client
/// (single accelerator, single worker — CPU testbed); HTTP I/O threads
/// hand requests over through the bounded queue (backpressure -> 429).
/// Returns cleanly after `POST /admin/drain` finishes the queued work;
/// if the worker dies outside supervision (artifact load), the accept
/// loop keeps serving `/metrics` and the stalled `/healthz`.
pub fn serve(cfg: ServeConfig) -> Result<()> {
    if let Some(spec) = &cfg.inject {
        #[cfg(feature = "fault-inject")]
        crate::util::failpoint::configure(spec)?;
        #[cfg(not(feature = "fault-inject"))]
        eprintln!("[server] --inject '{spec}' ignored: built without the fault-inject feature");
    }
    let queue = Arc::new(
        RequestQueue::new(cfg.queue_cap)
            .with_edf(cfg.edf)
            .with_aging_ms(cfg.aging_ms)
            .with_deadline_default(cfg.default_deadline_ms),
    );
    let metrics = Arc::new(ServerMetrics::new(cfg.trace_cap));
    let health = Arc::new(Health::new(cfg.stall_ms));
    // per-source acceptance tracker behind `--draft auto`: route threads
    // pick from it, the worker feeds it per-request acceptance
    let selector = Arc::new(SourceSelector::new());
    // committed-capacity shed seed (explicit --capacity-file, or a
    // BENCH_serve.json left by a prior loadgen run in the working dir)
    let capacity_path = cfg
        .capacity_file
        .clone()
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_serve.json"));
    let committed_service = load_committed_capacity(&capacity_path);
    if let Some(s) = committed_service {
        eprintln!(
            "[server] shed estimator seeded from committed capacity: {:.1} ms/request (from {})",
            s * 1e3,
            capacity_path.display()
        );
    }
    let pending: Arc<PendingMap> = Arc::new(Mutex::new(std::collections::HashMap::new()));
    // preemption controller, shared by the worker (round-boundary
    // governors) and the routes (runtime toggle, drain preempt). The
    // checkpoint store's slot allocator holds 16 suspended lanes per
    // batch lane with pressure below one free batch's worth; --kv-budget
    // bounds resident checkpoint KV bytes (0 = unbounded).
    let preempt_ctl = Arc::new(PreemptCtl::new(
        cfg.preempt,
        CheckpointStore::new(
            cfg.max_batch.max(1) * 16,
            cfg.max_batch.max(1),
            (cfg.kv_budget_mib as u64) << 20,
        ),
    ));

    // static cost model (offline calibration file, or the default) —
    // the seed and fallback for the online re-fit
    let static_cost = match &cfg.cost_model {
        Some(path) => match CostModel::load(path) {
            Ok(cm) => {
                eprintln!(
                    "[server] cost model calibrated: dispatch overhead {} node units (from {})",
                    cm.dispatch_overhead,
                    path.display()
                );
                cm
            }
            Err(e) => {
                eprintln!("[server] cost model load failed ({e}); using default");
                CostModel::default()
            }
        },
        None => CostModel::default(),
    };
    // the live re-fit: primed from the calibration file's verify curve
    // when one is present, then updated from the server's own rounds
    let live = Arc::new(OnlineCostModel::new(static_cost));
    if let Some(path) = &cfg.cost_model {
        if let Some(v) = std::fs::read_to_string(path).ok().and_then(|t| Json::parse(&t).ok()) {
            let points = verify_curve_points(&v);
            if !points.is_empty() {
                live.seed_curve(&points);
            }
        }
    }
    // the worker constructs the Scheduler (the real one needs manifest
    // constants from the artifact load); route threads get it through
    // this slot for scrape-time counter mirroring
    let sched_slot: Arc<OnceLock<Arc<Scheduler>>> = Arc::new(OnceLock::new());

    // ---- inference worker --------------------------------------------------
    let worker = if cfg.synthetic {
        let sched = Arc::new(
            Scheduler::new(cfg.max_batch, cfg.linger_ms)
                .with_policy(if cfg.width_grouping {
                    AdmissionPolicy::WidthGrouped { verify_widths: vec![8, 16, 32], max_t: 32 }
                } else {
                    AdmissionPolicy::Fcfs
                })
                .with_cost_model(static_cost)
                .with_live_cost(live.clone())
                .with_deadline_default(cfg.default_deadline_ms),
        );
        let _ = sched_slot.set(sched.clone());
        let queue = queue.clone();
        let pending = pending.clone();
        let metrics = metrics.clone();
        let health = health.clone();
        let live = live.clone();
        let preempt_ctl = preempt_ctl.clone();
        let selector = selector.clone();
        let round_us = cfg.synthetic_round_us;
        let default_deadline_ms = cfg.default_deadline_ms;
        std::thread::Builder::new().name("inference".into()).spawn(move || {
            eprintln!(
                "[server] synthetic worker: {round_us} us rounds, tau {SYNTH_TAU} \
                 (no artifacts; admission: {})",
                if queue.edf_enabled() { "edf" } else { "fcfs" }
            );
            let mut w = SyntheticWorker {
                round_us,
                default_deadline_ms,
                pending: &pending,
                metrics: &metrics,
                health: &health,
                live: Some(&live),
                queue: Some(&queue),
                preempt: Some(&preempt_ctl),
                selector: Some(&selector),
                agg: Aggregate::new(),
            };
            worker_loop(
                &queue,
                &sched,
                &pending,
                &metrics,
                &health,
                default_deadline_ms,
                Some(&preempt_ctl),
                &mut w,
            );
        })?
    } else {
        let queue = queue.clone();
        let pending = pending.clone();
        let metrics = metrics.clone();
        let health = health.clone();
        let live = live.clone();
        let preempt_ctl = preempt_ctl.clone();
        let selector = selector.clone();
        let sched_slot = sched_slot.clone();
        let artifacts = cfg.artifacts.clone();
        let model = cfg.model.clone();
        let default_tree = cfg.default_tree.clone();
        let default_width = cfg.default_width;
        let (max_batch, linger_ms) = (cfg.max_batch, cfg.linger_ms);
        let grouping = cfg.width_grouping;
        let default_deadline_ms = cfg.default_deadline_ms;
        std::thread::Builder::new().name("inference".into()).spawn(move || {
            let runner = Runner::new(&artifacts).expect("loading artifacts");
            let bpe = Bpe::load(runner.man.path(&runner.man.tokenizer).to_str().unwrap())
                .expect("loading vocab");
            let bundle = ModelBundle::load(
                &runner.rt, &runner.man, &model, &["eagle"], true, true,
            )
            .expect("loading model bundle");
            let c = runner.man.constants.clone();
            eprintln!(
                "[server] model '{model}' loaded; serving (tree: {}, verify width: {}, \
                 batch: {max_batch}, admission: {})",
                default_tree.name(),
                default_width.describe(),
                if grouping { "width-grouped" } else { "fcfs" }
            );
            let policy = if grouping {
                AdmissionPolicy::WidthGrouped {
                    verify_widths: c.verify_widths.clone(),
                    max_t: c.tree_t,
                }
            } else {
                AdmissionPolicy::Fcfs
            };
            let sched = Arc::new(
                Scheduler::new(max_batch, linger_ms)
                    .with_policy(policy)
                    .with_cost_model(static_cost)
                    .with_live_cost(live.clone())
                    .with_deadline_default(default_deadline_ms),
            );
            let _ = sched_slot.set(sched.clone());
            // one warm scratch pool for the worker's lifetime: batched
            // groups reuse per-lane round state across admissions; the
            // running aggregate feeds the τ / width / percentile gauges
            let mut w = EngineWorker {
                runner: &runner,
                bundle: &bundle,
                bpe: &bpe,
                c: &c,
                default_tree: &default_tree,
                default_width,
                default_deadline_ms,
                pending: &pending,
                metrics: &metrics,
                health: &health,
                live: Some(&live),
                queue: &queue,
                preempt: Some(&preempt_ctl),
                selector: Some(&selector),
                pool: ScratchPool::new(),
                agg: Aggregate::new(),
            };
            worker_loop(
                &queue,
                &sched,
                &pending,
                &metrics,
                &health,
                default_deadline_ms,
                Some(&preempt_ctl),
                &mut w,
            );
        })?
    };

    // ---- accept loop (own thread, so serve() can join the worker) ----------
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!("[server] listening on http://{}", cfg.addr);
    let accept = {
        let queue = queue.clone();
        let default_deadline_ms = cfg.default_deadline_ms;
        let default_draft = cfg.default_draft;
        std::thread::Builder::new().name("accept".into()).spawn(move || {
            let next_id = Arc::new(AtomicU64::new(1));
            for stream in listener.incoming() {
                let mut stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                let queue = queue.clone();
                let pending = pending.clone();
                let metrics = metrics.clone();
                let health = health.clone();
                let next_id = next_id.clone();
                let sched_slot = sched_slot.clone();
                let live = live.clone();
                let preempt_ctl = preempt_ctl.clone();
                let selector = selector.clone();
                std::thread::spawn(move || {
                    let req = match HttpRequest::read_from(&mut stream) {
                        Ok(r) => r,
                        Err(_) => return,
                    };
                    let ctx = RouteCtx {
                        queue: &queue,
                        pending: &pending,
                        metrics: &metrics,
                        health: &health,
                        next_id: &next_id,
                        default_deadline_ms,
                        sched: &sched_slot,
                        live: &live,
                        preempt: &preempt_ctl,
                        selector: &selector,
                        default_draft,
                        committed_service,
                    };
                    let resp = route(&req, &ctx);
                    let _ = stream.write_all(resp.to_bytes().as_slice());
                });
            }
        })?
    };

    match worker.join() {
        Ok(()) => {
            // clean worker exit only happens when the queue closed —
            // i.e. a drain finished every queued and in-flight group.
            // Returning drops the process (and the accept thread with
            // it): the graceful-drain exit path. The brief grace lets
            // route threads flush their last responses (the drain ack,
            // final generation bodies) before the listener dies.
            std::thread::sleep(std::time::Duration::from_millis(200));
            eprintln!("[server] drained; exiting");
            Ok(())
        }
        Err(_) => {
            // the worker died OUTSIDE supervision (artifact load is the
            // only unsupervised stretch). Keep the accept loop alive:
            // /metrics stays scrapeable and /healthz reports the stall —
            // the artifact-less CI smoke test relies on exactly this.
            let _ = accept.join();
            Ok(())
        }
    }
}

/// Execute one admitted group: the batched engine when it qualifies —
/// with the group's width cap under width-grouped admission, uncapped
/// (max over lane fits) for a compatible FCFS batch — the bs=1 path per
/// request otherwise.
#[allow(clippy::too_many_arguments)]
fn run_group(
    group: AdmittedGroup,
    runner: &Runner,
    bundle: &ModelBundle,
    bpe: &Bpe,
    c: &crate::runtime::manifest::Constants,
    default_tree: &TreePolicy,
    default_width: WidthSelect,
    default_deadline_ms: u64,
    pending: &PendingMap,
    metrics: &ServerMetrics,
    health: &Health,
    live: Option<&OnlineCostModel>,
    queue: &RequestQueue,
    preempt: Option<&PreemptCtl>,
    selector: Option<&SourceSelector>,
    pool: &mut ScratchPool,
    agg: &mut Aggregate,
) {
    // per-request policy feedback: the selector's EWMA eats each
    // finished record's accepted-per-round (τ), and the per-source
    // round counter follows the record's verify passes
    let observe_done = |req: &Request, rec: &GenRecord| {
        metrics.on_draft_source_rounds(req.source, rec.target_passes as u64);
        if let Some(sel) = selector {
            sel.observe(req.source, rec.tau());
        }
    };
    let reqs = &group.requests;
    let b = reqs.len();
    let observer = WorkerObserver { metrics, health, live, preempt, queue: Some(queue) };
    // the batched engine can take the group iff it is a multi-lane group
    // of batchable requests (`Request::width_batchable`, the same
    // predicate the scheduler groups by), the server is not pinned to a
    // fixed verify width (only the bs=1 path honors `--verify-width N`),
    // and the bs{b} executables are lowered. Width-planned groups arrive
    // pre-classed by the scheduler; an FCFS admission may mix classes,
    // so the batched FCFS baseline additionally requires one shared
    // (max_tokens, tree, temperature) class — the lock-step engine runs
    // every lane under one GenConfig (seeds stay per-lane).
    let same_class = reqs.windows(2).all(|p| {
        p[0].max_tokens == p[1].max_tokens
            && p[0].tree == p[1].tree
            && p[0].temperature_class() == p[1].temperature_class()
    });
    let batchable = b >= 2
        && default_width == WidthSelect::Auto
        && same_class
        && reqs.iter().all(Request::width_batchable)
        && bundle.target.exes.has(&format!("prefill_slot_bs{b}"))
        && bundle.drafts.contains_key("eagle");
    if batchable {
        metrics.on_dispatch(true, b as u64);
        health.set_inflight(b as u64);
        metrics.set_inflight(b as u64);
        // queue wait ends here: dispatch is the admission-to-engine edge
        let queue_waits: Vec<f64> =
            reqs.iter().map(|r| r.arrival.elapsed().as_secs_f64()).collect();
        let t0 = Instant::now();
        let prompts: Vec<Vec<u32>> = reqs.iter().map(|r| bpe.encode_prompt(&r.prompt)).collect();
        let policy = resolve_tree(reqs[0].tree, default_tree);
        let mut engine = BatchEagleEngine::new(&bundle.target, &bundle.drafts["eagle"], c)
            .with_policy(policy.clone())
            .with_deadlines(reqs.iter().map(|r| r.deadline(default_deadline_ms)).collect())
            .with_observer(&observer);
        if let Some(p) = preempt {
            if p.enabled() {
                engine = engine.with_preempt(p.signal.clone());
            }
        }
        // the group's width cap only applies under the dynamic planner,
        // which shrinks each lane's node budget to fit it; a static tree
        // is a fixed shape that no narrow cap can hold, so a static
        // group runs batched but uncapped (max over lane fits). FCFS
        // groups carry no cap at all — the uncapped batched baseline.
        if policy.is_dynamic() {
            if let Some(cap) = group.verify_cap {
                engine = engine.with_verify_cap(cap);
            }
        }
        let gen = GenConfig {
            max_new: reqs[0].max_tokens,
            temperature: reqs[0].temperature.max(0.0),
            seed: reqs[0].seed,
            eos: Some(bpe.eos()),
        };
        // per-lane inputs: a fresh prompt, or — for a request the
        // worker re-admitted after suspension — its parked checkpoint
        // (seeds stay per-lane either way, so a lane's sampled stream
        // is its own no matter which other lanes share the batch)
        let mut resumes = 0u64;
        let inputs: Vec<LaneInput<'_>> = reqs
            .iter()
            .zip(&prompts)
            .map(|(r, prompt)| {
                if r.resume {
                    if let Some(ckpt) = preempt.and_then(|p| p.store.take(r.id)) {
                        resumes += 1;
                        return LaneInput::Resume { ckpt };
                    }
                    // checkpoint gone (drain safety net beat us to it):
                    // fall through and regenerate from the prompt
                }
                LaneInput::Fresh { prompt: prompt.as_slice(), seed: r.seed }
            })
            .collect();
        if resumes > 0 {
            metrics.on_resumes(resumes);
        }
        match engine.generate_pooled_entries(inputs, &gen, pool) {
            Ok(outcomes) => {
                let lat_ms = t0.elapsed().as_secs_f64() * 1e3;
                for ((req, outcome), qw) in reqs.iter().zip(outcomes).zip(&queue_waits) {
                    match outcome {
                        LaneOutcome::Done(rec) => {
                            let e2e = req.arrival.elapsed().as_secs_f64();
                            metrics.record_gen(&rec, *qw, e2e, b as u64);
                            observe_done(req, &rec);
                            agg.add(&rec);
                            deliver(
                                pending,
                                req.id,
                                Response {
                                    id: req.id,
                                    text: bpe.decode(&rec.tokens),
                                    tokens: rec.tokens.len(),
                                    target_passes: rec.target_passes,
                                    tau: rec.tau(),
                                    latency_ms: lat_ms,
                                    queue_ms: qw * 1e3,
                                    status: 200,
                                    truncated: rec.truncated,
                                },
                            );
                        }
                        LaneOutcome::Suspended(ck) => {
                            suspend_to_store(ck, req, preempt, queue, metrics);
                        }
                    }
                }
                metrics.update_aggregate(agg);
            }
            Err(e) => {
                metrics.on_errors(b as u64);
                let e = anyhow::anyhow!("{e}");
                for req in reqs {
                    deliver(pending, req.id, error_response(req.id, &e));
                }
            }
        }
        health.set_inflight(0);
        metrics.set_inflight(0);
        return;
    }
    // bs=1 fallback: the latency path, one request at a time
    for req in reqs {
        metrics.on_dispatch(false, 1);
        health.set_inflight(1);
        metrics.set_inflight(1);
        let qw = req.arrival.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let gen = GenConfig {
            max_new: req.max_tokens,
            temperature: req.temperature,
            seed: req.seed,
            eos: Some(bpe.eos()),
        };
        // a suspended tree lane re-enters the engine straight from its
        // checkpoint — the runner only knows fresh prompts. Chain and
        // vanilla lanes never suspend; a stray resume flag with no
        // parked checkpoint falls through and regenerates.
        if req.resume && req.method == Method::Eagle {
            if let (Some(p), Some(draft)) = (preempt, bundle.drafts.get("eagle")) {
                if let Some(ckpt) = p.store.take(req.id) {
                    metrics.set_suspended(p.store.len());
                    metrics.on_resumes(1);
                    let mut engine = EagleEngine::new_tree(&bundle.target, draft, c)
                        .with_policy(resolve_tree(req.tree, default_tree))
                        .with_deadline(req.deadline(default_deadline_ms))
                        .with_observer(&observer);
                    if p.enabled() {
                        engine = engine.with_preempt(p.signal.clone());
                    }
                    match engine.generate_resumable(LaneInput::Resume { ckpt }, &gen) {
                        Ok(LaneOutcome::Done(rec)) => {
                            metrics.record_gen(&rec, qw, req.arrival.elapsed().as_secs_f64(), 1);
                            observe_done(req, &rec);
                            agg.add(&rec);
                            deliver(
                                pending,
                                req.id,
                                Response {
                                    id: req.id,
                                    text: bpe.decode(&rec.tokens),
                                    tokens: rec.tokens.len(),
                                    target_passes: rec.target_passes,
                                    tau: rec.tau(),
                                    latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                                    queue_ms: qw * 1e3,
                                    status: 200,
                                    truncated: rec.truncated,
                                },
                            );
                        }
                        Ok(LaneOutcome::Suspended(ck)) => {
                            // parked again; no delivery until it completes
                            suspend_to_store(ck, req, preempt, queue, metrics);
                        }
                        Err(e) => {
                            metrics.on_errors(1);
                            deliver(pending, req.id, error_response(req.id, &e));
                        }
                    }
                    continue;
                }
            }
        }
        let ids = bpe.encode_prompt(&req.prompt);
        // the resolved draft source picks the engine on the bs=1 path:
        // an explicit non-eagle method wins, otherwise the source maps
        // to its engine facade (chain -> classic spec, ngram ->
        // lookahead, medusa -> medusa heads)
        let spec = RunSpec {
            method: req.source_method(),
            temperature: req.temperature,
            max_new: req.max_tokens,
            seed: req.seed,
            tree: resolve_tree(req.tree, default_tree),
            verify_width: match req.verify_width {
                Some(t) => WidthSelect::Fixed(t),
                None => default_width,
            },
            deadline: req.deadline(default_deadline_ms),
            ..Default::default()
        };
        let resp = match runner.run_one_observed(bundle, &ids, &spec, &gen, Some(&observer)) {
            Ok(rec) => {
                metrics.record_gen(&rec, qw, req.arrival.elapsed().as_secs_f64(), 1);
                observe_done(req, &rec);
                agg.add(&rec);
                Response {
                    id: req.id,
                    text: bpe.decode(&rec.tokens),
                    tokens: rec.tokens.len(),
                    target_passes: rec.target_passes,
                    tau: rec.tau(),
                    latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                    queue_ms: qw * 1e3,
                    status: 200,
                    truncated: rec.truncated,
                }
            }
            Err(e) => {
                metrics.on_errors(1);
                error_response(req.id, &e)
            }
        };
        deliver(pending, req.id, resp);
    }
    metrics.update_aggregate(agg);
    health.set_inflight(0);
    metrics.set_inflight(0);
}

/// Accepted tokens per simulated round in synthetic mode.
const SYNTH_TAU: usize = 3;
/// Verify widths the synthetic worker cycles through, so the online
/// cost-model re-fit sees a spread of `(t, verify_ns)` observations.
const SYNTH_WIDTHS: [u32; 3] = [8, 16, 32];

/// A [`GroupWorker`] that simulates the engine's round loop without
/// artifacts: timed rounds through the real `verify` failpoint site,
/// full metrics/trace/deadline behavior, and deterministic output — a
/// pure function of request content (fingerprint-seeded token stream),
/// independent of batch composition and admission order. That purity is
/// what lets the load harness assert losslessness across an EDF-vs-FCFS
/// reordering. `repro serve --synthetic` runs the whole admission/
/// scheduling/shedding/drain stack on it, on any machine.
///
/// Simulated verify time is linear in the dispatched width with an
/// intercept/slope ratio equal to the default dispatch overhead (8 node
/// units), so the online re-fit converges to a known ground truth.
struct SyntheticWorker<'a> {
    round_us: u64,
    default_deadline_ms: u64,
    pending: &'a PendingMap,
    metrics: &'a ServerMetrics,
    health: &'a Health,
    live: Option<&'a OnlineCostModel>,
    /// Queue handle for requeueing suspended lanes (`None` in unit
    /// tests that drive `run` directly without preemption).
    queue: Option<&'a RequestQueue>,
    preempt: Option<&'a PreemptCtl>,
    /// Auto-draft policy feedback: each simulated round feeds the
    /// selector a repetitiveness-dependent acceptance for the lane's
    /// source, so `--draft auto` converges the same way it would on
    /// real engines (repetitive prompts reward ngram, chat rewards
    /// eagle) — without touching the fingerprint-pure token stream.
    selector: Option<&'a SourceSelector>,
    agg: Aggregate,
}

impl GroupWorker for SyntheticWorker<'_> {
    fn run(&mut self, group: AdmittedGroup) {
        let reqs = &group.requests;
        let b = reqs.len();
        self.metrics.on_dispatch(b >= 2, b as u64);
        self.health.set_inflight(b as u64);
        self.metrics.set_inflight(b as u64);
        let observer = WorkerObserver {
            metrics: self.metrics,
            health: self.health,
            live: self.live,
            preempt: self.preempt,
            queue: self.queue,
        };
        let t0 = Instant::now();
        let queue_waits: Vec<f64> =
            reqs.iter().map(|r| r.arrival.elapsed().as_secs_f64()).collect();
        // per-lane prompt repetitiveness, priced once per group: the
        // simulated acceptance curves are a pure function of (source,
        // repetitiveness), so the selector sees the same signal a real
        // engine's τ would carry
        let reps: Vec<f64> = reqs.iter().map(|r| prompt_repetitiveness(&r.prompt)).collect();
        // a resumed lane continues from its checkpointed record: the
        // token stream is a pure function of (fingerprint, index), so
        // the continuation is byte-identical to an uninterrupted run.
        // Evicted KV costs one simulated re-prefill round, mirroring
        // the real engines' refill path.
        let mut recs: Vec<GenRecord> = Vec::with_capacity(b);
        let mut resumes = 0u64;
        for r in reqs.iter() {
            let parked = if r.resume {
                self.preempt.and_then(|p| p.store.take(r.id))
            } else {
                None
            };
            match parked {
                Some(mut ck) => {
                    resumes += 1;
                    let mut rec = std::mem::replace(&mut ck.rec, GenRecord::new(0));
                    if crate::failpoint!("resume") {
                        ck.evict_kv();
                    }
                    if !ck.kv_resident {
                        let refill_ns = self.round_us.max(1) * 1_000;
                        std::thread::sleep(std::time::Duration::from_nanos(refill_ns));
                        rec.resume_refill_rounds += 1;
                    }
                    recs.push(rec);
                }
                None => recs.push(GenRecord::new(r.prompt.len())),
            }
        }
        if resumes > 0 {
            self.metrics.on_resumes(resumes);
            if let Some(p) = self.preempt {
                self.metrics.set_suspended(p.store.len());
            }
        }
        let mut done = vec![false; b];
        let mut suspended = vec![false; b];
        let mut ttft = vec![0u64; b];
        let rounds_max =
            reqs.iter().map(|r| r.max_tokens.max(1).div_ceil(SYNTH_TAU)).max().unwrap_or(1);
        for round in 0..rounds_max {
            if done.iter().all(|&d| d) {
                break;
            }
            // round boundary: retire lanes marked for suspension while
            // the rest of the group keeps running (the same per-lane
            // checkpoint failpoint the real engines consult)
            if let Some(p) = self.preempt {
                if p.signal.any() {
                    for i in 0..b {
                        if done[i] || !p.signal.take(i) {
                            continue;
                        }
                        if crate::failpoint!("checkpoint") {
                            continue; // degenerate: drop the request, run on
                        }
                        suspended[i] = true;
                        done[i] = true;
                    }
                    if done.iter().all(|&d| d) {
                        break;
                    }
                }
            }
            // fault-inject site: the same `verify` site the real engines
            // mark, so `--inject verify=panic@N` exercises supervision
            // under synthetic load (the chaos soak's injected fault)
            let _ = crate::failpoint!("verify");
            let t = SYNTH_WIDTHS[round % SYNTH_WIDTHS.len()];
            let round_ns = self.round_us.max(1) * 1_000;
            // verify_ns = k * (overhead + t) with overhead = 8: the
            // ground truth the online re-fit should recover
            let verify_ns = round_ns * (8 + t as u64) / 24;
            let draft_ns = round_ns / 4;
            let host_ns = round_ns / 8;
            std::thread::sleep(std::time::Duration::from_nanos(round_ns));
            for (i, r) in reqs.iter().enumerate() {
                if done[i] {
                    continue;
                }
                let rec = &mut recs[i];
                let take = (r.max_tokens - rec.tokens.len()).min(SYNTH_TAU);
                let base = fingerprint(r);
                for _ in 0..take {
                    // deterministic token stream derived from the content
                    // fingerprint: equal requests produce equal tokens in
                    // any batch, under any admission order
                    let idx = rec.tokens.len() as u64;
                    rec.tokens.push(((base.wrapping_mul(idx + 1)) >> 17) as u32 & 0x7fff);
                }
                rec.target_passes += 1;
                self.metrics.on_draft_source_rounds(r.source, 1);
                if let Some(sel) = self.selector {
                    sel.observe(r.source, sim_accepted_per_round(r.source, reps[i]));
                }
                rec.round_accepts.push(take);
                rec.round_tree_nodes.push(t as usize);
                rec.round_verify_t.push(t as usize);
                rec.round_draft_w.push(4);
                rec.round_host_alloc_bytes.push(0);
                rec.scratch_reuse_total += 1;
                rec.drafted += t as usize;
                rec.timeline.draft_ns += draft_ns;
                rec.timeline.verify_ns += verify_ns;
                rec.timeline.host_ns += host_ns;
                observer.on_round(&RoundEvent {
                    lane: i as u32,
                    round: round as u32,
                    tree_nodes: t,
                    verify_t: t,
                    draft_w: 4,
                    accepted: take as u32,
                    draft_ns,
                    verify_ns,
                    host_ns,
                    alloc_bytes: 0,
                });
                if ttft[i] == 0 {
                    ttft[i] = t0.elapsed().as_nanos() as u64;
                }
                if rec.tokens.len() >= r.max_tokens {
                    done[i] = true;
                } else if r.deadline(self.default_deadline_ms).expired() {
                    // mirror the real engines: deadline expiry truncates
                    // to partial text, marked on the record
                    rec.truncated = Some("deadline");
                    done[i] = true;
                }
            }
        }
        let wall = t0.elapsed().as_nanos() as u64;
        for (i, r) in reqs.iter().enumerate() {
            let rec = &mut recs[i];
            rec.wall_ns = rec.wall_ns.saturating_add(wall);
            if rec.ttft_ns == 0 && ttft[i] > 0 {
                // first token this group — or carried over on resume
                rec.ttft_ns = ttft[i];
            }
            if suspended[i] {
                // park the lane: a stand-in KV payload sized to the
                // generated context keeps the store's slot and byte
                // accounting (and its eviction policy) honest
                if let (Some(p), Some(q)) = (self.preempt, self.queue) {
                    let mut ck = Box::new(LaneCheckpoint::new());
                    ck.m = rec.tokens.len();
                    ck.kv_target.resize(ck.m.max(1) * 16, 0.0);
                    ck.kv_resident = true;
                    ck.deadline = r.deadline(self.default_deadline_ms);
                    ck.rec = std::mem::replace(rec, GenRecord::new(0));
                    suspend_to_store(ck, r, Some(p), q, self.metrics);
                }
                continue;
            }
            if rec.ttft_ns == 0 {
                rec.ttft_ns = 1;
            }
            self.metrics.record_gen(
                rec,
                queue_waits[i],
                r.arrival.elapsed().as_secs_f64(),
                b as u64,
            );
            self.agg.add(rec);
            deliver(
                self.pending,
                r.id,
                Response {
                    id: r.id,
                    text: format!("synthetic:{:016x}:{}", fingerprint(r), rec.tokens.len()),
                    tokens: rec.tokens.len(),
                    target_passes: rec.target_passes,
                    tau: rec.tau(),
                    latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                    queue_ms: queue_waits[i] * 1e3,
                    status: 200,
                    truncated: rec.truncated,
                },
            );
        }
        self.metrics.update_aggregate(&self.agg);
        self.health.set_inflight(0);
        self.metrics.set_inflight(0);
    }

    /// Nothing to rebuild: the simulated rounds hold no cross-group
    /// state (the per-group vectors unwound with the panic).
    fn rebuild(&mut self) {}
}

/// Everything a route thread needs, bundled so the accept loop hands
/// one reference around instead of a parameter list.
struct RouteCtx<'a> {
    queue: &'a RequestQueue,
    pending: &'a PendingMap,
    metrics: &'a ServerMetrics,
    health: &'a Health,
    next_id: &'a AtomicU64,
    default_deadline_ms: u64,
    /// The worker-constructed scheduler, shared for scrape-time counter
    /// mirroring. Unset until the worker finishes loading artifacts
    /// (always set in synthetic mode).
    sched: &'a OnceLock<Arc<Scheduler>>,
    live: &'a OnlineCostModel,
    preempt: &'a PreemptCtl,
    /// Per-source acceptance tracker behind `--draft auto`.
    selector: &'a SourceSelector,
    /// Server draft policy for requests whose `"draft"` field is unset.
    default_draft: DraftChoice,
    /// Per-request service seconds at the committed operating point
    /// (`BENCH_serve.json` `p99_search`), when one was loaded at boot.
    committed_service: Option<f64>,
}

fn route(req: &HttpRequest, ctx: &RouteCtx) -> HttpResponse {
    let RouteCtx { queue, pending, metrics, health, next_id, default_deadline_ms, .. } = *ctx;
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let body = health.to_json(queue.len()).to_string().into_bytes();
            if health.stalled() || health.draining() {
                HttpResponse::with_code(503, "application/json", body)
            } else {
                HttpResponse::ok("application/json", body)
            }
        }
        ("GET", "/metrics") => {
            // scrape-time gauges: depth is a queue property, in-flight a
            // worker property, and the robustness rates derive from the
            // lifetime counters; all refresh on read. The scheduling
            // families mirror the queue/scheduler/cost-model atomics.
            metrics.set_queue_depth(queue.len());
            metrics.set_inflight(health.inflight());
            metrics.refresh_derived();
            metrics.refresh_sched(queue, ctx.sched.get().map(|s| s.as_ref()), Some(ctx.live));
            HttpResponse::ok("text/plain; version=0.0.4", metrics.render().into_bytes())
        }
        ("GET", "/trace") => HttpResponse::ok(
            "application/json",
            metrics.trace.to_json().to_string().into_bytes(),
        ),
        ("POST", "/admin/drain") => {
            // graceful drain: stop admitting, let the worker finish the
            // queue, then serve() exits when the worker thread joins.
            // Idempotent — a second drain finds the queue already closed.
            // With preemption enabled, in-flight lanes are asked to
            // suspend at their next round boundary; `push_resume`
            // bypasses the closed queue, so suspended lanes re-admit
            // and run to completion before the worker exits — drain
            // latency is bounded by one round, not one full generation.
            let lanes = health.inflight();
            if ctx.preempt.enabled() && lanes > 0 {
                metrics.on_preempt(PreemptReason::Drain, lanes);
                ctx.preempt.signal.request_all();
            }
            health.set_draining();
            queue.close();
            HttpResponse::ok(
                "application/json",
                Json::obj(vec![
                    ("draining", Json::Bool(true)),
                    ("queue_depth", Json::Num(queue.len() as f64)),
                    ("suspended", Json::Num(ctx.preempt.store.len() as f64)),
                ])
                .to_string()
                .into_bytes(),
            )
        }
        ("POST", "/admin/preempt") => {
            // flip lane preemption at runtime: {"enabled": true|false}.
            // Off stops the governors and round-boundary polling; lanes
            // already suspended still resume normally (the store and
            // `push_resume` path stay live).
            let enabled = std::str::from_utf8(&req.body)
                .ok()
                .and_then(|s| Json::parse(s).ok())
                .and_then(|v| v.get("enabled").and_then(Json::as_bool));
            let Some(on) = enabled else {
                return HttpResponse::status(400, "enabled must be true or false");
            };
            ctx.preempt.set_enabled(on);
            HttpResponse::ok(
                "application/json",
                Json::obj(vec![
                    ("enabled", Json::Bool(on)),
                    ("suspended", Json::Num(ctx.preempt.store.len() as f64)),
                    ("kv_evictions", Json::Num(ctx.preempt.store.evictions() as f64)),
                    ("resident_bytes", Json::Num(ctx.preempt.store.resident_bytes() as f64)),
                ])
                .to_string()
                .into_bytes(),
            )
        }
        ("POST", "/admin/sched") => {
            // flip the admission order at runtime: {"order":"edf"|"fcfs"}.
            // The queue's two views read one ground-truth entry set, so
            // the flip is safe mid-stream (nothing lost or duplicated).
            let order = std::str::from_utf8(&req.body)
                .ok()
                .and_then(|s| Json::parse(s).ok())
                .and_then(|v| v.get("order").and_then(Json::as_str).map(str::to_string));
            match order.as_deref() {
                Some("edf") => queue.set_edf_enabled(true),
                Some("fcfs") => queue.set_edf_enabled(false),
                _ => return HttpResponse::status(400, "order must be \"edf\" or \"fcfs\""),
            }
            HttpResponse::ok(
                "application/json",
                Json::obj(vec![
                    ("order", Json::from(if queue.edf_enabled() { "edf" } else { "fcfs" })),
                    ("aged_pops", Json::Num(queue.aged_pops() as f64)),
                    ("reordered_pops", Json::Num(queue.reordered_pops() as f64)),
                ])
                .to_string()
                .into_bytes(),
            )
        }
        ("POST", "/v1/generate") => {
            let body = match std::str::from_utf8(&req.body).ok().and_then(|s| Json::parse(s).ok())
            {
                Some(v) => v,
                None => return HttpResponse::status(400, "bad json"),
            };
            let id = next_id.fetch_add(1, Ordering::Relaxed);
            let mut r = match Request::from_json(id, &body) {
                Ok(r) => r,
                Err(e) => return HttpResponse::status(400, &format!("{e}")),
            };
            if r.method == Method::Medusa && r.temperature > 0.0 {
                return HttpResponse::status(400, "medusa is greedy-only");
            }
            // resolve the draft source at admission: the scheduler's
            // compat classes and the quarantine fingerprint both key on
            // it, so it must be pinned before the request is queued
            let choice = match r.draft {
                DraftChoice::Default => ctx.default_draft,
                c => c,
            };
            r.source = match choice {
                DraftChoice::Auto => {
                    let before = ctx.selector.switches();
                    let kind = ctx.selector.pick(r.temperature);
                    if ctx.selector.switches() > before {
                        metrics.on_policy_switch();
                    }
                    kind
                }
                DraftChoice::Fixed(k) => k,
                DraftChoice::Default => SourceKind::Eagle,
            };
            // a pinned greedy-only source (the serving facades for
            // ngram/medusa run T=0 only; auto never picks one at T>0)
            if r.temperature > 0.0
                && matches!(r.source, SourceKind::Ngram | SourceKind::Medusa)
            {
                return HttpResponse::status(400, "draft source is greedy-only");
            }
            let dl = r.deadline(default_deadline_ms);
            // overload shedding, before the request takes a slot: if the
            // estimated queue wait already exceeds the deadline budget,
            // a 429 now beats a guaranteed 504 later. Cold start (no
            // service history yet — fresh boot or post-drain restart):
            // prefer the committed per-request capacity from a prior
            // loadgen `p99_search` (the budget the operator actually
            // signed off on), falling back to the live cost model's
            // prediction. A warm EWMA always wins over both.
            let mut est = metrics.est_service_secs();
            if est == 0.0 {
                est = ctx
                    .committed_service
                    .unwrap_or_else(|| ctx.live.predicted_service_secs(r.max_tokens));
            }
            if let Some(est_wait) = should_shed(queue.len(), est, dl.budget_secs()) {
                metrics.on_shed();
                // seconds until the predicted wait decays back under the
                // budget, not the raw wait: the earliest retry that can
                // actually be admitted
                let retry = retry_after_secs(est_wait, dl.budget_secs().unwrap_or(0.0));
                return HttpResponse::status(429, "shed: deadline cannot survive queue wait")
                    .with_header("Retry-After", &retry.to_string());
            }
            metrics.on_request();
            let slot: Slot = Arc::new((Mutex::new(None), std::sync::Condvar::new()));
            pending.lock().unwrap().insert(id, slot.clone());
            match queue.push(r) {
                Ok(()) => {}
                Err(PushError::Full) => {
                    // retire the slot before answering: the request never
                    // reached the queue, so nothing will ever deliver it
                    pending.lock().unwrap().remove(&id);
                    metrics.on_rejected();
                    return HttpResponse::status(429, "queue full");
                }
                Err(PushError::Closed) => {
                    pending.lock().unwrap().remove(&id);
                    return HttpResponse::status(503, "shutting down");
                }
            }
            // wait for the worker: until the request's deadline plus
            // grace (the worker delivers the deadline-truncated partial
            // result itself), or a 120 s safety net when unbounded.
            // Spurious condvar wakeups loop back — only real elapsed
            // time can 504 — and the slot guard is always dropped before
            // touching `pending` (the worker takes pending→slot; taking
            // slot→pending here would deadlock).
            let grace = std::time::Duration::from_secs(5);
            let wait_until = match dl.instant() {
                Some(t) => t + grace,
                None => Instant::now() + std::time::Duration::from_secs(120),
            };
            let (lock, cv) = &*slot;
            let mut g = lock.lock().unwrap();
            loop {
                if let Some(resp) = g.take() {
                    drop(g);
                    // the worker removed the slot at delivery; nothing
                    // left to clean up
                    return if resp.status == 200 {
                        HttpResponse::ok(
                            "application/json",
                            resp.to_json().to_string().into_bytes(),
                        )
                    } else {
                        HttpResponse::with_code(
                            resp.status,
                            "application/json",
                            resp.to_json().to_string().into_bytes(),
                        )
                    };
                }
                let now = Instant::now();
                if now >= wait_until {
                    drop(g);
                    pending.lock().unwrap().remove(&id);
                    return HttpResponse::status(504, "generation timeout");
                }
                let (ng, _timed_out) = cv.wait_timeout(g, wait_until - now).unwrap();
                g = ng;
            }
        }
        _ => HttpResponse::status(404, "not found"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Condvar;

    #[test]
    fn retry_after_subtracts_budget_and_clamps() {
        // 10s of queued work against a 3s budget: come back in 7
        assert_eq!(retry_after_secs(10.0, 3.0), 7);
        // wait already under budget (a race with the worker draining):
        // still at least 1 — "retry immediately" would re-shed
        assert_eq!(retry_after_secs(2.0, 5.0), 1);
        // no budget supplied: the whole wait must drain
        assert_eq!(retry_after_secs(2.5, 0.0), 3);
        // negative budgets (already-expired clocks) clamp to zero
        assert_eq!(retry_after_secs(4.0, -2.0), 4);
    }

    #[test]
    fn cold_shed_seeded_from_predicted_service() {
        // a cold server has no EWMA service estimate (0.0), which used
        // to make should_shed admit everything; the live model's
        // cold-start prediction is non-zero, so an instant burst sheds
        let live = OnlineCostModel::new(CostModel::default());
        let est = live.predicted_service_secs(64);
        assert!(est > 0.0, "cold prediction must be positive");
        // 10 queued requests at ~0.22s each against a 1s budget
        let shed = should_shed(10, est, Some(1.0));
        assert!(shed.is_some(), "cold burst should shed");
        // the degenerate zero estimate would have admitted it
        assert_eq!(should_shed(10, 0.0, Some(1.0)), None);
        // unbounded requests are never shed regardless of estimate
        assert_eq!(should_shed(10, est, None), None);
    }

    #[test]
    fn fingerprint_distinguishes_draft_sources() {
        // satellite of the DraftSource refactor: a poison request that
        // panics under one source must not quarantine the same prompt
        // running under another — the content fingerprint keys on the
        // resolved source
        let a = Request::synthetic(1);
        let mut b = Request::synthetic(1);
        b.source = SourceKind::Ngram;
        let mut c = Request::synthetic(1);
        c.source = SourceKind::Medusa;
        assert_ne!(fingerprint(&a), fingerprint(&b));
        assert_ne!(fingerprint(&b), fingerprint(&c));
        assert_eq!(fingerprint(&a), fingerprint(&Request::synthetic(2)), "id-independent");
    }

    fn synth_req(id: u64, prompt: &str, max_tokens: usize) -> Request {
        let mut r = Request::synthetic(id);
        r.prompt = prompt.into();
        r.max_tokens = max_tokens;
        r
    }

    /// Run one synthetic group to completion and return each member's
    /// delivered response, in request order.
    fn run_synth(requests: Vec<Request>) -> Vec<Response> {
        let pending: PendingMap = Mutex::new(std::collections::HashMap::new());
        let metrics = ServerMetrics::new(16);
        let health = Health::new(30_000);
        let slots: Vec<Slot> = requests
            .iter()
            .map(|r| {
                let slot: Slot = Arc::new((Mutex::new(None), Condvar::new()));
                pending.lock().unwrap().insert(r.id, slot.clone());
                slot
            })
            .collect();
        let mut w = SyntheticWorker {
            round_us: 50,
            default_deadline_ms: 0,
            pending: &pending,
            metrics: &metrics,
            health: &health,
            live: None,
            queue: None,
            preempt: None,
            selector: None,
            agg: Aggregate::new(),
        };
        w.run(AdmittedGroup { verify_cap: Some(32), requests });
        slots.iter().map(|s| s.0.lock().unwrap().take().expect("delivered")).collect()
    }

    #[test]
    fn synthetic_output_is_pure_function_of_request() {
        // the same request served solo, batched with a stranger, and in
        // a different admission position must produce the same text —
        // the losslessness invariant the EDF-vs-FCFS comparison rests on
        let solo = run_synth(vec![synth_req(1, "alpha", 12)]);
        let batched = run_synth(vec![synth_req(2, "beta", 9), synth_req(3, "alpha", 12)]);
        assert_eq!(solo[0].text, batched[1].text, "batch composition changed output");
        assert_eq!(solo[0].tokens, 12);
        assert_eq!(batched[1].tokens, 12);
        assert_ne!(batched[0].text, batched[1].text, "distinct prompts, distinct streams");
        assert_eq!(solo[0].status, 200);
        assert!(solo[0].truncated.is_none());
    }

    #[test]
    fn synthetic_rounds_feed_live_cost_model() {
        let pending: PendingMap = Mutex::new(std::collections::HashMap::new());
        let metrics = ServerMetrics::new(16);
        let health = Health::new(30_000);
        let live = OnlineCostModel::new(CostModel::default());
        let r = synth_req(9, "gamma", 30);
        let slot: Slot = Arc::new((Mutex::new(None), Condvar::new()));
        pending.lock().unwrap().insert(r.id, slot.clone());
        let mut w = SyntheticWorker {
            round_us: 50,
            default_deadline_ms: 0,
            pending: &pending,
            metrics: &metrics,
            health: &health,
            live: Some(&live),
            queue: None,
            preempt: None,
            selector: None,
            agg: Aggregate::new(),
        };
        w.run(AdmittedGroup { verify_cap: Some(32), requests: vec![r] });
        // 30 tokens at tau=3 -> 10 rounds observed
        assert_eq!(live.observations(), 10);
    }

    /// A PreemptCtl with a tight store: one resident slot, watermark 1,
    /// so a single parked resident checkpoint puts it under pressure.
    fn tight_ctl() -> PreemptCtl {
        PreemptCtl::new(true, CheckpointStore::new(1, 1, 0))
    }

    #[test]
    fn preempt_governors_fire_once_per_group() {
        let ctl = tight_ctl();
        let mut dummy = Box::new(LaneCheckpoint::new());
        dummy.id = 999;
        dummy.kv_target.resize(64, 0.0);
        dummy.kv_resident = true;
        ctl.store.insert(dummy);
        assert!(ctl.store.under_pressure());
        ctl.begin_group(None, 10);
        assert!(ctl.poll_pressure(true), "pressure + waiters fires");
        assert!(ctl.signal.any());
        assert!(!ctl.poll_pressure(true), "latched for the rest of the group");
        ctl.end_group();
        assert!(!ctl.signal.any(), "end_group clears unconsumed bits");
        ctl.begin_group(None, 10);
        assert!(ctl.poll_pressure(true), "new group re-arms the latch");
        ctl.end_group();
        // disabled: never fires
        ctl.set_enabled(false);
        ctl.begin_group(None, 10);
        assert!(!ctl.poll_pressure(true));
        ctl.end_group();
    }

    #[test]
    fn synthetic_suspend_resume_is_byte_identical() {
        // a lane suspended mid-run by the pressure governor, requeued,
        // and resumed must deliver exactly the text an uninterrupted
        // run produces — the serving-level half of the bit-identical
        // resume guarantee (the engine-level half lives in
        // tests/prop_checkpoint.rs)
        let uninterrupted = run_synth(vec![synth_req(1, "delta", 24)]);
        assert_eq!(uninterrupted[0].tokens, 24);

        let queue = RequestQueue::new(8);
        let ctl = tight_ctl();
        // park a dummy resident so the store is under pressure, and
        // leave a stranger queued so the governor sees waiting work
        let mut dummy = Box::new(LaneCheckpoint::new());
        dummy.id = 999;
        dummy.kv_target.resize(64, 0.0);
        dummy.kv_resident = true;
        ctl.store.insert(dummy);
        queue.push(synth_req(50, "stranger", 3)).unwrap();

        let pending: PendingMap = Mutex::new(std::collections::HashMap::new());
        let metrics = ServerMetrics::new(16);
        let health = Health::new(30_000);
        let slot: Slot = Arc::new((Mutex::new(None), Condvar::new()));
        pending.lock().unwrap().insert(2, slot.clone());
        let mut w = SyntheticWorker {
            round_us: 50,
            default_deadline_ms: 0,
            pending: &pending,
            metrics: &metrics,
            health: &health,
            live: None,
            queue: Some(&queue),
            preempt: Some(&ctl),
            selector: None,
            agg: Aggregate::new(),
        };
        ctl.begin_group(None, 24);
        w.run(AdmittedGroup { verify_cap: Some(32), requests: vec![synth_req(2, "delta", 24)] });
        ctl.end_group();
        assert!(slot.0.lock().unwrap().is_none(), "suspended lane must not deliver");
        assert!(ctl.store.contains(2), "checkpoint parked under the request id");

        // the worker requeued the lane as a resumable entry
        let resumed = queue
            .pop_up_to(8)
            .into_iter()
            .find(|r| r.resume)
            .expect("suspended lane requeued");
        assert_eq!(resumed.id, 2);

        ctl.begin_group(None, 24);
        w.run(AdmittedGroup { verify_cap: Some(32), requests: vec![resumed] });
        ctl.end_group();
        let out = slot.0.lock().unwrap().take().expect("resumed lane delivers");
        assert_eq!(out.status, 200);
        assert_eq!(out.tokens, 24);
        assert_eq!(out.text, uninterrupted[0].text, "resume diverged from uninterrupted run");
        assert!(!ctl.store.contains(2), "checkpoint consumed by resume");
    }

    #[test]
    fn suspended_deadline_expiry_delivers_partial() {
        // worker_loop's admission-time expiry check: a resumed request
        // whose checkpoint is parked gets its partial tokens as a 200
        // with the deadline marker, not a bare 504
        let mut ck = Box::new(LaneCheckpoint::new());
        ck.id = 7;
        ck.rec.tokens.extend([1, 2, 3]);
        ck.rec.target_passes = 1;
        let resp = suspended_partial_response(7, &ck, 12.0, "deadline");
        assert_eq!(resp.status, 200);
        assert_eq!(resp.tokens, 3);
        assert_eq!(resp.truncated, Some("deadline"));
        let drained = suspended_partial_response(7, &ck, 0.0, "drain");
        assert_eq!(drained.truncated, Some("drain"));
    }
}
