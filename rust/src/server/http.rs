//! Minimal HTTP/1.1 request/response framing over a TcpStream.

use anyhow::{anyhow, bail, Result};
use std::io::{BufRead, BufReader, Read};
use std::net::TcpStream;

#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn read_from(stream: &mut TcpStream) -> Result<HttpRequest> {
        stream.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let mut parts = line.split_whitespace();
        let method = parts.next().ok_or_else(|| anyhow!("empty request line"))?.to_string();
        let path = parts.next().ok_or_else(|| anyhow!("missing path"))?.to_string();
        let mut headers = Vec::new();
        let mut content_len = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            let h = h.trim_end().to_string();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                let k = k.trim().to_ascii_lowercase();
                let v = v.trim().to_string();
                if k == "content-length" {
                    content_len = v.parse().unwrap_or(0);
                }
                headers.push((k, v));
            }
        }
        if content_len > 16 * 1024 * 1024 {
            bail!("body too large");
        }
        let mut body = vec![0u8; content_len];
        reader.read_exact(&mut body)?;
        Ok(HttpRequest { method, path, headers, body })
    }
}

#[derive(Debug)]
pub struct HttpResponse {
    pub code: u16,
    pub reason: &'static str,
    pub content_type: String,
    pub body: Vec<u8>,
    /// Extra response headers (name, value), e.g. `Retry-After` on a
    /// shed 429. Names/values must already be header-safe.
    pub extra_headers: Vec<(String, String)>,
}

fn reason_for(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Error",
    }
}

impl HttpResponse {
    pub fn ok(content_type: &str, body: Vec<u8>) -> HttpResponse {
        HttpResponse::with_code(200, content_type, body)
    }

    /// Arbitrary status with a full body (the reason phrase is derived
    /// from the code) — used when a JSON payload rides on a non-200,
    /// e.g. a deadline-truncated 504 or a panic-failed lane's 500.
    pub fn with_code(code: u16, content_type: &str, body: Vec<u8>) -> HttpResponse {
        HttpResponse {
            code,
            reason: reason_for(code),
            content_type: content_type.into(),
            body,
            extra_headers: Vec::new(),
        }
    }

    pub fn status(code: u16, msg: &str) -> HttpResponse {
        HttpResponse::with_code(
            code,
            "application/json",
            format!("{{\"error\":{}}}", crate::util::json::Json::Str(msg.into())).into_bytes(),
        )
    }

    /// Attach an extra response header (builder-style).
    pub fn with_header(mut self, name: &str, value: &str) -> HttpResponse {
        self.extra_headers.push((name.into(), value.into()));
        self
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = format!(
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\n",
            self.code,
            self.reason,
            self.content_type,
            self.body.len()
        );
        for (name, value) in &self.extra_headers {
            out.push_str(name);
            out.push_str(": ");
            out.push_str(value);
            out.push_str("\r\n");
        }
        out.push_str("connection: close\r\n\r\n");
        let mut out = out.into_bytes();
        out.extend_from_slice(&self.body);
        out
    }
}

/// Minimal blocking HTTP client for examples/tests (talks to our server).
pub fn post_json(addr: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let (code, _, body) = post_json_full(addr, path, body)?;
    Ok((code, body))
}

/// [`post_json`] variant that also returns the response headers
/// (lowercased names) — the loadgen retry client reads `retry-after`
/// off shed 429s.
pub fn post_json_full(
    addr: &str,
    path: &str,
    body: &str,
) -> Result<(u16, Vec<(String, String)>, String)> {
    use std::io::Write;
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(std::time::Duration::from_secs(300)))?;
    write!(
        s,
        "POST {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
        body.len()
    )?;
    read_response_full(&mut s)
}

pub fn get(addr: &str, path: &str) -> Result<(u16, String)> {
    use std::io::Write;
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(std::time::Duration::from_secs(60)))?;
    write!(s, "GET {path} HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n\r\n")?;
    read_response(&mut s)
}

fn read_response(s: &mut TcpStream) -> Result<(u16, String)> {
    let (code, _, body) = read_response_full(s)?;
    Ok((code, body))
}

fn read_response_full(s: &mut TcpStream) -> Result<(u16, Vec<(String, String)>, String)> {
    let mut buf = Vec::new();
    s.read_to_end(&mut buf)?;
    let text = String::from_utf8_lossy(&buf);
    let code: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| anyhow!("bad response"))?;
    let mut split = text.splitn(2, "\r\n\r\n");
    let head = split.next().unwrap_or("");
    let body = split.next().unwrap_or("").to_string();
    let headers = head
        .lines()
        .skip(1) // status line
        .filter_map(|h| {
            h.split_once(':')
                .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        })
        .collect();
    Ok((code, headers, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_framing() {
        let r = HttpResponse::ok("text/plain", b"hello".to_vec());
        let s = String::from_utf8(r.to_bytes()).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("content-length: 5"));
        assert!(s.ends_with("hello"));
    }

    #[test]
    fn error_codes() {
        assert_eq!(HttpResponse::status(429, "x").reason, "Too Many Requests");
        assert_eq!(HttpResponse::status(400, "x").code, 400);
        assert_eq!(HttpResponse::status(500, "x").reason, "Internal Server Error");
        assert_eq!(HttpResponse::status(504, "x").reason, "Gateway Timeout");
    }

    #[test]
    fn post_json_full_returns_headers() {
        // loopback server that answers every request with a shed 429
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            let (mut c, _) = listener.accept().unwrap();
            let _ = HttpRequest::read_from(&mut c).unwrap();
            let resp = HttpResponse::status(429, "shed").with_header("Retry-After", "7");
            use std::io::Write;
            c.write_all(&resp.to_bytes()).unwrap();
        });
        let (code, headers, body) = post_json_full(&addr, "/v1/generate", "{}").unwrap();
        h.join().unwrap();
        assert_eq!(code, 429);
        assert!(body.contains("shed"));
        let ra = headers.iter().find(|(k, _)| k == "retry-after").map(|(_, v)| v.as_str());
        assert_eq!(ra, Some("7"), "headers: {headers:?}");
    }

    #[test]
    fn extra_headers_framed_before_terminator() {
        let r = HttpResponse::status(429, "shed").with_header("Retry-After", "3");
        let s = String::from_utf8(r.to_bytes()).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        let head = s.split("\r\n\r\n").next().unwrap();
        assert!(head.contains("Retry-After: 3"), "header in the head section: {head}");
    }
}
