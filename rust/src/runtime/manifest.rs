//! `manifest.json` model — the catalog the AOT pipeline writes and the
//! coordinator loads (the L2/L3 ABI).

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_len: usize,
    pub n_experts: usize,
}

#[derive(Debug, Clone)]
pub struct ExeEntry {
    pub hlo: String,
    pub bs: usize,
}

#[derive(Debug, Clone)]
pub struct DraftEntry {
    pub weights: String,
    pub param_names: Vec<String>,
    pub executables: BTreeMap<String, ExeEntry>,
    pub accuracy: f64,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub config: ModelConfig,
    pub weights: String,
    pub param_names: Vec<String>,
    pub executables: BTreeMap<String, ExeEntry>,
    pub drafts: BTreeMap<String, DraftEntry>,
    pub medusa: Option<DraftEntry>,
    pub tdlm: Option<Box<ModelEntry>>,
    pub quantized: bool,
}

#[derive(Debug, Clone)]
pub struct Constants {
    pub prefill_p: usize,
    pub tree_t: usize,
    pub chain_t: usize,
    pub accept_a: usize,
    pub draft_w: usize,
    /// Lowered verify-width family (`"verify_widths"` manifest field):
    /// each `t` here has `verify_t{t}` (and, where batched serving is
    /// lowered, `verify_t{t}_bs{b}`) executables, letting the engines
    /// dispatch a round to the cheapest width that holds its draft tree.
    /// Ascending, deduplicated, and always containing `tree_t`; older
    /// manifests without the field degrade to `[tree_t]` (the legacy
    /// single-width behavior).
    pub verify_widths: Vec<usize>,
    /// Lowered draft-step width family (`"draft_widths"` manifest
    /// field): each `w` here has `step_w{w}` (and, where batched serving
    /// is lowered, `step_w{w}_bs{b}`) executables, so draft levels run
    /// at the narrowest width holding their frontier — per lane group,
    /// not per batch. Ascending, deduplicated, always containing
    /// `draft_w`; older manifests degrade to `[draft_w]`.
    pub draft_widths: Vec<usize>,
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub constants: Constants,
    pub tokenizer: String,
    pub workloads: BTreeMap<String, String>,
    pub models: BTreeMap<String, ModelEntry>,
}

fn parse_names(v: &Json) -> Result<Vec<String>> {
    Ok(v.as_arr()
        .ok_or_else(|| anyhow!("param_names not array"))?
        .iter()
        .map(|s| s.as_str().unwrap_or_default().to_string())
        .collect())
}

fn parse_exes(v: &Json) -> Result<BTreeMap<String, ExeEntry>> {
    let mut out = BTreeMap::new();
    for (k, e) in v.as_obj().ok_or_else(|| anyhow!("executables not object"))? {
        out.insert(
            k.clone(),
            ExeEntry {
                hlo: e.req("hlo")?.as_str().unwrap_or_default().to_string(),
                bs: e.get("bs").and_then(|b| b.as_usize()).unwrap_or(1),
            },
        );
    }
    Ok(out)
}

fn parse_config(name: &str, v: &Json) -> Result<ModelConfig> {
    let g = |k: &str| -> Result<usize> {
        v.req(k)?.as_usize().ok_or_else(|| anyhow!("config.{k} not a number"))
    };
    Ok(ModelConfig {
        name: name.to_string(),
        vocab: g("vocab")?,
        d: g("d")?,
        n_layers: g("n_layers")?,
        n_heads: g("n_heads")?,
        head_dim: g("head_dim")?,
        max_len: g("max_len")?,
        n_experts: v.get("n_experts").and_then(|x| x.as_usize()).unwrap_or(0),
    })
}

fn parse_draft(v: &Json) -> Result<DraftEntry> {
    Ok(DraftEntry {
        weights: v.req("weights")?.as_str().unwrap_or_default().to_string(),
        param_names: parse_names(v.req("param_names")?)?,
        executables: parse_exes(v.req("executables")?)?,
        accuracy: v.get("accuracy").and_then(|a| a.as_f64()).unwrap_or(0.0),
    })
}

fn parse_model(name: &str, v: &Json) -> Result<ModelEntry> {
    let mut drafts = BTreeMap::new();
    if let Some(ds) = v.get("drafts").and_then(|d| d.as_obj()) {
        for (k, d) in ds {
            drafts.insert(k.clone(), parse_draft(d)?);
        }
    }
    let medusa = match v.get("medusa") {
        Some(m) => Some(parse_draft(m)?),
        None => None,
    };
    let tdlm = match v.get("tdlm") {
        Some(t) => {
            let mut entry = parse_model(&format!("{name}-tdlm"), t)?;
            entry.config = parse_config(&format!("{name}-tdlm"), t.req("config")?)?;
            Some(Box::new(entry))
        }
        None => None,
    };
    Ok(ModelEntry {
        config: parse_config(name, v.req("config")?)?,
        weights: v.req("weights")?.as_str().unwrap_or_default().to_string(),
        param_names: parse_names(v.req("param_names")?)?,
        executables: parse_exes(v.req("executables")?)?,
        drafts,
        medusa,
        tdlm,
        quantized: v.get("quantized").and_then(|q| q.as_bool()).unwrap_or(false),
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            anyhow!("reading manifest in {}: {e} (run `make artifacts`)", dir.display())
        })?;
        let v = Json::parse(&text)?;
        let c = v.req("constants")?;
        let gc = |k: &str| -> Result<usize> {
            c.req(k)?.as_usize().ok_or_else(|| anyhow!("constants.{k}"))
        };
        let mut models = BTreeMap::new();
        for (name, m) in v.req("models")?.as_obj().ok_or_else(|| anyhow!("models"))? {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        let mut workloads = BTreeMap::new();
        if let Some(ws) = v.get("workloads").and_then(|w| w.as_obj()) {
            for (k, p) in ws {
                workloads.insert(k.clone(), p.as_str().unwrap_or_default().to_string());
            }
        }
        let tree_t = gc("tree_t")?;
        let draft_w = gc("draft_w")?;
        let parse_widths = |key: &str, min_w: usize, anchor: usize| -> Vec<usize> {
            let mut widths: Vec<usize> = c
                .get(key)
                .and_then(|w| w.as_arr())
                .map(|arr| {
                    arr.iter().filter_map(|x| x.as_usize()).filter(|&t| t >= min_w).collect()
                })
                .unwrap_or_default();
            widths.push(anchor);
            widths.sort_unstable();
            widths.dedup();
            widths
        };
        let verify_widths = parse_widths("verify_widths", 2, tree_t);
        let draft_widths = parse_widths("draft_widths", 1, draft_w);
        Ok(Manifest {
            root: dir.to_path_buf(),
            constants: Constants {
                prefill_p: gc("prefill_p")?,
                tree_t,
                chain_t: gc("chain_t")?,
                accept_a: gc("accept_a")?,
                draft_w,
                verify_widths,
                draft_widths,
            },
            tokenizer: v.req("tokenizer")?.as_str().unwrap_or_default().to_string(),
            workloads,
            models,
        })
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| {
                let have: Vec<_> = self.models.keys().collect();
                anyhow!("model '{name}' not in manifest (have: {have:?})")
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("eagle_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"tokenizer":"vocab.json",
                "constants":{"prefill_p":64,"tree_t":32,"chain_t":8,"accept_a":8,"draft_w":8},
                "workloads":{"mtbench":"workloads/mtbench.json"},
                "models":{"m":{"config":{"vocab":10,"d":4,"n_layers":1,"n_heads":1,"head_dim":4,"max_len":16,"ffn":8},
                  "weights":"w.stensor","param_names":["a"],
                  "executables":{"decode":{"hlo":"d.hlo.txt","bs":1}},
                  "drafts":{"eagle":{"weights":"e.stensor","param_names":["fc"],
                    "executables":{"step_w8":{"hlo":"s.hlo.txt"}},"accuracy":0.5}}}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.constants.tree_t, 32);
        assert_eq!(m.constants.verify_widths, vec![32], "no field -> legacy single width");
        assert_eq!(m.constants.draft_widths, vec![8], "no field -> legacy single draft width");
        let me = m.model("m").unwrap();
        assert_eq!(me.config.d, 4);
        assert_eq!(me.drafts["eagle"].param_names, vec!["fc"]);
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn parses_verify_width_family() {
        let dir = std::env::temp_dir().join("eagle_manifest_widths_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"tokenizer":"vocab.json",
                "constants":{"prefill_p":64,"tree_t":32,"chain_t":8,"accept_a":8,"draft_w":8,
                             "verify_widths":[16,8,32,8,1],"draft_widths":[4,1,8,4,0]},
                "models":{}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(
            m.constants.verify_widths,
            vec![8, 16, 32],
            "sorted, deduplicated, degenerate widths dropped, tree_t included"
        );
        assert_eq!(
            m.constants.draft_widths,
            vec![1, 4, 8],
            "draft widths allow w=1 but drop w=0; draft_w included"
        );
    }
}
