//! L3 runtime (S9): PJRT client wrapper, HLO-text executable loading,
//! device-resident weights, and host<->device literal plumbing.
//!
//! Empirically (see DESIGN.md §Perf): the `xla` crate returns every
//! executable result as ONE tuple `PjRtBuffer` with no device-side
//! untuple, so outputs roundtrip through `to_literal_sync` +
//! `decompose_tuple`. Inputs, however, can stay device-side — parameter
//! leaves are uploaded once per model at load ([`ParamSet`]) and reused by
//! every call through `execute_b`, which keeps the per-step host traffic
//! down to the KV cache + small state tensors.

pub mod manifest;
pub mod tensorfile;

use anyhow::{anyhow, Context, Result};
use std::rc::Rc;

pub use manifest::Manifest;
pub use tensorfile::{Tensor, TensorData};

/// Shared PJRT CPU client.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Rc<Runtime>> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Rc::new(Runtime { client }))
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_tensor(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        match &t.data {
            TensorData::F32(v) => self.upload_f32(v, &t.dims),
            TensorData::I32(v) => self.upload_i32(v, &t.dims),
        }
    }
}

/// A compiled executable loaded from HLO text.
pub struct Exe {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Wall-clock accounting for the profiler (S17).
    pub calls: std::cell::Cell<u64>,
    pub nanos: std::cell::Cell<u64>,
}

impl Exe {
    pub fn load(rt: &Runtime, name: &str, hlo_path: &std::path::Path) -> Result<Exe> {
        let proto = xla::HloModuleProto::from_text_file(hlo_path)
            .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = rt
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", name))?;
        Ok(Exe {
            name: name.to_string(),
            exe,
            calls: std::cell::Cell::new(0),
            nanos: std::cell::Cell::new(0),
        })
    }

    /// Execute with device-resident inputs; decompose the tuple output
    /// into host literals.
    pub fn run(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let t0 = std::time::Instant::now();
        let out = self.exe.execute_b(args)?;
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        self.calls.set(self.calls.get() + 1);
        self.nanos
            .set(self.nanos.get() + t0.elapsed().as_nanos() as u64);
        Ok(parts)
    }
}

/// Read a literal into an f32 vec (converting if needed).
pub fn lit_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn lit_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// Device-resident parameter leaves in manifest order.
pub struct ParamSet {
    pub names: Vec<String>,
    pub bufs: Vec<xla::PjRtBuffer>,
    pub total_bytes: usize,
}

impl ParamSet {
    pub fn load(rt: &Runtime, path: &std::path::Path, expect_names: &[String]) -> Result<ParamSet> {
        let tensors = tensorfile::read_stensor(path)?;
        let names: Vec<String> = tensors.iter().map(|t| t.name.clone()).collect();
        if names != expect_names {
            return Err(anyhow!(
                "weights {} param order mismatch: got {} leaves, expected {}",
                path.display(),
                names.len(),
                expect_names.len()
            ));
        }
        let mut total = 0usize;
        let mut bufs = Vec::with_capacity(tensors.len());
        for t in &tensors {
            total += t.byte_len();
            bufs.push(rt.upload_tensor(t)?);
        }
        Ok(ParamSet { names, bufs, total_bytes: total })
    }

    pub fn refs(&self) -> Vec<&xla::PjRtBuffer> {
        self.bufs.iter().collect()
    }

    /// Find a leaf buffer by name (e.g. `tok_emb`, `lm_head`).
    pub fn get(&self, name: &str) -> Result<&xla::PjRtBuffer> {
        let i = self
            .names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow!("param leaf '{name}' not found"))?;
        Ok(&self.bufs[i])
    }
}
