//! `.stensor` reader — rust side of the weights ABI
//! (see `python/compile/tensorfile.py` for the format spec).

use anyhow::{anyhow, bail, Result};
use std::io::Read;

#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn len(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn byte_len(&self) -> usize {
        self.len() * 4
    }
    pub fn f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor {} is not f32", self.name)),
        }
    }
}

const MAGIC: &[u8; 8] = b"STNSR1\x00\x00";

pub fn read_stensor(path: &std::path::Path) -> Result<Vec<Tensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).map_err(|e| anyhow!("open {}: {e}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad stensor magic", path.display());
    }
    let count = read_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let nlen = read_u32(&mut f)? as usize;
        let mut name = vec![0u8; nlen];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let mut dt = [0u8; 1];
        f.read_exact(&mut dt)?;
        let ndim = read_u32(&mut f)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u64(&mut f)? as usize);
        }
        let n: usize = dims.iter().product::<usize>().max(1);
        let mut raw = vec![0u8; n * 4];
        f.read_exact(&mut raw)?;
        let data = match dt[0] {
            0 => TensorData::F32(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            1 => TensorData::I32(
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            d => bail!("{name}: unsupported dtype tag {d}"),
        };
        out.push(Tensor { name, dims, data });
    }
    Ok(out)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_fixture(path: &std::path::Path) {
        // one f32 [2,2] + one i32 [3] + one 0-d f32
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(MAGIC).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        // "w" f32 [2,2]
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(b"w").unwrap();
        f.write_all(&[0u8]).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&2u64.to_le_bytes()).unwrap();
        f.write_all(&2u64.to_le_bytes()).unwrap();
        for x in [1.0f32, 2.0, 3.0, 4.0] {
            f.write_all(&x.to_le_bytes()).unwrap();
        }
        // "i" i32 [3]
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(b"i").unwrap();
        f.write_all(&[1u8]).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&3u64.to_le_bytes()).unwrap();
        for x in [7i32, 8, 9] {
            f.write_all(&x.to_le_bytes()).unwrap();
        }
        // "s" scalar f32
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(b"s").unwrap();
        f.write_all(&[0u8]).unwrap();
        f.write_all(&0u32.to_le_bytes()).unwrap();
        f.write_all(&5.5f32.to_le_bytes()).unwrap();
    }

    #[test]
    fn reads_fixture() {
        let p = std::env::temp_dir().join("eagle_test.stensor");
        write_fixture(&p);
        let ts = read_stensor(&p).unwrap();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[0].name, "w");
        assert_eq!(ts[0].dims, vec![2, 2]);
        assert_eq!(ts[0].f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        match &ts[1].data {
            TensorData::I32(v) => assert_eq!(v, &[7, 8, 9]),
            _ => panic!("wrong dtype"),
        }
        assert_eq!(ts[2].dims.len(), 0);
        assert_eq!(ts[2].f32().unwrap(), &[5.5]);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = std::env::temp_dir().join("eagle_bad.stensor");
        std::fs::write(&p, b"NOTMAGIC").unwrap();
        assert!(read_stensor(&p).is_err());
    }
}
