//! Byte-level BPE encoder/decoder (S1) — bit-exact mirror of
//! `python/compile/tokenizer.py`. The vocab artifact carries merges in
//! rank order; fixtures dumped by the python tests are replayed in
//! `rust/tests/` to pin the cross-language contract.

use std::collections::HashMap;

use crate::util::json::Json;

pub const SPECIALS: [&str; 5] = ["<pad>", "<bos>", "<eos>", "<user>", "<asst>"];

pub struct Bpe {
    merges: Vec<(u32, u32)>,
    ranks: HashMap<(u32, u32), u32>,
    pub vocab_size: usize,
    special_base: u32,
}

impl Bpe {
    pub fn from_json(s: &str) -> anyhow::Result<Bpe> {
        let v = Json::parse(s)?;
        let merges: Vec<(u32, u32)> = v
            .req("merges")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("merges not array"))?
            .iter()
            .map(|p| {
                let a = p.as_arr().unwrap();
                (a[0].as_usize().unwrap() as u32, a[1].as_usize().unwrap() as u32)
            })
            .collect();
        let ranks = merges
            .iter()
            .enumerate()
            .map(|(r, &pair)| (pair, 256 + r as u32))
            .collect();
        let special_base = 256 + merges.len() as u32;
        Ok(Bpe {
            vocab_size: 256 + merges.len() + SPECIALS.len(),
            merges,
            ranks,
            special_base,
        })
    }

    pub fn load(path: &str) -> anyhow::Result<Bpe> {
        Bpe::from_json(&std::fs::read_to_string(path)?)
    }

    pub fn special(&self, name: &str) -> u32 {
        let idx = SPECIALS.iter().position(|s| *s == name).expect("unknown special");
        self.special_base + idx as u32
    }

    /// Mirror of python `split_words`: pieces of (optional single leading
    /// space + non-space run); lone extra spaces become " " pieces.
    pub fn split_words(text: &str) -> Vec<&str> {
        let b = text.as_bytes();
        let mut out = Vec::new();
        let mut i = 0;
        while i < b.len() {
            let j = if b[i] == b' ' { i + 1 } else { i };
            let mut k = j;
            while k < b.len() && b[k] != b' ' {
                k += 1;
            }
            if k == j {
                out.push(&text[i..j]); // lone space
                i = j;
            } else {
                out.push(&text[i..k]);
                i = k;
            }
        }
        out
    }

    fn encode_word(&self, word: &str) -> Vec<u32> {
        let mut ids: Vec<u32> = word.bytes().map(|b| b as u32).collect();
        while ids.len() >= 2 {
            let mut best: Option<(u32, usize)> = None;
            for i in 0..ids.len() - 1 {
                if let Some(&r) = self.ranks.get(&(ids[i], ids[i + 1])) {
                    if best.map(|(br, _)| r < br).unwrap_or(true) {
                        best = Some((r, i));
                    }
                }
            }
            match best {
                Some((r, i)) => {
                    ids[i] = r;
                    ids.remove(i + 1);
                }
                None => break,
            }
        }
        ids
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() / 2);
        for w in Self::split_words(text) {
            out.extend(self.encode_word(w));
        }
        out
    }

    /// `<bos> <user> prompt <asst>` — the generation-side dialogue prefix.
    pub fn encode_prompt(&self, user: &str) -> Vec<u32> {
        let mut ids = vec![self.special("<bos>"), self.special("<user>")];
        ids.extend(self.encode(user));
        ids.push(self.special("<asst>"));
        ids
    }

    fn expand(&self, tid: u32, out: &mut Vec<u8>) {
        if tid < 256 {
            out.push(tid as u8);
        } else if (tid as usize) < 256 + self.merges.len() {
            let (l, r) = self.merges[tid as usize - 256];
            self.expand(l, out);
            self.expand(r, out);
        } else {
            out.extend(SPECIALS[(tid - self.special_base) as usize].as_bytes());
        }
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::with_capacity(ids.len() * 3);
        for &t in ids {
            self.expand(t, &mut bytes);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn eos(&self) -> u32 {
        self.special("<eos>")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Bpe {
        // merges: (104,105)="hi"->256, (256,33)="hi!"->257
        Bpe::from_json(r#"{"merges":[[104,105],[256,33]],"specials":[],"vocab_size":263}"#)
            .unwrap()
    }

    #[test]
    fn split_words_matches_python_examples() {
        assert_eq!(Bpe::split_words("a b"), vec!["a", " b"]);
        assert_eq!(Bpe::split_words(" a"), vec![" a"]);
        assert_eq!(Bpe::split_words("a  b"), vec!["a", " ", " b"]);
        assert!(Bpe::split_words("").is_empty());
        assert_eq!(Bpe::split_words("  "), vec![" ", " "]);
        assert_eq!(Bpe::split_words("ab\ncd"), vec!["ab\ncd"]);
    }

    #[test]
    fn greedy_merge_order() {
        let b = tiny();
        assert_eq!(b.encode("hi!"), vec![257]);
        assert_eq!(b.encode("hih"), vec![256, 104]);
        assert_eq!(b.decode(&[257]), "hi!");
    }

    #[test]
    fn roundtrip_arbitrary_utf8() {
        let b = tiny();
        for s in ["héllo wörld", "a b  c", "", "日本語 text"] {
            assert_eq!(b.decode(&b.encode(s)), s);
        }
    }

    #[test]
    fn specials_at_tail() {
        let b = tiny();
        assert_eq!(b.special("<pad>"), 258);
        assert_eq!(b.special("<eos>"), 260);
        let p = b.encode_prompt("hi");
        assert_eq!(p[0], b.special("<bos>"));
        assert_eq!(*p.last().unwrap(), b.special("<asst>"));
    }
}
