//! Text processing: the BPE tokenizer shared (bit-exactly) with python.

pub mod bpe;
