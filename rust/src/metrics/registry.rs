//! Lock-free serving metrics registry with Prometheus text exposition.
//!
//! The registry is built ONCE at startup ([`RegistryBuilder`]) and never
//! grows afterwards: every counter, gauge and histogram is a fixed slot
//! of pre-sized atomics, so the record path ([`MetricsRegistry::inc`],
//! [`MetricsRegistry::add`], [`MetricsRegistry::set_gauge`],
//! [`MetricsRegistry::observe`]) is store/fetch-add only — no locks, no
//! heap traffic — and stays inside the S22 zero-allocation guarantee
//! even when called from the engine round loop (verified under the
//! `count-alloc` allocator in `rust/tests/count_alloc.rs`).
//!
//! Rendering ([`MetricsRegistry::render`]) produces Prometheus text
//! exposition format — `# HELP`/`# TYPE` headers, cumulative histogram
//! buckets ending in `+Inf`, `_sum`/`_count` series, escaped label
//! values — and is the ONLY allocating path; it runs on the HTTP route
//! thread, never in the round loop. [`parse_exposition`] is the
//! matching strict parser/validator used by the test suite and the
//! `repro scrape` CI smoke step.
//!
//! Design notes for the two non-obvious encodings:
//! * gauges hold `f64::to_bits` so `set_gauge` is a plain `store`;
//! * histogram `_sum` accumulates fixed-point micro-units
//!   (`SUM_SCALE = 1e6`) via `fetch_add`, avoiding even a CAS loop on
//!   the record path; counters may carry a render-time `scale` so time
//!   totals can be recorded as integer nanoseconds and exposed as
//!   seconds.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use anyhow::{anyhow, bail, ensure, Result};

/// Fixed-point denominator for histogram `_sum` (micro-units).
const SUM_SCALE: f64 = 1e6;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

/// Immutable description of one metric series (constant labels allowed).
struct MetricSpec {
    name: String,
    help: String,
    labels: Vec<(String, String)>,
    kind: Kind,
    /// Finite ascending bucket upper bounds (histogram only).
    bounds: Vec<f64>,
    /// Render-time multiplier for counter raw values (e.g. `1e-9` to
    /// record nanoseconds and expose seconds).
    scale: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct CounterId(usize);
#[derive(Clone, Copy, Debug)]
pub struct GaugeId(usize);
#[derive(Clone, Copy, Debug)]
pub struct HistId(usize);

/// Builds the fixed metric set; consumed by [`RegistryBuilder::build`].
#[derive(Default)]
pub struct RegistryBuilder {
    specs: Vec<MetricSpec>,
}

impl RegistryBuilder {
    pub fn new() -> RegistryBuilder {
        RegistryBuilder::default()
    }

    fn push(&mut self, spec: MetricSpec) -> usize {
        self.specs.push(spec);
        self.specs.len() - 1
    }

    pub fn counter(&mut self, name: &str, help: &str) -> CounterId {
        self.counter_with(name, help, &[])
    }

    pub fn counter_with(&mut self, name: &str, help: &str, labels: &[(&str, &str)]) -> CounterId {
        self.counter_scaled(name, help, labels, 1.0)
    }

    pub fn counter_scaled(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        scale: f64,
    ) -> CounterId {
        CounterId(self.push(MetricSpec {
            name: name.to_string(),
            help: help.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            kind: Kind::Counter,
            bounds: Vec::new(),
            scale,
        }))
    }

    pub fn gauge(&mut self, name: &str, help: &str) -> GaugeId {
        GaugeId(self.push(MetricSpec {
            name: name.to_string(),
            help: help.to_string(),
            labels: Vec::new(),
            kind: Kind::Gauge,
            bounds: Vec::new(),
            scale: 1.0,
        }))
    }

    pub fn histogram(&mut self, name: &str, help: &str, bounds: &[f64]) -> HistId {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        HistId(self.push(MetricSpec {
            name: name.to_string(),
            help: help.to_string(),
            labels: Vec::new(),
            kind: Kind::Histogram,
            bounds: bounds.to_vec(),
            scale: 1.0,
        }))
    }

    /// Allocate every atomic slot up front; after this the registry
    /// never allocates on the record path.
    pub fn build(self) -> MetricsRegistry {
        let metrics = self
            .specs
            .into_iter()
            .map(|spec| {
                let nb = spec.bounds.len();
                Metric {
                    spec,
                    value: AtomicU64::new(0),
                    buckets: (0..nb).map(|_| AtomicU64::new(0)).collect(),
                    count: AtomicU64::new(0),
                    sum_fp: AtomicU64::new(0),
                }
            })
            .collect();
        MetricsRegistry { metrics }
    }
}

struct Metric {
    spec: MetricSpec,
    /// Counter: raw u64 count. Gauge: `f64::to_bits`.
    value: AtomicU64,
    /// Histogram: per-bound (non-cumulative) hit counts.
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    /// Histogram sum in fixed-point micro-units (see [`SUM_SCALE`]).
    sum_fp: AtomicU64,
}

/// Log-scale bucket bounds: `start * factor^i` for `i in 0..n`.
pub fn log_buckets(start: f64, factor: f64, n: usize) -> Vec<f64> {
    assert!(start > 0.0 && factor > 1.0 && n > 0);
    let mut v = Vec::with_capacity(n);
    let mut b = start;
    for _ in 0..n {
        v.push(b);
        b *= factor;
    }
    v
}

pub struct MetricsRegistry {
    metrics: Vec<Metric>,
}

impl MetricsRegistry {
    // ---- record path: store/fetch-add only ----

    #[inline]
    pub fn inc(&self, id: CounterId) {
        self.add(id, 1);
    }

    #[inline]
    pub fn add(&self, id: CounterId, n: u64) {
        self.metrics[id.0].value.fetch_add(n, Relaxed);
    }

    #[inline]
    pub fn set_gauge(&self, id: GaugeId, v: f64) {
        self.metrics[id.0].value.store(v.to_bits(), Relaxed);
    }

    #[inline]
    pub fn observe(&self, id: HistId, v: f64) {
        let m = &self.metrics[id.0];
        for (i, b) in m.spec.bounds.iter().enumerate() {
            if v <= *b {
                m.buckets[i].fetch_add(1, Relaxed);
                break;
            }
        }
        // values above the last finite bound land only in +Inf (= count)
        m.count.fetch_add(1, Relaxed);
        let fp = (v * SUM_SCALE).round();
        m.sum_fp.fetch_add(if fp > 0.0 { fp as u64 } else { 0 }, Relaxed);
    }

    // ---- read-side accessors (tests, gauges derived from counters) ----

    pub fn counter_value(&self, id: CounterId) -> u64 {
        self.metrics[id.0].value.load(Relaxed)
    }

    pub fn gauge_value(&self, id: GaugeId) -> f64 {
        f64::from_bits(self.metrics[id.0].value.load(Relaxed))
    }

    pub fn hist_count(&self, id: HistId) -> u64 {
        self.metrics[id.0].count.load(Relaxed)
    }

    pub fn hist_sum(&self, id: HistId) -> f64 {
        self.metrics[id.0].sum_fp.load(Relaxed) as f64 / SUM_SCALE
    }

    // ---- exposition (allocates; route thread only) ----

    pub fn render(&self) -> String {
        let mut out = String::with_capacity(4096);
        let mut headed: Vec<&str> = Vec::new();
        for m in &self.metrics {
            if !headed.contains(&m.spec.name.as_str()) {
                headed.push(&m.spec.name);
                out.push_str(&format!(
                    "# HELP {} {}\n# TYPE {} {}\n",
                    m.spec.name,
                    escape_help(&m.spec.help),
                    m.spec.name,
                    m.spec.kind.as_str()
                ));
            }
            match m.spec.kind {
                Kind::Counter => {
                    let v = m.value.load(Relaxed) as f64 * m.spec.scale;
                    out.push_str(&format!(
                        "{}{} {}\n",
                        m.spec.name,
                        render_labels(&m.spec.labels, None),
                        fmt_value(v)
                    ));
                }
                Kind::Gauge => {
                    let v = f64::from_bits(m.value.load(Relaxed));
                    out.push_str(&format!(
                        "{}{} {}\n",
                        m.spec.name,
                        render_labels(&m.spec.labels, None),
                        fmt_value(v)
                    ));
                }
                Kind::Histogram => {
                    let mut cum = 0u64;
                    for (i, b) in m.spec.bounds.iter().enumerate() {
                        cum += m.buckets[i].load(Relaxed);
                        out.push_str(&format!(
                            "{}_bucket{} {}\n",
                            m.spec.name,
                            render_labels(&m.spec.labels, Some(("le", &fmt_value(*b)))),
                            cum
                        ));
                    }
                    let count = m.count.load(Relaxed);
                    out.push_str(&format!(
                        "{}_bucket{} {}\n",
                        m.spec.name,
                        render_labels(&m.spec.labels, Some(("le", "+Inf"))),
                        count
                    ));
                    out.push_str(&format!(
                        "{}_sum{} {}\n",
                        m.spec.name,
                        render_labels(&m.spec.labels, None),
                        fmt_value(m.sum_fp.load(Relaxed) as f64 / SUM_SCALE)
                    ));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        m.spec.name,
                        render_labels(&m.spec.labels, None),
                        count
                    ));
                }
            }
        }
        out
    }
}

fn fmt_value(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn render_labels(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    if labels.is_empty() && extra.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{}\"", escape_label_value(v)));
    }
    format!("{{{}}}", parts.join(","))
}

/// Escape a HELP text per the exposition format: `\` and newline.
pub fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Escape a label value per the exposition format: `\`, `"`, newline.
pub fn escape_label_value(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

// ---- strict exposition parser (tests + `repro scrape`) ----

#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

#[derive(Debug, Default, Clone)]
pub struct Family {
    pub typ: String,
    pub help: String,
    pub samples: Vec<Sample>,
}

#[derive(Debug, Default)]
pub struct Exposition {
    pub families: BTreeMap<String, Family>,
}

impl Exposition {
    pub fn family(&self, name: &str) -> Option<&Family> {
        self.families.get(name)
    }

    /// Value of the first sample whose full series name matches.
    pub fn value(&self, name: &str) -> Option<f64> {
        self.families.values().flat_map(|f| &f.samples).find(|s| s.name == name).map(|s| s.value)
    }
}

/// Parse and VALIDATE Prometheus text exposition: every sample must
/// belong to a `# TYPE`d family, histogram buckets must be cumulative
/// (monotone nondecreasing in `le` order), the `+Inf` bucket must equal
/// `_count`, and `_sum`/`_count` must be present.
pub fn parse_exposition(text: &str) -> Result<Exposition> {
    let mut exp = Exposition::default();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, help) = rest.split_once(' ').unwrap_or((rest, ""));
            exp.families.entry(name.to_string()).or_default().help = help.to_string();
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, typ) = match rest.split_once(' ') {
                Some(p) => p,
                None => bail!("line {}: malformed TYPE line: {line}", ln + 1),
            };
            ensure!(
                matches!(typ, "counter" | "gauge" | "histogram" | "summary" | "untyped"),
                "line {}: unknown metric type {typ:?}",
                ln + 1
            );
            exp.families.entry(name.to_string()).or_default().typ = typ.to_string();
        } else if let Some(stripped) = line.strip_prefix('#') {
            // other comments are legal and ignored
            let _ = stripped;
        } else {
            let s = parse_sample(line).map_err(|e| anyhow!("line {}: {e}", ln + 1))?;
            let fam = family_of(&exp, &s.name);
            match fam {
                Some(f) => exp
                    .families
                    .get_mut(&f)
                    .expect("family present")
                    .samples
                    .push(s),
                None => bail!("line {}: sample {} has no # TYPE'd family", ln + 1, s.name),
            }
        }
    }
    validate(&exp)?;
    Ok(exp)
}

/// Resolve the family a sample series belongs to, honoring histogram
/// `_bucket`/`_sum`/`_count` suffixes.
fn family_of(exp: &Exposition, series: &str) -> Option<String> {
    if exp.families.get(series).map(|f| !f.typ.is_empty()).unwrap_or(false) {
        return Some(series.to_string());
    }
    for suf in ["_bucket", "_sum", "_count"] {
        if let Some(base) = series.strip_suffix(suf) {
            if exp.families.get(base).map(|f| f.typ == "histogram").unwrap_or(false) {
                return Some(base.to_string());
            }
        }
    }
    None
}

fn parse_sample(line: &str) -> Result<Sample> {
    let (series, labels, rest) = match line.find('{') {
        Some(i) => {
            let close = match line.rfind('}') {
                Some(c) if c > i => c,
                _ => bail!("unclosed label braces: {line}"),
            };
            (&line[..i], parse_labels(&line[i + 1..close])?, line[close + 1..].trim_start())
        }
        None => match line.split_once(' ') {
            Some((n, r)) => (n, Vec::new(), r.trim_start()),
            None => bail!("sample line has no value: {line}"),
        },
    };
    ensure!(!series.is_empty(), "empty metric name: {line}");
    ensure!(
        series.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
        "bad metric name {series:?}"
    );
    let value_str = rest.split_whitespace().next().unwrap_or("");
    let value = match value_str {
        "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        "NaN" => f64::NAN,
        v => match v.parse::<f64>() {
            Ok(x) => x,
            Err(_) => bail!("bad sample value {v:?} in: {line}"),
        },
    };
    Ok(Sample { name: series.to_string(), labels, value })
}

fn parse_labels(body: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut it = body.chars().peekable();
    loop {
        while matches!(it.peek(), Some(',') | Some(' ')) {
            it.next();
        }
        if it.peek().is_none() {
            break;
        }
        let mut key = String::new();
        for c in it.by_ref() {
            if c == '=' {
                break;
            }
            key.push(c);
        }
        ensure!(!key.is_empty(), "empty label key in {{{body}}}");
        ensure!(it.next() == Some('"'), "label {key} value not quoted in {{{body}}}");
        let mut val = String::new();
        let mut closed = false;
        while let Some(c) = it.next() {
            match c {
                '\\' => match it.next() {
                    Some('\\') => val.push('\\'),
                    Some('"') => val.push('"'),
                    Some('n') => val.push('\n'),
                    other => bail!("bad escape \\{:?} in label {key}", other),
                },
                '"' => {
                    closed = true;
                    break;
                }
                c => val.push(c),
            }
        }
        ensure!(closed, "unterminated label value for {key} in {{{body}}}");
        out.push((key, val));
    }
    Ok(out)
}

fn validate(exp: &Exposition) -> Result<()> {
    for (name, fam) in &exp.families {
        ensure!(!fam.typ.is_empty(), "family {name} has samples but no # TYPE");
        if fam.typ != "histogram" {
            continue;
        }
        // group buckets by their non-le label set
        let mut groups: BTreeMap<String, Vec<&Sample>> = BTreeMap::new();
        for s in &fam.samples {
            if s.name == format!("{name}_bucket") {
                let key: Vec<String> = s
                    .labels
                    .iter()
                    .filter(|(k, _)| k != "le")
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect();
                groups.entry(key.join(",")).or_default().push(s);
            }
        }
        ensure!(!groups.is_empty(), "histogram {name} exposes no buckets");
        for (key, buckets) in &groups {
            let mut bounded: Vec<(f64, f64)> = Vec::new();
            let mut inf: Option<f64> = None;
            for b in buckets {
                let le = match b.label("le") {
                    Some(le) => le,
                    None => bail!("histogram {name} bucket without le label"),
                };
                if le == "+Inf" {
                    inf = Some(b.value);
                } else {
                    let bound = match le.parse::<f64>() {
                        Ok(x) => x,
                        Err(_) => bail!("histogram {name}: bad le {le:?}"),
                    };
                    bounded.push((bound, b.value));
                }
            }
            bounded.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite bounds"));
            for w in bounded.windows(2) {
                ensure!(
                    w[0].1 <= w[1].1,
                    "histogram {name}{{{key}}}: buckets not cumulative ({} > {})",
                    w[0].1,
                    w[1].1
                );
            }
            let inf = match inf {
                Some(v) => v,
                None => bail!("histogram {name}{{{key}}} missing +Inf bucket"),
            };
            if let Some(last) = bounded.last() {
                ensure!(
                    last.1 <= inf,
                    "histogram {name}{{{key}}}: last bucket {} exceeds +Inf {}",
                    last.1,
                    inf
                );
            }
            let count = exp
                .families
                .get(name)
                .and_then(|f| f.samples.iter().find(|s| s.name == format!("{name}_count")))
                .map(|s| s.value);
            match count {
                Some(c) => ensure!(
                    (c - inf).abs() < 1e-9,
                    "histogram {name}: +Inf bucket {inf} != _count {c}"
                ),
                None => bail!("histogram {name} missing _count"),
            }
            ensure!(
                fam.samples.iter().any(|s| s.name == format!("{name}_sum")),
                "histogram {name} missing _sum"
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn build_one() -> (MetricsRegistry, CounterId, GaugeId, HistId) {
        let mut b = RegistryBuilder::new();
        let c = b.counter("test_requests_total", "Requests served.");
        let g = b.gauge("test_queue_depth", "Queued requests.");
        let h = b.histogram("test_latency_seconds", "Request latency.", &log_buckets(0.001, 4.0, 6));
        (b.build(), c, g, h)
    }

    #[test]
    fn record_and_read_back() {
        let (r, c, g, h) = build_one();
        r.inc(c);
        r.add(c, 4);
        r.set_gauge(g, 2.5);
        r.observe(h, 0.003);
        r.observe(h, 0.5);
        r.observe(h, 1e9); // beyond last bound: +Inf only
        assert_eq!(r.counter_value(c), 5);
        assert!((r.gauge_value(g) - 2.5).abs() < 1e-12);
        assert_eq!(r.hist_count(h), 3);
        assert!((r.hist_sum(h) - 1e9).abs() / 1e9 < 1e-6);
    }

    #[test]
    fn render_parses_and_buckets_are_cumulative() {
        let (r, c, g, h) = build_one();
        r.add(c, 7);
        r.set_gauge(g, 3.0);
        for v in [0.0005, 0.002, 0.002, 0.1, 2.0, 1e6] {
            r.observe(h, v);
        }
        let text = r.render();
        assert!(text.contains("# TYPE test_latency_seconds histogram"));
        assert!(text.contains("# HELP test_requests_total Requests served."));
        let exp = parse_exposition(&text).expect("rendered exposition must parse");
        assert_eq!(exp.value("test_requests_total"), Some(7.0));
        assert_eq!(exp.value("test_queue_depth"), Some(3.0));
        assert_eq!(exp.value("test_latency_seconds_count"), Some(6.0));
        let fam = exp.family("test_latency_seconds").unwrap();
        let infs: Vec<&Sample> = fam
            .samples
            .iter()
            .filter(|s| s.name == "test_latency_seconds_bucket" && s.label("le") == Some("+Inf"))
            .collect();
        assert_eq!(infs.len(), 1);
        assert_eq!(infs[0].value, 6.0);
        // cumulative monotonicity across finite bounds
        let mut prev = 0.0;
        for s in fam.samples.iter().filter(|s| s.name == "test_latency_seconds_bucket") {
            if s.label("le") != Some("+Inf") {
                assert!(s.value >= prev, "bucket counts must be cumulative");
                prev = s.value;
            }
        }
    }

    #[test]
    fn sum_and_count_are_consistent() {
        let (r, _, _, h) = build_one();
        let vals = [0.001, 0.01, 0.25, 3.0];
        for v in vals {
            r.observe(h, v);
        }
        let exp = parse_exposition(&r.render()).unwrap();
        let sum = exp.value("test_latency_seconds_sum").unwrap();
        let count = exp.value("test_latency_seconds_count").unwrap();
        assert_eq!(count, vals.len() as f64);
        assert!((sum - vals.iter().sum::<f64>()).abs() < 1e-5, "sum {sum}");
    }

    #[test]
    fn label_escaping_roundtrips() {
        let mut b = RegistryBuilder::new();
        let c = b.counter_with(
            "test_labeled_total",
            "Help with a backslash \\ and\nnewline.",
            &[("phase", "ver\"ify\\x\ny")],
        );
        let r = b.build();
        r.add(c, 2);
        let text = r.render();
        assert!(text.contains("# HELP test_labeled_total Help with a backslash \\\\ and\\nnewline."));
        assert!(text.contains("phase=\"ver\\\"ify\\\\x\\ny\""));
        let exp = parse_exposition(&text).expect("escaped labels must parse");
        let s = &exp.family("test_labeled_total").unwrap().samples[0];
        assert_eq!(s.label("phase"), Some("ver\"ify\\x\ny"));
        assert_eq!(s.value, 2.0);
    }

    #[test]
    fn counter_scale_renders_seconds() {
        let mut b = RegistryBuilder::new();
        let c = b.counter_scaled("test_gen_seconds_total", "Generation time.", &[], 1e-9);
        let r = b.build();
        r.add(c, 2_500_000_000); // 2.5 s in ns
        let exp = parse_exposition(&r.render()).unwrap();
        assert!((exp.value("test_gen_seconds_total").unwrap() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn parser_rejects_broken_expositions() {
        // sample without a family
        assert!(parse_exposition("orphan_total 3\n").is_err());
        // non-cumulative buckets
        let bad = "# TYPE h histogram\n\
                   h_bucket{le=\"1\"} 5\nh_bucket{le=\"2\"} 3\nh_bucket{le=\"+Inf\"} 5\n\
                   h_sum 1\nh_count 5\n";
        assert!(parse_exposition(bad).is_err());
        // +Inf != count
        let bad2 = "# TYPE h histogram\n\
                    h_bucket{le=\"1\"} 1\nh_bucket{le=\"+Inf\"} 2\nh_sum 1\nh_count 3\n";
        assert!(parse_exposition(bad2).is_err());
        // missing _sum
        let bad3 = "# TYPE h histogram\nh_bucket{le=\"+Inf\"} 1\nh_count 1\n";
        assert!(parse_exposition(bad3).is_err());
    }

    #[test]
    fn log_buckets_ascend() {
        let b = log_buckets(0.001, 2.0, 10);
        assert_eq!(b.len(), 10);
        assert!(b.windows(2).all(|w| w[0] < w[1]));
        assert!((b[0] - 0.001).abs() < 1e-12);
    }
}
