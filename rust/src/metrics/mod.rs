//! Metrics (S17): per-generation records, aggregate statistics (walltime
//! speedup, τ, n-α), latency percentiles, and the step-phase profiler used
//! by the §Perf pass.
//!
//! Serving-side observability lives in the submodules: [`registry`] is
//! the lock-free counter/gauge/histogram registry behind `GET /metrics`
//! (Prometheus text exposition), [`trace`] is the fixed-capacity round
//! flight recorder behind `GET /trace` and `repro trace`. Both keep
//! their record paths allocation-free so the engines can report every
//! round without breaking the S22 zero-allocation guarantee.

pub mod registry;
pub mod trace;

/// Phase timing breakdown for one generation (nanoseconds).
#[derive(Debug, Default, Clone)]
pub struct Timeline {
    pub prefill_ns: u64,
    pub draft_ns: u64,
    pub verify_ns: u64,
    pub commit_ns: u64,
    pub host_ns: u64, // sampling/mask building/bookkeeping
}

impl Timeline {
    pub fn total_ns(&self) -> u64 {
        self.prefill_ns + self.draft_ns + self.verify_ns + self.commit_ns + self.host_ns
    }
}

/// Result of generating one sequence.
#[derive(Debug, Clone)]
pub struct GenRecord {
    pub prompt_len: usize,
    /// Generated tokens (after the prompt).
    pub tokens: Vec<u32>,
    /// Target-model forward passes (prefill counts as one).
    pub target_passes: usize,
    /// Draft-model forward passes.
    pub draft_passes: usize,
    /// Per-round accepted counts (drafted accepted + bonus), i.e. tokens
    /// committed per target pass after prefill.
    pub round_accepts: Vec<usize>,
    /// Per-round verified draft-tree size (nodes excluding the root) —
    /// constant for static trees, workload-dependent under the dynamic
    /// planner. Empty for non-tree engines.
    pub round_tree_nodes: Vec<usize>,
    /// Per-round selected verify width `t` (the `verify_t{t}` executable
    /// dispatched) — constant `tree_t` without a lowered width family,
    /// request-dependent with one. Empty for engines that predate width
    /// selection (baselines).
    pub round_verify_t: Vec<usize>,
    /// Per-call selected draft-step width `w` (the `step_w{w}`
    /// executable dispatched), one entry per draft step/extend call this
    /// sequence participated in. Empty for non-draft engines.
    pub round_draft_w: Vec<usize>,
    /// Rounds where this sequence's verify executed WIDER than its own
    /// tree's family fit — i.e. the lane was dragged up by a hotter lane
    /// sharing its batch. Always 0 at bs=1 and in width-grouped batches
    /// whose members fit the group width.
    pub dragged_rounds: usize,
    /// Per-round bytes of NEW host round-state capacity (scratch arenas,
    /// staging buffers, tree node storage) acquired during that round.
    /// 0 in steady state — the zero-allocation guarantee of the S22
    /// scratch subsystem; nonzero entries mark warm-up rounds. Batched
    /// lanes record the pool-wide delta (the pool is shared).
    pub round_host_alloc_bytes: Vec<u64>,
    /// Rounds that completed entirely on reused scratch (zero new host
    /// capacity). `scratch_reuse_total == rounds` once warm.
    pub scratch_reuse_total: u64,
    /// Per-round bytes the process ACTUALLY allocated during the round,
    /// measured by the thread-local counting allocator (test-only
    /// `count-alloc` feature; always empty otherwise). Unlike
    /// `round_host_alloc_bytes` — which tracks only the capacities the
    /// scratch subsystem knows about — this catches allocations hiding
    /// anywhere in the host round loop. Device-call staging (PJRT
    /// literal uploads/downloads) is excluded via a scoped pause in the
    /// model wrappers; see `util::count_alloc`.
    pub round_alloc_counted_bytes: Vec<u64>,
    /// n-alpha: [n] -> (accepted, tried) at chain-draft position n+1.
    pub alpha: Vec<(u64, u64)>,
    /// Draft tokens proposed in total (chain mode: gamma per round).
    pub drafted: usize,
    pub wall_ns: u64,
    /// Time from engine entry to the FIRST committed token (prefill +
    /// root sampling) — the engine-side component of TTFT. 0 for
    /// engines that predate the field (baselines).
    pub ttft_ns: u64,
    /// Prefill passes spent reconstructing evicted KV on resume (prefix
    /// re-prefill after a memory-pressure eviction — see
    /// `coordinator/checkpoint.rs`). 0 for fresh and resident-resume
    /// generations; feeds `eagle_resume_refill_rounds_total`.
    pub resume_refill_rounds: u64,
    /// Why generation stopped before `max_new` / EOS, if it did:
    /// `Some("deadline")` when the request's `DeadlineClock` expired
    /// mid-generation and the engine returned the partial text. `None`
    /// for complete generations. Static strings only — setting it never
    /// allocates.
    pub truncated: Option<&'static str>,
    pub timeline: Timeline,
}

impl GenRecord {
    pub fn new(prompt_len: usize) -> GenRecord {
        GenRecord {
            prompt_len,
            tokens: Vec::new(),
            target_passes: 0,
            draft_passes: 0,
            round_accepts: Vec::new(),
            round_tree_nodes: Vec::new(),
            round_verify_t: Vec::new(),
            round_draft_w: Vec::new(),
            dragged_rounds: 0,
            round_host_alloc_bytes: Vec::new(),
            scratch_reuse_total: 0,
            round_alloc_counted_bytes: Vec::new(),
            alpha: vec![(0, 0); 5],
            drafted: 0,
            wall_ns: 0,
            ttft_ns: 0,
            resume_refill_rounds: 0,
            truncated: None,
            timeline: Timeline::default(),
        }
    }

    /// Average acceptance length τ: tokens per target forward pass
    /// (excluding the prefill pass, matching the paper's decode-phase metric).
    pub fn tau(&self) -> f64 {
        if self.round_accepts.is_empty() {
            return 1.0;
        }
        self.round_accepts.iter().sum::<usize>() as f64 / self.round_accepts.len() as f64
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens.len() as f64 / (self.wall_ns as f64 / 1e9)
    }

    /// Mean verified tree size per round (0 when no tree rounds ran).
    pub fn mean_tree_nodes(&self) -> f64 {
        if self.round_tree_nodes.is_empty() {
            return 0.0;
        }
        self.round_tree_nodes.iter().sum::<usize>() as f64 / self.round_tree_nodes.len() as f64
    }

    /// Mean selected verify width per round (0 when no widths recorded).
    pub fn mean_verify_t(&self) -> f64 {
        if self.round_verify_t.is_empty() {
            return 0.0;
        }
        self.round_verify_t.iter().sum::<usize>() as f64 / self.round_verify_t.len() as f64
    }

    /// Mean selected draft-step width per call (0 when none recorded).
    pub fn mean_draft_w(&self) -> f64 {
        if self.round_draft_w.is_empty() {
            return 0.0;
        }
        self.round_draft_w.iter().sum::<usize>() as f64 / self.round_draft_w.len() as f64
    }

    /// Host round-state bytes newly allocated AFTER warm-up (everything
    /// past the first round). 0 is the steady-state guarantee the S22
    /// scratch subsystem is property-tested for.
    pub fn steady_host_alloc_bytes(&self) -> u64 {
        self.round_host_alloc_bytes.iter().skip(1).sum()
    }

    /// Allocator-counted bytes AFTER warm-up — the allocator-level form
    /// of [`GenRecord::steady_host_alloc_bytes`] (0 unless something
    /// outside the tracked scratch allocated; always 0 without the
    /// `count-alloc` feature because the vector stays empty).
    pub fn counted_steady_alloc_bytes(&self) -> u64 {
        self.round_alloc_counted_bytes.iter().skip(1).sum()
    }

    /// Pre-size every per-round vector for a generation of up to
    /// `max_new` tokens so steady-state rounds never grow the record —
    /// metrics bookkeeping is part of the zero-allocation guarantee the
    /// counting allocator asserts. (Draft-width entries can be several
    /// per round — one per draft level/extend call.)
    pub fn reserve_rounds(&mut self, max_new: usize) {
        use crate::spec::scratch::ensure_cap;
        let rounds = max_new.max(1);
        ensure_cap(&mut self.tokens, max_new + 16);
        ensure_cap(&mut self.round_accepts, rounds);
        ensure_cap(&mut self.round_tree_nodes, rounds);
        ensure_cap(&mut self.round_verify_t, rounds);
        ensure_cap(&mut self.round_draft_w, rounds * 12);
        ensure_cap(&mut self.round_host_alloc_bytes, rounds);
        ensure_cap(&mut self.round_alloc_counted_bytes, rounds);
    }
}

/// Aggregate over many generations.
#[derive(Debug, Default, Clone)]
pub struct Aggregate {
    pub n: usize,
    pub tokens: usize,
    pub wall_ns: u64,
    pub target_passes: usize,
    pub draft_passes: usize,
    pub round_accepts_sum: usize,
    pub rounds: usize,
    pub tree_nodes_sum: usize,
    pub tree_rounds: usize,
    pub verify_t_sum: usize,
    pub verify_t_rounds: usize,
    pub draft_w_sum: usize,
    pub draft_w_calls: usize,
    pub dragged_rounds: usize,
    pub host_alloc_bytes: u64,
    pub scratch_reuse_total: u64,
    /// Allocator-counted bytes across all rounds (`count-alloc` only).
    pub alloc_counted_bytes: u64,
    pub alpha: Vec<(u64, u64)>,
    pub wall_each: Vec<u64>,
    /// `wall_each` maintained in sorted order (binary-insert on `add`),
    /// so percentile queries are O(1) lookups instead of the old
    /// clone-and-sort-per-call.
    pub wall_sorted: Vec<u64>,
    pub timeline: Timeline,
}

impl Aggregate {
    pub fn new() -> Aggregate {
        Aggregate { alpha: vec![(0, 0); 5], ..Default::default() }
    }

    pub fn add(&mut self, r: &GenRecord) {
        self.n += 1;
        self.tokens += r.tokens.len();
        self.wall_ns += r.wall_ns;
        self.target_passes += r.target_passes;
        self.draft_passes += r.draft_passes;
        self.round_accepts_sum += r.round_accepts.iter().sum::<usize>();
        self.rounds += r.round_accepts.len();
        self.tree_nodes_sum += r.round_tree_nodes.iter().sum::<usize>();
        self.tree_rounds += r.round_tree_nodes.len();
        self.verify_t_sum += r.round_verify_t.iter().sum::<usize>();
        self.verify_t_rounds += r.round_verify_t.len();
        self.draft_w_sum += r.round_draft_w.iter().sum::<usize>();
        self.draft_w_calls += r.round_draft_w.len();
        self.dragged_rounds += r.dragged_rounds;
        self.host_alloc_bytes += r.round_host_alloc_bytes.iter().sum::<u64>();
        self.scratch_reuse_total += r.scratch_reuse_total;
        self.alloc_counted_bytes += r.round_alloc_counted_bytes.iter().sum::<u64>();
        for (i, &(a, t)) in r.alpha.iter().enumerate() {
            self.alpha[i].0 += a;
            self.alpha[i].1 += t;
        }
        self.wall_each.push(r.wall_ns);
        let pos = self.wall_sorted.partition_point(|&w| w <= r.wall_ns);
        self.wall_sorted.insert(pos, r.wall_ns);
        let tl = &r.timeline;
        self.timeline.prefill_ns += tl.prefill_ns;
        self.timeline.draft_ns += tl.draft_ns;
        self.timeline.verify_ns += tl.verify_ns;
        self.timeline.commit_ns += tl.commit_ns;
        self.timeline.host_ns += tl.host_ns;
    }

    pub fn tau(&self) -> f64 {
        if self.rounds == 0 {
            return 1.0;
        }
        self.round_accepts_sum as f64 / self.rounds as f64
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / (self.wall_ns as f64 / 1e9)
    }

    /// Mean verified tree size per round across all generations.
    pub fn mean_tree_nodes(&self) -> f64 {
        if self.tree_rounds == 0 {
            return 0.0;
        }
        self.tree_nodes_sum as f64 / self.tree_rounds as f64
    }

    /// Mean selected verify width per round across all generations.
    pub fn mean_verify_t(&self) -> f64 {
        if self.verify_t_rounds == 0 {
            return 0.0;
        }
        self.verify_t_sum as f64 / self.verify_t_rounds as f64
    }

    /// Mean selected draft-step width per call across all generations.
    pub fn mean_draft_w(&self) -> f64 {
        if self.draft_w_calls == 0 {
            return 0.0;
        }
        self.draft_w_sum as f64 / self.draft_w_calls as f64
    }

    /// n-alpha acceptance rates, None when that depth was never tried.
    pub fn alphas(&self) -> Vec<Option<f64>> {
        self.alpha
            .iter()
            .map(|&(a, t)| if t == 0 { None } else { Some(a as f64 / t as f64) })
            .collect()
    }

    /// Wall-clock latency percentile in milliseconds, answered from the
    /// sorted cache maintained by [`Aggregate::add`] — no clone, no
    /// re-sort per query.
    pub fn latency_percentile(&self, pct: f64) -> f64 {
        if self.wall_sorted.is_empty() {
            return 0.0;
        }
        let idx = ((self.wall_sorted.len() - 1) as f64 * pct / 100.0).round() as usize;
        self.wall_sorted[idx] as f64 / 1e6
    }

    pub fn latency_p50_ms(&self) -> f64 {
        self.latency_percentile(50.0)
    }

    pub fn latency_p90_ms(&self) -> f64 {
        self.latency_percentile(90.0)
    }

    pub fn latency_p99_ms(&self) -> f64 {
        self.latency_percentile(99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_counts_tokens_per_pass() {
        let mut r = GenRecord::new(4);
        r.round_accepts = vec![3, 4, 2];
        assert!((r.tau() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn aggregate_accumulates() {
        let mut a = Aggregate::new();
        let mut r = GenRecord::new(4);
        r.tokens = vec![1, 2, 3];
        r.wall_ns = 3_000_000_000;
        r.round_accepts = vec![3];
        r.alpha[0] = (2, 3);
        a.add(&r);
        a.add(&r);
        assert_eq!(a.tokens, 6);
        assert!((a.tokens_per_sec() - 1.0).abs() < 1e-9);
        assert_eq!(a.alphas()[0], Some(2.0 / 3.0));
        assert_eq!(a.alphas()[4], None);
    }

    #[test]
    fn tree_node_means() {
        let mut r = GenRecord::new(1);
        r.round_tree_nodes = vec![25, 15, 20];
        assert!((r.mean_tree_nodes() - 20.0).abs() < 1e-9);
        let mut a = Aggregate::new();
        a.add(&r);
        a.add(&r);
        assert!((a.mean_tree_nodes() - 20.0).abs() < 1e-9);
        assert_eq!(Aggregate::new().mean_tree_nodes(), 0.0);
        assert_eq!(GenRecord::new(1).mean_tree_nodes(), 0.0);
    }

    #[test]
    fn verify_width_means() {
        let mut r = GenRecord::new(1);
        r.round_verify_t = vec![32, 8, 8];
        assert!((r.mean_verify_t() - 16.0).abs() < 1e-9);
        let mut a = Aggregate::new();
        a.add(&r);
        a.add(&r);
        assert!((a.mean_verify_t() - 16.0).abs() < 1e-9);
        assert_eq!(Aggregate::new().mean_verify_t(), 0.0);
        assert_eq!(GenRecord::new(1).mean_verify_t(), 0.0);
    }

    #[test]
    fn draft_width_means_and_drag_counts() {
        let mut r = GenRecord::new(1);
        r.round_draft_w = vec![8, 4, 4, 8];
        r.dragged_rounds = 3;
        assert!((r.mean_draft_w() - 6.0).abs() < 1e-9);
        let mut a = Aggregate::new();
        a.add(&r);
        a.add(&r);
        assert!((a.mean_draft_w() - 6.0).abs() < 1e-9);
        assert_eq!(a.dragged_rounds, 6);
        assert_eq!(Aggregate::new().mean_draft_w(), 0.0);
        assert_eq!(GenRecord::new(1).mean_draft_w(), 0.0);
    }

    #[test]
    fn host_alloc_accounting() {
        let mut r = GenRecord::new(1);
        r.round_host_alloc_bytes = vec![4096, 0, 0, 0];
        r.scratch_reuse_total = 3;
        assert_eq!(r.steady_host_alloc_bytes(), 0, "warm-up round excluded");
        r.round_host_alloc_bytes.push(128);
        assert_eq!(r.steady_host_alloc_bytes(), 128);
        let mut a = Aggregate::new();
        a.add(&r);
        a.add(&r);
        assert_eq!(a.host_alloc_bytes, 2 * (4096 + 128));
        assert_eq!(a.scratch_reuse_total, 6);
        assert_eq!(GenRecord::new(1).steady_host_alloc_bytes(), 0);
    }

    #[test]
    fn counted_alloc_accounting_and_round_reserve() {
        let mut r = GenRecord::new(1);
        r.round_alloc_counted_bytes = vec![512, 0, 0];
        assert_eq!(r.counted_steady_alloc_bytes(), 0, "warm-up round excluded");
        r.round_alloc_counted_bytes.push(32);
        assert_eq!(r.counted_steady_alloc_bytes(), 32);
        let mut a = Aggregate::new();
        a.add(&r);
        a.add(&r);
        assert_eq!(a.alloc_counted_bytes, 2 * (512 + 32));
        assert_eq!(GenRecord::new(1).counted_steady_alloc_bytes(), 0, "empty without feature");
        // reserving twice is idempotent and never shrinks
        let mut r = GenRecord::new(1);
        r.reserve_rounds(64);
        let caps = (r.tokens.capacity(), r.round_accepts.capacity(), r.round_draft_w.capacity());
        assert!(caps.0 >= 64 && caps.1 >= 64 && caps.2 >= 64);
        r.reserve_rounds(8);
        assert_eq!(
            (r.tokens.capacity(), r.round_accepts.capacity(), r.round_draft_w.capacity()),
            caps
        );
    }

    #[test]
    fn percentiles_sorted() {
        let mut a = Aggregate::new();
        for ns in [1_000_000u64, 2_000_000, 10_000_000] {
            let mut r = GenRecord::new(1);
            r.wall_ns = ns;
            a.add(&r);
        }
        assert!((a.latency_percentile(0.0) - 1.0).abs() < 1e-6);
        assert!((a.latency_percentile(100.0) - 10.0).abs() < 1e-6);
    }

    #[test]
    fn percentile_cache_matches_reference_sort() {
        let mut a = Aggregate::new();
        // deliberately unsorted arrivals, with duplicates
        for ns in [7u64, 1, 9, 3, 3, 8, 2, 6, 5, 4] {
            let mut r = GenRecord::new(1);
            r.wall_ns = ns * 1_000_000;
            a.add(&r);
        }
        let mut reference = a.wall_each.clone();
        reference.sort_unstable();
        assert_eq!(a.wall_sorted, reference, "sorted cache must track add()");
        for pct in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            let idx = ((reference.len() - 1) as f64 * pct / 100.0).round() as usize;
            let want = reference[idx] as f64 / 1e6;
            assert!((a.latency_percentile(pct) - want).abs() < 1e-9, "pct {pct}");
        }
        assert!((a.latency_p50_ms() - 5.0).abs() < 1e-9);
        assert!((a.latency_p90_ms() - 8.0).abs() < 1e-9);
        assert!((a.latency_p99_ms() - 9.0).abs() < 1e-9);
        assert_eq!(Aggregate::new().latency_p99_ms(), 0.0);
    }
}
