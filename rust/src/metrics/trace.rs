//! Round-level flight recorder: a fixed-capacity, lock-free ring buffer
//! of structured [`RoundEvent`]s fed by the [`RoundObserver`] hook the
//! engines call once per speculation round.
//!
//! The ring is pre-sized at startup (capacity rounded up to a power of
//! two) and every slot field is an `AtomicU64`, so recording an event
//! is one `fetch_add` to claim a slot plus eleven relaxed/release stores
//! — no locks, no heap traffic — which keeps the observer inside the S22
//! zero-allocation round guarantee (asserted under `count-alloc` in
//! `rust/tests/count_alloc.rs`). The HTTP route thread snapshots the
//! ring for `GET /trace` with [`FlightRecorder::to_json`].
//!
//! Each slot carries a seqlock-style generation word so a reader racing
//! the single writer never surfaces a half-written event: the writer
//! bumps the generation to odd before its data stores and to even after
//! (release-fenced), and the reader accepts a slot only when it sees the
//! same even generation on both sides of its data loads (acquire-
//! fenced). A slot that stays torn across a few retries — the writer is
//! mid-store right now — is skipped and counted
//! ([`FlightRecorder::torn_skipped`]) rather than served. Generation 0
//! means never written, so pre-warm slots are invisible too.
//!
//! `repro trace` fetches that JSON from a running server and prints the
//! per-lane round summary produced by [`summarize`].

use std::sync::atomic::{
    fence, AtomicU64,
    Ordering::{Acquire, Relaxed, Release},
};

use crate::util::json::Json;

/// One speculation round as seen by the engines: identity (lane,
/// round), tree shape (nodes, verify_t, draft_w), outcome (accepted
/// tokens), and cost (per-phase nanoseconds, host-alloc bytes).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RoundEvent {
    /// KV lane (batch slot); 0 for bs=1 engines.
    pub lane: u32,
    /// Round index within the generation, starting at 0.
    pub round: u32,
    /// Draft-tree nodes proposed this round (root excluded).
    pub tree_nodes: u32,
    /// Verify-family width the round dispatched at.
    pub verify_t: u32,
    /// Draft-step width of the chain-extend (0 when the round ended
    /// the generation and no extend ran).
    pub draft_w: u32,
    /// Tokens committed by the acceptance walk (bonus token included).
    pub accepted: u32,
    /// Draft-model time attributed to this round.
    pub draft_ns: u64,
    /// Target verify time attributed to this round.
    pub verify_ns: u64,
    /// Host-side round-loop time attributed to this round.
    pub host_ns: u64,
    /// Scratch capacity growth this round (0 once warm).
    pub alloc_bytes: u64,
}

const FIELDS: usize = 10;

impl RoundEvent {
    fn pack(&self) -> [u64; FIELDS] {
        [
            self.lane as u64,
            self.round as u64,
            self.tree_nodes as u64,
            self.verify_t as u64,
            self.draft_w as u64,
            self.accepted as u64,
            self.draft_ns,
            self.verify_ns,
            self.host_ns,
            self.alloc_bytes,
        ]
    }

    fn unpack(f: [u64; FIELDS]) -> RoundEvent {
        RoundEvent {
            lane: f[0] as u32,
            round: f[1] as u32,
            tree_nodes: f[2] as u32,
            verify_t: f[3] as u32,
            draft_w: f[4] as u32,
            accepted: f[5] as u32,
            draft_ns: f[6],
            verify_ns: f[7],
            host_ns: f[8],
            alloc_bytes: f[9],
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lane", Json::Num(self.lane as f64)),
            ("round", Json::Num(self.round as f64)),
            ("tree_nodes", Json::Num(self.tree_nodes as f64)),
            ("verify_t", Json::Num(self.verify_t as f64)),
            ("draft_w", Json::Num(self.draft_w as f64)),
            ("accepted", Json::Num(self.accepted as f64)),
            ("draft_ns", Json::Num(self.draft_ns as f64)),
            ("verify_ns", Json::Num(self.verify_ns as f64)),
            ("host_ns", Json::Num(self.host_ns as f64)),
            ("alloc_bytes", Json::Num(self.alloc_bytes as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<RoundEvent> {
        let u32f = |k: &str| j.get(k).and_then(|v| v.as_f64()).map(|v| v as u32);
        let u64f = |k: &str| j.get(k).and_then(|v| v.as_f64()).map(|v| v as u64);
        Some(RoundEvent {
            lane: u32f("lane")?,
            round: u32f("round")?,
            tree_nodes: u32f("tree_nodes")?,
            verify_t: u32f("verify_t")?,
            draft_w: u32f("draft_w")?,
            accepted: u32f("accepted")?,
            draft_ns: u64f("draft_ns")?,
            verify_ns: u64f("verify_ns")?,
            host_ns: u64f("host_ns")?,
            alloc_bytes: u64f("alloc_bytes")?,
        })
    }
}

/// Hook the engines call once per completed speculation round. `&self`
/// because the implementor is shared (worker thread records, route
/// threads read); implementations MUST NOT allocate — they run inside
/// the zero-alloc round loop.
pub trait RoundObserver: Sync {
    fn on_round(&self, ev: &RoundEvent);
}

struct Slot {
    /// Seqlock generation: 0 = never written, odd = write in progress,
    /// even = stable. Single writer, so plain loads/stores suffice on
    /// the writer side.
    seq: AtomicU64,
    f: [AtomicU64; FIELDS],
}

impl Slot {
    fn empty() -> Slot {
        Slot { seq: AtomicU64::new(0), f: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    /// Seqlock read: accept only a stable generation observed unchanged
    /// across the data loads. `None` = never written, or still torn
    /// after a few retries (writer mid-store).
    fn read(&self) -> Option<RoundEvent> {
        for _ in 0..4 {
            let s1 = self.seq.load(Acquire);
            if s1 == 0 {
                return None;
            }
            if s1 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let f: [u64; FIELDS] = std::array::from_fn(|i| self.f[i].load(Relaxed));
            fence(Acquire);
            if self.seq.load(Relaxed) == s1 {
                return Some(RoundEvent::unpack(f));
            }
        }
        None
    }
}

/// Fixed-capacity ring of the most recent [`RoundEvent`]s (see module
/// doc for the concurrency contract).
pub struct FlightRecorder {
    mask: u64,
    head: AtomicU64,
    slots: Box<[Slot]>,
    /// Snapshot reads that skipped a slot still torn after retries.
    torn: AtomicU64,
}

impl FlightRecorder {
    /// Pre-size the ring; `capacity` is rounded up to a power of two
    /// (minimum 8). All allocation happens here, never in `record`.
    pub fn new(capacity: usize) -> FlightRecorder {
        let cap = capacity.max(8).next_power_of_two();
        FlightRecorder {
            mask: (cap - 1) as u64,
            head: AtomicU64::new(0),
            slots: (0..cap).map(|_| Slot::empty()).collect(),
            torn: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever recorded (monotonic; may exceed capacity).
    pub fn recorded(&self) -> u64 {
        self.head.load(Relaxed)
    }

    /// Record one event: claim a slot, bump its seqlock generation to
    /// odd, store ten words, close the generation. Allocation-free.
    #[inline]
    pub fn record(&self, ev: &RoundEvent) {
        let slot = &self.slots[(self.head.fetch_add(1, Relaxed) & self.mask) as usize];
        let s = slot.seq.load(Relaxed); // single writer: plain read-modify
        slot.seq.store(s.wrapping_add(1), Relaxed);
        fence(Release);
        for (dst, src) in slot.f.iter().zip(ev.pack()) {
            dst.store(src, Relaxed);
        }
        slot.seq.store(s.wrapping_add(2), Release);
    }

    /// Snapshot reads that skipped a torn slot (monotonic).
    pub fn torn_skipped(&self) -> u64 {
        self.torn.load(Relaxed)
    }

    /// Snapshot the retained events, oldest first, skipping any slot the
    /// writer holds torn at read time (allocates; dump path only).
    pub fn events(&self) -> Vec<RoundEvent> {
        let head = self.head.load(Relaxed);
        let cap = self.slots.len() as u64;
        let n = head.min(cap);
        let mut out = Vec::with_capacity(n as usize);
        for k in (head - n)..head {
            match self.slots[(k & self.mask) as usize].read() {
                Some(ev) => out.push(ev),
                None => {
                    self.torn.fetch_add(1, Relaxed);
                }
            }
        }
        out
    }

    /// The `GET /trace` payload: capacity, total recorded, torn-skip
    /// count, retained events oldest-first.
    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self.events().iter().map(|e| e.to_json()).collect();
        Json::obj(vec![
            ("capacity", Json::Num(self.capacity() as f64)),
            ("recorded", Json::Num(self.recorded() as f64)),
            ("torn_skipped", Json::Num(self.torn_skipped() as f64)),
            ("events", Json::Arr(events)),
        ])
    }
}

impl RoundObserver for FlightRecorder {
    #[inline]
    fn on_round(&self, ev: &RoundEvent) {
        self.record(ev);
    }
}

/// Parse a `GET /trace` payload back into events (accepts either the
/// full object or a bare array).
pub fn events_from_json(j: &Json) -> Vec<RoundEvent> {
    let arr = j.get("events").and_then(|e| e.as_arr()).or_else(|| j.as_arr());
    arr.map(|a| a.iter().filter_map(RoundEvent::from_json).collect()).unwrap_or_default()
}

/// Human-readable per-lane summary of a trace dump (used by
/// `repro trace`).
pub fn summarize(events: &[RoundEvent]) -> String {
    if events.is_empty() {
        return "trace: no rounds recorded\n".to_string();
    }
    let mut lanes: Vec<u32> = events.iter().map(|e| e.lane).collect();
    lanes.sort_unstable();
    lanes.dedup();
    let mut out = String::new();
    let (mut d, mut v, mut h) = (0u64, 0u64, 0u64);
    for e in events {
        d += e.draft_ns;
        v += e.verify_ns;
        h += e.host_ns;
    }
    let total = (d + v + h).max(1);
    out.push_str(&format!(
        "trace: {} rounds over {} lane(s)\n  phase split: draft {:.1} ms ({:.0}%) | verify {:.1} ms ({:.0}%) | host {:.1} ms ({:.0}%)\n",
        events.len(),
        lanes.len(),
        d as f64 / 1e6,
        100.0 * d as f64 / total as f64,
        v as f64 / 1e6,
        100.0 * v as f64 / total as f64,
        h as f64 / 1e6,
        100.0 * h as f64 / total as f64,
    ));
    out.push_str("  lane | rounds |    tau | nodes | ver_t | drf_w | alloc rounds\n");
    for lane in lanes {
        let evs: Vec<&RoundEvent> = events.iter().filter(|e| e.lane == lane).collect();
        let n = evs.len() as f64;
        let tau = evs.iter().map(|e| e.accepted as f64).sum::<f64>() / n;
        let nodes = evs.iter().map(|e| e.tree_nodes as f64).sum::<f64>() / n;
        let vt = evs.iter().map(|e| e.verify_t as f64).sum::<f64>() / n;
        let wrows: Vec<f64> =
            evs.iter().filter(|e| e.draft_w > 0).map(|e| e.draft_w as f64).collect();
        let dw = if wrows.is_empty() { 0.0 } else { wrows.iter().sum::<f64>() / wrows.len() as f64 };
        let allocs = evs.iter().filter(|e| e.alloc_bytes > 0).count();
        out.push_str(&format!(
            "  {lane:4} | {:6} | {tau:6.2} | {nodes:5.1} | {vt:5.1} | {dw:5.1} | {allocs:12}\n",
            evs.len()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(lane: u32, round: u32) -> RoundEvent {
        RoundEvent {
            lane,
            round,
            tree_nodes: 25,
            verify_t: 26,
            draft_w: 10,
            accepted: 4,
            draft_ns: 1_000_000,
            verify_ns: 3_000_000,
            host_ns: 500_000,
            alloc_bytes: 0,
        }
    }

    #[test]
    fn ring_retains_newest_in_order() {
        let r = FlightRecorder::new(8);
        assert_eq!(r.capacity(), 8);
        for i in 0..13 {
            r.record(&ev(0, i));
        }
        assert_eq!(r.recorded(), 13);
        let evs = r.events();
        assert_eq!(evs.len(), 8);
        let rounds: Vec<u32> = evs.iter().map(|e| e.round).collect();
        assert_eq!(rounds, (5..13).collect::<Vec<u32>>(), "oldest-first window");
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(FlightRecorder::new(0).capacity(), 8);
        assert_eq!(FlightRecorder::new(9).capacity(), 16);
        assert_eq!(FlightRecorder::new(1024).capacity(), 1024);
    }

    #[test]
    fn json_roundtrip() {
        let r = FlightRecorder::new(8);
        r.record(&ev(1, 0));
        r.record(&ev(2, 1));
        let j = r.to_json();
        assert_eq!(j.get("recorded").and_then(|v| v.as_usize()), Some(2));
        let back = events_from_json(&j);
        assert_eq!(back, vec![ev(1, 0), ev(2, 1)]);
        // also parses from serialized text
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(events_from_json(&parsed), back);
    }

    #[test]
    fn torn_slot_is_skipped_not_served() {
        let r = FlightRecorder::new(8);
        for i in 0..3 {
            r.record(&ev(0, i));
        }
        // simulate the writer parked mid-store in slot 1: odd generation
        let s = r.slots[1].seq.load(Relaxed);
        r.slots[1].seq.store(s | 1, Relaxed);
        let evs = r.events();
        assert_eq!(evs.len(), 2, "torn slot must not be served");
        assert_eq!(evs.iter().map(|e| e.round).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(r.torn_skipped(), 1);
        // restore: an even generation is served again
        r.slots[1].seq.store(s, Relaxed);
        assert_eq!(r.events().len(), 3);
    }

    #[test]
    fn concurrent_snapshots_see_only_whole_events() {
        // hammer the ring from a writer while snapshotting: every event
        // served must be internally consistent (all fields derived from
        // the same round), proving no torn read escapes
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;
        let r = Arc::new(FlightRecorder::new(8));
        let stop = Arc::new(AtomicBool::new(false));
        let (r2, stop2) = (r.clone(), stop.clone());
        let writer = std::thread::spawn(move || {
            let mut i: u32 = 0;
            while !stop2.load(Relaxed) {
                r2.record(&RoundEvent {
                    lane: i,
                    round: i,
                    tree_nodes: i,
                    verify_t: i,
                    draft_w: i,
                    accepted: i,
                    draft_ns: i as u64,
                    verify_ns: i as u64,
                    host_ns: i as u64,
                    alloc_bytes: i as u64,
                });
                i = i.wrapping_add(1);
            }
        });
        for _ in 0..200 {
            for e in r.events() {
                assert!(
                    e.round == e.lane
                        && e.round == e.tree_nodes
                        && e.round as u64 == e.verify_ns
                        && e.round as u64 == e.alloc_bytes,
                    "torn event escaped the seqlock: {e:?}"
                );
            }
        }
        stop.store(true, Relaxed);
        writer.join().unwrap();
    }

    #[test]
    fn observer_records_through_trait() {
        let r = FlightRecorder::new(8);
        let obs: &dyn RoundObserver = &r;
        obs.on_round(&ev(0, 0));
        assert_eq!(r.recorded(), 1);
    }

    #[test]
    fn summary_reports_lanes_and_tau() {
        let mut events = Vec::new();
        for round in 0..4 {
            events.push(ev(0, round));
            events.push(ev(1, round));
        }
        let s = summarize(&events);
        assert!(s.contains("8 rounds over 2 lane(s)"), "{s}");
        assert!(s.contains("4.00"), "tau column missing: {s}");
        assert!(summarize(&[]).contains("no rounds"));
    }
}
