//! Serving coordinator (S15): request types, thread-safe queue, KV-slot
//! allocator, scheduler, and the batched EAGLE engine (Table 7).
//!
//! The HTTP server (S16) feeds [`RequestQueue`]; a worker drains it via
//! the [`Scheduler`] admission policy. Latency-path requests run on the
//! bs=1 engines (the paper's primary setting); the batched engine
//! demonstrates the throughput regime offline and in `examples/`.

pub mod batch_engine;
pub mod kvslots;
pub mod queue;
pub mod request;
pub mod scheduler;

pub use batch_engine::BatchEagleEngine;
pub use kvslots::SlotAllocator;
pub use queue::RequestQueue;
pub use request::{Method, Request, Response, TreeChoice};
pub use scheduler::Scheduler;
