//! Serving coordinator (S15): request types, thread-safe queue, KV-slot
//! allocator, scheduler, and the batched EAGLE engine (Table 7).
//!
//! The HTTP server (S16) feeds [`RequestQueue`]; a worker drains it via
//! the [`Scheduler`] admission policy — per-request FCFS, or (with
//! `--width-grouping`) width-aware sub-batches where lanes are grouped
//! by their predicted verify width so a low-acceptance request never
//! executes at a hot lane's width (see `scheduler::plan_width_groups`
//! and the per-group fits in [`BatchEagleEngine`]).

pub mod batch_engine;
pub mod checkpoint;
pub mod costfit;
pub mod kvslots;
pub mod queue;
pub mod request;
pub mod scheduler;

pub use batch_engine::{BatchEagleEngine, LaneInput, LaneOutcome};
pub use checkpoint::{CheckpointStore, LaneCheckpoint, PreemptSignal};
pub use costfit::{load_committed_capacity, OnlineCostModel};
pub use kvslots::SlotAllocator;
pub use queue::RequestQueue;
pub use request::{Method, Request, Response, TreeChoice};
pub use scheduler::{
    group_cost, plan_width_groups, plan_width_groups_with, verify_curve_points, AdmissionPolicy,
    AdmittedGroup, CostModel, Scheduler, WidthGroup,
};
