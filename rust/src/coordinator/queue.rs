//! Thread-safe bounded request queue with condvar wakeups and
//! backpressure (reject-on-full), feeding the scheduler.
//!
//! Admission order is switchable at runtime (`POST /admin/sched`):
//! **FCFS** (arrival order, the default) or **EDF** — earliest effective
//! deadline first, where a request's effective deadline is
//! `min(deadline expiry, arrival + aging bound)`. The aging bound makes
//! starvation impossible: an unbounded- or loose-deadline request
//! behaves like one due `aging` after arrival, so a stream of tight
//! fresh arrivals can outrank it for at most the aging window.
//! Ties break by arrival sequence, so EDF degrades to exactly FCFS when
//! deadlines are equal or absent (a constant aging bound preserves
//! arrival order among unbounded requests).
//!
//! The EDF view is a lazily-deleted binary-heap index over the same
//! arrival-ordered entry map the FCFS path pops from — both orders read
//! one ground-truth set, so flipping the order mid-stream never loses or
//! duplicates a request. Heap entries whose sequence number is gone from
//! the map (popped by the FCFS path) are skipped on sight and compacted
//! away when they outnumber the live set.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::Request;

/// Default EDF aging bound in milliseconds: the longest an unbounded- or
/// loose-deadline request can be outranked by tighter arrivals before it
/// reaches the front of the deadline order.
pub const DEFAULT_AGING_MS: u64 = 5_000;

struct Entry {
    r: Request,
    /// Effective EDF key: `min(deadline expiry, arrival + aging)`.
    key: Instant,
    /// Real deadline expiry under the queue's default budget (`None` =
    /// unbounded) — what the scheduler's linger cap looks at.
    deadline: Option<Instant>,
    /// Whether the aging bound (not a real deadline) set `key`.
    aged: bool,
}

struct Inner {
    /// Arrival-ordered entries keyed by admission sequence: the FCFS
    /// view (`pop_first`) and the ground truth the heap indexes.
    entries: BTreeMap<u64, Entry>,
    /// EDF index: min-heap of (effective deadline, arrival seq), lazily
    /// deleted — a popped seq missing from `entries` is stale.
    heap: BinaryHeap<Reverse<(Instant, u64)>>,
    next_seq: u64,
    closed: bool,
}

impl Inner {
    fn rebuild_heap(&mut self) {
        self.heap.clear();
        self.heap.extend(self.entries.iter().map(|(&seq, e)| Reverse((e.key, seq))));
    }
}

#[derive(Debug, PartialEq)]
pub enum PushError {
    Full,
    Closed,
}

pub struct RequestQueue {
    inner: Mutex<Inner>,
    notify: Condvar,
    pub capacity: usize,
    /// Admission order: EDF when set, FCFS otherwise. Runtime-togglable
    /// (`set_edf_enabled`) so a live A/B never needs a restart.
    edf_enabled: AtomicBool,
    /// EDF aging bound (starvation ceiling for unbounded requests).
    aging: Duration,
    /// Server default deadline applied when a request carries none
    /// (mirrors `--default-deadline-ms`; 0 = unbounded).
    default_deadline_ms: u64,
    /// Pops whose EDF key came from the aging bound, not a real
    /// deadline (mirrored to `eagle_edf_aged_pops_total`).
    aged_pops: AtomicU64,
    /// EDF pops that deviated from arrival order (mirrored to
    /// `eagle_edf_reordered_pops_total`). 0 under pure FCFS traffic.
    reordered_pops: AtomicU64,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner {
                entries: BTreeMap::new(),
                heap: BinaryHeap::new(),
                next_seq: 0,
                closed: false,
            }),
            notify: Condvar::new(),
            capacity,
            edf_enabled: AtomicBool::new(false),
            aging: Duration::from_millis(DEFAULT_AGING_MS),
            default_deadline_ms: 0,
            aged_pops: AtomicU64::new(0),
            reordered_pops: AtomicU64::new(0),
        }
    }

    /// Start in EDF (builder-style; `repro serve --edf`).
    pub fn with_edf(self, edf: bool) -> RequestQueue {
        self.edf_enabled.store(edf, Ordering::Relaxed);
        self
    }

    /// Set the EDF aging bound (builder-style).
    pub fn with_aging_ms(mut self, ms: u64) -> RequestQueue {
        self.aging = Duration::from_millis(ms.max(1));
        self
    }

    /// Set the default deadline the EDF key derives from when a request
    /// carries no explicit budget (builder-style).
    pub fn with_deadline_default(mut self, ms: u64) -> RequestQueue {
        self.default_deadline_ms = ms;
        self
    }

    /// Flip the admission order at runtime (`POST /admin/sched`).
    pub fn set_edf_enabled(&self, edf: bool) {
        self.edf_enabled.store(edf, Ordering::Relaxed);
    }

    pub fn edf_enabled(&self) -> bool {
        self.edf_enabled.load(Ordering::Relaxed)
    }

    /// Lifetime count of pops ordered by the aging bound (EDF only).
    pub fn aged_pops(&self) -> u64 {
        self.aged_pops.load(Ordering::Relaxed)
    }

    /// Lifetime count of pops that deviated from arrival order.
    pub fn reordered_pops(&self) -> u64 {
        self.reordered_pops.load(Ordering::Relaxed)
    }

    /// Non-blocking push; `Full` signals backpressure to the server (429).
    pub fn push(&self, r: Request) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed);
        }
        if g.entries.len() >= self.capacity {
            return Err(PushError::Full);
        }
        self.push_locked(&mut g, r);
        Ok(())
    }

    /// Re-admit a suspended lane as a resumable entry. Unlike [`push`],
    /// this bypasses both the capacity bound and `closed`: a resume is
    /// not new work — its admission was already paid for, and rejecting
    /// it (queue momentarily full, or a drain racing the suspension)
    /// would strand a half-served lane. The entry keeps the request's
    /// original arrival and deadline, so under EDF it re-sorts by its
    /// real urgency and under FCFS the aging bound keeps it from
    /// starving behind fresh arrivals.
    ///
    /// [`push`]: RequestQueue::push
    pub fn push_resume(&self, mut r: Request) {
        r.resume = true;
        let mut g = self.inner.lock().unwrap();
        self.push_locked(&mut g, r);
    }

    fn push_locked(&self, g: &mut Inner, r: Request) {
        let deadline = r.deadline(self.default_deadline_ms).instant();
        let aging_bound = r.arrival + self.aging;
        let (key, aged) = match deadline {
            Some(at) if at <= aging_bound => (at, false),
            _ => (aging_bound, true),
        };
        let seq = g.next_seq;
        g.next_seq += 1;
        g.heap.push(Reverse((key, seq)));
        g.entries.insert(seq, Entry { r, key, deadline, aged });
        // compact stale heap entries left by FCFS pops before they can
        // dominate the index (bounded: heap size stays O(live set))
        if g.heap.len() > g.entries.len() * 2 + 64 {
            g.rebuild_heap();
        }
        self.notify.notify_one();
    }

    /// Remove and return the next request in the configured order.
    /// Caller holds the lock.
    fn take_locked(&self, g: &mut Inner) -> Option<Request> {
        if self.edf_enabled.load(Ordering::Relaxed) {
            while let Some(Reverse((_, seq))) = g.heap.pop() {
                let min_seq = *g.entries.keys().next()?;
                if let Some(e) = g.entries.remove(&seq) {
                    if e.aged {
                        self.aged_pops.fetch_add(1, Ordering::Relaxed);
                    }
                    if seq != min_seq {
                        self.reordered_pops.fetch_add(1, Ordering::Relaxed);
                    }
                    return Some(e.r);
                }
                // stale heap entry (FCFS already popped it): skip
            }
        }
        let (_, e) = g.entries.pop_first()?;
        if g.entries.is_empty() {
            g.heap.clear();
        }
        Some(e.r)
    }

    /// Blocking pop; returns None once closed and drained.
    pub fn pop(&self) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = self.take_locked(&mut g) {
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.notify.wait(g).unwrap();
        }
    }

    /// Pop up to `n` requests without blocking (batch formation),
    /// in the configured admission order.
    pub fn pop_up_to(&self, n: usize) -> Vec<Request> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(n.min(g.entries.len()));
        while out.len() < n {
            match self.take_locked(&mut g) {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    /// Block until the queue is non-empty, `deadline` passes, or the
    /// queue closes. Returns `true` when requests are available — the
    /// scheduler's linger wait, woken by the push-side condvar instead
    /// of a sleep-poll tick, so admission latency is not quantized.
    pub fn wait_nonempty_until(&self, deadline: std::time::Instant) -> bool {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.entries.is_empty() {
                return true;
            }
            if g.closed {
                return false;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            g = self.notify.wait_timeout(g, deadline - now).unwrap().0;
        }
    }

    /// Expiry of the tightest REAL deadline still queued (aging bounds
    /// excluded), for the scheduler's deadline-aware linger cap. O(n)
    /// over the live set — admission-path only, never inside a round.
    pub fn earliest_deadline(&self) -> Option<Instant> {
        let g = self.inner.lock().unwrap();
        g.entries.values().filter_map(|e| e.deadline).min()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        Request::synthetic(id)
    }

    /// A request with an explicit deadline, back-dated so deadlines can
    /// be made tight without sleeping.
    fn req_dl(id: u64, deadline_ms: u64) -> Request {
        let mut r = req(id);
        r.deadline_ms = Some(deadline_ms);
        r
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(10);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn backpressure_full() {
        let q = RequestQueue::new(1);
        q.push(req(1)).unwrap();
        assert_eq!(q.push(req(2)), Err(PushError::Full));
    }

    #[test]
    fn close_unblocks_pop() {
        let q = Arc::new(RequestQueue::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
        assert_eq!(q.push(req(3)), Err(PushError::Closed));
    }

    #[test]
    fn wait_nonempty_basic_transitions() {
        use std::time::{Duration, Instant};
        let q = RequestQueue::new(4);
        // non-empty: returns immediately regardless of deadline
        q.push(req(1)).unwrap();
        assert!(q.wait_nonempty_until(Instant::now()));
        q.pop_up_to(1);
        // empty + past deadline: false without blocking
        assert!(!q.wait_nonempty_until(Instant::now()));
        // a push from another thread wakes the waiter before the deadline
        let q = Arc::new(RequestQueue::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.push(req(2)).unwrap();
        });
        assert!(q.wait_nonempty_until(Instant::now() + Duration::from_secs(5)));
        h.join().unwrap();
        // closed: false even with a far deadline
        q.close();
        q.pop_up_to(1);
        assert!(!q.wait_nonempty_until(Instant::now() + Duration::from_secs(5)));
    }

    #[test]
    fn pop_up_to_batches() {
        let q = RequestQueue::new(10);
        for i in 0..5 {
            q.push(req(i)).unwrap();
        }
        let b = q.pop_up_to(3);
        assert_eq!(b.len(), 3);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn edf_orders_by_deadline() {
        let q = RequestQueue::new(10).with_edf(true);
        q.push(req_dl(1, 5_000)).unwrap(); // loose
        q.push(req_dl(2, 100)).unwrap(); // tight
        q.push(req_dl(3, 1_000)).unwrap(); // medium
        assert_eq!(q.pop().unwrap().id, 2, "tightest deadline first");
        assert_eq!(q.pop().unwrap().id, 3);
        assert_eq!(q.pop().unwrap().id, 1);
        assert!(q.reordered_pops() >= 1, "EDF deviated from arrival order");
    }

    #[test]
    fn edf_degrades_to_fcfs_without_deadlines() {
        let q = RequestQueue::new(10).with_edf(true);
        for i in 0..5 {
            q.push(req(i)).unwrap();
        }
        let ids: Vec<u64> = q.pop_up_to(5).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4], "all-unbounded EDF = arrival order");
        assert_eq!(q.reordered_pops(), 0);
        assert_eq!(q.aged_pops(), 5, "unbounded keys come from the aging bound");
    }

    #[test]
    fn edf_fcfs_tiebreak_on_equal_deadlines() {
        let q = RequestQueue::new(10).with_edf(true);
        // same explicit budget anchored at (nearly) the same arrival:
        // arrival-sequence tiebreak keeps FCFS order
        let base = Instant::now();
        for i in 0..4 {
            let mut r = req_dl(i, 60_000);
            r.arrival = base;
            q.push(r).unwrap();
        }
        let ids: Vec<u64> = q.pop_up_to(4).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn edf_aging_bounds_unbounded_wait() {
        // an unbounded request whose age exceeds the aging bound
        // outranks a fresh tight-deadline arrival
        let q = RequestQueue::new(10).with_edf(true).with_aging_ms(50);
        let mut old = req(1); // unbounded
        old.arrival = Instant::now() - Duration::from_millis(200);
        q.push(old).unwrap();
        q.push(req_dl(2, 100)).unwrap(); // fresh + tight
        assert_eq!(q.pop().unwrap().id, 1, "aged request served first");
        assert!(q.aged_pops() >= 1);
    }

    #[test]
    fn runtime_toggle_and_default_deadline() {
        let q = RequestQueue::new(10).with_deadline_default(60_000);
        assert!(!q.edf_enabled());
        q.set_edf_enabled(true);
        assert!(q.edf_enabled());
        // default deadline is a real deadline for EDF/linger purposes
        q.push(req(1)).unwrap();
        assert!(q.earliest_deadline().is_some(), "server default counts as a deadline");
        q.pop();
        // explicit 0 opts out of the default -> unbounded
        let mut r = req(2);
        r.deadline_ms = Some(0);
        q.push(r).unwrap();
        assert!(q.earliest_deadline().is_none());
    }

    #[test]
    fn earliest_deadline_reports_tightest() {
        let q = RequestQueue::new(10);
        assert!(q.earliest_deadline().is_none());
        q.push(req(1)).unwrap(); // unbounded: no deadline contribution
        assert!(q.earliest_deadline().is_none());
        q.push(req_dl(2, 5_000)).unwrap();
        q.push(req_dl(3, 500)).unwrap();
        let tight = q.earliest_deadline().unwrap();
        assert!(tight <= Instant::now() + Duration::from_millis(500));
    }

    #[test]
    fn push_resume_bypasses_capacity_and_closed() {
        let q = RequestQueue::new(1);
        q.push(req(1)).unwrap();
        assert_eq!(q.push(req(2)), Err(PushError::Full));
        // a suspended lane's re-admission is not subject to backpressure
        q.push_resume(req(3));
        assert_eq!(q.len(), 2);
        q.close();
        assert_eq!(q.push(req(4)), Err(PushError::Closed));
        // ... nor to drain: rejecting it would strand a half-served lane
        q.push_resume(req(5));
        assert_eq!(q.pop().unwrap().id, 1);
        let r3 = q.pop().unwrap();
        assert_eq!(r3.id, 3);
        assert!(r3.resume, "requeue path marks the entry resumable");
        assert_eq!(q.pop().unwrap().id, 5);
        assert!(q.pop().is_none(), "closed and drained");
    }

    #[test]
    fn resume_entries_sort_by_original_deadline_under_edf() {
        let q = RequestQueue::new(10).with_edf(true);
        q.push(req_dl(1, 5_000)).unwrap();
        // a resumed lane whose original deadline is tight outranks the
        // loose fresh arrival even though it re-entered the queue later
        let mut r = req_dl(2, 100);
        r.arrival = Instant::now() - Duration::from_millis(50);
        q.push_resume(r);
        assert_eq!(q.pop().unwrap().id, 2, "resume re-sorts by real urgency");
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn order_flip_midstream_loses_nothing() {
        let q = RequestQueue::new(16);
        for i in 0..6 {
            q.push(req_dl(i, 1_000 + i * 100)).unwrap();
        }
        // two FCFS pops leave stale heap entries behind
        assert_eq!(q.pop().unwrap().id, 0);
        assert_eq!(q.pop().unwrap().id, 1);
        q.set_edf_enabled(true);
        let mut ids: Vec<u64> = q.pop_up_to(10).iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![2, 3, 4, 5], "stale heap entries skipped, none lost");
    }
}
