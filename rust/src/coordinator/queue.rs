//! Thread-safe bounded request queue with condvar wakeups and
//! backpressure (reject-on-full), feeding the scheduler.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use super::request::Request;

pub struct RequestQueue {
    inner: Mutex<Inner>,
    notify: Condvar,
    pub capacity: usize,
}

struct Inner {
    q: VecDeque<Request>,
    closed: bool,
}

#[derive(Debug, PartialEq)]
pub enum PushError {
    Full,
    Closed,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            notify: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking push; `Full` signals backpressure to the server (429).
    pub fn push(&self, r: Request) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed);
        }
        if g.q.len() >= self.capacity {
            return Err(PushError::Full);
        }
        g.q.push_back(r);
        self.notify.notify_one();
        Ok(())
    }

    /// Blocking pop; returns None once closed and drained.
    pub fn pop(&self) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.q.pop_front() {
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.notify.wait(g).unwrap();
        }
    }

    /// Pop up to `n` requests without blocking (batch formation).
    pub fn pop_up_to(&self, n: usize) -> Vec<Request> {
        let mut g = self.inner.lock().unwrap();
        let take = n.min(g.q.len());
        g.q.drain(..take).collect()
    }

    /// Block until the queue is non-empty, `deadline` passes, or the
    /// queue closes. Returns `true` when requests are available — the
    /// scheduler's linger wait, woken by the push-side condvar instead
    /// of a sleep-poll tick, so admission latency is not quantized.
    pub fn wait_nonempty_until(&self, deadline: std::time::Instant) -> bool {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.q.is_empty() {
                return true;
            }
            if g.closed {
                return false;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            g = self.notify.wait_timeout(g, deadline - now).unwrap().0;
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        Request::synthetic(id)
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(10);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn backpressure_full() {
        let q = RequestQueue::new(1);
        q.push(req(1)).unwrap();
        assert_eq!(q.push(req(2)), Err(PushError::Full));
    }

    #[test]
    fn close_unblocks_pop() {
        let q = Arc::new(RequestQueue::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
        assert_eq!(q.push(req(3)), Err(PushError::Closed));
    }

    #[test]
    fn wait_nonempty_basic_transitions() {
        use std::time::{Duration, Instant};
        let q = RequestQueue::new(4);
        // non-empty: returns immediately regardless of deadline
        q.push(req(1)).unwrap();
        assert!(q.wait_nonempty_until(Instant::now()));
        q.pop_up_to(1);
        // empty + past deadline: false without blocking
        assert!(!q.wait_nonempty_until(Instant::now()));
        // a push from another thread wakes the waiter before the deadline
        let q = Arc::new(RequestQueue::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.push(req(2)).unwrap();
        });
        assert!(q.wait_nonempty_until(Instant::now() + Duration::from_secs(5)));
        h.join().unwrap();
        // closed: false even with a far deadline
        q.close();
        q.pop_up_to(1);
        assert!(!q.wait_nonempty_until(Instant::now() + Duration::from_secs(5)));
    }

    #[test]
    fn pop_up_to_batches() {
        let q = RequestQueue::new(10);
        for i in 0..5 {
            q.push(req(i)).unwrap();
        }
        let b = q.pop_up_to(3);
        assert_eq!(b.len(), 3);
        assert_eq!(q.len(), 2);
    }
}
