//! Checkpointable lanes (S24): round-boundary suspension with
//! bit-identical resume, plus memory-pressure KV eviction.
//!
//! A generating lane's full state at a round boundary is small and
//! host-visible: the committed token prefix, the KV length `m`, the
//! draft root feature/logits (the next round's inputs), the SplitMix64
//! stream position ([`crate::util::rng::Rng::draws`]), the adaptive
//! controller's EWMA/width-hysteresis state
//! ([`crate::spec::dyntree::ControllerSnapshot`]), the remaining
//! [`DeadlineClock`], the fused-commit pending triple the *next* verify
//! call would have consumed, and the lane's KV-cache rows. A
//! [`LaneCheckpoint`] captures all of it into pre-sized buffers (the S22
//! zero-alloc discipline: `clear` + `extend_from_slice` into existing
//! capacity), so suspending a warm lane allocates nothing.
//!
//! Resume has two paths, both yielding output bit-identical to the
//! uninterrupted run:
//!
//! * **Resident KV** — the checkpoint still holds the lane's cache rows;
//!   they are spliced back into a fresh batch cache (the same strided
//!   memcpy the per-lane prefill uses) together with the pending commit
//!   triple, and generation continues as if nothing happened.
//! * **Evicted KV** — memory pressure dropped the rows; resume
//!   re-prefills the committed prefix (degraded latency, identical
//!   output: the root feature/logits travelled in the checkpoint, the
//!   RNG stream resumes at its exact draw count, and deterministic
//!   kernels rebuild the same KV rows). `eagle_resume_refill_rounds_total`
//!   counts the extra work.
//!
//! [`CheckpointStore`] holds suspended lanes between the suspension and
//! their re-admission (the worker re-enqueues them as resumable queue
//! entries). Resident KV is bounded two ways: a byte budget
//! (`--kv-budget`) and a [`SlotAllocator`] watermark — crossing either
//! evicts the *oldest* resident checkpoints first
//! (`eagle_kv_evictions_total`).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::metrics::GenRecord;
use crate::models::target::KvCache;
use crate::spec::dyntree::ControllerSnapshot;
use crate::spec::scratch::ensure_cap;
use crate::util::deadline::DeadlineClock;

use super::kvslots::SlotAllocator;

/// Everything a suspended lane needs to resume bit-identically.
/// Buffers are pre-sized ([`LaneCheckpoint::reserve`]) and reused across
/// suspend/resume cycles so warm captures allocate zero bytes.
#[derive(Debug)]
pub struct LaneCheckpoint {
    /// Request id the lane belongs to (the store key).
    pub id: u64,
    /// Context tokens: the first `m` are KV-cached, `committed[m]` is
    /// the pending root token of the next round.
    pub committed: Vec<u32>,
    /// KV length (committed cache rows) at the suspension boundary.
    pub m: usize,
    /// Draft root feature for the next round (`d` floats).
    pub root_feat: Vec<f32>,
    /// Draft root children logits for the next round (`vocab` floats).
    pub root_logits: Vec<f32>,
    /// Lane RNG stream identity: original seed + draws consumed. Resume
    /// rebuilds the exact stream position in O(1) via `Rng::resume`.
    pub rng_seed: u64,
    pub rng_draws: u64,
    /// Adaptive controller state (None for static-tree lanes).
    pub controller: Option<ControllerSnapshot>,
    /// The lane's original absolute deadline (not a remaining budget —
    /// time suspended still counts against it).
    pub deadline: DeadlineClock,
    /// Partial metrics record, moved across the suspension.
    pub rec: GenRecord,
    /// Fused-commit pending state the next verify call consumes
    /// (`old_lens` / `prev_idx` / `prev_n` of the batched verify).
    pub pending_old: i32,
    pub pending_idx: Vec<i32>,
    pub pending_n: i32,
    /// Verify width the controller's *current* EWMA justifies, computed
    /// at suspension so the re-enqueued entry migrates width groups.
    pub width_hint: Option<usize>,
    /// Lane rows of the target / draft KV caches (empty once evicted).
    pub kv_target: Vec<f32>,
    pub kv_draft: Vec<f32>,
    pub kv_resident: bool,
    /// KV slot held while resident (managed by [`CheckpointStore`]).
    pub kv_slot: Option<usize>,
    /// Refill rounds spent reconstructing evicted KV on resume.
    pub refill_rounds: u64,
}

impl Default for LaneCheckpoint {
    fn default() -> Self {
        LaneCheckpoint {
            id: 0,
            committed: Vec::new(),
            m: 0,
            root_feat: Vec::new(),
            root_logits: Vec::new(),
            rng_seed: 0,
            rng_draws: 0,
            controller: None,
            deadline: DeadlineClock::unbounded(),
            rec: GenRecord::new(0),
            pending_old: 0,
            pending_idx: Vec::new(),
            pending_n: 0,
            width_hint: None,
            kv_target: Vec::new(),
            kv_draft: Vec::new(),
            kv_resident: false,
            kv_slot: None,
            refill_rounds: 0,
        }
    }
}

impl LaneCheckpoint {
    pub fn new() -> LaneCheckpoint {
        LaneCheckpoint::default()
    }

    /// Pre-size the host-state buffers so a later capture stays
    /// allocation-free. `max_ctx` bounds the committed context,
    /// `d`/`vocab` the root feature/logits, `accept_a` the pending
    /// commit indices.
    pub fn reserve(&mut self, max_ctx: usize, d: usize, vocab: usize, accept_a: usize) {
        ensure_cap(&mut self.committed, max_ctx);
        ensure_cap(&mut self.root_feat, d);
        ensure_cap(&mut self.root_logits, vocab);
        ensure_cap(&mut self.pending_idx, accept_a);
    }

    /// Pre-size the KV row buffers (float counts per cache; see
    /// [`lane_kv_floats`]).
    pub fn reserve_kv(&mut self, target_floats: usize, draft_floats: usize) {
        ensure_cap(&mut self.kv_target, target_floats);
        ensure_cap(&mut self.kv_draft, draft_floats);
    }

    /// Capture the token-level lane state (committed prefix + boundary).
    pub fn capture_tokens(&mut self, committed: &[u32], m: usize) {
        self.committed.clear();
        self.committed.extend_from_slice(committed);
        self.m = m;
    }

    /// Capture the next round's draft root inputs.
    pub fn capture_root(&mut self, feat: &[f32], logits: &[f32]) {
        self.root_feat.clear();
        self.root_feat.extend_from_slice(feat);
        self.root_logits.clear();
        self.root_logits.extend_from_slice(logits);
    }

    /// Capture the fused-commit pending triple for the next verify call.
    pub fn capture_pending(&mut self, old: i32, idx: &[i32], n: i32) {
        self.pending_old = old;
        self.pending_idx.clear();
        self.pending_idx.extend_from_slice(idx);
        self.pending_n = n;
    }

    /// Resident KV bytes this checkpoint pins (0 once evicted).
    pub fn kv_bytes(&self) -> u64 {
        if !self.kv_resident {
            return 0;
        }
        ((self.kv_target.capacity() + self.kv_draft.capacity()) * std::mem::size_of::<f32>())
            as u64
    }

    /// Drop the resident KV rows (memory-pressure eviction). Returns the
    /// bytes freed; resume must then re-prefill the committed prefix.
    pub fn evict_kv(&mut self) -> u64 {
        let freed = self.kv_bytes();
        self.kv_target = Vec::new();
        self.kv_draft = Vec::new();
        self.kv_resident = false;
        freed
    }

    /// Total capacity pinned by the host-state buffers (the checkpoint
    /// analogue of `RoundScratch::footprint`; the moved-in `rec` is
    /// excluded — it changes hands, it is never copied). Warm captures
    /// must leave this unchanged.
    pub fn footprint(&self) -> u64 {
        let f32s = std::mem::size_of::<f32>();
        let mut b = self.committed.capacity() * std::mem::size_of::<u32>()
            + self.root_feat.capacity() * f32s
            + self.root_logits.capacity() * f32s
            + self.pending_idx.capacity() * std::mem::size_of::<i32>()
            + self.kv_target.capacity() * f32s
            + self.kv_draft.capacity() * f32s;
        if let Some(c) = &self.controller {
            b += c.capacity_bytes();
        }
        b as u64
    }
}

/// Per-lane floats of one lane's slice of a [`KvCache`]
/// (`[2, L, B, S, H, dh]` → `2 * L * S * H * dh`).
pub fn lane_kv_floats(cache: &KvCache) -> usize {
    let [two, nl, _b, s, h, dh] = cache.dims;
    two * nl * s * h * dh
}

/// Copy lane `lane`'s rows (every `(kv, layer)` block, full sequence
/// length — the scratch region included, so the pending fused commit
/// survives the round trip) out of a batch cache into `dst`.
pub fn copy_lane_kv_out(cache: &KvCache, lane: usize, dst: &mut Vec<f32>) {
    let [two, nl, b, s, h, dh] = cache.dims;
    assert!(lane < b, "lane {lane} out of range for batch {b}");
    let block = s * h * dh;
    dst.clear();
    for k in 0..two {
        for l in 0..nl {
            let off = ((k * nl + l) * b + lane) * block;
            dst.extend_from_slice(&cache.data[off..off + block]);
        }
    }
}

/// Splice a [`copy_lane_kv_out`] snapshot back into lane `lane` of a
/// batch cache (the checkpoint analogue of the per-lane prefill splice).
pub fn copy_lane_kv_in(cache: &mut KvCache, lane: usize, src: &[f32]) {
    let [two, nl, b, s, h, dh] = cache.dims;
    assert!(lane < b, "lane {lane} out of range for batch {b}");
    let block = s * h * dh;
    assert_eq!(src.len(), two * nl * block, "kv snapshot shape mismatch");
    let mut so = 0;
    for k in 0..two {
        for l in 0..nl {
            let off = ((k * nl + l) * b + lane) * block;
            cache.data[off..off + block].copy_from_slice(&src[so..so + block]);
            so += block;
        }
    }
}

/// Lock-free per-lane suspension mask, shared between the worker (which
/// requests) and an engine's round loop (which honors requests at the
/// next round boundary). Lanes are the engine's batch indices; batch
/// sizes beyond 64 lanes saturate into "no preemption" for the excess
/// lanes rather than misfiring.
#[derive(Debug, Default)]
pub struct PreemptSignal {
    mask: AtomicU64,
}

impl PreemptSignal {
    pub fn new() -> PreemptSignal {
        PreemptSignal::default()
    }

    /// Mark one lane for suspension at its next round boundary.
    pub fn request(&self, lane: usize) {
        if lane < 64 {
            self.mask.fetch_or(1u64 << lane, Ordering::SeqCst);
        }
    }

    /// Mark every lane for suspension (whole-group preemption).
    pub fn request_all(&self) {
        self.mask.store(u64::MAX, Ordering::SeqCst);
    }

    /// Consume the request for `lane`: true exactly once per request.
    pub fn take(&self, lane: usize) -> bool {
        if lane >= 64 {
            return false;
        }
        let bit = 1u64 << lane;
        self.mask.fetch_and(!bit, Ordering::SeqCst) & bit != 0
    }

    pub fn requested(&self, lane: usize) -> bool {
        lane < 64 && self.mask.load(Ordering::SeqCst) & (1u64 << lane) != 0
    }

    pub fn any(&self) -> bool {
        self.mask.load(Ordering::SeqCst) != 0
    }

    pub fn clear(&self) {
        self.mask.store(0, Ordering::SeqCst);
    }
}

struct StoreInner {
    map: HashMap<u64, Box<LaneCheckpoint>>,
    /// Resident-KV checkpoint ids, oldest first (the eviction order).
    order: VecDeque<u64>,
    resident_bytes: u64,
    slots: SlotAllocator,
}

/// Holds suspended lanes between suspension and re-admission, and owns
/// the memory-pressure policy: resident KV is bounded by a byte budget
/// and by the slot allocator's watermark, and crossing either evicts the
/// oldest resident checkpoints (their lanes resume via prefix
/// re-prefill).
pub struct CheckpointStore {
    inner: Mutex<StoreInner>,
    budget_bytes: u64,
    evictions: AtomicU64,
}

impl CheckpointStore {
    /// `kv_slots` / `watermark` size the resident-KV slot allocator;
    /// `budget_bytes` bounds total resident bytes (0 = unbounded).
    pub fn new(kv_slots: usize, watermark: usize, budget_bytes: u64) -> CheckpointStore {
        CheckpointStore {
            inner: Mutex::new(StoreInner {
                map: HashMap::new(),
                order: VecDeque::new(),
                resident_bytes: 0,
                slots: SlotAllocator::new(kv_slots).with_watermark(watermark),
            }),
            budget_bytes,
            evictions: AtomicU64::new(0),
        }
    }

    /// Park a suspended lane. Allocates a KV slot for resident KV —
    /// evicting immediately when slots are exhausted — then enforces the
    /// byte budget and watermark against the oldest residents. Returns
    /// the number of evictions this insert caused.
    pub fn insert(&self, mut ckpt: Box<LaneCheckpoint>) -> usize {
        let mut evicted = 0usize;
        {
            let mut g = self.inner.lock().unwrap();
            // replacing an id (should not happen in normal operation)
            // must release the old checkpoint's slot and bytes first
            if let Some(old) = g.map.remove(&ckpt.id) {
                Self::forget_locked(&mut g, &old);
            }
            if ckpt.kv_resident {
                match g.slots.alloc() {
                    Some(s) => {
                        ckpt.kv_slot = Some(s);
                        g.resident_bytes += ckpt.kv_bytes();
                        g.order.push_back(ckpt.id);
                    }
                    None => {
                        ckpt.evict_kv();
                        evicted += 1;
                    }
                }
            }
            g.map.insert(ckpt.id, ckpt);
            evicted += self.enforce_locked(&mut g);
        }
        self.evictions.fetch_add(evicted as u64, Ordering::Relaxed);
        evicted
    }

    fn forget_locked(g: &mut StoreInner, old: &LaneCheckpoint) {
        if old.kv_resident {
            g.resident_bytes = g.resident_bytes.saturating_sub(old.kv_bytes());
            g.order.retain(|&i| i != old.id);
        }
        if let Some(s) = old.kv_slot {
            g.slots.release(s);
        }
    }

    fn enforce_locked(&self, g: &mut StoreInner) -> usize {
        let mut n = 0;
        while (self.budget_bytes > 0 && g.resident_bytes > self.budget_bytes)
            || g.slots.under_pressure()
        {
            let Some(id) = g.order.pop_front() else { break };
            if let Some(c) = g.map.get_mut(&id) {
                let freed = c.evict_kv();
                g.resident_bytes = g.resident_bytes.saturating_sub(freed);
                if let Some(s) = c.kv_slot.take() {
                    g.slots.release(s);
                }
                n += 1;
            }
        }
        n
    }

    /// Pull a suspended lane back out for resume (releases its KV slot).
    pub fn take(&self, id: u64) -> Option<Box<LaneCheckpoint>> {
        let mut g = self.inner.lock().unwrap();
        let mut ckpt = g.map.remove(&id)?;
        Self::forget_locked(&mut g, &ckpt);
        ckpt.kv_slot = None;
        Some(ckpt)
    }

    /// Remove and return every parked checkpoint (the drain safety net:
    /// any lane still here after the queue drains must be delivered, not
    /// stranded).
    pub fn drain_all(&self) -> Vec<Box<LaneCheckpoint>> {
        let mut g = self.inner.lock().unwrap();
        g.order.clear();
        g.resident_bytes = 0;
        let mut out: Vec<Box<LaneCheckpoint>> = g.map.drain().map(|(_, c)| c).collect();
        for c in &mut out {
            if let Some(s) = c.kv_slot.take() {
                g.slots.release(s);
            }
        }
        out.sort_by_key(|c| c.id);
        out
    }

    pub fn contains(&self, id: u64) -> bool {
        self.inner.lock().unwrap().map.contains_key(&id)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn resident_bytes(&self) -> u64 {
        self.inner.lock().unwrap().resident_bytes
    }

    /// Total evictions performed (feeds `eagle_kv_evictions_total`).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Whether the slot allocator is below its free-slot watermark — the
    /// signal the worker uses for `reason="pressure"` preemption.
    pub fn under_pressure(&self) -> bool {
        self.inner.lock().unwrap().slots.under_pressure()
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resident(id: u64, kv_floats: usize) -> Box<LaneCheckpoint> {
        let mut c = Box::new(LaneCheckpoint::new());
        c.id = id;
        // exact capacity so the byte-accounting assertions stay precise
        c.kv_target = Vec::with_capacity(kv_floats);
        c.kv_target.resize(kv_floats, 0.0);
        c.kv_resident = true;
        c
    }

    #[test]
    fn preempt_signal_bits() {
        let s = PreemptSignal::new();
        assert!(!s.any());
        s.request(3);
        assert!(s.requested(3) && !s.requested(2));
        assert!(s.take(3), "take consumes the request");
        assert!(!s.take(3), "exactly once");
        s.request_all();
        assert!(s.take(0) && s.take(63));
        assert!(!s.take(64), "out-of-range lanes never fire");
        s.clear();
        assert!(!s.any());
    }

    #[test]
    fn kv_lane_copy_roundtrip_leaves_peers_untouched() {
        // tiny batch cache: [2, L=2, B=3, S=4, H=1, dh=2]
        let dims = [2usize, 2, 3, 4, 1, 2];
        let n: usize = dims.iter().product();
        let mut cache = KvCache { data: (0..n).map(|i| i as f32).collect(), dims };
        let orig = cache.data.clone();
        let mut snap = Vec::new();
        copy_lane_kv_out(&cache, 1, &mut snap);
        assert_eq!(snap.len(), lane_kv_floats(&cache));
        // scribble over lane 1 everywhere, then restore from the snapshot
        let block = 4 * 1 * 2;
        for k in 0..2 {
            for l in 0..2 {
                let off = ((k * 2 + l) * 3 + 1) * block;
                for v in &mut cache.data[off..off + block] {
                    *v = -1.0;
                }
            }
        }
        copy_lane_kv_in(&mut cache, 1, &snap);
        assert_eq!(cache.data, orig, "restore is exact and peers never moved");
    }

    #[test]
    fn warm_checkpoint_reuse_does_not_grow() {
        let mut c = LaneCheckpoint::new();
        c.reserve(64, 8, 32, 4);
        c.reserve_kv(128, 64);
        let fp0 = c.footprint();
        for round in 0..3 {
            c.capture_tokens(&vec![7; 40 + round], 39 + round);
            c.capture_root(&[0.5; 8], &[0.1; 32]);
            c.capture_pending(39, &[1, 2, 3], 3);
            c.kv_target.clear();
            c.kv_target.extend_from_slice(&[0.0; 128]);
            c.kv_resident = true;
            assert_eq!(c.footprint(), fp0, "warm capture {round} grew a buffer");
        }
    }

    #[test]
    fn store_evicts_oldest_over_budget() {
        // each resident checkpoint pins 100 floats = 400 bytes
        let store = CheckpointStore::new(8, 0, 900);
        assert_eq!(store.insert(resident(1, 100)), 0);
        assert_eq!(store.insert(resident(2, 100)), 0);
        assert_eq!(store.resident_bytes(), 800);
        // third crosses the 900-byte budget: the OLDEST (id 1) is evicted
        assert_eq!(store.insert(resident(3, 100)), 1);
        assert_eq!(store.evictions(), 1);
        assert_eq!(store.resident_bytes(), 800);
        let c1 = store.take(1).unwrap();
        assert!(!c1.kv_resident, "id 1 lost its KV");
        let c3 = store.take(3).unwrap();
        assert!(c3.kv_resident, "id 3 kept its KV");
        assert_eq!(store.resident_bytes(), 400);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn store_slot_exhaustion_and_watermark() {
        // 2 slots, watermark 1: pressure once fewer than 1 slot is free,
        // i.e. inserting the second resident triggers eviction of the
        // first to restore a free slot
        let store = CheckpointStore::new(2, 1, 0);
        store.insert(resident(1, 10));
        assert!(store.take(1).unwrap().kv_resident);
        store.insert(resident(2, 10));
        let ev = store.insert(resident(3, 10));
        assert_eq!(ev, 1, "watermark eviction fires");
        assert!(!store.take(2).unwrap().kv_resident, "oldest evicted");
        assert!(store.take(3).unwrap().kv_resident);
        // slot exhaustion (capacity 1, no watermark): second resident is
        // evicted immediately at insert
        let tight = CheckpointStore::new(1, 0, 0);
        tight.insert(resident(4, 10));
        assert_eq!(tight.insert(resident(5, 10)), 1);
        assert!(!tight.take(5).unwrap().kv_resident);
    }

    #[test]
    fn drain_all_returns_everything_and_resets() {
        let store = CheckpointStore::new(4, 0, 0);
        store.insert(resident(9, 10));
        store.insert(resident(4, 10));
        let mut plain = Box::new(LaneCheckpoint::new());
        plain.id = 7;
        store.insert(plain);
        let drained = store.drain_all();
        assert_eq!(drained.iter().map(|c| c.id).collect::<Vec<_>>(), vec![4, 7, 9]);
        assert!(store.is_empty());
        assert_eq!(store.resident_bytes(), 0);
        // all slots released: a fresh resident insert succeeds
        assert_eq!(store.insert(resident(1, 10)), 0);
    }
}
