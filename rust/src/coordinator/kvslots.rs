//! KV-slot allocator (S15): fixed-capacity sequence slots over the batched
//! cache, with allocation/free invariants property-tested in
//! `rust/tests/prop_coordinator.rs` (the vLLM "block manager" scaled to
//! this testbed's whole-sequence slots). Since the checkpointing PR the
//! allocator also carries a memory-pressure watermark: once free slots
//! fall below it, holders of evictable slots (suspended lanes with
//! resident KV — see `coordinator/checkpoint.rs`) are expected to give
//! theirs back, and the serving layer preempts running groups
//! (`eagle_preempt_total{reason="pressure"}`) instead of admitting more.

#[derive(Debug)]
pub struct SlotAllocator {
    free: Vec<usize>,
    in_use: Vec<bool>,
    watermark: usize,
}

impl SlotAllocator {
    pub fn new(capacity: usize) -> SlotAllocator {
        SlotAllocator {
            free: (0..capacity).rev().collect(),
            in_use: vec![false; capacity],
            watermark: 0,
        }
    }

    /// Set the low-free-slots watermark: the allocator reports pressure
    /// while fewer than `watermark` slots remain free. A watermark of 0
    /// (the default) never reports pressure.
    pub fn with_watermark(mut self, watermark: usize) -> SlotAllocator {
        self.watermark = watermark.min(self.capacity());
        self
    }

    pub fn watermark(&self) -> usize {
        self.watermark
    }

    /// Memory pressure: free slots have dropped below the watermark.
    pub fn under_pressure(&self) -> bool {
        self.watermark > 0 && self.free.len() < self.watermark
    }

    pub fn capacity(&self) -> usize {
        self.in_use.len()
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn alloc(&mut self) -> Option<usize> {
        let s = self.free.pop()?;
        debug_assert!(!self.in_use[s]);
        self.in_use[s] = true;
        Some(s)
    }

    pub fn release(&mut self, slot: usize) {
        assert!(slot < self.in_use.len(), "slot {slot} out of range");
        assert!(self.in_use[slot], "double free of slot {slot}");
        self.in_use[slot] = false;
        self.free.push(slot);
    }

    pub fn is_allocated(&self, slot: usize) -> bool {
        self.in_use.get(slot).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_until_exhausted() {
        let mut a = SlotAllocator::new(3);
        let s: Vec<_> = (0..3).map(|_| a.alloc().unwrap()).collect();
        assert_eq!(a.alloc(), None);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2]);
        a.release(s[1]);
        assert_eq!(a.alloc(), Some(s[1]));
    }

    #[test]
    fn watermark_reports_pressure_below_threshold() {
        let mut a = SlotAllocator::new(4).with_watermark(2);
        assert!(!a.under_pressure(), "4 free >= watermark 2");
        let s0 = a.alloc().unwrap();
        let _s1 = a.alloc().unwrap();
        assert!(!a.under_pressure(), "2 free == watermark 2 is not yet pressure");
        let _s2 = a.alloc().unwrap();
        assert!(a.under_pressure(), "1 free < watermark 2");
        a.release(s0);
        assert!(!a.under_pressure(), "release clears pressure");
        // watermark 0 (default) never reports pressure, even exhausted
        let mut b = SlotAllocator::new(1);
        b.alloc().unwrap();
        assert!(!b.under_pressure());
        // watermark clamps to capacity
        assert_eq!(SlotAllocator::new(2).with_watermark(9).watermark(), 2);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = SlotAllocator::new(2);
        let s = a.alloc().unwrap();
        a.release(s);
        a.release(s);
    }
}
