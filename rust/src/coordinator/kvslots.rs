//! KV-slot allocator (S15): fixed-capacity sequence slots over the batched
//! cache, with allocation/free invariants property-tested in
//! `rust/tests/prop_coordinator.rs` (the vLLM "block manager" scaled to
//! this testbed's whole-sequence slots).

#[derive(Debug)]
pub struct SlotAllocator {
    free: Vec<usize>,
    in_use: Vec<bool>,
}

impl SlotAllocator {
    pub fn new(capacity: usize) -> SlotAllocator {
        SlotAllocator { free: (0..capacity).rev().collect(), in_use: vec![false; capacity] }
    }

    pub fn capacity(&self) -> usize {
        self.in_use.len()
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn alloc(&mut self) -> Option<usize> {
        let s = self.free.pop()?;
        debug_assert!(!self.in_use[s]);
        self.in_use[s] = true;
        Some(s)
    }

    pub fn release(&mut self, slot: usize) {
        assert!(slot < self.in_use.len(), "slot {slot} out of range");
        assert!(self.in_use[slot], "double free of slot {slot}");
        self.in_use[slot] = false;
        self.free.push(slot);
    }

    pub fn is_allocated(&self, slot: usize) -> bool {
        self.in_use.get(slot).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_until_exhausted() {
        let mut a = SlotAllocator::new(3);
        let s: Vec<_> = (0..3).map(|_| a.alloc().unwrap()).collect();
        assert_eq!(a.alloc(), None);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2]);
        a.release(s[1]);
        assert_eq!(a.alloc(), Some(s[1]));
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = SlotAllocator::new(2);
        let s = a.alloc().unwrap();
        a.release(s);
        a.release(s);
    }
}
