//! Request/response types for the serving API.

use crate::spec::source::{DraftChoice, SourceKind};
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Eagle,
    EagleChain,
    Vanilla,
    Medusa,
    Lookahead,
    ClassicSpec,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "eagle" | "eagle-tree" => Method::Eagle,
            "eagle-chain" => Method::EagleChain,
            "vanilla" => Method::Vanilla,
            "medusa" => Method::Medusa,
            "lookahead" => Method::Lookahead,
            "classic" | "spec" | "classic-spec" => Method::ClassicSpec,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Eagle => "eagle",
            Method::EagleChain => "eagle-chain",
            Method::Vanilla => "vanilla",
            Method::Medusa => "medusa",
            Method::Lookahead => "lookahead",
            Method::ClassicSpec => "classic-spec",
        }
    }
}

/// Per-request draft-tree shaping choice ("tree" field of the generate
/// API). `Default` defers to the server's configured policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TreeChoice {
    Default,
    Static,
    Dynamic,
}

impl TreeChoice {
    pub fn parse(s: &str) -> Option<TreeChoice> {
        Some(match s {
            "default" => TreeChoice::Default,
            "static" => TreeChoice::Static,
            "dynamic" | "dyntree" => TreeChoice::Dynamic,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TreeChoice::Default => "default",
            TreeChoice::Static => "static",
            TreeChoice::Dynamic => "dynamic",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_tokens: usize,
    pub temperature: f32,
    pub method: Method,
    pub tree: TreeChoice,
    /// Draft-source choice (`"draft"` field / `--draft` flag):
    /// `eagle|chain|ngram|medusa` pins a strategy, `auto` asks the
    /// online [`crate::spec::dyntree::SourceSelector`] policy, `Default`
    /// defers to the server's configured default.
    pub draft: DraftChoice,
    /// The draft source this request actually runs with, resolved at
    /// admission (route thread) from `draft` + the server config + the
    /// online policy. Part of the scheduler compatibility class and the
    /// quarantine fingerprint. Never client-settable directly.
    pub source: SourceKind,
    /// Per-request verify-width pin (`"verify_width"` field): `Some(t)`
    /// forces every round onto the `verify_t{t}` executable; `None`
    /// defers to the server's configured width policy (auto by default).
    pub verify_width: Option<usize>,
    /// Predicted verify width (`"width_hint"` field) used by the
    /// width-grouping admission policy: clients (or a requeue path
    /// carrying a live controller EWMA) declare the width this request
    /// is expected to run at, and the scheduler groups compatible lanes
    /// so a low-acceptance request is not dragged to a hot lane's width.
    /// `None` means "assume the widest lowered width" — never truncating.
    pub width_hint: Option<usize>,
    pub seed: u64,
    /// Per-request latency budget (`"deadline_ms"` field), measured from
    /// arrival. `None` defers to the server's `--default-deadline-ms`
    /// (0 = unbounded). An expired request stops drafting and returns
    /// its partial text with `"truncated": "deadline"`; a request whose
    /// deadline passes while still queued is dropped with 504.
    pub deadline_ms: Option<u64>,
    pub arrival: std::time::Instant,
    /// Set by the worker's requeue path when this entry resumes a
    /// suspended lane (a checkpoint is parked under `id` in the
    /// `CheckpointStore`). Resume entries bypass queue capacity and
    /// `closed` (they are not new work — rejecting them would strand a
    /// half-served lane) and cap the scheduler's linger (the lane
    /// already waited once). Never client-settable.
    pub resume: bool,
}

impl Request {
    pub fn from_json(id: u64, v: &Json) -> anyhow::Result<Request> {
        let prompt = v
            .req("prompt")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("prompt must be a string"))?
            .to_string();
        Ok(Request {
            id,
            prompt,
            max_tokens: v.get("max_tokens").and_then(|x| x.as_usize()).unwrap_or(64),
            temperature: v.get("temperature").and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
            method: v
                .get("method")
                .and_then(|m| m.as_str())
                .and_then(Method::parse)
                .unwrap_or(Method::Eagle),
            tree: v
                .get("tree")
                .and_then(|t| t.as_str())
                .and_then(TreeChoice::parse)
                .unwrap_or(TreeChoice::Default),
            draft: v
                .get("draft")
                .and_then(|t| t.as_str())
                .and_then(DraftChoice::parse)
                .unwrap_or(DraftChoice::Default),
            source: SourceKind::Eagle,
            verify_width: v
                .get("verify_width")
                .and_then(|x| x.as_usize())
                .filter(|&t| t >= 2),
            width_hint: v
                .get("width_hint")
                .and_then(|x| x.as_usize())
                .filter(|&t| t >= 2),
            seed: v.get("seed").and_then(|x| x.as_f64()).map(|f| f as u64).unwrap_or(7),
            deadline_ms: v.get("deadline_ms").and_then(|x| x.as_f64()).map(|f| f as u64),
            arrival: std::time::Instant::now(),
            resume: false,
        })
    }

    /// The request's deadline clock: the explicit `deadline_ms` budget,
    /// else the server default (`0` = unbounded), anchored at arrival so
    /// queue wait counts against the budget.
    pub fn deadline(&self, default_ms: u64) -> crate::util::deadline::DeadlineClock {
        let ms = self.deadline_ms.unwrap_or(default_ms);
        crate::util::deadline::DeadlineClock::from_ms(Some(ms), self.arrival)
    }

    /// The width the admission scheduler should assume for this request:
    /// the explicit hint, else the verify pin, else `max` (widest — a
    /// request that declared nothing must never be narrowed).
    pub fn admission_width(&self, max: usize) -> usize {
        self.width_hint.or(self.verify_width).unwrap_or(max)
    }

    /// Whether the batched (lock-step) engine can run this request
    /// alongside others — the single eligibility predicate shared by the
    /// scheduler's width grouping and the server's group executor.
    /// Sampled (T>0) requests qualify: each lane runs its own seeded RNG
    /// stream and the SpecInfer acceptance walk, so batching preserves
    /// the per-request output distribution (and the exact equal-seed
    /// bs=1 tokens whenever the per-round tree plans match — see the
    /// batch-engine module doc). Lanes must still share a temperature to
    /// co-execute
    /// (one lock-step `GenConfig`), which the scheduler's compatibility
    /// classes enforce. Requests pinning an exact verify width are
    /// excluded: the pin is a per-request contract the bs=1 path honors,
    /// and one pinned lane would otherwise force its whole group back to
    /// serial execution.
    pub fn width_batchable(&self) -> bool {
        self.method == Method::Eagle
            && self.source == SourceKind::Eagle
            && self.verify_width.is_none()
    }

    /// The engine `Method` this request dispatches to once its draft
    /// source is resolved: a non-eagle source re-routes an `eagle`
    /// request onto the matching bs=1 source engine; explicit baseline
    /// methods are honored as-is.
    pub fn source_method(&self) -> Method {
        if self.method != Method::Eagle {
            return self.method;
        }
        match self.source {
            SourceKind::Eagle => Method::Eagle,
            SourceKind::Chain => Method::ClassicSpec,
            SourceKind::Ngram => Method::Lookahead,
            SourceKind::Medusa => Method::Medusa,
        }
    }

    /// Temperature key for batching compatibility: all greedy requests
    /// (t <= 0) share one class; sampled requests class by exact
    /// temperature bits (the lock-step engine runs a group under a
    /// single `GenConfig`).
    pub fn temperature_class(&self) -> u32 {
        if self.temperature > 0.0 {
            self.temperature.to_bits()
        } else {
            0
        }
    }

    /// Minimal request for tests, benches, and synthetic eval workloads.
    pub fn synthetic(id: u64) -> Request {
        Request {
            id,
            prompt: String::new(),
            max_tokens: 1,
            temperature: 0.0,
            method: Method::Vanilla,
            tree: TreeChoice::Default,
            draft: DraftChoice::Default,
            source: SourceKind::Eagle,
            verify_width: None,
            width_hint: None,
            seed: 0,
            deadline_ms: None,
            arrival: std::time::Instant::now(),
            resume: false,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub tokens: usize,
    pub target_passes: usize,
    pub tau: f64,
    pub latency_ms: f64,
    pub queue_ms: f64,
    /// HTTP status the route thread answers with: 200 on success;
    /// worker-side failures (panicked lane → 500, queue-expired
    /// deadline → 504) deliver through the same pending slot.
    pub status: u16,
    /// Why generation stopped early, if it did (`"deadline"`). Carried
    /// into the response JSON so clients can tell a partial answer from
    /// a complete one.
    pub truncated: Option<&'static str>,
}

impl Response {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::Num(self.id as f64)),
            ("text", Json::Str(self.text.clone())),
            ("tokens", Json::Num(self.tokens as f64)),
            ("target_passes", Json::Num(self.target_passes as f64)),
            ("tau", Json::Num(self.tau)),
            ("latency_ms", Json::Num(self.latency_ms)),
            ("queue_ms", Json::Num(self.queue_ms)),
        ];
        if let Some(t) = self.truncated {
            fields.push(("truncated", Json::Str(t.into())));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_defaults() {
        let v = Json::parse(r#"{"prompt":"hi"}"#).unwrap();
        let r = Request::from_json(1, &v).unwrap();
        assert_eq!(r.max_tokens, 64);
        assert_eq!(r.method, Method::Eagle);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.tree, TreeChoice::Default);
        assert_eq!(r.draft, DraftChoice::Default);
        assert_eq!(r.source, SourceKind::Eagle);
        assert!(r.width_batchable());
        assert_eq!(r.verify_width, None);
        assert_eq!(r.width_hint, None);
        assert_eq!(r.admission_width(32), 32, "no hint -> widest");
        assert_eq!(r.deadline_ms, None);
        assert!(r.deadline(0).is_unbounded(), "no budget anywhere -> unbounded");
        assert!(!r.deadline(5_000).is_unbounded(), "server default applies");
    }

    #[test]
    fn parse_request_deadline() {
        let v = Json::parse(r#"{"prompt":"x","deadline_ms":250}"#).unwrap();
        let r = Request::from_json(9, &v).unwrap();
        assert_eq!(r.deadline_ms, Some(250));
        let c = r.deadline(60_000);
        assert!(!c.is_unbounded(), "explicit budget wins over server default");
        assert!(c.remaining().unwrap() <= std::time::Duration::from_millis(250));
        let v = Json::parse(r#"{"prompt":"x","deadline_ms":0}"#).unwrap();
        let r = Request::from_json(10, &v).unwrap();
        assert!(r.deadline(60_000).is_unbounded(), "explicit 0 disables the default");
    }

    #[test]
    fn parse_request_full() {
        let v = Json::parse(
            r#"{"prompt":"x","max_tokens":8,"temperature":1.0,"method":"vanilla","tree":"dynamic","verify_width":16,"width_hint":8}"#,
        )
        .unwrap();
        let r = Request::from_json(2, &v).unwrap();
        assert_eq!(r.max_tokens, 8);
        assert_eq!(r.method, Method::Vanilla);
        assert_eq!(r.tree, TreeChoice::Dynamic);
        assert_eq!(r.verify_width, Some(16));
        assert_eq!(r.width_hint, Some(8));
        assert_eq!(r.admission_width(32), 8, "hint wins over the pin");
        let v = Json::parse(r#"{"prompt":"x","verify_width":1,"width_hint":1}"#).unwrap();
        let r = Request::from_json(3, &v).unwrap();
        assert_eq!(r.verify_width, None, "degenerate widths ignored");
        assert_eq!(r.width_hint, None);
        let v = Json::parse(r#"{"prompt":"x","verify_width":16}"#).unwrap();
        let r = Request::from_json(4, &v).unwrap();
        assert_eq!(r.admission_width(32), 16, "pin stands in for a missing hint");
    }

    #[test]
    fn parse_request_draft_source() {
        let v = Json::parse(r#"{"prompt":"x","draft":"ngram"}"#).unwrap();
        let mut r = Request::from_json(5, &v).unwrap();
        assert_eq!(r.draft, DraftChoice::Fixed(SourceKind::Ngram));
        // admission resolves the source; a non-eagle source leaves the
        // width-batched fast path and dispatches to the matching engine
        r.source = SourceKind::Ngram;
        assert!(!r.width_batchable());
        assert_eq!(r.source_method(), Method::Lookahead);
        let v = Json::parse(r#"{"prompt":"x","draft":"auto"}"#).unwrap();
        let r = Request::from_json(6, &v).unwrap();
        assert_eq!(r.draft, DraftChoice::Auto);
        let v = Json::parse(r#"{"prompt":"x","draft":"bogus"}"#).unwrap();
        let r = Request::from_json(7, &v).unwrap();
        assert_eq!(r.draft, DraftChoice::Default, "unknown draft falls back to default");
        // an explicit baseline method is honored regardless of source
        let v = Json::parse(r#"{"prompt":"x","method":"classic"}"#).unwrap();
        let r = Request::from_json(8, &v).unwrap();
        assert_eq!(r.source_method(), Method::ClassicSpec);
    }

    #[test]
    fn tree_choice_roundtrip() {
        for t in ["default", "static", "dynamic"] {
            assert_eq!(TreeChoice::parse(t).unwrap().name(), t);
        }
        assert_eq!(TreeChoice::parse("dyntree"), Some(TreeChoice::Dynamic));
        assert!(TreeChoice::parse("nope").is_none());
    }

    #[test]
    fn method_roundtrip() {
        for m in ["eagle", "vanilla", "medusa", "lookahead", "classic-spec", "eagle-chain"] {
            assert_eq!(Method::parse(m).unwrap().name(), m);
        }
        assert!(Method::parse("nope").is_none());
    }
}
