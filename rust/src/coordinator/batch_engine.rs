//! Batched EAGLE engine (S15, Table 7): B sequences draft and verify in
//! lock-step on bs>1 executables. Lanes advance at their own acceptance
//! rate; finished lanes stay in the batch with `n_accept = 0` (their
//! cache stops changing) until every lane completes — the paper's
//! synchronous-batch setting. Also provides batched *vanilla* decoding as
//! the throughput baseline.
//!
//! Tree shaping follows the engine's [`TreePolicy`]: static per-level
//! widths, or the dynamic confidence-driven planner with one
//! [`SpecController`] per lane — each lane's speculation depth/width
//! adapts to its own request while the draft calls stay lock-step
//! (lanes that stop early contribute harmless padding rows).
//!
//! Each verify round dispatches to the cheapest lowered
//! `verify_t{t}_bs{b}` executable that holds every lane's tree (the max
//! over per-lane width fits — see `spec/dyntree/widths.rs`), so a batch
//! of low-acceptance lanes stops paying worst-case verify FLOPs. Draft
//! levels likewise dispatch the narrowest lowered `step_w{w}_bs{b}`
//! holding the round's widest per-lane step set (the `"draft_widths"`
//! family). One engine call executes ONE scheduler group: under
//! width-grouped admission the caller caps the verify family at the
//! group's planned width ([`BatchEagleEngine::with_verify_cap`]), so
//! both fits are group-local — a low-acceptance group never runs at a
//! hot lane's width, and any lane that still executes wider than its
//! own tree's fit is counted in `GenRecord::dragged_rounds`.
//!
//! Per-lane prefill reuses the bs=1 draft prefill and splices the lane's
//! rows into the batched draft cache host-side (caches are host vectors
//! between calls, so the splice is a memcpy — no extra executable).

use anyhow::{bail, Result};
use std::time::Instant;

use crate::metrics::GenRecord;
use crate::models::target::KvCache;
use crate::models::{EagleDraft, TargetModel};
use crate::spec::dyntree::{
    expand_candidates, plan_round_width, rerank, select_frontier, width_hint, DynTreeParams,
    SpecController, TreePolicy, WidthFamily,
};
use crate::spec::engine::GenConfig;
use crate::spec::sampling::{argmax, sample, softmax, top_k};
use crate::spec::tree::{chain_extend_bias, fill_step_rows, DraftTree, TreeSpec};
use crate::util::rng::Rng;

pub struct BatchEagleEngine<'a> {
    pub target: &'a TargetModel,
    pub draft: &'a EagleDraft,
    /// Per-lane draft-tree shaping (static widths or the dynamic planner
    /// with one [`SpecController`] per lane).
    pub policy: TreePolicy,
    /// Max verify width (budget anchor; the `_bs{b}` family fallback).
    pub verify_t: usize,
    /// Declared verify-width family (filtered per batch size at
    /// generate time against the lowered `verify_t{t}_bs{b}` set).
    pub verify_widths: Vec<usize>,
    /// Declared draft-step width family (filtered per batch size at
    /// generate time against the lowered `step_w{w}_bs{b}` set).
    pub draft_widths: Vec<usize>,
    pub accept_a: usize,
    pub draft_w: usize,
}

struct Lane {
    committed: Vec<u32>,
    m: usize,
    root_feat: Vec<f32>,
    root_logits: Vec<f32>,
    done: bool,
    rec: GenRecord,
}

impl<'a> BatchEagleEngine<'a> {
    pub fn new(
        target: &'a TargetModel,
        draft: &'a EagleDraft,
        c: &crate::runtime::manifest::Constants,
    ) -> Self {
        BatchEagleEngine {
            target,
            draft,
            policy: TreePolicy::default_tree(),
            verify_t: c.tree_t,
            verify_widths: c.verify_widths.clone(),
            draft_widths: c.draft_widths.clone(),
            accept_a: c.accept_a,
            draft_w: c.draft_w,
        }
    }

    /// Swap the tree policy (builder-style).
    pub fn with_policy(mut self, policy: TreePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Cap the verify-width family at a scheduler group's planned width
    /// (builder-style). Per-lane node budgets are planned against the
    /// capped family, so no lane's tree can outgrow the cap — the group
    /// executes at its own width, not a hotter group's.
    pub fn with_verify_cap(mut self, t: usize) -> Self {
        self.verify_t = t.clamp(2, self.verify_t);
        self
    }

    /// Generate for B prompts in lock-step (greedy, T=0 — the Table-7
    /// setting). Returns one record per lane.
    pub fn generate(&self, prompts: &[Vec<u32>], cfg: &GenConfig) -> Result<Vec<GenRecord>> {
        assert!(cfg.temperature <= 0.0, "batched engine is greedy (Table 7 setting)");
        let b = prompts.len();
        assert!(b >= 2, "use EagleEngine for bs=1");
        let t_all = Instant::now();
        let tgt = self.target;
        let d = tgt.d;
        let vocab = tgt.vocab;
        let s_tot = tgt.max_len;
        let p_win = tgt.prefill_p;
        let w = self.draft_w;

        // ---- per-lane prefill into the batched caches -----------------------
        let mut cache = tgt.new_cache(b);
        let mut dcache_b = self.draft.new_cache(b);
        let mut lanes: Vec<Lane> = Vec::with_capacity(b);
        for (li, prompt) in prompts.iter().enumerate() {
            let mut rec = GenRecord::new(prompt.len());
            let t0 = Instant::now();
            let (out, plen) = tgt.prefill_slot(b, &mut cache, li, prompt)?;
            rec.timeline.prefill_ns += t0.elapsed().as_nanos() as u64;
            rec.target_passes += 1;
            let root_tok = argmax(tgt.row(&out.logits, p_win, 0, plen - 1, vocab)) as u32;
            let mut committed = prompt.clone();
            committed.push(root_tok);
            rec.tokens.push(root_tok);

            // draft prefill (bs=1) then splice into the batched draft cache
            let mut dcache1 = self.draft.new_cache(1);
            let mut dtoks = vec![0i32; p_win];
            for i in 0..plen {
                dtoks[i] = committed[i + 1] as i32;
            }
            let mut dfeats = vec![0f32; p_win * d];
            dfeats[..plen * d].copy_from_slice(&out.feats[..plen * d]);
            let t0 = Instant::now();
            let dout = self.draft.prefill(&dfeats, &dtoks, plen, &mut dcache1)?;
            rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
            rec.draft_passes += 1;
            // splice lane rows: draft cache layout [2, B, S, H, dh]
            let lane_sz = s_tot * self.draft.n_heads * self.draft.head_dim;
            for kv in 0..2 {
                let src = &dcache1.data[kv * lane_sz..(kv + 1) * lane_sz];
                let dst_off = (kv * b + li) * lane_sz;
                dcache_b.data[dst_off..dst_off + lane_sz].copy_from_slice(src);
            }
            lanes.push(Lane {
                committed,
                m: plen,
                root_feat: dout.feats,
                root_logits: dout.logits,
                done: false,
                rec,
            });
        }

        // ---- lock-step rounds ------------------------------------------------
        // verify-width family lowered for THIS batch size; the per-round
        // width is the max over lane fits, so no lane is ever truncated.
        // Under width-grouped admission `verify_t` is the group's planned
        // cap, making both fits below group-local.
        let family = WidthFamily::from_available(&self.verify_widths, self.verify_t, |t| {
            tgt.has_verify(t, b)
        });
        // draft-step width family lowered for THIS batch size: each draft
        // level runs at the narrowest step_w{w}_bs{b} holding the round's
        // widest per-lane step set
        let dfam = WidthFamily::filtered(&self.draft_widths, self.draft_w, 1, |wd| {
            self.draft.has_step(wd, b)
        });
        // dynamic policy: one acceptance controller per lane, so each lane's
        // speculation depth/width tracks its own request
        let mut controllers: Vec<Option<SpecController>> = (0..b)
            .map(|_| match &self.policy {
                TreePolicy::Dynamic(dc) if dc.adaptive => Some(SpecController::new(
                    dc.clamped_controller(w, self.accept_a),
                    dc.params(self.verify_t, w, self.accept_a),
                )),
                _ => None,
            })
            .collect();
        let mut pending_old = vec![0i32; b];
        for (li, l) in lanes.iter().enumerate() {
            pending_old[li] = l.m as i32;
        }
        let mut pending_idx = vec![0i32; b * self.accept_a];
        let mut pending_n = vec![0i32; b];
        while lanes.iter().any(|l| !l.done) {
            // 1. grow per-lane trees with batched draft steps
            let mut trees: Vec<DraftTree> = lanes
                .iter()
                .map(|l| DraftTree::with_root(l.committed[l.m]))
                .collect();
            match &self.policy {
                TreePolicy::Static(spec) => {
                    self.grow_static_batch(spec, &dfam, &mut lanes, &mut trees, &mut dcache_b)?;
                }
                TreePolicy::Dynamic(dc) => {
                    // per-lane width plan BEFORE growth: each lane's node
                    // budget is clamped to the width its controller's EWMA
                    // justifies (see dyntree/widths.rs)
                    let lane_params: Vec<DynTreeParams> = (0..b)
                        .map(|li| {
                            let p = controllers[li]
                                .as_ref()
                                .map(|c| c.params())
                                .unwrap_or_else(|| dc.params(self.verify_t, w, self.accept_a));
                            plan_round_width(&family, &p, width_hint(controllers[li].as_ref())).1
                        })
                        .collect();
                    self.grow_dynamic_batch(
                        &lane_params, &dfam, &mut lanes, &mut trees, &mut dcache_b,
                    )?;
                }
            }

            // 2. batched verify at the max over lane width fits — the
            //    cheapest family member holding EVERY lane's tree
            let t = lanes
                .iter()
                .zip(&trees)
                .filter(|(l, _)| !l.done)
                .map(|(_, tr)| family.fit(tr.len()))
                .max()
                .unwrap_or_else(|| family.max());
            for li in 0..b {
                if lanes[li].done {
                    continue;
                }
                if trees[li].len() > t {
                    bail!(
                        "lane {li} draft tree of {} nodes exceeds the verify width family (max {})",
                        trees[li].len(),
                        family.max()
                    );
                }
                lanes[li].rec.round_tree_nodes.push(trees[li].len() - 1);
                lanes[li].rec.round_verify_t.push(t);
                // a lane executing wider than its OWN tree's fit was
                // dragged up by a hotter lane sharing this batch
                if t > family.fit(trees[li].len()) {
                    lanes[li].rec.dragged_rounds += 1;
                }
            }
            let mut tokens = vec![0i32; b * t];
            let mut pos = vec![0i32; b * t];
            let mut bias = vec![0f32; b * t * s_tot];
            for li in 0..b {
                let (tk, ps, bs) = trees[li].verify_inputs(t, lanes[li].m, s_tot);
                tokens[li * t..(li + 1) * t].copy_from_slice(&tk);
                pos[li * t..(li + 1) * t].copy_from_slice(&ps);
                bias[li * t * s_tot..(li + 1) * t * s_tot].copy_from_slice(&bs);
            }
            let t0 = Instant::now();
            let vout = tgt.verify(
                t, &mut cache, &pending_old, &pending_idx, &pending_n,
                &tokens, &pos, &bias, self.accept_a,
            )?;
            let ver_ns = t0.elapsed().as_nanos() as u64;
            for l in lanes.iter_mut().filter(|l| !l.done) {
                l.rec.timeline.verify_ns += ver_ns / b as u64;
                l.rec.target_passes += 1;
            }

            // 3. per-lane acceptance (committed inside the NEXT verify)
            pending_idx = vec![0i32; b * self.accept_a];
            pending_n = vec![0i32; b];
            for li in 0..b {
                pending_old[li] = lanes[li].m as i32;
            }
            let accept_idx = &mut pending_idx;
            let n_accept = &mut pending_n;
            let mut paths: Vec<Vec<usize>> = Vec::with_capacity(b);
            let mut bonuses = vec![0u32; b];
            for li in 0..b {
                if lanes[li].done {
                    paths.push(vec![]);
                    continue;
                }
                let path = trees[li].greedy_walk(|i| {
                    argmax(tgt.row(&vout.logits, t, li, i, vocab))
                });
                let deepest = *path.last().unwrap();
                bonuses[li] = argmax(tgt.row(&vout.logits, t, li, deepest, vocab)) as u32;
                for (j, &ni) in path.iter().enumerate() {
                    accept_idx[li * self.accept_a + j] = ni as i32;
                }
                n_accept[li] = path.len() as i32;
                paths.push(path);
            }
            // feed each lane's controller with its round outcome (dynamic
            // adaptive policy); attempted = deepest drafted chain position
            for li in 0..b {
                if lanes[li].done || paths[li].is_empty() {
                    continue;
                }
                if let Some(c) = controllers[li].as_mut() {
                    let attempted = trees[li].nodes.iter().map(|n| n.depth).max().unwrap_or(0);
                    c.observe_round(paths[li].len() - 1, attempted);
                }
            }
            let com_ns = 0u64;

            // 4. bookkeeping + batched draft extend at the narrowest
            //    lowered step width holding the widest accepted path
            let max_commit = paths.iter().map(|p| p.len()).max().unwrap_or(0).max(1);
            if max_commit > dfam.max() {
                bail!("accepted path of {max_commit} pairs exceeds draft width {}", dfam.max());
            }
            let w = dfam.fit(max_commit);
            let mut ef = vec![0f32; b * w * d];
            let mut et = vec![0i32; b * w];
            let mut ep = vec![0i32; b * w];
            let mut ebias = vec![0f32; b * w * s_tot];
            let mut wb = vec![0i32; b];
            for li in 0..b {
                wb[li] = lanes[li].m as i32;
                if lanes[li].done {
                    // harmless self-attending rows
                    let lb = chain_extend_bias(w, s_tot, lanes[li].m, 1);
                    ebias[li * w * s_tot..(li + 1) * w * s_tot].copy_from_slice(&lb);
                    for r in 0..w {
                        ep[li * w + r] = (lanes[li].m + r) as i32;
                    }
                    continue;
                }
                lanes[li].rec.timeline.commit_ns += com_ns / b as u64;
                let path = &paths[li];
                let n_commit = path.len();
                let round: Vec<u32> = path[1..]
                    .iter()
                    .map(|&ni| trees[li].nodes[ni].token)
                    .chain(std::iter::once(bonuses[li]))
                    .collect();
                lanes[li].rec.round_accepts.push(round.len());
                for &tok in &round {
                    lanes[li].committed.push(tok);
                    lanes[li].rec.tokens.push(tok);
                    if cfg.eos == Some(tok) || lanes[li].rec.tokens.len() >= cfg.max_new {
                        lanes[li].done = true;
                        break;
                    }
                }
                let m_new = lanes[li].m + n_commit;
                if m_new + self.verify_t + 1 >= s_tot {
                    lanes[li].done = true;
                }
                if lanes[li].done {
                    // lane just finished: fill harmless extend rows (eos may
                    // have cut `committed` short of slot_pos+1 pairs). `m` is
                    // deliberately frozen at its last valid value so later
                    // rounds keep building in-bounds (root-only) inputs.
                    let lb = chain_extend_bias(w, s_tot, lanes[li].m, 1);
                    ebias[li * w * s_tot..(li + 1) * w * s_tot].copy_from_slice(&lb);
                    for r in 0..w {
                        ep[li * w + r] = (lanes[li].m + r) as i32;
                    }
                    continue;
                }
                for (r, &ni) in path.iter().enumerate() {
                    let f = tgt.row(&vout.feats, t, li, ni, d);
                    ef[(li * w + r) * d..(li * w + r + 1) * d].copy_from_slice(f);
                    let slot_pos = lanes[li].m + r;
                    et[li * w + r] = lanes[li].committed[slot_pos + 1] as i32;
                    ep[li * w + r] = slot_pos as i32;
                }
                for r in n_commit..w {
                    ep[li * w + r] = (lanes[li].m + r) as i32;
                }
                let lb = chain_extend_bias(w, s_tot, lanes[li].m, n_commit);
                ebias[li * w * s_tot..(li + 1) * w * s_tot].copy_from_slice(&lb);
                lanes[li].m = m_new;
            }
            if lanes.iter().all(|l| l.done) {
                break;
            }
            let t0 = Instant::now();
            let eout = self.draft.step(w, &mut dcache_b, &wb, &ef, &et, &ep, &ebias)?;
            let ext_ns = t0.elapsed().as_nanos() as u64;
            for li in 0..b {
                if lanes[li].done {
                    continue;
                }
                lanes[li].rec.timeline.draft_ns += ext_ns / b as u64;
                lanes[li].rec.draft_passes += 1;
                lanes[li].rec.round_draft_w.push(w);
                let last = paths[li].len() - 1;
                lanes[li].root_feat =
                    eout.feats[(li * w + last) * d..(li * w + last + 1) * d].to_vec();
                lanes[li].root_logits =
                    eout.logits[(li * w + last) * vocab..(li * w + last + 1) * vocab].to_vec();
            }
        }

        let wall = t_all.elapsed().as_nanos() as u64;
        Ok(lanes
            .into_iter()
            .map(|mut l| {
                l.rec.wall_ns = wall;
                l.rec
            })
            .collect())
    }

    /// STATIC lock-step growth: fixed per-level widths, greedy top-k by
    /// cumulative score per lane (the seed behavior). Each level's step
    /// runs at the narrowest lowered `step_w{w}_bs{b}` holding the
    /// round's widest per-lane node set.
    fn grow_static_batch(
        &self,
        spec: &TreeSpec,
        dfam: &WidthFamily,
        lanes: &mut [Lane],
        trees: &mut [DraftTree],
        dcache_b: &mut KvCache,
    ) -> Result<()> {
        let b = lanes.len();
        let d = self.target.d;
        let vocab = self.target.vocab;
        let s_tot = self.target.max_len;

        let mut node_feat: Vec<Vec<Vec<f32>>> =
            lanes.iter().map(|l| vec![l.root_feat.clone()]).collect();
        let mut node_logits: Vec<Vec<Vec<f32>>> =
            lanes.iter().map(|l| vec![l.root_logits.clone()]).collect();
        let mut node_slot: Vec<Vec<Option<usize>>> = vec![vec![None]; b];
        let mut scratch_used = vec![0usize; b];
        let mut frontier: Vec<Vec<usize>> = vec![vec![0]; b];

        for (lvl, &width) in spec.level_widths.iter().enumerate() {
            // select per-lane candidates (greedy top-k by cum score)
            let mut new_nodes: Vec<Vec<usize>> = vec![Vec::new(); b];
            for li in 0..b {
                if lanes[li].done {
                    continue;
                }
                let mut cands: Vec<(usize, u32, f32)> = Vec::new();
                for &p in &frontier[li] {
                    let probs = softmax(&node_logits[li][p], 1.0);
                    for (tok, pr) in top_k(&probs, spec.branch) {
                        cands.push((p, tok as u32, trees[li].nodes[p].score + pr.max(1e-20).ln()));
                    }
                }
                cands.sort_by(|a, c| c.2.partial_cmp(&a.2).unwrap());
                cands.truncate(width);
                for (p, tok, score) in cands {
                    let ni = trees[li].add(p, tok, score, None);
                    node_feat[li].push(Vec::new());
                    node_logits[li].push(Vec::new());
                    node_slot[li].push(None);
                    new_nodes[li].push(ni);
                    lanes[li].rec.drafted += 1;
                }
            }
            if lvl + 1 == spec.level_widths.len() {
                break;
            }
            // batched draft step at the narrowest width holding every
            // lane's node set for this level
            let maxset = new_nodes.iter().map(|s| s.len()).max().unwrap_or(0).max(1);
            if maxset > dfam.max() {
                bail!("level of {maxset} nodes exceeds draft width {}", dfam.max());
            }
            let w = dfam.fit(maxset);
            let mut sf = vec![0f32; b * w * d];
            let mut st = vec![0i32; b * w];
            let mut sp = vec![0i32; b * w];
            let mut bias = vec![0f32; b * w * s_tot];
            let mut wb = vec![0i32; b];
            for li in 0..b {
                let base = lanes[li].m + scratch_used[li];
                wb[li] = base as i32;
                let lane_bias = fill_step_rows(
                    &trees[li],
                    &new_nodes[li],
                    &node_feat[li],
                    &mut node_slot[li],
                    true,
                    d,
                    s_tot,
                    lanes[li].m,
                    lanes[li].m,
                    base,
                    w,
                    &mut sf[li * w * d..(li + 1) * w * d],
                    &mut st[li * w..(li + 1) * w],
                    &mut sp[li * w..(li + 1) * w],
                );
                bias[li * w * s_tot..(li + 1) * w * s_tot].copy_from_slice(&lane_bias);
            }
            let t0 = Instant::now();
            let sout = self.draft.step(w, dcache_b, &wb, &sf, &st, &sp, &bias)?;
            let dns = t0.elapsed().as_nanos() as u64;
            for l in lanes.iter_mut().filter(|l| !l.done) {
                l.rec.timeline.draft_ns += dns / b as u64;
                l.rec.draft_passes += 1;
                l.rec.round_draft_w.push(w);
            }
            for li in 0..b {
                scratch_used[li] += w;
                for (r, &ni) in new_nodes[li].iter().enumerate() {
                    node_feat[li][ni] = sout.feats[(li * w + r) * d..(li * w + r + 1) * d].to_vec();
                    node_logits[li][ni] =
                        sout.logits[(li * w + r) * vocab..(li * w + r + 1) * vocab].to_vec();
                }
                frontier[li] = new_nodes[li].clone();
            }
        }
        Ok(())
    }

    /// DYNAMIC lock-step growth: per-lane confidence-driven expansion.
    /// Each lane expands its top-K frontier by cumulative draft log-prob
    /// and may run at a different (controller-adapted) depth; after
    /// growth every lane's candidate tree is globally reranked down to
    /// its verify budget. `lane_params` arrive pre-planned by the caller
    /// (controller shape + width-plan budget clamp, see
    /// `dyntree/widths.rs`). Drafted-token accounting happens
    /// post-rerank.
    fn grow_dynamic_batch(
        &self,
        lane_params: &[DynTreeParams],
        dfam: &WidthFamily,
        lanes: &mut [Lane],
        trees: &mut [DraftTree],
        dcache_b: &mut KvCache,
    ) -> Result<()> {
        let b = lanes.len();
        let d = self.target.d;
        let vocab = self.target.vocab;
        let s_tot = self.target.max_len;
        let w_cap = dfam.max();

        let max_depth = lane_params.iter().map(|p| p.depth).max().unwrap_or(1);
        let mut node_feat: Vec<Vec<Vec<f32>>> =
            lanes.iter().map(|l| vec![l.root_feat.clone()]).collect();
        let mut node_logits: Vec<Vec<Vec<f32>>> =
            lanes.iter().map(|l| vec![l.root_logits.clone()]).collect();
        let mut node_slot: Vec<Vec<Option<usize>>> = vec![vec![None]; b];
        let mut scratch_used = vec![0usize; b];
        let mut expandable: Vec<Vec<usize>> = vec![vec![0]; b];

        for lvl in 0..max_depth {
            // per-lane candidate generation + step-set selection
            let mut step_sets: Vec<Vec<usize>> = vec![Vec::new(); b];
            for li in 0..b {
                if lanes[li].done || lvl >= lane_params[li].depth {
                    continue;
                }
                let front =
                    select_frontier(&trees[li], &expandable[li], lane_params[li].frontier_k);
                let mut new_nodes = Vec::new();
                for &p in &front {
                    if node_logits[li][p].is_empty() {
                        continue;
                    }
                    let probs = softmax(&node_logits[li][p], 1.0);
                    for (tok, score) in
                        expand_candidates(trees[li].nodes[p].score, &probs, lane_params[li].branch)
                    {
                        let ni = trees[li].add(p, tok, score, None);
                        node_feat[li].push(Vec::new());
                        node_logits[li].push(Vec::new());
                        node_slot[li].push(None);
                        new_nodes.push(ni);
                    }
                }
                // step only while another level follows and scratch remains
                // (conservatively reserved at the family's widest step)
                if lvl + 1 < lane_params[li].depth
                    && lanes[li].m + scratch_used[li] + w_cap < s_tot
                {
                    step_sets[li] =
                        select_frontier(&trees[li], &new_nodes, lane_params[li].frontier_k);
                }
            }
            if step_sets.iter().all(|s| s.is_empty()) {
                break; // no lane can expand further
            }
            // batched draft step over the per-lane step sets, at the
            // narrowest lowered width holding the widest of them
            let maxset = step_sets.iter().map(|s| s.len()).max().unwrap_or(0).max(1);
            if maxset > dfam.max() {
                bail!("step set of {maxset} nodes exceeds draft width {}", dfam.max());
            }
            let w = dfam.fit(maxset);
            let mut sf = vec![0f32; b * w * d];
            let mut st = vec![0i32; b * w];
            let mut sp = vec![0i32; b * w];
            let mut bias = vec![0f32; b * w * s_tot];
            let mut wb = vec![0i32; b];
            for li in 0..b {
                // idle lanes rewrite fresh scratch at m: self-attending rows
                // only, always in-bounds (m + w << s_tot while a lane lives)
                let base = if step_sets[li].is_empty() {
                    lanes[li].m
                } else {
                    lanes[li].m + scratch_used[li]
                };
                wb[li] = base as i32;
                let lane_bias = fill_step_rows(
                    &trees[li],
                    &step_sets[li],
                    &node_feat[li],
                    &mut node_slot[li],
                    true,
                    d,
                    s_tot,
                    lanes[li].m,
                    lanes[li].m,
                    base,
                    w,
                    &mut sf[li * w * d..(li + 1) * w * d],
                    &mut st[li * w..(li + 1) * w],
                    &mut sp[li * w..(li + 1) * w],
                );
                bias[li * w * s_tot..(li + 1) * w * s_tot].copy_from_slice(&lane_bias);
            }
            let t0 = Instant::now();
            let sout = self.draft.step(w, dcache_b, &wb, &sf, &st, &sp, &bias)?;
            let dns = t0.elapsed().as_nanos() as u64;
            for l in lanes.iter_mut().filter(|l| !l.done) {
                l.rec.timeline.draft_ns += dns / b as u64;
                l.rec.draft_passes += 1;
                l.rec.round_draft_w.push(w);
            }
            for li in 0..b {
                if step_sets[li].is_empty() {
                    expandable[li].clear();
                    continue;
                }
                scratch_used[li] += w;
                for (r, &ni) in step_sets[li].iter().enumerate() {
                    node_feat[li][ni] = sout.feats[(li * w + r) * d..(li * w + r + 1) * d].to_vec();
                    node_logits[li][ni] =
                        sout.logits[(li * w + r) * vocab..(li * w + r + 1) * vocab].to_vec();
                }
                expandable[li] = step_sets[li].clone();
            }
        }
        // global rerank per lane: keep the best `budget` nodes for verify
        for li in 0..b {
            if lanes[li].done {
                continue;
            }
            if trees[li].len() - 1 > lane_params[li].budget {
                let (pruned, _kept) = rerank(&trees[li], lane_params[li].budget);
                trees[li] = pruned;
            }
            lanes[li].rec.drafted += trees[li].len() - 1;
        }
        Ok(())
    }

    /// Batched vanilla decoding — the Table-7 throughput baseline.
    pub fn vanilla_batch(&self, prompts: &[Vec<u32>], cfg: &GenConfig) -> Result<Vec<GenRecord>> {
        let b = prompts.len();
        let tgt = self.target;
        let vocab = tgt.vocab;
        let t_all = Instant::now();
        let mut cache: KvCache = tgt.new_cache(b);
        let mut recs: Vec<GenRecord> = prompts.iter().map(|p| GenRecord::new(p.len())).collect();
        let mut lens = vec![0i32; b];
        let mut toks = vec![0i32; b];
        let mut done = vec![false; b];
        let mut rng = Rng::new(cfg.seed);
        for (li, p) in prompts.iter().enumerate() {
            let (out, plen) = tgt.prefill_slot(b, &mut cache, li, p)?;
            recs[li].target_passes += 1;
            let logits = tgt.row(&out.logits, tgt.prefill_p, 0, plen - 1, vocab);
            let tok = if cfg.temperature <= 0.0 {
                argmax(logits) as u32
            } else {
                sample(&softmax(logits, cfg.temperature), &mut rng) as u32
            };
            recs[li].tokens.push(tok);
            toks[li] = tok as i32;
            lens[li] = plen as i32;
        }
        while !done.iter().all(|&d| d) {
            let out = tgt.decode(&mut cache, &lens, &toks)?;
            for li in 0..b {
                if done[li] {
                    continue;
                }
                recs[li].target_passes += 1;
                recs[li].round_accepts.push(1);
                lens[li] += 1;
                let logits = &out.logits[li * vocab..(li + 1) * vocab];
                let tok = if cfg.temperature <= 0.0 {
                    argmax(logits) as u32
                } else {
                    sample(&softmax(logits, cfg.temperature), &mut rng) as u32
                };
                recs[li].tokens.push(tok);
                toks[li] = tok as i32;
                if cfg.eos == Some(tok)
                    || recs[li].tokens.len() >= cfg.max_new
                    || (lens[li] as usize) + 2 >= tgt.max_len
                {
                    done[li] = true;
                }
            }
        }
        let wall = t_all.elapsed().as_nanos() as u64;
        for r in &mut recs {
            r.wall_ns = wall;
        }
        Ok(recs)
    }
}
