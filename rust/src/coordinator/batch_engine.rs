//! Batched EAGLE engine (S15, Table 7): B sequences draft and verify in
//! lock-step on bs>1 executables. Lanes advance at their own acceptance
//! rate; finished lanes stay in the batch with `n_accept = 0` (their
//! cache stops changing) until every lane completes — the paper's
//! synchronous-batch setting. Also provides batched *vanilla* decoding as
//! the throughput baseline.
//!
//! Sampling (T>0) runs multi-lane too: every lane owns an independent
//! RNG stream ([`BatchEagleEngine::generate_pooled_seeded`] takes one
//! seed per lane — the server passes each request's own seed), grows its
//! tree by i.i.d. draws from the draft distributions (retained in the
//! lane scratch's q-slab for the SpecInfer rule), and walks acceptance
//! through the same [`sampled_accept_walk`] the bs=1 engine uses. A
//! lane's sampled output therefore depends only on its own (prompt,
//! seed, per-round tree plan) — always invariant to batch composition
//! at a fixed batch size, and distribution-preserving regardless.
//! Bit-equality with the equal-seed bs=1 run additionally requires the
//! same plans: a static tree (always), or a dynamic policy whose width
//! family matches across batch sizes (`verify_t{t}` vs `_bs{b}`
//! lowerings) with the adaptive controller off — adaptive controllers
//! observe per-engine and may reshape trees, changing RNG draw counts
//! without biasing the output.
//!
//! Tree shaping follows the engine's [`TreePolicy`]: static per-level
//! widths, or the dynamic confidence-driven planner with one
//! [`SpecController`] per lane — each lane's speculation depth/frontier
//! adapts to its own request while the draft calls stay lock-step
//! (lanes that stop early contribute harmless padding rows).
//!
//! Each verify round dispatches to the cheapest lowered
//! `verify_t{t}_bs{b}` executable that holds every lane's tree (the max
//! over per-lane width fits — see `spec/dyntree/widths.rs`). Draft
//! levels likewise dispatch the narrowest lowered `step_w{w}_bs{b}`
//! holding the round's widest per-lane step set (the `"draft_widths"`
//! family). One engine call executes ONE scheduler group: under
//! width-grouped admission the caller caps the verify family at the
//! group's planned width ([`BatchEagleEngine::with_verify_cap`]), so
//! both fits are group-local — a low-acceptance group never runs at a
//! hot lane's width, and any lane that still executes wider than its
//! own tree's fit is counted in `GenRecord::dragged_rounds`.
//!
//! Host round state is zero-allocation in steady state (S22): per-lane
//! arenas/slabs and the `[B, ..]` staging buffers live in a
//! [`ScratchPool`] **keyed by KV slot** — pass one to
//! [`BatchEagleEngine::generate_pooled`] to reuse warm buffers across
//! admissions (the server worker owns one pool for its whole lifetime);
//! [`BatchEagleEngine::generate`] allocates a throwaway pool for
//! one-shot callers. Per-round scratch growth is recorded per lane as
//! `GenRecord::round_host_alloc_bytes` (the pool-wide delta; 0 once
//! warm).
//!
//! Per-lane prefill reuses the bs=1 draft prefill and splices the lane's
//! rows into the batched draft cache host-side (caches are host vectors
//! between calls, so the splice is a memcpy — no extra executable).
//!
//! **Checkpointable lanes (S24):** with a [`PreemptSignal`] attached
//! ([`BatchEagleEngine::with_preempt`]), any lane can be suspended at a
//! round boundary: [`BatchEagleEngine::generate_pooled_entries`]
//! captures the lane's full state — committed prefix, both KV-cache row
//! slices, the draft root feature/logits, the RNG stream position, the
//! controller's EWMA/width-hysteresis state, and the fused-commit
//! pending triple the next verify would have consumed — into a
//! [`LaneCheckpoint`], and the batch runs on without the lane (it
//! becomes padding, like a finished lane). The checkpoint re-enters a
//! later call as [`LaneInput::Resume`] and continues **bit-identically**
//! to the uninterrupted run: resident KV is spliced back by the same
//! strided memcpy the prefill uses; evicted KV is rebuilt by prefix
//! re-prefill (`GenRecord::resume_refill_rounds` counts the extra
//! passes). Bit-identity additionally requires the resumed group to
//! lower the same verify/draft width families — the serving default,
//! where every group filters the one declared `verify_widths` list.
//!
//! **Draft-source homogeneity (PR 10):** this engine batches the EAGLE
//! source only. Heterogeneous sources (chain / n-gram / Medusa, see
//! `spec/source.rs` and `docs/drafting.md`) run on the bs=1
//! [`crate::spec::source::SourceEngine`] path; the scheduler's
//! compatibility key includes the resolved source, so a width group
//! never mixes sources and anything non-eagle simply forms bs=1 groups.
//! A generic batched loop over `DraftSource` lanes is the ROADMAP
//! follow-on.

use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Instant;

use super::checkpoint::{copy_lane_kv_in, copy_lane_kv_out, LaneCheckpoint, PreemptSignal};
use crate::metrics::trace::{RoundEvent, RoundObserver};
use crate::metrics::GenRecord;
use crate::models::target::KvCache;
use crate::models::{EagleDraft, TargetModel};
use crate::spec::dyntree::{
    expand_candidates_into, plan_round_width, rerank_into, select_frontier_into, width_hint,
    SpecController, TreePolicy, WidthFamily,
};
use crate::spec::engine::{sampled_accept_walk, GenConfig};
use crate::spec::sampling::{argmax, sample, softmax, softmax_into, top_k_into};
use crate::spec::scratch::ScratchPool;
use crate::spec::tree::{chain_extend_bias_to, fill_step_rows_into, DraftTree, TreeSpec};
use crate::util::deadline::DeadlineClock;
use crate::util::rng::Rng;

pub struct BatchEagleEngine<'a> {
    pub target: &'a TargetModel,
    pub draft: &'a EagleDraft,
    /// Per-lane draft-tree shaping (static widths or the dynamic planner
    /// with one [`SpecController`] per lane).
    pub policy: TreePolicy,
    /// Max verify width (budget anchor; the `_bs{b}` family fallback).
    pub verify_t: usize,
    /// Declared verify-width family (filtered per batch size at
    /// generate time against the lowered `verify_t{t}_bs{b}` set).
    pub verify_widths: Vec<usize>,
    /// Declared draft-step width family (filtered per batch size at
    /// generate time against the lowered `step_w{w}_bs{b}` set).
    pub draft_widths: Vec<usize>,
    pub accept_a: usize,
    pub draft_w: usize,
    /// Optional per-round hook (flight recorder / serving metrics),
    /// invoked once per lane per completed lock-step round with the
    /// lane index as the event's lane id. Must not allocate — it runs
    /// inside the zero-alloc round loop.
    pub observer: Option<&'a dyn RoundObserver>,
    /// Per-lane request deadlines (empty = all unbounded), polled at the
    /// top of every lock-step round. An expired lane is marked done with
    /// `rec.truncated = Some("deadline")` and — like any finished lane —
    /// contributes only harmless padding rows from then on, so the rest
    /// of the group keeps its lock-step cadence. Allocated once at
    /// builder time; the per-round checks are clock reads only.
    pub deadlines: Vec<DeadlineClock>,
    /// Suspension requests, polled at round boundaries: a requested lane
    /// is captured into a [`LaneCheckpoint`] at its next boundary and
    /// the batch runs on without it. `None` (the default) disables
    /// preemption entirely.
    pub preempt: Option<Arc<PreemptSignal>>,
}

/// One lane's input to [`BatchEagleEngine::generate_pooled_entries`]:
/// a fresh prompt, or a suspended lane's checkpoint to resume.
pub enum LaneInput<'p> {
    Fresh { prompt: &'p [u32], seed: u64 },
    Resume { ckpt: Box<LaneCheckpoint> },
}

/// One lane's outcome: a finished generation record, or the checkpoint
/// of a lane suspended at a round boundary (re-enqueue it as a
/// [`LaneInput::Resume`] to continue).
pub enum LaneOutcome {
    Done(GenRecord),
    Suspended(Box<LaneCheckpoint>),
}

struct Lane {
    committed: Vec<u32>,
    m: usize,
    root_feat: Vec<f32>,
    root_logits: Vec<f32>,
    done: bool,
    /// Suspended at a round boundary this call: done for the lock-step
    /// loop but NOT complete — the checkpoint is parked in `ckpt`.
    suspended: bool,
    /// RNG stream identity: the ORIGINAL seed, surviving re-suspension
    /// (`Rng::draws` counts from it cumulatively).
    seed: u64,
    /// The lane's reusable checkpoint box: present for resumed lanes so
    /// a warm re-capture allocates nothing, and after suspension.
    ckpt: Option<Box<LaneCheckpoint>>,
    rec: GenRecord,
}

impl<'a> BatchEagleEngine<'a> {
    pub fn new(
        target: &'a TargetModel,
        draft: &'a EagleDraft,
        c: &crate::runtime::manifest::Constants,
    ) -> Self {
        BatchEagleEngine {
            target,
            draft,
            policy: TreePolicy::default_tree(),
            verify_t: c.tree_t,
            verify_widths: c.verify_widths.clone(),
            draft_widths: c.draft_widths.clone(),
            accept_a: c.accept_a,
            draft_w: c.draft_w,
            observer: None,
            deadlines: Vec::new(),
            preempt: None,
        }
    }

    /// Swap the tree policy (builder-style).
    pub fn with_policy(mut self, policy: TreePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Attach one deadline clock per lane (builder-style; the server
    /// passes each request's own budget). Must match the batch size at
    /// generate time; an empty vec (the default) disables deadlines.
    pub fn with_deadlines(mut self, deadlines: Vec<DeadlineClock>) -> Self {
        self.deadlines = deadlines;
        self
    }

    /// Attach a per-round observer (builder-style; the server threads
    /// its flight recorder + metrics registry through here).
    pub fn with_observer(mut self, observer: &'a dyn RoundObserver) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Attach a preemption signal (builder-style). The serving worker
    /// requests lanes (deadline / memory-pressure / drain preemption);
    /// [`BatchEagleEngine::generate_pooled_entries`] honors each request
    /// at the lane's next round boundary.
    pub fn with_preempt(mut self, sig: Arc<PreemptSignal>) -> Self {
        self.preempt = Some(sig);
        self
    }

    /// Cap the verify-width family at a scheduler group's planned width
    /// (builder-style). Per-lane node budgets are planned against the
    /// capped family, so no lane's tree can outgrow the cap — the group
    /// executes at its own width, not a hotter group's.
    pub fn with_verify_cap(mut self, t: usize) -> Self {
        self.verify_t = t.clamp(2, self.verify_t);
        self
    }

    /// The largest draft tree any lane's round can grow (the per-lane
    /// scratch reservation ceiling).
    fn max_tree_nodes(&self) -> usize {
        match &self.policy {
            TreePolicy::Static(spec) => spec.total_nodes(),
            TreePolicy::Dynamic(dc) => {
                let base = dc.params(self.verify_t, self.draft_w, self.accept_a);
                let cc = dc.clamped_controller(self.draft_w, self.accept_a);
                let depth = base.depth.max(cc.max_depth);
                let fk = base.frontier_k.max(cc.max_frontier);
                depth * fk * base.branch + 1
            }
        }
    }

    /// Generate for B prompts in lock-step with a throwaway scratch
    /// pool. One-shot convenience over
    /// [`BatchEagleEngine::generate_pooled`].
    pub fn generate(&self, prompts: &[Vec<u32>], cfg: &GenConfig) -> Result<Vec<GenRecord>> {
        self.generate_pooled(prompts, cfg, &mut ScratchPool::new())
    }

    /// Generate for B prompts in lock-step, drawing per-lane round state
    /// from `pool` (keyed by KV slot = lane index). Lane seeds default
    /// to `cfg.seed + lane index` (each lane still gets its own stream);
    /// callers that care which request lands in which lane — the server
    /// worker — pass explicit per-request seeds via
    /// [`BatchEagleEngine::generate_pooled_seeded`] so sampled output is
    /// invariant to batch composition.
    pub fn generate_pooled(
        &self,
        prompts: &[Vec<u32>],
        cfg: &GenConfig,
        pool: &mut ScratchPool,
    ) -> Result<Vec<GenRecord>> {
        let seeds: Vec<u64> =
            (0..prompts.len()).map(|li| cfg.seed.wrapping_add(li as u64)).collect();
        self.generate_pooled_seeded(prompts, &seeds, cfg, pool)
    }

    /// [`BatchEagleEngine::generate_pooled`] with one RNG seed per lane.
    /// `seeds[li]` seeds lane `li`'s independent stream exactly as
    /// `GenConfig::seed` seeds a bs=1 [`crate::spec::engine::EagleEngine`]
    /// run, so a sampled request's tokens do not depend on which other
    /// lanes share the batch (T=0 lanes ignore their stream), and — when
    /// the per-round tree plans match (see the module doc) — equal the
    /// equal-seed bs=1 run exactly. Callers that serve many admissions
    /// keep one pool so lane buffers stay warm across groups. Returns
    /// one record per lane.
    pub fn generate_pooled_seeded(
        &self,
        prompts: &[Vec<u32>],
        seeds: &[u64],
        cfg: &GenConfig,
        pool: &mut ScratchPool,
    ) -> Result<Vec<GenRecord>> {
        assert_eq!(seeds.len(), prompts.len(), "one seed per lane");
        let inputs: Vec<LaneInput<'_>> = prompts
            .iter()
            .zip(seeds)
            .map(|(p, &seed)| LaneInput::Fresh { prompt: p.as_slice(), seed })
            .collect();
        Ok(self
            .generate_pooled_entries(inputs, cfg, pool)?
            .into_iter()
            .map(|o| match o {
                LaneOutcome::Done(rec) => rec,
                LaneOutcome::Suspended(_) => {
                    unreachable!("record-only callers run without a preempt signal")
                }
            })
            .collect())
    }

    /// The lock-step workhorse: each lane is either a fresh prompt or a
    /// suspended lane's checkpoint ([`LaneInput`]), and each outcome is
    /// either a finished record or a new checkpoint ([`LaneOutcome`]) —
    /// lanes whose [`PreemptSignal`] bit was raised are captured at
    /// their next round boundary while their peers run on unchanged.
    /// Resume is bit-identical to the uninterrupted run (see the module
    /// doc); a resumed lane whose KV was evicted first rebuilds it by
    /// re-prefilling its committed prefix, which requires the prefix to
    /// fit the prefill window (`TargetModel::prefill_p`) — longer
    /// contexts must keep their KV resident (raise `--kv-budget`).
    pub fn generate_pooled_entries(
        &self,
        inputs: Vec<LaneInput<'_>>,
        cfg: &GenConfig,
        pool: &mut ScratchPool,
    ) -> Result<Vec<LaneOutcome>> {
        let b = inputs.len();
        assert!(b >= 2, "use EagleEngine for bs=1");
        assert!(
            self.deadlines.is_empty() || self.deadlines.len() == b,
            "one deadline per lane (or none)"
        );
        let mut rngs: Vec<Rng> = Vec::with_capacity(b);
        let t_all = Instant::now();
        let tgt = self.target;
        let d = tgt.d;
        let vocab = tgt.vocab;
        let s_tot = tgt.max_len;
        let p_win = tgt.prefill_p;
        let w = self.draft_w;

        // fused-commit pending state, seeded during lane setup: a fresh
        // prefill (and an evicted-KV resume, which re-creates the fresh
        // initial condition) contributes `(m, -, 0)`; a resident resume
        // restores the suspended round's triple verbatim
        let mut pending_old = vec![0i32; b];
        let mut pending_idx = vec![0i32; b * self.accept_a];
        let mut pending_n = vec![0i32; b];

        // ---- per-lane prefill / checkpoint restore into the batched caches --
        let mut cache = tgt.new_cache(b);
        let mut dcache_b = self.draft.new_cache(b);
        // draft cache layout [2, B, S, H, dh]: one lane's rows per kv half
        let lane_sz = s_tot * self.draft.n_heads * self.draft.head_dim;
        let mut lanes: Vec<Lane> = Vec::with_capacity(b);
        for (li, input) in inputs.into_iter().enumerate() {
            match input {
                LaneInput::Fresh { prompt, seed } => {
                    rngs.push(Rng::new(seed));
                    let mut rec = GenRecord::new(prompt.len());
                    rec.reserve_rounds(cfg.max_new);
                    let t0 = Instant::now();
                    let (out, plen) = tgt.prefill_slot(b, &mut cache, li, prompt)?;
                    rec.timeline.prefill_ns += t0.elapsed().as_nanos() as u64;
                    rec.target_passes += 1;
                    let last_logits = tgt.row(&out.logits, p_win, 0, plen - 1, vocab);
                    // root pick mirrors EagleEngine::pick on the lane's own
                    // stream
                    let root_tok = if cfg.temperature <= 0.0 {
                        argmax(last_logits) as u32
                    } else {
                        sample(&softmax(last_logits, cfg.temperature), &mut rngs[li]) as u32
                    };
                    // pre-sized so steady-state commits never grow it
                    let mut committed: Vec<u32> =
                        Vec::with_capacity(prompt.len() + cfg.max_new + self.accept_a + 2);
                    committed.extend_from_slice(prompt);
                    committed.push(root_tok);
                    rec.tokens.push(root_tok);
                    // first committed token for this lane (lock-step prefill
                    // is sequential, so later lanes see earlier lanes'
                    // prefill time)
                    rec.ttft_ns = t_all.elapsed().as_nanos() as u64;

                    // draft prefill (bs=1) then splice into the batched
                    // draft cache
                    let mut dcache1 = self.draft.new_cache(1);
                    let mut dtoks = vec![0i32; p_win];
                    for i in 0..plen {
                        dtoks[i] = committed[i + 1] as i32;
                    }
                    let mut dfeats = vec![0f32; p_win * d];
                    dfeats[..plen * d].copy_from_slice(&out.feats[..plen * d]);
                    let t0 = Instant::now();
                    let dout = self.draft.prefill(&dfeats, &dtoks, plen, &mut dcache1)?;
                    rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
                    rec.draft_passes += 1;
                    for kv in 0..2 {
                        let src = &dcache1.data[kv * lane_sz..(kv + 1) * lane_sz];
                        let dst_off = (kv * b + li) * lane_sz;
                        dcache_b.data[dst_off..dst_off + lane_sz].copy_from_slice(src);
                    }
                    pending_old[li] = plen as i32;
                    lanes.push(Lane {
                        committed,
                        m: plen,
                        root_feat: dout.feats,
                        root_logits: dout.logits,
                        done: false,
                        suspended: false,
                        seed,
                        ckpt: None,
                        rec,
                    });
                }
                LaneInput::Resume { mut ckpt } => {
                    // the stream continues at its exact draw position —
                    // every future draw equals the uninterrupted run's
                    rngs.push(Rng::resume(ckpt.rng_seed, ckpt.rng_draws));
                    let committed = std::mem::take(&mut ckpt.committed);
                    let m = ckpt.m;
                    let root_feat = std::mem::take(&mut ckpt.root_feat);
                    let root_logits = std::mem::take(&mut ckpt.root_logits);
                    let mut rec = std::mem::replace(&mut ckpt.rec, GenRecord::new(0));
                    rec.reserve_rounds(cfg.max_new);
                    if crate::failpoint!("resume") {
                        // degenerate resume: drop the resident KV so the
                        // lane exercises the slow re-prefill path
                        ckpt.evict_kv();
                    }
                    if ckpt.kv_resident {
                        copy_lane_kv_in(&mut cache, li, &ckpt.kv_target);
                        copy_lane_kv_in(&mut dcache_b, li, &ckpt.kv_draft);
                        pending_old[li] = ckpt.pending_old;
                        let pr = li * self.accept_a..(li + 1) * self.accept_a;
                        pending_idx[pr].copy_from_slice(&ckpt.pending_idx);
                        pending_n[li] = ckpt.pending_n;
                    } else {
                        // evicted KV: rebuild the lane's rows by prefix
                        // re-prefill — deterministic kernels reproduce the
                        // exact cache state, and the root feature/logits
                        // travelled in the checkpoint, so only latency
                        // degrades. The suspended round's pending commit is
                        // already part of `committed[..m]` here (eviction
                        // clears the scratch region), so pending resets to
                        // the fresh-prefill initial condition.
                        if m > p_win {
                            bail!(
                                "evicted lane of {m} committed tokens exceeds the prefill \
                                 window {p_win}; keep its KV resident (raise --kv-budget)"
                            );
                        }
                        let t0 = Instant::now();
                        let (out, plen) = tgt.prefill_slot(b, &mut cache, li, &committed[..m])?;
                        rec.timeline.prefill_ns += t0.elapsed().as_nanos() as u64;
                        rec.target_passes += 1;
                        debug_assert_eq!(plen, m);
                        let mut dcache1 = self.draft.new_cache(1);
                        let mut dtoks = vec![0i32; p_win];
                        for i in 0..m {
                            dtoks[i] = committed[i + 1] as i32;
                        }
                        let mut dfeats = vec![0f32; p_win * d];
                        dfeats[..m * d].copy_from_slice(&out.feats[..m * d]);
                        let t0 = Instant::now();
                        self.draft.prefill(&dfeats, &dtoks, m, &mut dcache1)?;
                        rec.timeline.draft_ns += t0.elapsed().as_nanos() as u64;
                        rec.draft_passes += 1;
                        for kv in 0..2 {
                            let src = &dcache1.data[kv * lane_sz..(kv + 1) * lane_sz];
                            let dst_off = (kv * b + li) * lane_sz;
                            dcache_b.data[dst_off..dst_off + lane_sz].copy_from_slice(src);
                        }
                        pending_old[li] = m as i32;
                        ckpt.refill_rounds += 1;
                        rec.resume_refill_rounds += 1;
                    }
                    lanes.push(Lane {
                        committed,
                        m,
                        root_feat,
                        root_logits,
                        done: false,
                        suspended: false,
                        seed: ckpt.rng_seed,
                        ckpt: Some(ckpt),
                        rec,
                    });
                }
            }
        }

        // ---- lock-step rounds ------------------------------------------------
        // verify-width family lowered for THIS batch size; the per-round
        // width is the max over lane fits, so no lane is ever truncated.
        // Under width-grouped admission `verify_t` is the group's planned
        // cap, making both fits below group-local.
        let family = WidthFamily::from_available(&self.verify_widths, self.verify_t, |t| {
            tgt.has_verify(t, b)
        });
        // draft-step width family lowered for THIS batch size: each draft
        // level runs at the narrowest step_w{w}_bs{b} holding the round's
        // widest per-lane step set
        let dfam = WidthFamily::filtered(&self.draft_widths, self.draft_w, 1, |wd| {
            self.draft.has_step(wd, b)
        });
        // dynamic policy: one acceptance controller per lane, so each lane's
        // speculation depth/width tracks its own request
        let mut controllers: Vec<Option<SpecController>> = (0..b)
            .map(|_| match &self.policy {
                TreePolicy::Dynamic(dc) if dc.adaptive => Some(SpecController::new(
                    dc.clamped_controller(w, self.accept_a),
                    dc.params(self.verify_t, w, self.accept_a),
                )),
                _ => None,
            })
            .collect();
        // resumed lanes restore their controller's EWMA / hysteresis
        // state, so adaptation continues exactly where it left off
        for (li, l) in lanes.iter().enumerate() {
            if let (Some(ctl), Some(snap)) =
                (controllers[li].as_mut(), l.ckpt.as_ref().and_then(|c| c.controller.as_ref()))
            {
                ctl.restore(snap);
            }
        }

        // ---- round state (S22): lane scratch keyed by KV slot ---------------
        let max_nodes = self.max_tree_nodes();
        let t_reserve = family.max().max(self.verify_t);
        let w_reserve = dfam.max().max(self.draft_w);
        pool.ensure_lanes(b, d, vocab);
        for lane in &mut pool.lanes[..b] {
            lane.reserve(d, vocab, s_tot, max_nodes, t_reserve, w_reserve);
            if cfg.temperature > 0.0 {
                lane.reserve_q(vocab, max_nodes);
            }
        }
        pool.batch.reserve(b, d, s_tot, t_reserve, w_reserve);
        let mut trees: Vec<DraftTree> = (0..b)
            .map(|_| {
                let mut t = DraftTree::default();
                t.nodes.reserve(max_nodes);
                t
            })
            .collect();
        let mut bonuses = vec![0u32; b];

        // per-lane timeline snapshot at round start (observer phase
        // deltas); allocated once, before the zero-alloc round loop
        let mut tl0: Vec<(u64, u64, u64)> = vec![(0, 0, 0); b];
        while lanes.iter().any(|l| !l.done) {
            // deadline cancellation: an expired live lane stops drafting
            // HERE — marked done with its partial record tagged; from now
            // on the padding machinery below treats it exactly like a
            // finished lane (frozen `m`, harmless self-attending rows),
            // so the rest of the group keeps its lock-step cadence
            if !self.deadlines.is_empty() {
                for (li, l) in lanes.iter_mut().enumerate() {
                    if !l.done && self.deadlines[li].expired() {
                        l.done = true;
                        l.rec.truncated = Some("deadline");
                    }
                }
                if lanes.iter().all(|l| l.done) {
                    break;
                }
            }
            // round-boundary preemption: a requested lane is captured
            // into its checkpoint HERE — after the previous round's
            // controller observation, before the next round's growth —
            // and becomes padding; peers keep the lock-step cadence. A
            // resumed lane re-captures into its own box (warm: zero
            // allocation); a fresh lane's first suspension sizes its
            // buffers once.
            if let Some(sig) = self.preempt.as_deref() {
                if sig.any() {
                    for li in 0..b {
                        if lanes[li].done || !sig.take(li) {
                            continue;
                        }
                        if crate::failpoint!("checkpoint") {
                            // degenerate capture: the request is dropped
                            // and the lane simply keeps running
                            continue;
                        }
                        self.suspend_lane(
                            li,
                            &mut lanes[li],
                            &cache,
                            &dcache_b,
                            &controllers[li],
                            &family,
                            &rngs[li],
                            &pending_old,
                            &pending_idx,
                            &pending_n,
                        );
                    }
                    if lanes.iter().all(|l| l.done) {
                        break;
                    }
                }
            }
            let fp0 =
                pool.footprint() + trees.iter().map(DraftTree::capacity_bytes).sum::<usize>();
            #[cfg(feature = "count-alloc")]
            let counted0 = crate::util::count_alloc::thread_allocated_bytes();
            for (li, l) in lanes.iter().enumerate() {
                tl0[li] =
                    (l.rec.timeline.draft_ns, l.rec.timeline.verify_ns, l.rec.timeline.host_ns);
            }
            {
                let bs = &mut pool.batch;
                bs.live.clear();
                bs.live.extend(lanes.iter().map(|l| !l.done));
            }
            // 1. grow per-lane trees with batched draft steps
            for li in 0..b {
                trees[li].reset(lanes[li].committed[lanes[li].m]);
                pool.lanes[li].begin_round(&lanes[li].root_feat, &lanes[li].root_logits);
            }
            match &self.policy {
                TreePolicy::Static(spec) => {
                    self.grow_static_batch(
                        spec, &dfam, &mut lanes, &mut trees, &mut dcache_b, pool, cfg, &mut rngs,
                    )?;
                }
                TreePolicy::Dynamic(dc) => {
                    // per-lane width plan BEFORE growth: each lane's node
                    // budget is clamped to the width its controller's EWMA
                    // justifies (see dyntree/widths.rs)
                    {
                        let bs = &mut pool.batch;
                        bs.lane_params.clear();
                        for ctl in controllers.iter().take(b) {
                            let p = ctl
                                .as_ref()
                                .map(|c| c.params())
                                .unwrap_or_else(|| dc.params(self.verify_t, w, self.accept_a));
                            bs.lane_params
                                .push(plan_round_width(&family, &p, width_hint(ctl.as_ref())).1);
                        }
                    }
                    self.grow_dynamic_batch(
                        &dfam, &mut lanes, &mut trees, &mut dcache_b, pool, cfg, &mut rngs,
                    )?;
                }
            }

            // 2. batched verify at the max over lane width fits — the
            //    cheapest family member holding EVERY lane's tree
            let t = lanes
                .iter()
                .zip(&trees)
                .filter(|(l, _)| !l.done)
                .map(|(_, tr)| family.fit(tr.len()))
                .max()
                .unwrap_or_else(|| family.max());
            for li in 0..b {
                if lanes[li].done {
                    continue;
                }
                if trees[li].len() > t {
                    bail!(
                        "lane {li} draft tree of {} nodes exceeds the verify width family (max {})",
                        trees[li].len(),
                        family.max()
                    );
                }
                lanes[li].rec.round_tree_nodes.push(trees[li].len() - 1);
                lanes[li].rec.round_verify_t.push(t);
                // a lane executing wider than its OWN tree's fit was
                // dragged up by a hotter lane sharing this batch
                if t > family.fit(trees[li].len()) {
                    lanes[li].rec.dragged_rounds += 1;
                }
            }
            {
                let bs = &mut pool.batch;
                bs.vtokens.clear();
                bs.vtokens.resize(b * t, 0);
                bs.vpos.clear();
                bs.vpos.resize(b * t, 0);
                bs.vbias.clear();
                bs.vbias.resize(b * t * s_tot, 0.0);
                for li in 0..b {
                    trees[li].verify_inputs_to(
                        t,
                        lanes[li].m,
                        s_tot,
                        &mut bs.vtokens[li * t..(li + 1) * t],
                        &mut bs.vpos[li * t..(li + 1) * t],
                        &mut bs.vbias[li * t * s_tot..(li + 1) * t * s_tot],
                        &mut bs.anc,
                    );
                }
            }
            let t0 = Instant::now();
            let fp_degenerate_verify = crate::failpoint!("verify");
            let mut vout = tgt.verify(
                t,
                &mut cache,
                &pending_old,
                &pending_idx,
                &pending_n,
                &pool.batch.vtokens,
                &pool.batch.vpos,
                &pool.batch.vbias,
                self.accept_a,
            )?;
            if fp_degenerate_verify {
                vout.logits.iter_mut().for_each(|x| *x = f32::NAN);
            }
            let ver_ns = t0.elapsed().as_nanos() as u64;
            for l in lanes.iter_mut().filter(|l| !l.done) {
                l.rec.timeline.verify_ns += ver_ns / b as u64;
                l.rec.target_passes += 1;
            }

            // 3. per-lane acceptance (committed inside the NEXT verify);
            //    per-lane path buffers come from the pool
            pending_idx.iter_mut().for_each(|x| *x = 0);
            pending_n.iter_mut().for_each(|x| *x = 0);
            for li in 0..b {
                pending_old[li] = lanes[li].m as i32;
            }
            for li in 0..b {
                if lanes[li].done {
                    pool.lanes[li].path.clear();
                    continue;
                }
                if cfg.temperature <= 0.0 {
                    let path = &mut pool.lanes[li].path;
                    let walk = |i: usize| argmax(tgt.row(&vout.logits, t, li, i, vocab));
                    trees[li].greedy_walk_into(walk, path);
                    let deepest = *path.last().unwrap();
                    bonuses[li] = argmax(tgt.row(&vout.logits, t, li, deepest, vocab)) as u32;
                } else {
                    // the same SpecInfer walk the bs=1 engine runs, on
                    // this lane's scratch + RNG stream (bit-identical to
                    // the lane's equal-seed bs=1 run)
                    bonuses[li] = sampled_accept_walk(
                        &trees[li],
                        |i| tgt.row(&vout.logits, t, li, i, vocab),
                        cfg.temperature,
                        &mut rngs[li],
                        &mut lanes[li].rec.alpha,
                        &mut pool.lanes[li],
                    );
                }
                let path = &pool.lanes[li].path;
                for (j, &ni) in path.iter().enumerate() {
                    pending_idx[li * self.accept_a + j] = ni as i32;
                }
                pending_n[li] = path.len() as i32;
            }
            // feed each lane's controller with its round outcome (dynamic
            // adaptive policy); attempted = deepest drafted chain position
            for li in 0..b {
                if lanes[li].done || pool.lanes[li].path.is_empty() {
                    continue;
                }
                if let Some(c) = controllers[li].as_mut() {
                    let attempted = trees[li].nodes.iter().map(|n| n.depth).max().unwrap_or(0);
                    c.observe_round(pool.lanes[li].path.len() - 1, attempted);
                }
            }
            let com_ns = 0u64;

            // 4. bookkeeping + batched draft extend at the narrowest
            //    lowered step width holding the widest accepted path
            let max_commit =
                pool.lanes[..b].iter().map(|l| l.path.len()).max().unwrap_or(0).max(1);
            if max_commit > dfam.max() {
                bail!("accepted path of {max_commit} pairs exceeds draft width {}", dfam.max());
            }
            let w = dfam.fit(max_commit);
            {
                let bs = &mut pool.batch;
                bs.sf.clear();
                bs.sf.resize(b * w * d, 0.0);
                bs.st.clear();
                bs.st.resize(b * w, 0);
                bs.sp.clear();
                bs.sp.resize(b * w, 0);
                bs.sbias.clear();
                bs.sbias.resize(b * w * s_tot, 0.0);
                bs.wb.clear();
                bs.wb.resize(b, 0);
            }
            for li in 0..b {
                pool.batch.wb[li] = lanes[li].m as i32;
                if lanes[li].done {
                    // harmless self-attending rows
                    let brange = li * w * s_tot..(li + 1) * w * s_tot;
                    chain_extend_bias_to(w, s_tot, lanes[li].m, 1, &mut pool.batch.sbias[brange]);
                    for r in 0..w {
                        pool.batch.sp[li * w + r] = (lanes[li].m + r) as i32;
                    }
                    continue;
                }
                lanes[li].rec.timeline.commit_ns += com_ns / b as u64;
                let n_commit = pool.lanes[li].path.len();
                lanes[li].rec.round_accepts.push(n_commit);
                for k in 0..n_commit {
                    let tok = if k + 1 < n_commit {
                        trees[li].nodes[pool.lanes[li].path[k + 1]].token
                    } else {
                        bonuses[li]
                    };
                    lanes[li].committed.push(tok);
                    lanes[li].rec.tokens.push(tok);
                    if cfg.eos == Some(tok) || lanes[li].rec.tokens.len() >= cfg.max_new {
                        lanes[li].done = true;
                        break;
                    }
                }
                let m_new = lanes[li].m + n_commit;
                if m_new + self.verify_t + 1 >= s_tot {
                    lanes[li].done = true;
                }
                if lanes[li].done {
                    // lane just finished: fill harmless extend rows (eos may
                    // have cut `committed` short of slot_pos+1 pairs). `m` is
                    // deliberately frozen at its last valid value so later
                    // rounds keep building in-bounds (root-only) inputs.
                    let brange = li * w * s_tot..(li + 1) * w * s_tot;
                    chain_extend_bias_to(w, s_tot, lanes[li].m, 1, &mut pool.batch.sbias[brange]);
                    for r in 0..w {
                        pool.batch.sp[li * w + r] = (lanes[li].m + r) as i32;
                    }
                    continue;
                }
                for (r, &ni) in pool.lanes[li].path.iter().enumerate() {
                    let f = tgt.row(&vout.feats, t, li, ni, d);
                    pool.batch.sf[(li * w + r) * d..(li * w + r + 1) * d].copy_from_slice(f);
                    let slot_pos = lanes[li].m + r;
                    pool.batch.st[li * w + r] = lanes[li].committed[slot_pos + 1] as i32;
                    pool.batch.sp[li * w + r] = slot_pos as i32;
                }
                for r in n_commit..w {
                    pool.batch.sp[li * w + r] = (lanes[li].m + r) as i32;
                }
                let brange = li * w * s_tot..(li + 1) * w * s_tot;
                let lb = &mut pool.batch.sbias[brange];
                chain_extend_bias_to(w, s_tot, lanes[li].m, n_commit, lb);
                lanes[li].m = m_new;
            }
            if lanes.iter().all(|l| l.done) {
                let fp = pool.footprint()
                    + trees.iter().map(DraftTree::capacity_bytes).sum::<usize>();
                let grew = fp.saturating_sub(fp0) as u64;
                // observer runs BEFORE the counted-alloc delta is taken so
                // the zero-alloc assertion covers it too (no extend ran:
                // draft_w = 0)
                for li in 0..b {
                    if pool.batch.live[li] {
                        lanes[li].rec.round_host_alloc_bytes.push(grew);
                        if grew == 0 {
                            lanes[li].rec.scratch_reuse_total += 1;
                        }
                        self.emit_lane_event(&lanes[li], li, tl0[li], 0, grew);
                    }
                }
                #[cfg(feature = "count-alloc")]
                {
                    let counted = crate::util::count_alloc::thread_allocated_bytes() - counted0;
                    for li in 0..b {
                        if pool.batch.live[li] {
                            lanes[li].rec.round_alloc_counted_bytes.push(counted);
                        }
                    }
                }
                break;
            }
            let t0 = Instant::now();
            let fp_degenerate_draft = crate::failpoint!("draft-step");
            let mut eout = self.draft.step(
                w,
                &mut dcache_b,
                &pool.batch.wb,
                &pool.batch.sf,
                &pool.batch.st,
                &pool.batch.sp,
                &pool.batch.sbias,
            )?;
            if fp_degenerate_draft {
                eout.logits.iter_mut().for_each(|x| *x = f32::NAN);
            }
            let ext_ns = t0.elapsed().as_nanos() as u64;
            for li in 0..b {
                if lanes[li].done {
                    continue;
                }
                lanes[li].rec.timeline.draft_ns += ext_ns / b as u64;
                lanes[li].rec.draft_passes += 1;
                lanes[li].rec.round_draft_w.push(w);
                let last = pool.lanes[li].path.len() - 1;
                let frange = (li * w + last) * d..(li * w + last + 1) * d;
                lanes[li].root_feat.clear();
                lanes[li].root_feat.extend_from_slice(&eout.feats[frange]);
                let lrange = (li * w + last) * vocab..(li * w + last + 1) * vocab;
                lanes[li].root_logits.clear();
                lanes[li].root_logits.extend_from_slice(&eout.logits[lrange]);
            }
            let fp =
                pool.footprint() + trees.iter().map(DraftTree::capacity_bytes).sum::<usize>();
            let grew = fp.saturating_sub(fp0) as u64;
            // observer runs BEFORE the counted-alloc delta is taken so the
            // zero-alloc assertion covers it too; a lane that finished this
            // round skipped the extend, so its draft_w is 0
            for li in 0..b {
                if pool.batch.live[li] {
                    lanes[li].rec.round_host_alloc_bytes.push(grew);
                    if grew == 0 {
                        lanes[li].rec.scratch_reuse_total += 1;
                    }
                    let lane_w = if lanes[li].done { 0 } else { w as u32 };
                    self.emit_lane_event(&lanes[li], li, tl0[li], lane_w, grew);
                }
            }
            #[cfg(feature = "count-alloc")]
            {
                let counted = crate::util::count_alloc::thread_allocated_bytes() - counted0;
                for li in 0..b {
                    if pool.batch.live[li] {
                        lanes[li].rec.round_alloc_counted_bytes.push(counted);
                    }
                }
            }
        }

        let wall = t_all.elapsed().as_nanos() as u64;
        Ok(lanes
            .into_iter()
            .map(|mut l| {
                if l.suspended {
                    let ck = l.ckpt.take().expect("suspended lane parked its checkpoint");
                    LaneOutcome::Suspended(ck)
                } else {
                    l.rec.wall_ns = wall;
                    LaneOutcome::Done(l.rec)
                }
            })
            .collect())
    }

    /// Capture one live lane into its checkpoint at a round boundary and
    /// retire it from the batch (it becomes padding, like a finished
    /// lane). All captures are `clear` + `extend` into the checkpoint's
    /// existing buffers — warm boxes grow nothing.
    #[allow(clippy::too_many_arguments)]
    fn suspend_lane(
        &self,
        li: usize,
        lane: &mut Lane,
        cache: &KvCache,
        dcache: &KvCache,
        controller: &Option<SpecController>,
        family: &WidthFamily,
        rng: &Rng,
        pending_old: &[i32],
        pending_idx: &[i32],
        pending_n: &[i32],
    ) {
        let mut ck = lane.ckpt.take().unwrap_or_default();
        ck.capture_tokens(&lane.committed, lane.m);
        ck.capture_root(&lane.root_feat, &lane.root_logits);
        let a = self.accept_a;
        ck.capture_pending(pending_old[li], &pending_idx[li * a..(li + 1) * a], pending_n[li]);
        ck.rng_seed = lane.seed;
        ck.rng_draws = rng.draws();
        match controller {
            Some(c) => {
                let snap = ck.controller.get_or_insert_with(Default::default);
                c.snapshot_into(snap);
                // the width this lane would verify at next round per its
                // controller's CURRENT EWMA — the re-enqueued entry
                // carries it so the lane migrates width groups
                let hint = width_hint(Some(c));
                ck.width_hint = Some(plan_round_width(family, &c.params(), hint).0);
            }
            None => {
                ck.controller = None;
                ck.width_hint = None;
            }
        }
        ck.deadline = if self.deadlines.is_empty() {
            DeadlineClock::unbounded()
        } else {
            self.deadlines[li]
        };
        // full-S lane rows of BOTH caches, scratch region included, so
        // the pending fused commit survives the round trip
        copy_lane_kv_out(cache, li, &mut ck.kv_target);
        copy_lane_kv_out(dcache, li, &mut ck.kv_draft);
        ck.kv_resident = true;
        ck.kv_slot = None;
        ck.rec = std::mem::replace(&mut lane.rec, GenRecord::new(0));
        lane.ckpt = Some(ck);
        lane.suspended = true;
        lane.done = true;
    }

    /// Report one lane's just-finished round to the attached observer
    /// (no-op without one). Reads the lane's stats back off its record
    /// tails and the timeline deltas since `tl0` = (draft, verify, host)
    /// ns at round start. Stack-only: safe inside the zero-alloc round
    /// loop.
    #[inline]
    fn emit_lane_event(&self, lane: &Lane, li: usize, tl0: (u64, u64, u64), w: u32, alloc: u64) {
        if let Some(obs) = self.observer {
            let rec = &lane.rec;
            obs.on_round(&RoundEvent {
                lane: li as u32,
                round: (rec.round_accepts.len().max(1) - 1) as u32,
                tree_nodes: rec.round_tree_nodes.last().copied().unwrap_or(0) as u32,
                verify_t: rec.round_verify_t.last().copied().unwrap_or(0) as u32,
                draft_w: w,
                accepted: rec.round_accepts.last().copied().unwrap_or(0) as u32,
                draft_ns: rec.timeline.draft_ns - tl0.0,
                verify_ns: rec.timeline.verify_ns - tl0.1,
                host_ns: rec.timeline.host_ns - tl0.2,
                alloc_bytes: alloc,
            });
        }
    }

    /// STATIC lock-step growth: fixed per-level widths — greedy top-k by
    /// cumulative score per lane (the seed behavior) at T=0, i.i.d.
    /// draws from each frontier node's q (retained in the lane's q-slab
    /// for the SpecInfer rule) on the lane's own RNG stream at T>0,
    /// mirroring `EagleEngine::grow_tree` draw-for-draw. Each level's
    /// step runs at the narrowest lowered `step_w{w}_bs{b}` holding the
    /// round's widest per-lane node set. Per-lane node state lives in
    /// the pool's lane scratch (seeded by the caller's `begin_round`).
    #[allow(clippy::too_many_arguments)]
    fn grow_static_batch(
        &self,
        spec: &TreeSpec,
        dfam: &WidthFamily,
        lanes: &mut [Lane],
        trees: &mut [DraftTree],
        dcache_b: &mut KvCache,
        pool: &mut ScratchPool,
        cfg: &GenConfig,
        rngs: &mut [Rng],
    ) -> Result<()> {
        let b = lanes.len();
        let d = self.target.d;
        let vocab = self.target.vocab;
        let s_tot = self.target.max_len;

        {
            let bs = &mut pool.batch;
            bs.used.clear();
            bs.used.resize(b, 0);
        }
        for lane in &mut pool.lanes[..b] {
            lane.frontier.clear();
            lane.frontier.push(0);
        }

        for (lvl, &width) in spec.level_widths.iter().enumerate() {
            // select per-lane candidates (greedy top-k by cum score)
            for li in 0..b {
                let lane = &mut pool.lanes[li];
                lane.new_nodes.clear();
                if lanes[li].done {
                    continue;
                }
                lane.cands.clear();
                if cfg.temperature <= 0.0 {
                    for &p in &lane.frontier {
                        let q = lane.logits.get(p).expect("frontier node has logits");
                        softmax_into(q, 1.0, &mut lane.probs);
                        top_k_into(&lane.probs, spec.branch, &mut lane.idx);
                        for &ti in &lane.idx {
                            let score = trees[li].nodes[p].score + lane.probs[ti].max(1e-20).ln();
                            lane.cands.push((p, ti as u32, score, None));
                        }
                    }
                    // allocation-free unstable sort with a total (parent,
                    // token) tiebreak — see EagleEngine::grow_tree;
                    // total_cmp so a NaN logit degrades deterministically
                    lane.cands.sort_unstable_by(|a, c| {
                        c.2.total_cmp(&a.2).then(a.0.cmp(&c.0)).then(a.1.cmp(&c.1))
                    });
                    lane.cands.truncate(width);
                } else {
                    // T>0: children sampled i.i.d. from q on the lane's
                    // own stream — exactly EagleEngine::grow_tree's
                    // sampled branch, q rows shared via the lane q-slab
                    let per = (width / lane.frontier.len().max(1)).max(1);
                    for &p in &lane.frontier {
                        let logits = lane.logits.get(p).expect("frontier node has logits");
                        softmax_into(logits, cfg.temperature, &mut lane.probs);
                        let qid = lane.qs.push(&lane.probs) as u32;
                        for _ in 0..per {
                            if lane.cands.len() >= width {
                                break;
                            }
                            let tok = sample(lane.qs.get(qid as usize), &mut rngs[li]) as u32;
                            lane.cands.push((p, tok, 0.0, Some(qid)));
                        }
                    }
                }
                for (p, tok, score, q) in lane.cands.drain(..) {
                    let ni = trees[li].add(p, tok, score, q);
                    lane.feat.push_empty();
                    lane.logits.push_empty();
                    lane.node_slot.push(None);
                    lane.new_nodes.push(ni);
                    lanes[li].rec.drafted += 1;
                }
            }
            if lvl + 1 == spec.level_widths.len() {
                break;
            }
            // batched draft step at the narrowest width holding every
            // lane's node set for this level
            let maxset = pool.lanes[..b].iter().map(|l| l.new_nodes.len()).max().unwrap_or(0);
            let maxset = maxset.max(1);
            if maxset > dfam.max() {
                bail!("level of {maxset} nodes exceeds draft width {}", dfam.max());
            }
            let w = dfam.fit(maxset);
            {
                let bs = &mut pool.batch;
                bs.sf.clear();
                bs.sf.resize(b * w * d, 0.0);
                bs.st.clear();
                bs.st.resize(b * w, 0);
                bs.sp.clear();
                bs.sp.resize(b * w, 0);
                bs.sbias.clear();
                bs.sbias.resize(b * w * s_tot, 0.0);
                bs.wb.clear();
                bs.wb.resize(b, 0);
            }
            for li in 0..b {
                let base = lanes[li].m + pool.batch.used[li];
                pool.batch.wb[li] = base as i32;
                let lane = &mut pool.lanes[li];
                let bs = &mut pool.batch;
                fill_step_rows_into(
                    &trees[li],
                    &lane.new_nodes,
                    &lane.feat,
                    &mut lane.node_slot,
                    true,
                    d,
                    s_tot,
                    lanes[li].m,
                    lanes[li].m,
                    base,
                    w,
                    &mut bs.sf[li * w * d..(li + 1) * w * d],
                    &mut bs.st[li * w..(li + 1) * w],
                    &mut bs.sp[li * w..(li + 1) * w],
                    &mut bs.sbias[li * w * s_tot..(li + 1) * w * s_tot],
                );
            }
            let t0 = Instant::now();
            let sout = self.draft.step(
                w,
                dcache_b,
                &pool.batch.wb,
                &pool.batch.sf,
                &pool.batch.st,
                &pool.batch.sp,
                &pool.batch.sbias,
            )?;
            let dns = t0.elapsed().as_nanos() as u64;
            for l in lanes.iter_mut().filter(|l| !l.done) {
                l.rec.timeline.draft_ns += dns / b as u64;
                l.rec.draft_passes += 1;
                l.rec.round_draft_w.push(w);
            }
            for li in 0..b {
                pool.batch.used[li] += w;
                let lane = &mut pool.lanes[li];
                for (r, &ni) in lane.new_nodes.iter().enumerate() {
                    lane.feat.set(ni, &sout.feats[(li * w + r) * d..(li * w + r + 1) * d]);
                    let lrange = (li * w + r) * vocab..(li * w + r + 1) * vocab;
                    lane.logits.set(ni, &sout.logits[lrange]);
                }
                std::mem::swap(&mut lane.frontier, &mut lane.new_nodes);
            }
        }
        Ok(())
    }

    /// DYNAMIC lock-step growth: per-lane confidence-driven expansion.
    /// Each lane expands its top-K frontier by cumulative draft log-prob
    /// and may run at a different (controller-adapted) depth; after
    /// growth every lane's candidate tree is globally reranked down to
    /// its verify budget. At T>0 children are instead sampled i.i.d.
    /// from each frontier node's q on the lane's own RNG stream and
    /// growth is capped at the lane's budget UP FRONT (generation-order
    /// truncation, value-independent — the rerank becomes an identity),
    /// mirroring `EagleEngine::grow_tree_dynamic` draw-for-draw so the
    /// SpecInfer rule stays lossless. Per-lane params arrive pre-planned
    /// by the caller in `pool.batch.lane_params` (controller shape +
    /// width-plan budget clamp, see `dyntree/widths.rs`). Drafted-token
    /// accounting happens post-rerank. Each lane's step set lives in its
    /// scratch `expandable` buffer (doubling as next level's expansion
    /// set).
    #[allow(clippy::too_many_arguments)]
    fn grow_dynamic_batch(
        &self,
        dfam: &WidthFamily,
        lanes: &mut [Lane],
        trees: &mut [DraftTree],
        dcache_b: &mut KvCache,
        pool: &mut ScratchPool,
        cfg: &GenConfig,
        rngs: &mut [Rng],
    ) -> Result<()> {
        let b = lanes.len();
        let d = self.target.d;
        let vocab = self.target.vocab;
        let s_tot = self.target.max_len;
        let w_cap = dfam.max();

        let max_depth = pool.batch.lane_params.iter().map(|p| p.depth).max().unwrap_or(1);
        {
            let bs = &mut pool.batch;
            bs.used.clear();
            bs.used.resize(b, 0);
        }
        for lane in &mut pool.lanes[..b] {
            lane.expandable.clear();
            lane.expandable.push(0);
        }

        for lvl in 0..max_depth {
            // per-lane candidate generation + step-set selection (the
            // step set overwrites `expandable` — it IS the next level's
            // expansion set)
            for li in 0..b {
                let lp = pool.batch.lane_params[li];
                let lane = &mut pool.lanes[li];
                if lanes[li].done || lvl >= lp.depth {
                    lane.expandable.clear();
                    continue;
                }
                select_frontier_into(
                    &trees[li],
                    &lane.expandable,
                    lp.frontier_k,
                    &mut lane.frontier,
                );
                lane.new_nodes.clear();
                if cfg.temperature <= 0.0 {
                    for &p in &lane.frontier {
                        let Some(logits) = lane.logits.get(p) else { continue };
                        softmax_into(logits, 1.0, &mut lane.probs);
                        expand_candidates_into(
                            trees[li].nodes[p].score,
                            &lane.probs,
                            lp.branch,
                            &mut lane.idx,
                            &mut lane.pairs,
                        );
                        for &(tok, score) in &lane.pairs {
                            let ni = trees[li].add(p, tok, score, None);
                            lane.feat.push_empty();
                            lane.logits.push_empty();
                            lane.node_slot.push(None);
                            lane.new_nodes.push(ni);
                        }
                    }
                } else {
                    // T>0: EagleEngine::grow_tree_dynamic's sampled
                    // branch on the lane's own stream — candidates
                    // collected first, then truncated to the budget by
                    // GENERATION order (value-independent) before any
                    // node is created
                    lane.cands.clear();
                    for &p in &lane.frontier {
                        // same tolerance as the greedy arm above: a
                        // frontier node without a stepped logits row is
                        // skipped, never a mid-round server panic (the
                        // expandable-set invariant makes this unreachable
                        // in practice, as in the bs=1 engine)
                        let Some(logits) = lane.logits.get(p) else { continue };
                        softmax_into(logits, cfg.temperature, &mut lane.probs);
                        let qid = lane.qs.push(&lane.probs) as u32;
                        for _ in 0..lp.branch {
                            let q = lane.qs.get(qid as usize);
                            let tok = sample(q, &mut rngs[li]);
                            let score = trees[li].nodes[p].score + q[tok].max(1e-20).ln();
                            lane.cands.push((p, tok as u32, score, Some(qid)));
                        }
                    }
                    let room = lp.budget.saturating_sub(trees[li].len() - 1);
                    lane.cands.truncate(room);
                    for (p, tok, score, q) in lane.cands.drain(..) {
                        let ni = trees[li].add(p, tok, score, q);
                        lane.feat.push_empty();
                        lane.logits.push_empty();
                        lane.node_slot.push(None);
                        lane.new_nodes.push(ni);
                    }
                }
                // step only while another level follows and scratch remains
                // (conservatively reserved at the family's widest step)
                if lvl + 1 < lp.depth && lanes[li].m + pool.batch.used[li] + w_cap < s_tot {
                    select_frontier_into(
                        &trees[li],
                        &lane.new_nodes,
                        lp.frontier_k,
                        &mut lane.expandable,
                    );
                } else {
                    lane.expandable.clear();
                }
            }
            if pool.lanes[..b].iter().all(|l| l.expandable.is_empty()) {
                break; // no lane can expand further
            }
            // batched draft step over the per-lane step sets, at the
            // narrowest lowered width holding the widest of them
            let maxset = pool.lanes[..b].iter().map(|l| l.expandable.len()).max().unwrap_or(0);
            let maxset = maxset.max(1);
            if maxset > dfam.max() {
                bail!("step set of {maxset} nodes exceeds draft width {}", dfam.max());
            }
            let w = dfam.fit(maxset);
            {
                let bs = &mut pool.batch;
                bs.sf.clear();
                bs.sf.resize(b * w * d, 0.0);
                bs.st.clear();
                bs.st.resize(b * w, 0);
                bs.sp.clear();
                bs.sp.resize(b * w, 0);
                bs.sbias.clear();
                bs.sbias.resize(b * w * s_tot, 0.0);
                bs.wb.clear();
                bs.wb.resize(b, 0);
            }
            for li in 0..b {
                // idle lanes rewrite fresh scratch at m: self-attending rows
                // only, always in-bounds (m + w << s_tot while a lane lives)
                let base = if pool.lanes[li].expandable.is_empty() {
                    lanes[li].m
                } else {
                    lanes[li].m + pool.batch.used[li]
                };
                pool.batch.wb[li] = base as i32;
                let lane = &mut pool.lanes[li];
                let bs = &mut pool.batch;
                fill_step_rows_into(
                    &trees[li],
                    &lane.expandable,
                    &lane.feat,
                    &mut lane.node_slot,
                    true,
                    d,
                    s_tot,
                    lanes[li].m,
                    lanes[li].m,
                    base,
                    w,
                    &mut bs.sf[li * w * d..(li + 1) * w * d],
                    &mut bs.st[li * w..(li + 1) * w],
                    &mut bs.sp[li * w..(li + 1) * w],
                    &mut bs.sbias[li * w * s_tot..(li + 1) * w * s_tot],
                );
            }
            let t0 = Instant::now();
            let sout = self.draft.step(
                w,
                dcache_b,
                &pool.batch.wb,
                &pool.batch.sf,
                &pool.batch.st,
                &pool.batch.sp,
                &pool.batch.sbias,
            )?;
            let dns = t0.elapsed().as_nanos() as u64;
            for l in lanes.iter_mut().filter(|l| !l.done) {
                l.rec.timeline.draft_ns += dns / b as u64;
                l.rec.draft_passes += 1;
                l.rec.round_draft_w.push(w);
            }
            for li in 0..b {
                if pool.lanes[li].expandable.is_empty() {
                    continue;
                }
                pool.batch.used[li] += w;
                let lane = &mut pool.lanes[li];
                for (r, &ni) in lane.expandable.iter().enumerate() {
                    lane.feat.set(ni, &sout.feats[(li * w + r) * d..(li * w + r + 1) * d]);
                    let lrange = (li * w + r) * vocab..(li * w + r + 1) * vocab;
                    lane.logits.set(ni, &sout.logits[lrange]);
                }
            }
        }
        // global rerank per lane: keep the best `budget` nodes for verify
        for li in 0..b {
            if lanes[li].done {
                continue;
            }
            let budget = pool.batch.lane_params[li].budget;
            if trees[li].len() - 1 > budget {
                let lane = &mut pool.lanes[li];
                rerank_into(&trees[li], budget, &mut lane.spare_tree, &mut lane.rr);
                std::mem::swap(&mut trees[li], &mut lane.spare_tree);
            }
            lanes[li].rec.drafted += trees[li].len() - 1;
        }
        Ok(())
    }

    /// Batched vanilla decoding — the Table-7 throughput baseline. Lane
    /// seeds default to `cfg.seed + lane index` (the same derivation as
    /// [`BatchEagleEngine::generate_pooled`]); pass explicit per-request
    /// seeds via [`BatchEagleEngine::vanilla_batch_seeded`].
    pub fn vanilla_batch(&self, prompts: &[Vec<u32>], cfg: &GenConfig) -> Result<Vec<GenRecord>> {
        let seeds: Vec<u64> =
            (0..prompts.len()).map(|li| cfg.seed.wrapping_add(li as u64)).collect();
        self.vanilla_batch_seeded(prompts, &seeds, cfg)
    }

    /// [`BatchEagleEngine::vanilla_batch`] with one RNG seed per lane:
    /// each lane draws its T>0 samples from its own stream (seeded as a
    /// bs=1 run would be), so a request's sampled output no longer
    /// depends on how many other lanes share the batch or what they
    /// sample — it A/B-matches its equal-seed bs=1 vanilla run.
    pub fn vanilla_batch_seeded(
        &self,
        prompts: &[Vec<u32>],
        seeds: &[u64],
        cfg: &GenConfig,
    ) -> Result<Vec<GenRecord>> {
        let b = prompts.len();
        assert_eq!(seeds.len(), b, "one seed per lane");
        let tgt = self.target;
        let vocab = tgt.vocab;
        let t_all = Instant::now();
        let mut cache: KvCache = tgt.new_cache(b);
        let mut recs: Vec<GenRecord> = prompts.iter().map(|p| GenRecord::new(p.len())).collect();
        let mut lens = vec![0i32; b];
        let mut toks = vec![0i32; b];
        let mut done = vec![false; b];
        let mut rngs: Vec<Rng> = seeds.iter().map(|&s| Rng::new(s)).collect();
        for (li, p) in prompts.iter().enumerate() {
            let (out, plen) = tgt.prefill_slot(b, &mut cache, li, p)?;
            recs[li].target_passes += 1;
            let logits = tgt.row(&out.logits, tgt.prefill_p, 0, plen - 1, vocab);
            let tok = if cfg.temperature <= 0.0 {
                argmax(logits) as u32
            } else {
                sample(&softmax(logits, cfg.temperature), &mut rngs[li]) as u32
            };
            recs[li].tokens.push(tok);
            toks[li] = tok as i32;
            lens[li] = plen as i32;
        }
        while !done.iter().all(|&d| d) {
            let out = tgt.decode(&mut cache, &lens, &toks)?;
            for li in 0..b {
                if done[li] {
                    continue;
                }
                recs[li].target_passes += 1;
                recs[li].round_accepts.push(1);
                lens[li] += 1;
                let logits = &out.logits[li * vocab..(li + 1) * vocab];
                let tok = if cfg.temperature <= 0.0 {
                    argmax(logits) as u32
                } else {
                    sample(&softmax(logits, cfg.temperature), &mut rngs[li]) as u32
                };
                recs[li].tokens.push(tok);
                toks[li] = tok as i32;
                if cfg.eos == Some(tok)
                    || recs[li].tokens.len() >= cfg.max_new
                    || (lens[li] as usize) + 2 >= tgt.max_len
                {
                    done[li] = true;
                }
            }
        }
        let wall = t_all.elapsed().as_nanos() as u64;
        for r in &mut recs {
            r.wall_ns = wall;
        }
        Ok(recs)
    }
}
