//! Online re-fit of the scheduler's dispatch-cost model from the
//! server's own per-round verify timings.
//!
//! The offline path (`repro bench --json` + `--cost-model`) fits
//! `ms(t) = a + b*t` from a bench dump once, at boot. In production the
//! curve drifts — thermal state, co-tenancy, backend changes — so the
//! serving worker feeds every round's `(verify_t, verify_ns)`
//! observation into EWMA-weighted least-squares moments here, and the
//! dispatch overhead (`a / b`, in node units) is re-fit every
//! [`DEFAULT_REFIT_EVERY`] observations. [`Scheduler::effective_cost`]
//! consumes the live fit for width grouping, and the shed path's
//! cold-start seed consumes [`OnlineCostModel::predicted_service_secs`].
//!
//! Concurrency contract mirrors the rest of the serving metrics: ONE
//! writer (the worker thread, through the round observer) and any number
//! of readers. All state is f64-bits-in-`AtomicU64` / plain atomics, so
//! the record path allocates nothing and readers never block.
//!
//! The fit math: EWMA moments `m_x = (1-α)·m_x + α·x` are weighted means
//! with identical weights across `m_t, m_y, m_tt, m_ty`, so the weighted
//! least-squares slope `(m_ty − m_t·m_y) / (m_tt − m_t²)` needs no
//! separate weight bookkeeping — the weights cancel.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::scheduler::CostModel;
use crate::util::json::Json;

/// Observations between overhead re-fits.
pub const DEFAULT_REFIT_EVERY: u64 = 64;

/// EWMA weight for the fit moments and the round/τ estimates: ~1/α
/// rounds of memory, slow enough to ride out acceptance noise, fast
/// enough to track thermal/backend drift within a few hundred rounds.
const ALPHA: f64 = 0.1;

/// Cold-start per-round wall time (seconds) before any observation:
/// a deliberately conservative host-sim round, so a cold server predicts
/// non-zero service time and [`should_shed`] can act on an instant
/// burst right after restart.
pub const COLD_ROUND_SECS: f64 = 0.010;

/// Cold-start accepted-tokens-per-round (τ) before any observation.
pub const COLD_TAU: f64 = 3.0;

/// Committed per-request service time (seconds) from a loadgen result
/// file (`BENCH_serve.json`, `schema: bench_serve_v1`): the reciprocal
/// of the `p99_search` stanza's best feasible offered rate. This is the
/// capacity an operator actually signed off on — the shed estimator
/// prefers it over the cost model's cold-start prediction when the file
/// is present (see the shed block in `server::route`). Returns `None`
/// when the file is absent, unparseable, has no `p99_search` stanza, or
/// the search found no feasible level.
pub fn load_committed_capacity(path: &std::path::Path) -> Option<f64> {
    let text = std::fs::read_to_string(path).ok()?;
    let v = Json::parse(&text).ok()?;
    let search = v.get("p99_search")?;
    if !search.get("feasible")?.as_bool()? {
        return None;
    }
    let rps = search.get("best_offered_rps")?.as_f64()?;
    (rps.is_finite() && rps > 0.0).then(|| 1.0 / rps)
}

fn load_f64(a: &AtomicU64) -> f64 {
    f64::from_bits(a.load(Ordering::Relaxed))
}

fn store_f64(a: &AtomicU64, v: f64) {
    a.store(v.to_bits(), Ordering::Relaxed);
}

fn ewma(a: &AtomicU64, x: f64, first: bool) {
    let v = if first { x } else { (1.0 - ALPHA) * load_f64(a) + ALPHA * x };
    store_f64(a, v);
}

/// Live dispatch-cost model: EWMA least-squares over `(verify_t,
/// verify_ms)` round observations plus round-time/τ EWMAs for service
/// prediction. Single-writer (the worker's round observer); lock-free
/// readers.
pub struct OnlineCostModel {
    /// EWMA moments of the (t, ms) stream (f64 bits).
    m_t: AtomicU64,
    m_y: AtomicU64,
    m_tt: AtomicU64,
    m_ty: AtomicU64,
    /// Total observations fed in.
    n_obs: AtomicU64,
    /// Current fitted dispatch overhead in node units (starts at the
    /// seed model's; replaced by each successful re-fit).
    overhead: AtomicUsize,
    /// Successful re-fits (mirrored to `eagle_cost_refits_total`).
    refits: AtomicU64,
    /// How often to re-fit (observations between fits).
    refit_every: u64,
    /// EWMA whole-round wall seconds (seeded [`COLD_ROUND_SECS`]).
    round_secs: AtomicU64,
    /// EWMA accepted tokens per round (seeded [`COLD_TAU`]).
    tau: AtomicU64,
}

impl OnlineCostModel {
    pub fn new(seed: CostModel) -> OnlineCostModel {
        OnlineCostModel {
            m_t: AtomicU64::new(0f64.to_bits()),
            m_y: AtomicU64::new(0f64.to_bits()),
            m_tt: AtomicU64::new(0f64.to_bits()),
            m_ty: AtomicU64::new(0f64.to_bits()),
            n_obs: AtomicU64::new(0),
            overhead: AtomicUsize::new(seed.dispatch_overhead),
            refits: AtomicU64::new(0),
            refit_every: DEFAULT_REFIT_EVERY,
            round_secs: AtomicU64::new(COLD_ROUND_SECS.to_bits()),
            tau: AtomicU64::new(COLD_TAU.to_bits()),
        }
    }

    /// Prime the moments from an offline `(t, median_ms)` bench curve
    /// (see `verify_curve_points`) so the first live fit starts from the
    /// calibrated line instead of a cold window. Also seeds the
    /// round-time EWMA from the curve's mean latency.
    pub fn seed_curve(&self, points: &[(usize, f64)]) {
        if points.is_empty() {
            return;
        }
        let n = points.len() as f64;
        store_f64(&self.m_t, points.iter().map(|p| p.0 as f64).sum::<f64>() / n);
        store_f64(&self.m_y, points.iter().map(|p| p.1).sum::<f64>() / n);
        store_f64(&self.m_tt, points.iter().map(|p| (p.0 * p.0) as f64).sum::<f64>() / n);
        store_f64(&self.m_ty, points.iter().map(|p| p.0 as f64 * p.1).sum::<f64>() / n);
        self.n_obs.store(points.len() as u64, Ordering::Relaxed);
        store_f64(&self.round_secs, load_f64(&self.m_y) / 1e3);
        self.refit();
    }

    /// Feed one round observation. Called from the worker's round
    /// observer — single writer, atomics only, no allocation.
    pub fn observe(&self, verify_t: u32, verify_secs: f64, round_secs: f64, accepted: u32) {
        if verify_t == 0 || !verify_secs.is_finite() || verify_secs <= 0.0 {
            return;
        }
        let n = self.n_obs.fetch_add(1, Ordering::Relaxed);
        let first = n == 0;
        let t = verify_t as f64;
        let y = verify_secs * 1e3; // fit in ms, matching the offline curve
        ewma(&self.m_t, t, first);
        ewma(&self.m_y, y, first);
        ewma(&self.m_tt, t * t, first);
        ewma(&self.m_ty, t * y, first);
        if round_secs.is_finite() && round_secs > 0.0 {
            ewma(&self.round_secs, round_secs, false);
        }
        ewma(&self.tau, f64::from(accepted.max(1)), false);
        if (n + 1) % self.refit_every == 0 {
            self.refit();
        }
    }

    /// Re-fit the dispatch overhead from the current moments. Skipped
    /// (keeping the previous value) when the observed width spread is
    /// degenerate or the slope is non-positive — a single-width workload
    /// cannot identify the intercept.
    fn refit(&self) {
        let (m_t, m_y) = (load_f64(&self.m_t), load_f64(&self.m_y));
        let var = load_f64(&self.m_tt) - m_t * m_t;
        if var <= 1e-9 {
            return;
        }
        let slope = (load_f64(&self.m_ty) - m_t * m_y) / var;
        if slope <= 0.0 {
            return;
        }
        let intercept = m_y - slope * m_t;
        let overhead = (intercept / slope).round().clamp(1.0, 10_000.0) as usize;
        self.overhead.store(overhead, Ordering::Relaxed);
        self.refits.fetch_add(1, Ordering::Relaxed);
    }

    /// The current fit as a [`CostModel`] for the width planner.
    pub fn current(&self) -> CostModel {
        CostModel { dispatch_overhead: self.overhead.load(Ordering::Relaxed) }
    }

    /// Predicted wall seconds to serve one request of `max_tokens`
    /// output: EWMA round time × predicted rounds (`ceil(tokens / τ)`).
    /// Non-zero even on a cold server (cold-start constants), which is
    /// what seeds the shed estimate after drain/restart.
    pub fn predicted_service_secs(&self, max_tokens: usize) -> f64 {
        let tau = load_f64(&self.tau).max(1.0);
        let rounds = (max_tokens.max(1) as f64 / tau).ceil();
        load_f64(&self.round_secs).max(1e-6) * rounds
    }

    pub fn dispatch_overhead(&self) -> usize {
        self.overhead.load(Ordering::Relaxed)
    }

    pub fn refits(&self) -> u64 {
        self.refits.load(Ordering::Relaxed)
    }

    pub fn observations(&self) -> u64 {
        self.n_obs.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_model_reports_seed_and_predicts_nonzero() {
        let m = OnlineCostModel::new(CostModel { dispatch_overhead: 7 });
        assert_eq!(m.current().dispatch_overhead, 7);
        assert_eq!(m.refits(), 0);
        let p = m.predicted_service_secs(64);
        // 64 tokens / τ=3 -> 22 rounds at 10ms
        assert!((p - 0.22).abs() < 1e-9, "cold prediction {p}");
    }

    #[test]
    fn refit_recovers_overhead_from_linear_curve() {
        // ms(t) = 0.5 + 0.05*t -> overhead 10, same line the offline
        // fit test uses
        let m = OnlineCostModel::new(CostModel::default());
        let widths = [8u32, 16, 32];
        for i in 0..DEFAULT_REFIT_EVERY * 2 {
            let t = widths[(i % 3) as usize];
            let ms = 0.5 + 0.05 * t as f64;
            m.observe(t, ms / 1e3, 2e-3, 3);
        }
        assert!(m.refits() >= 1);
        assert_eq!(m.current().dispatch_overhead, 10);
        // round EWMA converged to the 2ms observations
        let p = m.predicted_service_secs(3);
        assert!(p > 1e-3 && p < 3e-3, "one-round prediction {p}");
    }

    #[test]
    fn single_width_stream_keeps_previous_fit() {
        let m = OnlineCostModel::new(CostModel { dispatch_overhead: 9 });
        for _ in 0..DEFAULT_REFIT_EVERY * 2 {
            m.observe(16, 1.3e-3, 2e-3, 3);
        }
        // zero width variance: unidentifiable intercept, fit unchanged
        assert_eq!(m.current().dispatch_overhead, 9);
        assert_eq!(m.refits(), 0);
    }

    #[test]
    fn seed_curve_primes_fit_before_any_observation() {
        let m = OnlineCostModel::new(CostModel::default());
        m.seed_curve(&[(8, 0.9), (16, 1.3), (32, 2.1)]);
        assert_eq!(m.current().dispatch_overhead, 10);
        assert_eq!(m.refits(), 1);
        assert!(m.predicted_service_secs(3) > 0.0);
    }

    #[test]
    fn committed_capacity_reads_feasible_p99_search() {
        let dir = std::env::temp_dir();
        let path = dir.join("costfit_capacity_test.json");
        std::fs::write(
            &path,
            r#"{"schema":"bench_serve_v1","p99_search":{"feasible":true,"best_offered_rps":50.0}}"#,
        )
        .unwrap();
        let s = load_committed_capacity(&path).expect("feasible stanza");
        assert!((s - 0.02).abs() < 1e-12, "50 rps -> 20 ms/request, got {s}");

        // infeasible searches and missing stanzas yield no capacity
        std::fs::write(&path, r#"{"p99_search":{"feasible":false}}"#).unwrap();
        assert_eq!(load_committed_capacity(&path), None);
        std::fs::write(&path, r#"{"schema":"bench_serve_v1"}"#).unwrap();
        assert_eq!(load_committed_capacity(&path), None);
        std::fs::remove_file(&path).unwrap();
        assert_eq!(load_committed_capacity(&path), None, "absent file");
    }

    #[test]
    fn degenerate_observations_ignored() {
        let m = OnlineCostModel::new(CostModel { dispatch_overhead: 5 });
        m.observe(0, 1.0, 1.0, 1);
        m.observe(8, 0.0, 1.0, 1);
        m.observe(8, f64::NAN, 1.0, 1);
        assert_eq!(m.observations(), 0);
        assert_eq!(m.current().dispatch_overhead, 5);
    }
}
