//! Admission scheduler: forms work batches from the queue with a simple
//! deadline policy (take what's there, wait on the queue condvar up to
//! `linger` for more when batching is enabled), and tracks serving
//! statistics.
//!
//! With [`AdmissionPolicy::WidthGrouped`] the scheduler is width-aware:
//! each request carries a predicted verify width
//! ([`Request::admission_width`] — its controller/client `width_hint`,
//! falling back to the widest lowered width), and an admitted batch is
//! split into sub-batches of compatible lanes via [`plan_width_groups`]
//! so a narrow (low-acceptance) lane is never executed at a hot lane's
//! width. Grouping decisions follow the [`group_cost`] model: a group of
//! `b` lanes at verify width `t` costs one dispatch overhead plus `t*b`
//! width-proportional work, so two lone lanes at adjacent widths merge
//! (the overhead dominates) while bulk narrow traffic keeps its own
//! cheap sub-batch. [`AdmissionPolicy::Fcfs`] is the legacy fallback:
//! one arrival-ordered batch whose execution width is the max over lane
//! fits.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::costfit::OnlineCostModel;
use super::queue::RequestQueue;
use super::request::Request;
use crate::spec::dyntree::WidthFamily;
use crate::util::json::Json;

/// Fixed per-group dispatch cost in verify-node units: host marshalling,
/// buffer upload, and executable launch amortized over the round. One
/// extra sub-batch is worth it only when it saves more than this many
/// node-widths of verify work. The default is an assumed ratio;
/// calibrate per backend with `repro bench --json BENCH_host.json` and
/// `--cost-model BENCH_host.json` (see [`CostModel`]).
pub const DISPATCH_OVERHEAD: usize = 8;

/// Cost of one verify round for a group of `b` lanes at width `t`,
/// under the default (uncalibrated) cost model.
pub fn group_cost(t: usize, b: usize) -> usize {
    CostModel::default().group_cost(t, b)
}

/// The scheduler's dispatch-cost model: `cost(t, b) = overhead + t*b` in
/// verify-node units. The default overhead is [`DISPATCH_OVERHEAD`]; a
/// calibrated value can be loaded from a small JSON file (`--cost-model
/// path`) that either states it directly or carries the measured
/// `exe/verify_t{t}` bench curve to fit it from — the file
/// `repro bench --json` emits works for both.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CostModel {
    /// Per-group dispatch overhead in verify-node units.
    pub dispatch_overhead: usize,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { dispatch_overhead: DISPATCH_OVERHEAD }
    }
}

impl CostModel {
    /// Cost of one verify round for a group of `b` lanes at width `t`.
    pub fn group_cost(&self, t: usize, b: usize) -> usize {
        self.dispatch_overhead + t * b
    }

    /// [`CostModel::group_cost`] of `n` lanes at width `t` once split
    /// into sub-batches of at most `max_group` — what a bucket actually
    /// dispatches as.
    fn chunked_cost(&self, t: usize, n: usize, max_group: usize) -> usize {
        let chunks = n.div_ceil(max_group.max(1));
        chunks * self.dispatch_overhead + t * n
    }

    /// Fit the dispatch overhead from a measured verify-latency curve:
    /// least-squares `ms(t) = a + b*t` over `(t, median_ms)` points, and
    /// the overhead in node units is `a / b` (the fixed cost expressed
    /// in per-node-width time). `None` when the curve is degenerate
    /// (fewer than 2 distinct widths, or a non-positive slope).
    pub fn fit_dispatch_overhead(points: &[(usize, f64)]) -> Option<usize> {
        if points.len() < 2 {
            return None;
        }
        let n = points.len() as f64;
        let mean_t = points.iter().map(|p| p.0 as f64).sum::<f64>() / n;
        let mean_ms = points.iter().map(|p| p.1).sum::<f64>() / n;
        let mut cov = 0.0;
        let mut var = 0.0;
        for &(t, ms) in points {
            cov += (t as f64 - mean_t) * (ms - mean_ms);
            var += (t as f64 - mean_t) * (t as f64 - mean_t);
        }
        if var <= 0.0 {
            return None;
        }
        let slope = cov / var;
        if slope <= 0.0 {
            return None;
        }
        let intercept = mean_ms - slope * mean_t;
        let overhead = (intercept / slope).round();
        Some(overhead.clamp(1.0, 10_000.0) as usize)
    }

    /// Parse a calibration JSON value. Accepted shapes:
    /// * `{"dispatch_overhead": N}` — direct override;
    /// * `{"cost_model": {"dispatch_overhead": N}}` — as emitted by
    ///   `repro bench --json`;
    /// * `{"benches": [{"name": "exe/verify_t8", "median_ms": ..}, ..]}`
    ///   — a bench dump; the overhead is fit from the `exe/verify_t{t}`
    ///   curve (bs=1 entries, name parsed for `t`).
    pub fn from_json(v: &Json) -> anyhow::Result<CostModel> {
        if let Some(n) = v.get("dispatch_overhead").and_then(Json::as_usize) {
            anyhow::ensure!(n >= 1, "dispatch_overhead must be >= 1");
            return Ok(CostModel { dispatch_overhead: n });
        }
        if let Some(cm) = v.get("cost_model") {
            return CostModel::from_json(cm);
        }
        if v.get("benches").and_then(Json::as_arr).is_some() {
            let points = verify_curve_points(v);
            if let Some(overhead) = CostModel::fit_dispatch_overhead(&points) {
                return Ok(CostModel { dispatch_overhead: overhead });
            }
            anyhow::bail!(
                "cost-model file has no fittable exe/verify_t curve ({} points)",
                points.len()
            );
        }
        anyhow::bail!("cost-model json needs dispatch_overhead, cost_model, or benches")
    }

    /// Load a calibration file (see [`CostModel::from_json`]).
    pub fn load(path: &std::path::Path) -> anyhow::Result<CostModel> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading cost model {}: {e}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing cost model: {e}"))?;
        CostModel::from_json(&v)
    }
}

/// Extract the `(t, median_ms)` verify-latency curve from a bench-dump
/// JSON value (`{"benches": [{"name": "exe/verify_t{t}", ..}, ..]}`) —
/// shared by the offline fit above and [`OnlineCostModel`] curve seeding.
pub fn verify_curve_points(v: &Json) -> Vec<(usize, f64)> {
    let mut points: Vec<(usize, f64)> = Vec::new();
    let Some(benches) = v.get("benches").and_then(Json::as_arr) else {
        return points;
    };
    for b in benches {
        let Some(name) = b.get("name").and_then(Json::as_str) else { continue };
        let Some(ms) = b.get("median_ms").and_then(Json::as_f64) else { continue };
        // "exe/verify_t{t}" (optionally with a trailing " (..)" label)
        let Some(rest) = name.strip_prefix("exe/verify_t") else { continue };
        let digits: String = rest.chars().take_while(char::is_ascii_digit).collect();
        if let Ok(t) = digits.parse::<usize>() {
            points.push((t, ms));
        }
    }
    points
}

/// One planned sub-batch: the verify width it will execute at and the
/// member indices into the planner's input slice (ascending = FCFS
/// order within the group).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WidthGroup {
    pub width: usize,
    pub members: Vec<usize>,
}

/// Partition lanes by predicted verify width. Each hint is fitted to the
/// lowered family, buckets merge upward while the [`group_cost`] model
/// (evaluated after `max_group` chunking) says the saved dispatch
/// overhead outweighs the widened members, and the result is chunked to
/// `max_group` lanes per sub-batch. Guarantees:
/// every input index appears in exactly one group, and no member's
/// fitted width exceeds its group's width (lanes are never truncated).
///
/// Uses the default (uncalibrated) [`CostModel`]; the scheduler itself
/// plans through [`plan_width_groups_with`] so `--cost-model` files take
/// effect.
pub fn plan_width_groups(
    hints: &[usize],
    family: &WidthFamily,
    max_group: usize,
) -> Vec<WidthGroup> {
    plan_width_groups_with(hints, family, max_group, &CostModel::default())
}

/// [`plan_width_groups`] under an explicit [`CostModel`] (the calibrated
/// dispatch overhead changes where the greedy upward merge breaks even).
pub fn plan_width_groups_with(
    hints: &[usize],
    family: &WidthFamily,
    max_group: usize,
    cost: &CostModel,
) -> Vec<WidthGroup> {
    let widths = family.widths();
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); widths.len()];
    for (i, &h) in hints.iter().enumerate() {
        let w = family.fit(h.min(family.max()));
        let wi = widths.iter().position(|&x| x == w).expect("fit returns a family member");
        buckets[wi].push(i);
    }
    // greedy upward merge: absorb a narrow bucket into the next wider
    // one when merging is no more expensive — costed AFTER `max_group`
    // chunking, so a merge that would spill into an extra sub-batch
    // (paying the dispatch overhead anyway, plus the widened lanes)
    // is rejected
    let max_group = max_group.max(1);
    for i in 0..widths.len().saturating_sub(1) {
        if buckets[i].is_empty() {
            continue;
        }
        let Some(j) = (i + 1..widths.len()).find(|&j| !buckets[j].is_empty()) else {
            break;
        };
        let (ni, nj) = (buckets[i].len(), buckets[j].len());
        let merged = cost.chunked_cost(widths[j], ni + nj, max_group);
        let split = cost.chunked_cost(widths[i], ni, max_group)
            + cost.chunked_cost(widths[j], nj, max_group);
        if merged <= split {
            let moved = std::mem::take(&mut buckets[i]);
            buckets[j].extend(moved);
            buckets[j].sort_unstable(); // FCFS order within the merged group
        }
    }
    let mut out = Vec::new();
    for (wi, bucket) in buckets.iter().enumerate() {
        for chunk in bucket.chunks(max_group) {
            out.push(WidthGroup { width: widths[wi], members: chunk.to_vec() });
        }
    }
    out
}

/// How `next_groups` splits an admitted batch.
#[derive(Debug, Clone)]
pub enum AdmissionPolicy {
    /// One FCFS batch; the engine takes the max over lane width fits.
    Fcfs,
    /// Group batchable lanes by predicted width over the declared
    /// verify-width family (the `"verify_widths"` manifest constant).
    WidthGrouped { verify_widths: Vec<usize>, max_t: usize },
}

/// One admitted sub-batch. `verify_cap` is the group's planned width
/// (the executor caps its width family there); `None` means FCFS — the
/// engine picks per round with no scheduler-imposed cap.
#[derive(Debug)]
pub struct AdmittedGroup {
    pub verify_cap: Option<usize>,
    pub requests: Vec<Request>,
}

pub struct Scheduler {
    pub max_batch: usize,
    pub linger: Duration,
    pub policy: AdmissionPolicy,
    /// Dispatch-cost model for width grouping: the static fallback
    /// (default, or calibrated from a `--cost-model` file). When
    /// `live_cost` is set, [`Scheduler::effective_cost`] supersedes it.
    pub cost: CostModel,
    /// Online re-fit of the dispatch cost from the server's own verify
    /// timings; when present its current fit drives width grouping.
    pub live_cost: Option<Arc<OnlineCostModel>>,
    /// Server default deadline budget (ms, 0 = unbounded) — applied to
    /// requests without an explicit `deadline_ms` when computing the
    /// deadline-aware linger cap.
    pub default_deadline_ms: u64,
    /// Latest EWMA per-request service-time estimate in seconds (f64
    /// bits), refreshed by the serving worker; bounds how much of a
    /// queued request's remaining budget linger may consume.
    est_service: AtomicU64,
    pub served: AtomicU64,
    pub queued_ns: AtomicU64,
    /// Sub-batches formed (equals admissions under FCFS).
    pub groups_formed: AtomicU64,
    /// Admissions whose linger window was shortened by a queued or
    /// admitted deadline (mirrored to `eagle_linger_capped_total`).
    pub linger_capped: AtomicU64,
}

impl Scheduler {
    pub fn new(max_batch: usize, linger_ms: u64) -> Scheduler {
        Scheduler {
            max_batch,
            linger: Duration::from_millis(linger_ms),
            policy: AdmissionPolicy::Fcfs,
            cost: CostModel::default(),
            live_cost: None,
            default_deadline_ms: 0,
            est_service: AtomicU64::new(0f64.to_bits()),
            served: AtomicU64::new(0),
            queued_ns: AtomicU64::new(0),
            groups_formed: AtomicU64::new(0),
            linger_capped: AtomicU64::new(0),
        }
    }

    /// Set the admission policy (builder-style).
    pub fn with_policy(mut self, policy: AdmissionPolicy) -> Scheduler {
        self.policy = policy;
        self
    }

    /// Set the dispatch-cost model (builder-style; from `--cost-model`).
    pub fn with_cost_model(mut self, cost: CostModel) -> Scheduler {
        self.cost = cost;
        self
    }

    /// Attach a live cost model (builder-style); its rolling re-fit
    /// replaces the static `cost` for width-grouping decisions.
    pub fn with_live_cost(mut self, live: Arc<OnlineCostModel>) -> Scheduler {
        self.live_cost = Some(live);
        self
    }

    /// Set the server default deadline budget (builder-style).
    pub fn with_deadline_default(mut self, ms: u64) -> Scheduler {
        self.default_deadline_ms = ms;
        self
    }

    /// Publish the latest EWMA service-time estimate (seconds). Called
    /// by the serving worker between groups; single writer.
    pub fn note_service_estimate(&self, secs: f64) {
        self.est_service.store(secs.max(0.0).to_bits(), Ordering::Relaxed);
    }

    /// Latest published service-time estimate in seconds (0 = unknown).
    pub fn est_service_secs(&self) -> f64 {
        f64::from_bits(self.est_service.load(Ordering::Relaxed))
    }

    /// The cost model width grouping actually plans under: the live
    /// re-fit when attached, else the static (offline/default) one.
    pub fn effective_cost(&self) -> CostModel {
        self.live_cost.as_ref().map(|l| l.current()).unwrap_or(self.cost)
    }

    /// Block for the next FCFS batch (waiting on the queue condvar up to
    /// `linger` for the batch to fill). Returns empty Vec when the queue
    /// is closed.
    pub fn next_batch(&self, q: &RequestQueue) -> Vec<Request> {
        let batch = self.collect(q);
        if !batch.is_empty() {
            self.groups_formed.fetch_add(1, Ordering::Relaxed);
        }
        batch
    }

    /// Block for the next admission and split it into execution groups
    /// per the configured policy. Empty Vec when the queue is closed.
    ///
    /// Only lanes the batched engine can co-execute are width-grouped:
    /// EAGLE tree requests sharing (max_tokens, tree choice,
    /// temperature class) — sampled requests batch with equal-temperature
    /// peers (each lane keeps its own seeded RNG stream), greedy ones
    /// with greedy. Everything else becomes an FCFS singleton group,
    /// preserving arrival order within each group.
    pub fn next_groups(&self, q: &RequestQueue) -> Vec<AdmittedGroup> {
        let batch = self.collect(q);
        if batch.is_empty() {
            return Vec::new();
        }
        let groups = match &self.policy {
            AdmissionPolicy::Fcfs => {
                vec![AdmittedGroup { verify_cap: None, requests: batch }]
            }
            AdmissionPolicy::WidthGrouped { verify_widths, max_t } => {
                let family = WidthFamily::from_available(verify_widths, *max_t, |_| true);
                let mut out: Vec<AdmittedGroup> = Vec::new();
                // partition into batchable compatibility classes + the rest.
                // The resolved draft source is part of the key so a width
                // group never mixes sources (`width_batchable` already
                // restricts grouping to the eagle source today; keying on
                // it keeps that invariant explicit if more sources become
                // batchable).
                type ClassKey = (usize, &'static str, u32, &'static str);
                let mut classes: Vec<(ClassKey, Vec<Request>)> = Vec::new();
                for r in batch {
                    if r.width_batchable() {
                        let key =
                            (r.max_tokens, r.tree.name(), r.temperature_class(), r.source.as_str());
                        match classes.iter_mut().find(|(k, _)| *k == key) {
                            Some((_, v)) => v.push(r),
                            None => classes.push((key, vec![r])),
                        }
                    } else {
                        out.push(AdmittedGroup { verify_cap: None, requests: vec![r] });
                    }
                }
                let cost = self.effective_cost();
                for (_, class) in classes {
                    let hints: Vec<usize> =
                        class.iter().map(|r| r.admission_width(family.max())).collect();
                    let mut class: Vec<Option<Request>> = class.into_iter().map(Some).collect();
                    for g in plan_width_groups_with(&hints, &family, self.max_batch, &cost) {
                        let requests: Vec<Request> = g
                            .members
                            .iter()
                            .map(|&i| class[i].take().expect("planner emits each index once"))
                            .collect();
                        out.push(AdmittedGroup { verify_cap: Some(g.width), requests });
                    }
                }
                out
            }
        };
        self.groups_formed.fetch_add(groups.len() as u64, Ordering::Relaxed);
        groups
    }

    /// Tightest deadline among the requests already admitted to `batch`
    /// and those still queued, minus the estimated service time: the
    /// instant past which lingering for a fuller batch would turn into a
    /// deadline miss batching could have avoided. `None` = no cap.
    fn linger_cap(&self, batch: &[Request], q: &RequestQueue) -> Option<Instant> {
        let mut tight: Option<Instant> = q.earliest_deadline();
        for r in batch {
            if let Some(at) = r.deadline(self.default_deadline_ms).instant() {
                tight = Some(tight.map_or(at, |t| t.min(at)));
            }
        }
        let est = Duration::from_secs_f64(self.est_service_secs().clamp(0.0, 3600.0));
        tight.map(|t| t.checked_sub(est).unwrap_or_else(Instant::now))
    }

    fn collect(&self, q: &RequestQueue) -> Vec<Request> {
        let first = match q.pop() {
            Some(r) => r,
            None => return Vec::new(),
        };
        let mut batch = vec![first];
        if self.max_batch > 1 {
            let full = Instant::now() + self.linger;
            let mut capped = false;
            while batch.len() < self.max_batch {
                let more = q.pop_up_to(self.max_batch - batch.len());
                if !more.is_empty() {
                    batch.extend(more);
                    continue;
                }
                // a resumed lane already waited once (it was preempted
                // mid-generation): admit immediately rather than
                // lingering for a fuller batch a second time
                if batch.iter().any(|r| r.resume) {
                    break;
                }
                // deadline-aware linger: never wait past the point where
                // the tightest queued/admitted deadline could still be
                // met after the estimated service time
                let mut deadline = full;
                if let Some(cap) = self.linger_cap(&batch, q) {
                    if cap < deadline {
                        deadline = cap;
                        if !capped {
                            capped = true;
                            self.linger_capped.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                if Instant::now() >= deadline {
                    break;
                }
                // condvar wait (not a sleep-poll tick): woken the moment
                // a request arrives or the queue closes
                if !q.wait_nonempty_until(deadline) {
                    break;
                }
            }
        }
        for r in &batch {
            self.queued_ns
                .fetch_add(r.arrival.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        self.served.fetch_add(batch.len() as u64, Ordering::Relaxed);
        batch
    }

    pub fn mean_queue_ms(&self) -> f64 {
        let n = self.served.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.queued_ns.load(Ordering::Relaxed) as f64 / n as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Method, TreeChoice};

    fn req(id: u64) -> Request {
        Request::synthetic(id)
    }

    fn fam() -> WidthFamily {
        WidthFamily::from_available(&[8, 16, 32], 32, |_| true)
    }

    #[test]
    fn batches_up_to_max() {
        let q = RequestQueue::new(16);
        for i in 0..5 {
            q.push(req(i)).unwrap();
        }
        let s = Scheduler::new(4, 0);
        let b = s.next_batch(&q);
        assert_eq!(b.len(), 4);
        assert_eq!(b[0].id, 0);
        let b2 = s.next_batch(&q);
        assert_eq!(b2.len(), 1);
        assert_eq!(s.served.load(Ordering::Relaxed), 5);
        assert_eq!(s.groups_formed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn closed_queue_yields_empty() {
        let q = RequestQueue::new(4);
        q.close();
        let s = Scheduler::new(2, 0);
        assert!(s.next_batch(&q).is_empty());
        assert!(s.next_groups(&q).is_empty());
    }

    #[test]
    fn plan_splits_bulk_traffic_by_width() {
        // 2 narrow + 2 wide lanes: splitting saves 2*(32-8) = 48 node
        // widths vs one merged bs4 round, far above the dispatch overhead
        let g = plan_width_groups(&[8, 32, 8, 32], &fam(), 4);
        assert_eq!(
            g,
            vec![
                WidthGroup { width: 8, members: vec![0, 2] },
                WidthGroup { width: 32, members: vec![1, 3] },
            ]
        );
    }

    #[test]
    fn plan_merges_lone_adjacent_lanes() {
        // one t8 + one t16 lane: a second dispatch costs more than
        // widening the narrow lane (1 * (16-8) <= DISPATCH_OVERHEAD)
        let g = plan_width_groups(&[8, 16], &fam(), 4);
        assert_eq!(g, vec![WidthGroup { width: 16, members: vec![0, 1] }]);
    }

    #[test]
    fn plan_merge_accounts_for_chunk_spill() {
        // 1x t8 + 4x t16 with max_group 4: absorbing the lone t8 lane
        // would spill the merged bucket into a fifth lane -> a second
        // dispatch is paid anyway, so the unchunked cost model would
        // merge (88 <= 88) while the chunk-aware one must keep it split
        let g = plan_width_groups(&[8, 16, 16, 16, 16], &fam(), 4);
        assert_eq!(
            g,
            vec![
                WidthGroup { width: 8, members: vec![0] },
                WidthGroup { width: 16, members: vec![1, 2, 3, 4] },
            ]
        );
    }

    #[test]
    fn plan_fits_hints_and_chunks_to_max_group() {
        let g = plan_width_groups(&[3, 5, 7, 6, 40], &fam(), 2);
        // hints 3..7 fit t8; 40 exceeds the family -> widest
        assert_eq!(
            g,
            vec![
                WidthGroup { width: 8, members: vec![0, 1] },
                WidthGroup { width: 8, members: vec![2, 3] },
                WidthGroup { width: 32, members: vec![4] },
            ]
        );
        for grp in &g {
            assert!(grp.members.len() <= 2);
        }
    }

    #[test]
    fn cost_model_parses_direct_and_nested_json() {
        let v = Json::parse(r#"{"dispatch_overhead": 13}"#).unwrap();
        assert_eq!(CostModel::from_json(&v).unwrap().dispatch_overhead, 13);
        let v = Json::parse(r#"{"cost_model": {"dispatch_overhead": 4}}"#).unwrap();
        assert_eq!(CostModel::from_json(&v).unwrap().dispatch_overhead, 4);
        let v = Json::parse(r#"{"dispatch_overhead": 0}"#).unwrap();
        assert!(CostModel::from_json(&v).is_err(), "zero overhead rejected");
        let v = Json::parse(r#"{"unrelated": true}"#).unwrap();
        assert!(CostModel::from_json(&v).is_err());
    }

    #[test]
    fn cost_model_fits_from_bench_curve() {
        // ms(t) = 0.5 + 0.05*t -> overhead = 0.5/0.05 = 10 node units
        let pts = [(8usize, 0.9f64), (16, 1.3), (32, 2.1)];
        assert_eq!(CostModel::fit_dispatch_overhead(&pts), Some(10));
        assert_eq!(CostModel::fit_dispatch_overhead(&pts[..1]), None, "one point");
        let flat = [(8usize, 1.0f64), (16, 1.0), (32, 1.0)];
        assert_eq!(CostModel::fit_dispatch_overhead(&flat), None, "zero slope");
        // the bench-dump shape repro bench --json emits
        let v = Json::parse(
            r#"{"benches": [
                {"name": "exe/verify_t8 (fused commit)", "median_ms": 0.9},
                {"name": "exe/verify_t16 (fused commit)", "median_ms": 1.3},
                {"name": "exe/verify_t32 (fused commit)", "median_ms": 2.1},
                {"name": "host/softmax(761)", "median_ms": 0.01}
            ]}"#,
        )
        .unwrap();
        assert_eq!(CostModel::from_json(&v).unwrap().dispatch_overhead, 10);
    }

    #[test]
    fn calibrated_overhead_changes_merge_decisions() {
        // one t8 + one t16 lane: default overhead 8 merges (widening the
        // narrow lane costs 8 <= 8); a calibrated overhead of 2 says a
        // second dispatch is cheap -> keep the split
        let cheap = CostModel { dispatch_overhead: 2 };
        let g = plan_width_groups_with(&[8, 16], &fam(), 4, &cheap);
        assert_eq!(
            g,
            vec![
                WidthGroup { width: 8, members: vec![0] },
                WidthGroup { width: 16, members: vec![1] },
            ]
        );
        let dear = CostModel { dispatch_overhead: 50 };
        let g = plan_width_groups_with(&[8, 32, 8, 32], &fam(), 4, &dear);
        assert_eq!(g.len(), 1, "huge overhead merges everything");
        assert_eq!(g[0].width, 32);
    }

    #[test]
    fn next_groups_respects_policy_and_compat() {
        let q = RequestQueue::new(16);
        // two batchable eagle lanes with different hints + one vanilla
        for (id, hint, method) in [
            (0u64, Some(8), Method::Eagle),
            (1, None, Method::Eagle),
            (2, None, Method::Vanilla),
            (3, Some(8), Method::Eagle),
        ] {
            let mut r = req(id);
            r.method = method;
            r.width_hint = hint;
            r.tree = TreeChoice::Default;
            q.push(r).unwrap();
        }
        let s = Scheduler::new(4, 0).with_policy(AdmissionPolicy::WidthGrouped {
            verify_widths: vec![8, 16, 32],
            max_t: 32,
        });
        let groups = s.next_groups(&q);
        // vanilla -> FCFS singleton; eagle lanes split {0,3}@8 and {1}@32
        assert_eq!(groups.len(), 3);
        let singleton = groups.iter().find(|g| g.verify_cap.is_none()).unwrap();
        assert_eq!(singleton.requests.len(), 1);
        assert_eq!(singleton.requests[0].id, 2);
        let narrow = groups.iter().find(|g| g.verify_cap == Some(8)).unwrap();
        assert_eq!(narrow.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 3]);
        let wide = groups.iter().find(|g| g.verify_cap == Some(32)).unwrap();
        assert_eq!(wide.requests[0].id, 1);
    }

    #[test]
    fn next_groups_classes_sampled_lanes_by_temperature() {
        let q = RequestQueue::new(16);
        // two T=1 eagle lanes batch together; a T=0.7 lane and a greedy
        // lane land in their own classes (one lock-step GenConfig per
        // group); per-lane seeds keep sampled outputs composition-proof
        for (id, temp) in [(0u64, 1.0f32), (1, 0.0), (2, 1.0), (3, 0.7)] {
            let mut r = req(id);
            r.method = Method::Eagle;
            r.temperature = temp;
            q.push(r).unwrap();
        }
        let s = Scheduler::new(4, 0).with_policy(AdmissionPolicy::WidthGrouped {
            verify_widths: vec![8, 16, 32],
            max_t: 32,
        });
        let groups = s.next_groups(&q);
        assert_eq!(groups.len(), 3);
        let ids = |g: &AdmittedGroup| g.requests.iter().map(|r| r.id).collect::<Vec<_>>();
        assert!(groups.iter().any(|g| ids(g) == vec![0, 2]), "equal-T lanes share a group");
        assert!(groups.iter().any(|g| ids(g) == vec![1]));
        assert!(groups.iter().any(|g| ids(g) == vec![3]));
        // sampled lanes are width-batchable now; a verify-width pin is not
        let mut r = req(9);
        r.method = Method::Eagle;
        r.temperature = 1.0;
        assert!(r.width_batchable(), "T>0 eagle requests join width groups");
        r.verify_width = Some(16);
        assert!(!r.width_batchable(), "pinned requests stay on the bs=1 path");
    }

    #[test]
    fn next_groups_never_mix_draft_sources() {
        use crate::spec::source::SourceKind;
        let q = RequestQueue::new(16);
        // two eagle-source lanes batch; a resolved n-gram-source request
        // (same method/tree/temperature) must run as its own singleton
        for (id, source) in
            [(0u64, SourceKind::Eagle), (1, SourceKind::Ngram), (2, SourceKind::Eagle)]
        {
            let mut r = req(id);
            r.method = Method::Eagle;
            r.source = source;
            q.push(r).unwrap();
        }
        let s = Scheduler::new(4, 0).with_policy(AdmissionPolicy::WidthGrouped {
            verify_widths: vec![8, 16, 32],
            max_t: 32,
        });
        let groups = s.next_groups(&q);
        assert_eq!(groups.len(), 2);
        let ids = |g: &AdmittedGroup| g.requests.iter().map(|r| r.id).collect::<Vec<_>>();
        assert!(groups.iter().any(|g| ids(g) == vec![0, 2]), "eagle-source lanes share a group");
        let single = groups.iter().find(|g| ids(g) == vec![1]).unwrap();
        assert!(single.verify_cap.is_none(), "non-eagle source runs outside width groups");
    }

    #[test]
    fn linger_capped_by_tight_deadline() {
        // one request with a 20ms budget, linger of 5s: the deadline cap
        // must cut the wait to ~the budget, not the full linger window
        let q = RequestQueue::new(16);
        let mut r = req(1);
        r.deadline_ms = Some(20);
        q.push(r).unwrap();
        let s = Scheduler::new(4, 5_000);
        let t0 = Instant::now();
        let b = s.next_batch(&q);
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(2), "linger not capped by deadline");
        assert_eq!(s.linger_capped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn linger_cap_subtracts_service_estimate() {
        // loose 60s deadline but a 60s service estimate: the cap lands at
        // ~now, so collect returns immediately instead of lingering
        let q = RequestQueue::new(16);
        let mut r = req(1);
        r.deadline_ms = Some(60_000);
        q.push(r).unwrap();
        let s = Scheduler::new(4, 5_000);
        s.note_service_estimate(60.0);
        assert!((s.est_service_secs() - 60.0).abs() < 1e-9);
        let t0 = Instant::now();
        let b = s.next_batch(&q);
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(2));
    }

    #[test]
    fn resume_entries_skip_linger() {
        // a resumed lane already waited once: with a resume entry in the
        // batch, collect must not sit out the 5s linger window again
        let q = RequestQueue::new(16);
        q.push_resume(req(1));
        let s = Scheduler::new(4, 5_000);
        let t0 = Instant::now();
        let b = s.next_batch(&q);
        assert_eq!(b.len(), 1);
        assert!(b[0].resume, "push_resume marks the entry");
        assert!(t0.elapsed() < Duration::from_secs(2), "resume entry lingered");
    }

    #[test]
    fn unbounded_requests_keep_full_linger_path() {
        // no deadlines anywhere: linger_cap is None and the batch fills
        // normally without touching the capped counter
        let q = RequestQueue::new(16);
        for i in 0..3 {
            q.push(req(i)).unwrap();
        }
        let s = Scheduler::new(3, 0);
        let b = s.next_batch(&q);
        assert_eq!(b.len(), 3);
        assert_eq!(s.linger_capped.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn effective_cost_prefers_live_fit() {
        let s = Scheduler::new(1, 0).with_cost_model(CostModel { dispatch_overhead: 3 });
        assert_eq!(s.effective_cost().dispatch_overhead, 3);
        let live = Arc::new(OnlineCostModel::new(CostModel { dispatch_overhead: 17 }));
        let s = s.with_live_cost(live);
        assert_eq!(s.effective_cost().dispatch_overhead, 17, "live seed wins once attached");
    }

    #[test]
    fn fcfs_policy_is_one_group() {
        let q = RequestQueue::new(16);
        for i in 0..3 {
            q.push(req(i)).unwrap();
        }
        let s = Scheduler::new(4, 0);
        let groups = s.next_groups(&q);
        assert_eq!(groups.len(), 1);
        assert!(groups[0].verify_cap.is_none());
        assert_eq!(groups[0].requests.len(), 3);
    }
}
