//! Admission scheduler: forms work batches from the queue with a simple
//! deadline policy (take what's there, wait up to `linger` for more when
//! batching is enabled), and tracks serving statistics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::queue::RequestQueue;
use super::request::Request;

pub struct Scheduler {
    pub max_batch: usize,
    pub linger: Duration,
    pub served: AtomicU64,
    pub queued_ns: AtomicU64,
}

impl Scheduler {
    pub fn new(max_batch: usize, linger_ms: u64) -> Scheduler {
        Scheduler {
            max_batch,
            linger: Duration::from_millis(linger_ms),
            served: AtomicU64::new(0),
            queued_ns: AtomicU64::new(0),
        }
    }

    /// Block for the next batch (FCFS). Returns empty Vec when the queue
    /// is closed.
    pub fn next_batch(&self, q: &RequestQueue) -> Vec<Request> {
        let first = match q.pop() {
            Some(r) => r,
            None => return Vec::new(),
        };
        let mut batch = vec![first];
        if self.max_batch > 1 {
            let deadline = Instant::now() + self.linger;
            while batch.len() < self.max_batch {
                let more = q.pop_up_to(self.max_batch - batch.len());
                if !more.is_empty() {
                    batch.extend(more);
                    continue;
                }
                if Instant::now() >= deadline {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        for r in &batch {
            self.queued_ns
                .fetch_add(r.arrival.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        self.served.fetch_add(batch.len() as u64, Ordering::Relaxed);
        batch
    }

    pub fn mean_queue_ms(&self) -> f64 {
        let n = self.served.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.queued_ns.load(Ordering::Relaxed) as f64 / n as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Method, TreeChoice};

    fn req(id: u64) -> Request {
        Request {
            id,
            prompt: String::new(),
            max_tokens: 1,
            temperature: 0.0,
            method: Method::Vanilla,
            tree: TreeChoice::Default,
            seed: 0,
            arrival: std::time::Instant::now(),
        }
    }

    #[test]
    fn batches_up_to_max() {
        let q = RequestQueue::new(16);
        for i in 0..5 {
            q.push(req(i)).unwrap();
        }
        let s = Scheduler::new(4, 0);
        let b = s.next_batch(&q);
        assert_eq!(b.len(), 4);
        assert_eq!(b[0].id, 0);
        let b2 = s.next_batch(&q);
        assert_eq!(b2.len(), 1);
        assert_eq!(s.served.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn closed_queue_yields_empty() {
        let q = RequestQueue::new(4);
        q.close();
        let s = Scheduler::new(2, 0);
        assert!(s.next_batch(&q).is_empty());
    }
}
