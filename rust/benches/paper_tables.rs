//! Bench harness — one target per paper table/figure (criterion is not in
//! the offline crate set; this is a hand-rolled harness=false bench that
//! reuses the exact eval code path at reduced scale and prints
//! median-of-repeats timings plus the table itself).
//!
//!   cargo bench                 # all tables, reduced n
//!   cargo bench -- fig1 tab7    # a subset
//!
//! Full-scale tables: `repro eval --all` (see Makefile `eval`).

use eagle_serve::eval::tables::EvalCtx;
use eagle_serve::models::artifacts_dir;

fn main() {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("paper_tables bench skipped: run `make artifacts` first");
        return;
    }
    let filter: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let ctx = EvalCtx::new(&artifacts_dir(), 4, 24).expect("eval ctx");
    let mut failures = 0;
    for id in EvalCtx::ALL {
        if !filter.is_empty() && !filter.iter().any(|f| f == id) {
            continue;
        }
        let t0 = std::time::Instant::now();
        match ctx.run(id) {
            Ok(table) => {
                let dt = t0.elapsed().as_secs_f64();
                println!("== bench {id}: {dt:.2}s ==\n{table}");
            }
            Err(e) => {
                failures += 1;
                eprintln!("== bench {id} FAILED: {e:#}");
            }
        }
    }
    if failures > 0 {
        std::process::exit(1);
    }
}
