//! Hot-path micro-bench (harness=false): per-executable latency of the
//! serving-critical calls (prefill / decode / fused verify / draft step)
//! plus the pure-host components (bias building, softmax, acceptance) —
//! the numbers behind EXPERIMENTS.md §Perf.

use eagle_serve::coordinator::plan_width_groups;
use eagle_serve::eval::bench::{sim_round_ref, sim_round_scratch, sim_scratch, SIM_M, SIM_S};
use eagle_serve::eval::runner::Runner;
use eagle_serve::models::{artifacts_dir, ModelBundle};
use eagle_serve::spec::dyntree::{
    expand_candidates, plan_round_width, rerank, select_frontier, DynTreeParams, WidthFamily,
};
use eagle_serve::spec::sampling::{argmax, softmax};
use eagle_serve::spec::tree::{DraftTree, TreeSpec};
use eagle_serve::util::rng::Rng;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    for _ in 0..iters.min(3) {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "{name:28} median {:8.3} ms   p10 {:8.3}   p90 {:8.3}   ({} iters)",
        times[times.len() / 2],
        times[times.len() / 10],
        times[times.len() * 9 / 10],
        iters
    );
}

fn main() {
    // -- host-only components (always run) ---------------------------------
    let logits: Vec<f32> =
        (0..761).map(|i| ((i * 2654435761u64 as usize) % 997) as f32 / 997.0).collect();
    bench("host/softmax(761)", 1000, || {
        std::hint::black_box(softmax(&logits, 1.0));
    });
    bench("host/argmax(761)", 1000, || {
        std::hint::black_box(argmax(&logits));
    });
    let mut tree = DraftTree::with_root(1);
    let spec = TreeSpec::tree_default();
    let mut parent = 0;
    for (d, &w) in spec.level_widths.iter().enumerate() {
        for i in 0..w {
            let p = if d == 0 { 0 } else { parent };
            tree.add(p, (d * 10 + i) as u32, 0.0, None);
        }
        parent = tree.len() - 1;
    }
    bench("host/verify_inputs(32x192)", 500, || {
        std::hint::black_box(tree.verify_inputs(32, 40, 192));
    });

    // the zero-allocation round state (S22): the verify-input build on
    // reused buffers, and the full host-round pair — allocating
    // reference vs arena/scratch path (same work, property-tested
    // equal outputs; the arena path must win)
    let mut rs = sim_scratch();
    let (mut vt, mut vp, mut vb, mut anc) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    bench("host/verify_inputs_into(32x192)", 500, || {
        vt.clear();
        vt.resize(32, 0);
        vp.clear();
        vp.resize(32, 0);
        vb.clear();
        vb.resize(32 * SIM_S, 0.0);
        tree.verify_inputs_to(32, SIM_M, SIM_S, &mut vt, &mut vp, &mut vb, &mut anc);
        std::hint::black_box(vt.len());
    });
    let sim_tree = eagle_serve::eval::bench::default_bench_tree();
    bench("host/round_ref", 500, || {
        std::hint::black_box(sim_round_ref(&sim_tree));
    });
    bench("host/round_scratch", 500, || {
        std::hint::black_box(sim_round_scratch(&sim_tree, &mut rs));
    });

    // dynamic-planner host components: candidate expansion over a full
    // vocab row, and the global rerank over a grown candidate tree — the
    // planner overhead that sits next to bias-building each round
    let probs = softmax(&logits, 1.0);
    bench("host/dyntree_expand(8x761)", 1000, || {
        for _ in 0..8 {
            std::hint::black_box(expand_candidates(-1.0, &probs, 4));
        }
    });
    let mut rng = Rng::new(7);
    let mut dtree = DraftTree::with_root(1);
    let mut expandable: Vec<usize> = vec![0];
    for _ in 0..5 {
        let frontier = select_frontier(&dtree, &expandable, 8);
        let mut new_nodes = Vec::new();
        for &p in &frontier {
            for ci in 0..4u32 {
                let score = dtree.nodes[p].score - rng.f32() - 0.05;
                new_nodes.push(dtree.add(p, ci, score, None));
            }
        }
        expandable = new_nodes;
    }
    bench(
        &format!("host/dyntree_rerank({}->31)", dtree.len() - 1),
        1000,
        || {
            std::hint::black_box(rerank(&dtree, 31));
        },
    );

    // verify-width selection: the per-round plan (pre-growth budget cap)
    // plus the post-growth fit — pure host overhead of the width family
    let fam = WidthFamily::from_available(&[8, 16, 32], 32, |_| true);
    let wparams = DynTreeParams { depth: 4, frontier_k: 6, branch: 4, budget: 31 };
    bench("host/width_select", 1000, || {
        for nodes in [3usize, 9, 17, 26, 32] {
            std::hint::black_box(plan_round_width(&fam, &wparams, Some((0.5, 0.35))));
            std::hint::black_box(fam.fit(nodes));
        }
    });

    // scheduler grouping decision: partition a 32-lane admission by
    // predicted width under the cost model — the per-admission host
    // overhead of `--width-grouping`
    let ghints: Vec<usize> = (0..32).map(|i| [4usize, 7, 12, 20, 31, 40][i % 6]).collect();
    bench("host/width_group(32)", 1000, || {
        std::hint::black_box(plan_width_groups(&ghints, &fam, 4));
    });

    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("executable benches skipped: run `make artifacts` first");
        return;
    }
    let runner = Runner::new(&artifacts_dir()).expect("runner");
    let bundle = ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], false, false)
        .expect("bundle");
    let tgt = &bundle.target;
    let draft = &bundle.drafts["eagle"];
    let c = &runner.man.constants;
    let prompt: Vec<u32> = (1..30).collect();

    let mut cache = tgt.new_cache(1);
    bench("exe/prefill(p64)", 20, || {
        let mut c2 = tgt.new_cache(1);
        tgt.prefill(&prompt, &mut c2).unwrap();
        std::mem::swap(&mut cache, &mut c2);
    });
    let m = prompt.len();
    bench("exe/decode(1)", 30, || {
        tgt.decode(&mut cache, &[m as i32], &[5]).unwrap();
    });
    // the lowered verify-width family: one bench per width with a tree
    // filling that width, so the per-width cost spread is visible
    let zero_idx = vec![0i32; c.accept_a];
    for &t in &c.verify_widths {
        if !tgt.has_verify(t, 1) {
            eprintln!("exe/verify_t{t} skipped: executable not lowered");
            continue;
        }
        let mut wtree = DraftTree::with_root(1);
        for i in 1..t {
            // chain-ish fill capped at the commit depth, then siblings
            let parent = if i <= c.accept_a - 1 { i - 1 } else { 1 + (i % (c.accept_a - 1)) };
            wtree.add(parent, i as u32, -(i as f32), None);
        }
        let (tokens, pos, bias) = wtree.verify_inputs(t, m, tgt.max_len);
        bench(&format!("exe/verify_t{t} (fused commit)"), 30, || {
            tgt.verify(
                t, &mut cache, &[m as i32], &zero_idx, &[0], &tokens, &pos, &bias, c.accept_a,
            )
            .unwrap();
        });
    }
    let mut dcache = draft.new_cache(1);
    let feats = vec![0.1f32; 8 * tgt.d];
    let toks = vec![3i32; 8];
    let dpos: Vec<i32> = (0..8).map(|i| (m + i) as i32).collect();
    let dbias = eagle_serve::spec::tree::chain_extend_bias(8, tgt.max_len, m, 8);
    bench("exe/draft.step_w8", 30, || {
        draft.step(8, &mut dcache, &[m as i32], &feats, &toks, &dpos, &dbias).unwrap();
    });
    let feats4 = vec![0.1f32; 4 * tgt.d];
    let toks4 = vec![3i32; 4];
    let dpos4: Vec<i32> = (0..4).map(|i| (m + i) as i32).collect();
    let dbias4 = eagle_serve::spec::tree::chain_extend_bias(4, tgt.max_len, m, 4);
    bench("exe/draft.step_w4", 30, || {
        draft.step(4, &mut dcache, &[m as i32], &feats4, &toks4, &dpos4, &dbias4).unwrap();
    });

    // the batched draft-step family (step_w{w}_bs{b}): the per-width cost
    // spread the width-grouped scheduler trades against DISPATCH_OVERHEAD
    for &bsz in &[2usize, 4] {
        for &wd in &c.draft_widths {
            if !draft.has_step(wd, bsz) {
                eprintln!("exe/step_w{wd}_bs{bsz} skipped: executable not lowered");
                continue;
            }
            let mut dc = draft.new_cache(bsz);
            let bf = vec![0.1f32; bsz * wd * tgt.d];
            let bt = vec![3i32; bsz * wd];
            let bp: Vec<i32> = (0..bsz * wd).map(|i| (m + i % wd) as i32).collect();
            let lane_bias = eagle_serve::spec::tree::chain_extend_bias(wd, tgt.max_len, m, wd);
            let mut bb = Vec::with_capacity(bsz * lane_bias.len());
            for _ in 0..bsz {
                bb.extend_from_slice(&lane_bias);
            }
            let wb = vec![m as i32; bsz];
            bench(&format!("exe/step_w{wd}_bs{bsz}"), 20, || {
                draft.step(wd, &mut dc, &wb, &bf, &bt, &bp, &bb).unwrap();
            });
        }
    }
}
