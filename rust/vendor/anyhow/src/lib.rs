//! Vendored, minimal `anyhow`-compatible error handling.
//!
//! The build environment has no crates.io access, so the coordinator
//! carries the small subset of the real `anyhow` API it uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the
//! [`Context`] extension trait. Errors are stored as a flattened message
//! chain (outermost first); `{e}` prints the outermost message, `{e:#}`
//! prints the whole chain joined by `": "`, matching anyhow's formatting
//! contract that the rest of the crate relies on.

use std::fmt;

/// Drop-in replacement for `anyhow::Error`: an owned message chain.
pub struct Error {
    /// chain[0] is the outermost (most recently attached) message.
    chain: Vec<String>,
}

impl Error {
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message (used by [`Context`]).
    pub fn context(mut self, message: impl fmt::Display) -> Error {
        self.chain.insert(0, message.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, so this
// blanket conversion does not overlap the reflexive `From<T> for T`
// (the same trick the real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, ()> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(e.to_string(), "disk on fire");
    }

    #[test]
    fn context_chains_and_alternate_formats() {
        let e: Result<(), std::io::Error> = Err(io_err());
        let e = e.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: disk on fire");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macros_build_errors() {
        let name = "x";
        let e = anyhow!("missing '{name}'");
        assert_eq!(e.to_string(), "missing 'x'");
        let e = anyhow!("got {} of {}", 2, 3);
        assert_eq!(e.to_string(), "got 2 of 3");

        fn bails() -> Result<()> {
            bail!("nope {}", 7);
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 7");

        fn ensures(v: usize) -> Result<()> {
            ensure!(v > 1, "v too small: {v}");
            Ok(())
        }
        assert!(ensures(2).is_ok());
        assert_eq!(ensures(0).unwrap_err().to_string(), "v too small: 0");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
    }
}
