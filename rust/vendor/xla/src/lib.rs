//! Vendored `xla` binding surface (PJRT client / executable / literal).
//!
//! This crate mirrors the exact API subset the coordinator uses from the
//! real `xla` crate (PJRT-over-CPU). The build environment for this repo
//! has no PJRT plugin, so every entry point returns a descriptive
//! [`Error`]; the serving stack degrades gracefully because all engine
//! paths first check for compiled artifacts (`make artifacts`) before
//! touching the runtime. Swapping in the real backend is a Cargo.toml
//! one-liner — the signatures match.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error {
        msg: format!(
            "{what}: PJRT backend not linked in this build (vendored xla stub); \
             point Cargo.toml's `xla` dependency at a real PJRT binding to enable execution"
        ),
    }
}

/// Element types uploadable to / downloadable from device buffers.
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}
impl ArrayElement for i32 {}
impl ArrayElement for u32 {}
impl ArrayElement for i64 {}

pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_buffer"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_missing_backend() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT backend not linked"));
    }
}
