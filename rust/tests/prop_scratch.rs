//! Property tests for the zero-allocation round state (S22): the
//! arena/scratch hot paths must be BIT-IDENTICAL to the allocating
//! reference implementations — including under dirty reuse, where one
//! set of buffers serves many consecutive "rounds" without ever being
//! freed — and steady-state reuse must not grow the scratch footprint.

use eagle_serve::eval::bench::{sim_round_ref, sim_round_scratch, sim_scratch};
use eagle_serve::spec::dyntree::{
    expand_candidates, expand_candidates_into, rerank, rerank_into, select_frontier,
    select_frontier_into, RerankScratch,
};
use eagle_serve::spec::sampling::{
    chain_accept, chain_accept_into, softmax, softmax_into, top_k, top_k_into, tree_accept,
    tree_accept_into, tree_accept_rows,
};
use eagle_serve::spec::scratch::{FeatArena, LogitsSlab, RoundScratch};
use eagle_serve::spec::tree::{
    chain_extend_bias, chain_extend_bias_to, fill_step_rows, fill_step_rows_into, reference,
    DraftTree,
};
use eagle_serve::util::prop::{check, random_dist};
use eagle_serve::util::rng::Rng;

fn random_tree(rng: &mut Rng, max_nodes: usize) -> DraftTree {
    let mut t = DraftTree::with_root(rng.below(100) as u32);
    let extra = 1 + rng.below(max_nodes.max(2) - 1);
    for _ in 0..extra {
        let parent = rng.below(t.len());
        t.add(parent, rng.below(100) as u32, -rng.f32(), None);
    }
    t
}

#[test]
fn prop_verify_inputs_to_matches_reference_under_dirty_reuse() {
    // ONE buffer set across all cases: stale contents from the previous
    // (differently-shaped) case must never leak into the next result
    let mut tokens = Vec::new();
    let mut pos = Vec::new();
    let mut bias = Vec::new();
    let mut anc = Vec::new();
    check("verify_inputs_to == reference", 60, |rng, _| {
        let t = random_tree(rng, 24);
        let t_pad = t.len() + rng.below(8);
        let cache_len = 1 + rng.below(12);
        let s = cache_len + t_pad + 1 + rng.below(16);
        let (rt, rp, rb) = reference::verify_inputs_ref(&t, t_pad, cache_len, s);
        tokens.clear();
        tokens.resize(t_pad, i32::MIN); // poison: every cell must be written
        pos.clear();
        pos.resize(t_pad, i32::MIN);
        bias.clear();
        bias.resize(t_pad * s, f32::NAN);
        t.verify_inputs_to(t_pad, cache_len, s, &mut tokens, &mut pos, &mut bias, &mut anc);
        assert_eq!(tokens, rt);
        assert_eq!(pos, rp);
        assert_eq!(bias, rb, "bias rows diverged (t_pad {t_pad}, cache {cache_len}, s {s})");
        // the thin allocating wrapper agrees too
        let (wt, wp, wb) = t.verify_inputs(t_pad, cache_len, s);
        assert_eq!((wt, wp, wb), (rt, rp, rb));
    });
}

#[test]
fn prop_ancestor_bits_match_bool_mask() {
    let mut words = Vec::new();
    check("ancestor bits == mask", 60, |rng, _| {
        let t = random_tree(rng, 80);
        for i in 0..t.len() {
            let mask = t.ancestor_mask(i);
            t.ancestor_bits_into(i, &mut words);
            assert_eq!(words.len(), t.len().div_ceil(64));
            for (j, &m) in mask.iter().enumerate() {
                let bit = (words[j / 64] >> (j % 64)) & 1 == 1;
                assert_eq!(bit, m, "node {i}, bit {j}");
            }
        }
    });
}

#[test]
fn prop_children_into_matches_allocating_children() {
    let mut buf = vec![99usize; 7]; // dirty
    check("children_into == children", 40, |rng, _| {
        let t = random_tree(rng, 40);
        for i in 0..t.len() {
            t.children_into(i, &mut buf);
            assert_eq!(buf, t.children(i));
        }
    });
}

#[test]
fn prop_fill_step_rows_into_matches_reference() {
    // reused arena + staging vs the allocating reference on identical
    // inputs: features, tokens, positions, slot assignment, bias — all
    // must agree exactly
    let mut arena = FeatArena::new(1);
    let (mut sf, mut st, mut sp, mut sb) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    check("fill_step_rows_into == reference", 60, |rng, _| {
        let t = random_tree(rng, 20);
        let d = 1 + rng.below(5);
        let m = 4 + rng.below(8);
        // chunk: random non-root nodes (freshly-added set), no repeats
        let mut chunk: Vec<usize> = (1..t.len()).filter(|_| rng.f32() < 0.5).collect();
        if chunk.is_empty() {
            chunk.push(1);
        }
        let w = chunk.len() + rng.below(4);
        let s = m + t.len() + w + 24 + rng.below(8);
        let write_base = m + t.len() + rng.below(8);
        let shifted = rng.f32() < 0.5;
        // per-node features, mirrored into the arena
        let node_feat: Vec<Vec<f32>> =
            (0..t.len()).map(|_| (0..d).map(|_| rng.f32()).collect()).collect();
        arena.clear(d);
        for row in &node_feat {
            arena.push(row);
        }
        // some ancestors already stepped: random scratch slots in [m, write_base)
        let mut slots_ref: Vec<Option<usize>> = vec![None; t.len()];
        for (i, slot) in slots_ref.iter_mut().enumerate().skip(1) {
            if rng.f32() < 0.4 && !chunk.contains(&i) && write_base > m {
                *slot = Some(m + rng.below(write_base - m));
            }
        }
        let mut slots_new = slots_ref.clone();
        // reference (allocating) path
        let mut rf = vec![0f32; w * d];
        let mut rt = vec![0i32; w];
        let mut rp = vec![0i32; w];
        let rb = fill_step_rows(
            &t, &chunk, &node_feat, &mut slots_ref, shifted, d, s, m, m, write_base, w, &mut rf,
            &mut rt, &mut rp,
        );
        // arena path on dirty reused buffers (poisoned)
        sf.clear();
        sf.resize(w * d, f32::NAN);
        st.clear();
        st.resize(w, i32::MIN);
        sp.clear();
        sp.resize(w, i32::MIN);
        sb.clear();
        sb.resize(w * s, f32::NAN);
        fill_step_rows_into(
            &t, &chunk, &arena, &mut slots_new, shifted, d, s, m, m, write_base, w, &mut sf,
            &mut st, &mut sp, &mut sb,
        );
        assert_eq!(sf, rf, "feature rows diverged");
        assert_eq!(st, rt, "token rows diverged");
        assert_eq!(sp, rp, "position rows diverged");
        assert_eq!(sb, rb, "bias block diverged");
        assert_eq!(slots_new, slots_ref, "slot assignment diverged");
    });
}

#[test]
fn prop_chain_extend_bias_to_matches_reference() {
    let mut buf = Vec::new();
    check("chain_extend_bias_to == reference", 60, |rng, _| {
        let w = 1 + rng.below(8);
        let n = 1 + rng.below(w);
        let s = 16 + rng.below(48);
        let write_base = rng.below(s.saturating_sub(w).max(1));
        let rb = reference::chain_extend_bias_ref(w, s, write_base, n);
        buf.clear();
        buf.resize(w * s, f32::NAN);
        chain_extend_bias_to(w, s, write_base, n, &mut buf);
        assert_eq!(buf, rb);
        assert_eq!(chain_extend_bias(w, s, write_base, n), rb, "wrapper agrees");
    });
}

#[test]
fn prop_sampling_into_variants_are_bit_identical() {
    let mut probs = Vec::new();
    let mut idx = Vec::new();
    let mut pairs = Vec::new();
    check("softmax/top_k/expand into == allocating", 60, |rng, _| {
        let n = 2 + rng.below(40);
        let logits: Vec<f32> = (0..n).map(|_| rng.f32() * 8.0 - 4.0).collect();
        let t = 0.25 + rng.f32() * 2.0;
        softmax_into(&logits, t, &mut probs);
        assert_eq!(probs, softmax(&logits, t), "softmax_into must be bit-identical");
        let k = 1 + rng.below(n);
        top_k_into(&probs, k, &mut idx);
        let reference = top_k(&probs, k);
        assert_eq!(idx.len(), reference.len());
        for (i, &(ri, rp)) in reference.iter().enumerate() {
            assert_eq!(idx[i], ri);
            assert_eq!(probs[idx[i]], rp);
        }
        let parent_score = -rng.f32() * 3.0;
        let branch = 1 + rng.below(6);
        expand_candidates_into(parent_score, &probs, branch, &mut idx, &mut pairs);
        assert_eq!(pairs, expand_candidates(parent_score, &probs, branch));
    });
}

#[test]
fn prop_accept_rule_into_variants_are_bit_identical() {
    // one reused (dirty) residual/work buffer across every case: the
    // _into accept rules and the slab-row accessor form must reproduce
    // the allocating references verdict-for-verdict AND draw-for-draw
    let mut work = vec![f32::NAN; 3];
    let mut slab = FeatArena::new(1);
    check("accept rules into == allocating", 80, |rng, case| {
        let n = 2 + rng.below(6);
        let p = random_dist(rng, n);
        let k = 1 + rng.below(4);
        let qs: Vec<Vec<f32>> = (0..k).map(|_| random_dist(rng, n)).collect();
        let toks: Vec<usize> = (0..k).map(|_| rng.below(n)).collect();
        let seed = rng.next_u64();
        // chain rule
        let mut ra = Rng::new(seed);
        let mut rb = Rng::new(seed);
        let va = chain_accept(&p, &qs[0], toks[0], &mut ra);
        let vb = chain_accept_into(&p, &qs[0], toks[0], &mut work, &mut rb);
        assert_eq!(va, vb, "case {case}: chain verdicts diverged");
        assert_eq!(ra.next_u64(), rb.next_u64(), "case {case}: chain RNG diverged");
        // tree rule: allocating vs _into vs slab-row accessor
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| q.as_slice()).collect();
        slab.clear(n);
        for q in &qs {
            slab.push(q);
        }
        let (mut r1, mut r2, mut r3) = (Rng::new(seed), Rng::new(seed), Rng::new(seed));
        let v1 = tree_accept(&p, &qrefs, &toks, &mut r1);
        let v2 = tree_accept_into(&p, &qrefs, &toks, &mut work, &mut r2);
        let v3 = tree_accept_rows(&p, k, |ci| slab.get(ci), &toks, &mut work, &mut r3);
        assert_eq!(v1, v2, "case {case}: tree_accept_into diverged");
        assert_eq!(v1, v3, "case {case}: tree_accept_rows (slab) diverged");
        let tail = r1.next_u64();
        assert_eq!(tail, r2.next_u64(), "case {case}: tree RNG diverged (into)");
        assert_eq!(tail, r3.next_u64(), "case {case}: tree RNG diverged (rows)");
    });
}

#[test]
fn prop_select_frontier_and_rerank_into_match_under_reuse() {
    let mut out = vec![7usize; 3]; // dirty
    let mut pruned = DraftTree::default();
    let mut rr = RerankScratch::default();
    check("select/rerank into == allocating", 60, |rng, _| {
        let t = random_tree(rng, 40);
        let cands: Vec<usize> = (0..t.len()).filter(|_| rng.f32() < 0.6).collect();
        let k = 1 + rng.below(10);
        select_frontier_into(&t, &cands, k, &mut out);
        assert_eq!(out, select_frontier(&t, &cands, k));
        let budget = 1 + rng.below(t.len() + 4);
        let (rp, rkept) = rerank(&t, budget);
        rerank_into(&t, budget, &mut pruned, &mut rr);
        assert_eq!(pruned.len(), rp.len());
        assert_eq!(rr.kept, rkept);
        for (a, b) in pruned.nodes.iter().zip(&rp.nodes) {
            assert_eq!(a.token, b.token);
            assert_eq!(a.parent, b.parent);
            assert_eq!(a.depth, b.depth);
            assert_eq!(a.score, b.score);
        }
    });
}

#[test]
fn prop_round_sim_scratch_reuse_is_lossless_and_alloc_free() {
    // consecutive rounds over RANDOM trees on one scratch: results equal
    // the allocating reference every round, and after the first few
    // rounds the footprint must stop growing (steady state)
    let mut s = sim_scratch();
    let mut fp_after_warmup = 0usize;
    check("round sim: dirty reuse lossless", 40, |rng, case| {
        let t = random_tree(rng, 24);
        assert_eq!(sim_round_scratch(&t, &mut s), sim_round_ref(&t), "case {case}");
        if case == 4 {
            fp_after_warmup = s.footprint();
        }
        if case > 4 {
            assert_eq!(
                s.footprint(),
                fp_after_warmup,
                "scratch footprint grew after warm-up (case {case})"
            );
        }
    });
}

#[test]
fn prop_logits_slab_and_arena_reuse_has_no_stale_state() {
    let mut arena = FeatArena::new(1);
    let mut slab = LogitsSlab::new(1);
    check("arena/slab reuse", 40, |rng, _| {
        let d = 1 + rng.below(6);
        let vocab = 2 + rng.below(12);
        let n = 1 + rng.below(20);
        arena.clear(d);
        slab.clear(vocab);
        let rows: Vec<Vec<f32>> = (0..n).map(|_| (0..d).map(|_| rng.f32()).collect()).collect();
        let dists: Vec<Option<Vec<f32>>> = (0..n)
            .map(|_| if rng.f32() < 0.3 { None } else { Some(random_dist(rng, vocab)) })
            .collect();
        for i in 0..n {
            arena.push_empty();
            arena.set(i, &rows[i]);
            slab.push_empty();
            if let Some(q) = &dists[i] {
                slab.set(i, q);
            }
        }
        for i in 0..n {
            assert_eq!(arena.get(i), rows[i].as_slice());
            match &dists[i] {
                Some(q) => assert_eq!(slab.get(i), Some(q.as_slice())),
                None => assert!(slab.get(i).is_none(), "unfilled row {i} must read None"),
            }
        }
        assert!(slab.get(n).is_none());
    });
}

#[test]
fn round_scratch_begin_round_seeds_root_and_clears() {
    let mut s = RoundScratch::new(3, 4);
    s.begin_round(&[1.0, 2.0, 3.0], &[0.1, 0.2, 0.3, 0.4]);
    s.feat.push_empty();
    s.node_slot.push(Some(9));
    s.frontier.push(5);
    s.begin_round(&[4.0, 5.0, 6.0], &[0.4, 0.3, 0.2, 0.1]);
    assert_eq!(s.feat.len(), 1, "only the root row survives a reset");
    assert_eq!(s.feat.get(0), &[4.0, 5.0, 6.0]);
    assert_eq!(s.logits.get(0), Some(&[0.4f32, 0.3, 0.2, 0.1][..]));
    assert_eq!(s.node_slot, vec![None]);
    assert!(s.frontier.is_empty() && s.new_nodes.is_empty() && s.expandable.is_empty());
}
