//! Property tests for the batched T>0 sampling path: the q-slab growth +
//! [`sampled_accept_walk`] machinery both engines share must (a) be
//! BIT-IDENTICAL to the `Rc<Vec<f32>>` reference implementation it
//! replaced — including under dirty scratch reuse — (b) preserve the
//! target distribution per lane when many lanes run lock-step on
//! independent RNG streams, and (c) make a lane's sampled output
//! invariant to batch composition (equal seed => equal tokens, alone or
//! batched) — the guarantee behind `Request::width_batchable` admitting
//! T>0 requests to width groups.

use std::rc::Rc;

use eagle_serve::eval::bench::sim_sampled_grow;
use eagle_serve::spec::engine::sampled_accept_walk;
use eagle_serve::spec::sampling::{sample, softmax, tree_accept, TreeVerdict};
use eagle_serve::spec::scratch::RoundScratch;
use eagle_serve::spec::tree::DraftTree;
use eagle_serve::util::prop::{check, random_dist};
use eagle_serve::util::rng::Rng;

/// Logits whose softmax (t=1) reproduces `p` up to float slop.
fn logits_of(p: &[f32]) -> Vec<f32> {
    p.iter().map(|&x| x.max(1e-20).ln()).collect()
}

/// The Rc reference: the pre-slab implementation kept verbatim as the
/// oracle — `Rc::new(softmax(..))` per frontier node, clones shared by
/// siblings, per-node q retained in a side table.
#[allow(clippy::type_complexity)]
fn grow_sampled_rc(
    draft_logits: &[f32],
    temp: f32,
    levels: &[usize],
    rng: &mut Rng,
) -> (DraftTree, Vec<Option<Rc<Vec<f32>>>>) {
    let mut tree = DraftTree::with_root(0);
    let mut qmap: Vec<Option<Rc<Vec<f32>>>> = vec![None];
    let mut frontier = vec![0usize];
    for &width in levels {
        let mut cands: Vec<(usize, u32, Rc<Vec<f32>>)> = Vec::new();
        let per = (width / frontier.len().max(1)).max(1);
        for &parent in &frontier {
            let q = Rc::new(softmax(draft_logits, temp));
            for _ in 0..per {
                if cands.len() >= width {
                    break;
                }
                let tok = sample(&q, rng) as u32;
                cands.push((parent, tok, q.clone()));
            }
        }
        if cands.is_empty() {
            break;
        }
        let mut new_nodes = Vec::new();
        for (p, tok, q) in cands {
            // the side table is keyed by node index; the in-node id is
            // unused by this reference (the slab path is what stores ids)
            let ni = tree.add(p, tok, 0.0, Some(0));
            qmap.push(Some(q));
            new_nodes.push(ni);
        }
        frontier = new_nodes;
    }
    (tree, qmap)
}

/// The Rc reference acceptance walk: fresh `toks`/`qs`/`qrefs` vectors
/// per node and the allocating [`tree_accept`] — what the engines did
/// before the q-slab. Same RNG draw sequence as [`sampled_accept_walk`].
fn walk_rc(
    tree: &DraftTree,
    qmap: &[Option<Rc<Vec<f32>>>],
    target_logits: &[f32],
    temp: f32,
    rng: &mut Rng,
) -> (Vec<usize>, u32) {
    let mut path = vec![0usize];
    let mut cur = 0usize;
    loop {
        let children = tree.children(cur);
        let probs = softmax(target_logits, temp);
        if children.is_empty() {
            return (path, sample(&probs, rng) as u32);
        }
        let toks: Vec<usize> = children.iter().map(|&c| tree.nodes[c].token as usize).collect();
        let qs: Vec<Rc<Vec<f32>>> =
            children.iter().map(|&c| qmap[c].clone().expect("sampled node has q")).collect();
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| q.as_slice()).collect();
        match tree_accept(&probs, &qrefs, &toks, rng) {
            TreeVerdict::AcceptChild(ci) => {
                path.push(children[ci]);
                cur = children[ci];
            }
            TreeVerdict::Residual(t) => return (path, t as u32),
        }
    }
}

/// First token a round commits: the first accepted child, or the bonus.
fn first_token(tree: &DraftTree, path: &[usize], bonus: u32) -> usize {
    if path.len() > 1 {
        tree.nodes[path[1]].token as usize
    } else {
        bonus as usize
    }
}

#[test]
fn prop_qslab_round_is_bit_identical_to_rc_reference_under_dirty_reuse() {
    // ONE scratch serves every case (poisoned state from the previous
    // differently-shaped case must never leak), exactly like a warm
    // lane in the server's pool
    let mut s = RoundScratch::new(1, 4);
    let mut tree = DraftTree::default();
    check("q-slab == Rc reference", 60, |rng, case| {
        let n = 2 + rng.below(6);
        let draft_logits: Vec<f32> = (0..n).map(|_| rng.f32() * 6.0 - 3.0).collect();
        let target_logits: Vec<f32> = (0..n).map(|_| rng.f32() * 6.0 - 3.0).collect();
        let temp = 0.25 + rng.f32() * 1.5;
        let levels: Vec<usize> = (0..1 + rng.below(3)).map(|_| 1 + rng.below(4)).collect();
        let seed = rng.next_u64();
        // slab path on the reused scratch
        let mut rng_a = Rng::new(seed);
        sim_sampled_grow(&mut tree, &mut s, &draft_logits, temp, &levels, &mut rng_a);
        let mut alpha = [(0u64, 0u64); 5];
        let bonus = sampled_accept_walk(
            &tree,
            |_i| target_logits.as_slice(),
            temp,
            &mut rng_a,
            &mut alpha,
            &mut s,
        );
        // Rc reference from the same seed
        let mut rng_b = Rng::new(seed);
        let (rtree, qmap) = grow_sampled_rc(&draft_logits, temp, &levels, &mut rng_b);
        let (rpath, rbonus) = walk_rc(&rtree, &qmap, &target_logits, temp, &mut rng_b);
        assert_eq!(tree.len(), rtree.len(), "case {case}: tree sizes diverged");
        for (a, b) in tree.nodes.iter().zip(&rtree.nodes) {
            assert_eq!(a.token, b.token, "case {case}: sampled tokens diverged");
            assert_eq!(a.parent, b.parent);
        }
        assert_eq!(s.path, rpath, "case {case}: accepted paths diverged");
        assert_eq!(bonus, rbonus, "case {case}: bonus tokens diverged");
        // and the q rows themselves are bit-identical to the Rc copies
        for (ni, node) in tree.nodes.iter().enumerate().skip(1) {
            let qid = node.q.expect("sampled node has q") as usize;
            let rq = qmap[ni].as_ref().expect("reference q");
            assert_eq!(s.qs.get(qid), rq.as_slice(), "case {case}: q row {ni} diverged");
        }
        // both streams fully consumed in lock-step
        assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "case {case}: RNG streams diverged");
    });
}

#[test]
fn prop_batched_t1_walk_preserves_distribution_per_lane() {
    // B lanes lock-step with independent streams + scratch (mirroring
    // chain_accept_preserves_distribution through the full batched
    // machinery): each lane's first committed token must be distributed
    // as ITS OWN target p, untouched by what the other lanes sample.
    check("batched T>0 law per lane", 3, |rng, _| {
        let lanes = 2 + rng.below(2);
        let n = 2 + rng.below(4);
        let ps: Vec<Vec<f32>> = (0..lanes).map(|_| random_dist(rng, n)).collect();
        let qs: Vec<Vec<f32>> = (0..lanes).map(|_| random_dist(rng, n)).collect();
        let tlogits: Vec<Vec<f32>> = ps.iter().map(|p| logits_of(p)).collect();
        let dlogits: Vec<Vec<f32>> = qs.iter().map(|q| logits_of(q)).collect();
        let levels: Vec<usize> = (0..1 + rng.below(2)).map(|_| 1 + rng.below(3)).collect();
        let mut rngs: Vec<Rng> = (0..lanes).map(|li| Rng::new(1000 + li as u64)).collect();
        let mut scratch: Vec<RoundScratch> =
            (0..lanes).map(|_| RoundScratch::new(1, n)).collect();
        let mut trees: Vec<DraftTree> = (0..lanes).map(|_| DraftTree::default()).collect();
        let trials = 20_000;
        let mut counts = vec![vec![0usize; n]; lanes];
        let mut alpha = [(0u64, 0u64); 5];
        for _ in 0..trials {
            for li in 0..lanes {
                sim_sampled_grow(
                    &mut trees[li],
                    &mut scratch[li],
                    &dlogits[li],
                    1.0,
                    &levels,
                    &mut rngs[li],
                );
                let bonus = sampled_accept_walk(
                    &trees[li],
                    |_i| tlogits[li].as_slice(),
                    1.0,
                    &mut rngs[li],
                    &mut alpha,
                    &mut scratch[li],
                );
                counts[li][first_token(&trees[li], &scratch[li].path, bonus)] += 1;
            }
        }
        for li in 0..lanes {
            for i in 0..n {
                let emp = counts[li][i] as f32 / trials as f32;
                assert!(
                    (emp - ps[li][i]).abs() < 0.025,
                    "lane {li} token {i}: emp {emp} vs p {}",
                    ps[li][i]
                );
            }
        }
    });
}

#[test]
fn prop_equal_seed_lane_output_is_invariant_to_batch_composition() {
    // a lane's (seed, prompt-distributions) fully determine its sampled
    // rounds: running it ALONE and running it interleaved with other
    // lanes (whose streams advance between its rounds) must produce the
    // same trees, paths, and bonus tokens — the bs=1 vs batched
    // equal-seed equivalence at the component level
    check("lane invariance", 20, |rng, _| {
        let n = 2 + rng.below(5);
        let dlogits: Vec<f32> = (0..n).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let tlogits: Vec<f32> = (0..n).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let other_d: Vec<f32> = (0..n).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let levels = [2usize, 3];
        let seed = rng.next_u64();
        let rounds = 6;
        let mut alpha = [(0u64, 0u64); 5];
        // solo run
        let mut solo: Vec<(Vec<u32>, Vec<usize>, u32)> = Vec::new();
        {
            let mut r = Rng::new(seed);
            let mut s = RoundScratch::new(1, n);
            let mut tree = DraftTree::default();
            for _ in 0..rounds {
                sim_sampled_grow(&mut tree, &mut s, &dlogits, 1.0, &levels, &mut r);
                let bonus = sampled_accept_walk(
                    &tree, |_| tlogits.as_slice(), 1.0, &mut r, &mut alpha, &mut s,
                );
                solo.push((tree.nodes.iter().map(|x| x.token).collect(), s.path.clone(), bonus));
            }
        }
        // batched run: a second lane with its own stream works between
        // this lane's rounds
        {
            let mut r = Rng::new(seed);
            let mut r2 = Rng::new(seed ^ 0xDEAD_BEEF);
            let mut s = RoundScratch::new(1, n);
            let mut s2 = RoundScratch::new(1, n);
            let mut tree = DraftTree::default();
            let mut tree2 = DraftTree::default();
            for (i, expect) in solo.iter().enumerate() {
                sim_sampled_grow(&mut tree2, &mut s2, &other_d, 1.0, &levels, &mut r2);
                sim_sampled_grow(&mut tree, &mut s, &dlogits, 1.0, &levels, &mut r);
                let _b2 = sampled_accept_walk(
                    &tree2, |_| tlogits.as_slice(), 1.0, &mut r2, &mut alpha, &mut s2,
                );
                let bonus = sampled_accept_walk(
                    &tree, |_| tlogits.as_slice(), 1.0, &mut r, &mut alpha, &mut s,
                );
                let got: Vec<u32> = tree.nodes.iter().map(|x| x.token).collect();
                assert_eq!(got, expect.0, "round {i}: tree diverged under batching");
                assert_eq!(s.path, expect.1, "round {i}: path diverged under batching");
                assert_eq!(bonus, expect.2, "round {i}: bonus diverged under batching");
            }
        }
    });
}

#[test]
fn prop_walk_scratch_stays_allocation_free_once_warm() {
    // the T>0 footprint law: after a warm-up round, repeated sampled
    // rounds (growth + walk) must not grow the scratch — the q-slab and
    // walk staging reuse their capacity like every other S22 buffer
    let n = 8;
    let mut s = RoundScratch::new(1, n);
    s.reserve(1, n, 64, 32, 32, 8);
    s.reserve_q(n, 32); // the sampled-path reservation the engines add at T>0
    let mut tree = DraftTree::default();
    let mut rng = Rng::new(11);
    let dlogits: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
    let tlogits: Vec<f32> = (0..n).map(|i| (i as f32 * 0.71).cos()).collect();
    let mut alpha = [(0u64, 0u64); 5];
    let mut fp = 0usize;
    for round in 0..10 {
        sim_sampled_grow(&mut tree, &mut s, &dlogits, 1.0, &[4, 8, 8, 5], &mut rng);
        let _ = sampled_accept_walk(
            &tree, |_| tlogits.as_slice(), 1.0, &mut rng, &mut alpha, &mut s,
        );
        if round == 0 {
            fp = s.footprint();
        } else {
            assert_eq!(s.footprint(), fp, "sampled round {round} grew the scratch");
        }
    }
}
