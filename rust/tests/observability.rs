//! Serving observability integration tests (no artifacts needed): build
//! the EXACT registry the server scrapes (`ServerMetrics`), drive it
//! with synthetic generations and round events, and assert the
//! `GET /metrics` body is valid Prometheus text exposition carrying
//! every family the acceptance criteria name. Also covers the
//! `/healthz` stall logic and the `/trace` JSON roundtrip.

use eagle_serve::metrics::registry::parse_exposition;
use eagle_serve::metrics::trace::{events_from_json, summarize, RoundEvent, RoundObserver};
use eagle_serve::metrics::{Aggregate, GenRecord};
use eagle_serve::server::{Health, ServerMetrics};
use eagle_serve::util::json::Json;

/// A plausible finished generation: 24 tokens over 8 rounds, 60 ms
/// wall, with width/drag/phase detail filled in.
fn fake_rec(wall_ms: u64, dragged: usize) -> GenRecord {
    let mut r = GenRecord::new(16);
    r.tokens = (0..24).collect();
    r.target_passes = 9;
    r.round_accepts = vec![3; 8];
    r.round_verify_t = vec![26, 26, 8, 8, 26, 26, 8, 8];
    r.round_draft_w = vec![10, 10, 4, 4, 10, 10, 4, 4];
    r.dragged_rounds = dragged;
    r.wall_ns = wall_ms * 1_000_000;
    r.ttft_ns = 4_000_000;
    r.timeline.prefill_ns = 4_000_000;
    r.timeline.draft_ns = 20_000_000;
    r.timeline.verify_ns = 30_000_000;
    r.timeline.commit_ns = 2_000_000;
    r.timeline.host_ns = 4_000_000;
    r
}

fn ev(lane: u32, round: u32, accepted: u32) -> RoundEvent {
    RoundEvent {
        lane,
        round,
        tree_nodes: 25,
        verify_t: 26,
        draft_w: 10,
        accepted,
        draft_ns: 2_500_000,
        verify_ns: 3_750_000,
        host_ns: 500_000,
        alloc_bytes: 0,
    }
}

/// Drive a ServerMetrics the way the worker does and return the parsed
/// exposition plus the aggregate that fed the gauges.
fn driven_metrics() -> (ServerMetrics, Aggregate) {
    let m = ServerMetrics::new(32);
    let mut agg = Aggregate::new();
    for i in 0..4u64 {
        m.on_request();
        m.on_dispatch(i % 2 == 0, if i % 2 == 0 { 2 } else { 1 });
        let rec = fake_rec(40 + i * 20, i as usize);
        for round in 0..8 {
            m.on_round(&ev(i as u32, round, 3));
        }
        m.record_gen(&rec, 0.005 * (i + 1) as f64, 0.1 * (i + 1) as f64, 1);
        agg.add(&rec);
    }
    m.on_rejected();
    m.on_errors(1);
    m.update_aggregate(&agg);
    m.set_queue_depth(3);
    m.set_inflight(2);
    (m, agg)
}

#[test]
fn exposition_carries_required_families_and_parses() {
    let (m, agg) = driven_metrics();
    let text = m.render();
    // the parser validates: typed families, cumulative buckets,
    // +Inf == _count, _sum present — a parse failure IS a test failure
    let exp = parse_exposition(&text).expect("server exposition must be valid");

    // request lifecycle histograms
    for fam in
        ["eagle_request_seconds", "eagle_ttft_seconds", "eagle_queue_wait_seconds", "eagle_token_seconds"]
    {
        let f = exp.family(fam).unwrap_or_else(|| panic!("{fam} missing"));
        assert_eq!(f.typ, "histogram", "{fam} must be a histogram");
        assert_eq!(exp.value(&format!("{fam}_count")), Some(4.0), "{fam} count");
    }
    // TTFT = queue_wait + engine ttft_ns: first request 5 ms + 4 ms
    let ttft_sum = exp.value("eagle_ttft_seconds_sum").unwrap();
    let want_ttft: f64 = (1..=4).map(|i| 0.005 * i as f64 + 0.004).sum();
    assert!((ttft_sum - want_ttft).abs() < 1e-4, "ttft sum {ttft_sum} want {want_ttft}");

    // tau and width gauges mirror the aggregate
    assert!((exp.value("eagle_tau").unwrap() - agg.tau()).abs() < 1e-9);
    assert!((exp.value("eagle_mean_verify_t").unwrap() - agg.mean_verify_t()).abs() < 1e-9);
    assert!((exp.value("eagle_mean_draft_w").unwrap() - agg.mean_draft_w()).abs() < 1e-9);
    assert!(
        (exp.value("eagle_latency_p50_seconds").unwrap() - agg.latency_p50_ms() / 1e3).abs()
            < 1e-9
    );
    assert!(
        (exp.value("eagle_latency_p99_seconds").unwrap() - agg.latency_p99_ms() / 1e3).abs()
            < 1e-9
    );

    // scheduler gauges + dispatch/drag counters
    assert_eq!(exp.value("eagle_queue_depth"), Some(3.0));
    assert_eq!(exp.value("eagle_inflight_lanes"), Some(2.0));
    assert_eq!(exp.value("eagle_last_group_lanes"), Some(1.0));
    assert_eq!(exp.value("eagle_dispatch_batched_total"), Some(4.0));
    assert_eq!(exp.value("eagle_dispatch_bs1_total"), Some(2.0));
    assert_eq!(exp.value("eagle_dragged_rounds_total"), Some(0.0 + 1.0 + 2.0 + 3.0));
    assert_eq!(exp.value("eagle_requests_total"), Some(4.0));
    assert_eq!(exp.value("eagle_rejected_total"), Some(1.0));
    assert_eq!(exp.value("eagle_errors_total"), Some(1.0));
    assert_eq!(exp.value("eagle_tokens_total"), Some(96.0));

    // per-phase time totals: one labeled series per phase, in seconds
    let phases = exp.family("eagle_phase_seconds_total").expect("phase family");
    assert_eq!(phases.typ, "counter");
    for (phase, per_gen_s) in
        [("prefill", 0.004), ("draft", 0.02), ("verify", 0.03), ("commit", 0.002), ("host", 0.004)]
    {
        let s = phases
            .samples
            .iter()
            .find(|s| s.label("phase") == Some(phase))
            .unwrap_or_else(|| panic!("phase={phase} series missing"));
        assert!(
            (s.value - 4.0 * per_gen_s).abs() < 1e-9,
            "phase {phase}: {} want {}",
            s.value,
            4.0 * per_gen_s
        );
    }

    // round-level histograms fed by the observer
    assert_eq!(exp.value("eagle_rounds_total"), Some(32.0));
    assert_eq!(exp.value("eagle_round_accepted_tokens_count"), Some(32.0));
    assert_eq!(exp.value("eagle_round_verify_seconds_count"), Some(32.0));
    // every observe was accepted=3 -> the le="3" cumulative bucket holds all 32
    let fam = exp.family("eagle_round_accepted_tokens").unwrap();
    let b3 = fam
        .samples
        .iter()
        .find(|s| s.name == "eagle_round_accepted_tokens_bucket" && s.label("le") == Some("3"))
        .expect("le=3 bucket");
    assert_eq!(b3.value, 32.0);
}

#[test]
fn gen_seconds_shares_batched_wall_across_lanes() {
    let m = ServerMetrics::new(8);
    let rec = fake_rec(60, 0);
    // two lanes of one bs=2 group report the same 60 ms wall; the total
    // must count it once, not twice
    m.record_gen(&rec, 0.0, 0.06, 2);
    m.record_gen(&rec, 0.0, 0.06, 2);
    let exp = parse_exposition(&m.render()).unwrap();
    let total = exp.value("eagle_gen_seconds_total").unwrap();
    assert!((total - 0.06).abs() < 1e-9, "gen seconds {total} want 0.06");
}

#[test]
fn trace_dump_roundtrips_and_summarizes() {
    let m = ServerMetrics::new(16);
    for lane in 0..2u32 {
        for round in 0..4 {
            m.on_round(&ev(lane, round, 4));
        }
    }
    // the /trace payload: serialize, reparse, recover the events
    let text = m.trace.to_json().to_string();
    let parsed = Json::parse(&text).expect("trace payload is valid json");
    let events = events_from_json(&parsed);
    assert_eq!(events.len(), 8);
    assert_eq!(events[0], ev(0, 0, 4));
    let s = summarize(&events);
    assert!(s.contains("8 rounds over 2 lane(s)"), "{s}");
}

#[test]
fn robustness_families_render_and_derive() {
    let m = ServerMetrics::new(8);
    m.on_request();
    m.on_shed();
    m.on_worker_panic(2);
    m.on_lane_failures(1);
    m.on_deadline_queue();
    m.refresh_derived();
    let exp = parse_exposition(&m.render()).expect("exposition with robustness families parses");
    assert_eq!(exp.value("eagle_shed_total"), Some(1.0));
    assert_eq!(exp.value("eagle_worker_panics_total"), Some(1.0));
    assert_eq!(exp.value("eagle_lane_failures_total"), Some(3.0), "panic lanes + refusals");
    let fam = exp.family("eagle_deadline_expired_total").expect("stage-labeled family");
    let stages: Vec<_> = fam.samples.iter().filter_map(|s| s.label("stage")).collect();
    assert!(stages.contains(&"queue") && stages.contains(&"generate"), "stages: {stages:?}");
    // derived gauges over 1 admitted request: 1 shed, 1 queue-expiry
    assert_eq!(exp.value("eagle_shed_rate"), Some(1.0));
    assert_eq!(exp.value("eagle_deadline_miss_rate"), Some(1.0));
    assert_eq!(exp.value("eagle_worker_restarts"), Some(1.0));
    assert_eq!(exp.value("eagle_est_service_seconds"), Some(0.0), "no generation served yet");
}

#[test]
fn draining_health_flips_ok_and_reports_the_phase() {
    let h = Health::new(50);
    h.set_busy(false);
    let j = h.to_json(0);
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(j.get("draining").and_then(|v| v.as_bool()), Some(false));
    // POST /admin/drain: ok turns false (load balancers stop routing)
    // while the body still distinguishes drain from a stall
    h.set_draining();
    assert!(h.draining());
    let j = h.to_json(0);
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));
    assert_eq!(j.get("draining").and_then(|v| v.as_bool()), Some(true));
}

#[test]
fn health_reports_stall_only_when_busy_and_silent() {
    let h = Health::new(50); // 50 ms stall threshold
    // starts busy with heartbeat at 0: not yet stalled
    assert!(!h.stalled());
    std::thread::sleep(std::time::Duration::from_millis(80));
    assert!(h.stalled(), "busy + heartbeat older than stall_ms must read as stalled");
    // idle (blocking on the queue) is never a stall, however old
    h.set_busy(false);
    std::thread::sleep(std::time::Duration::from_millis(80));
    assert!(!h.stalled());
    // busy with a fresh beat is healthy; the beat is what the observer
    // supplies every speculation round
    h.set_busy(true);
    assert!(!h.stalled());
    std::thread::sleep(std::time::Duration::from_millis(80));
    assert!(h.stalled());
    h.beat();
    assert!(!h.stalled());
    // the /healthz body carries the liveness fields
    h.set_inflight(3);
    let j = h.to_json(5);
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(j.get("queue_depth").and_then(|v| v.as_usize()), Some(5));
    assert_eq!(j.get("inflight_lanes").and_then(|v| v.as_usize()), Some(3));
    assert!(j.get("heartbeat_age_ms").is_some() && j.get("uptime_seconds").is_some());
}
