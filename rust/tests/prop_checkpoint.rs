//! Property tests for checkpointable lanes (S24): RNG draw-counter
//! replay, bit-identical suspend/resume of a simulated lane at T=0 and
//! T>0, warm-capture allocation stability, and checkpoint-store
//! eviction round-trips. See docs/robustness.md "Preemption &
//! checkpointing".
//!
//! The real engines need lowered executables, so these properties drive
//! the checkpoint *primitives* end to end instead — the SplitMix64 draw
//! counter, the controller snapshot, and a simulated lane loop that
//! composes them exactly the way `EagleEngine::generate_resumable`
//! does: capture at a round boundary, rebuild the RNG with
//! `Rng::resume`, splice the controller state back in, and continue.

use eagle_serve::coordinator::{CheckpointStore, LaneCheckpoint, PreemptSignal};
use eagle_serve::spec::dyntree::{
    ControllerConfig, ControllerSnapshot, DynTreeParams, SpecController,
};
use eagle_serve::util::prop::{check, random_dist};
use eagle_serve::util::rng::Rng;

const VOCAB: usize = 257;

/// Mixed stream of derived draws: whatever combination of draw kinds a
/// lane consumes, `Rng::resume(seed, draws)` must continue the exact
/// stream from any cut point.
#[test]
fn rng_resume_replays_mixed_draw_streams() {
    check("rng-resume", 200, |rng, _| {
        let seed = rng.next_u64();
        let n = 8 + rng.below(120);
        let kinds: Vec<usize> = (0..n).map(|_| rng.below(5)).collect();
        let weights = random_dist(rng, 1 + rng.below(16));
        let draw = |r: &mut Rng, k: usize| -> u64 {
            match k {
                0 => r.next_u64(),
                1 => r.f64().to_bits(),
                2 => u64::from(r.f32().to_bits()),
                3 => r.below(977) as u64,
                _ => r.weighted(&weights) as u64,
            }
        };
        let mut full = Rng::new(seed);
        let reference: Vec<u64> = kinds.iter().map(|&k| draw(&mut full, k)).collect();

        let cut = rng.below(n + 1);
        let mut head = Rng::new(seed);
        for &k in &kinds[..cut] {
            draw(&mut head, k);
        }
        let mut tail = Rng::resume(seed, head.draws());
        assert_eq!(tail.draws(), head.draws(), "resume restores the draw counter");
        for (i, &k) in kinds[cut..].iter().enumerate() {
            assert_eq!(draw(&mut tail, k), reference[cut + i], "draw {} after cut {cut}", cut + i);
        }
        assert_eq!(tail.draws(), full.draws(), "draw counters agree at stream end");
    });
}

fn greedy_tok(prefix_len: usize, d: usize) -> u32 {
    let h = (prefix_len as u64).wrapping_mul(0x9E37_79B9).wrapping_add(d as u64);
    (h % VOCAB as u64) as u32
}

/// One simulated speculative round: draft `depth` positions, accept a
/// prefix, commit accepted + bonus tokens, feed the controller. At T=0
/// the lane draws nothing (greedy acceptance is a pure function of the
/// committed prefix); at T>0 both the acceptance tests and the token
/// picks consume lane RNG draws, so resume must restart the stream at
/// the exact draw counter.
fn lane_round(
    rng: &mut Rng,
    ctrl: &mut SpecController,
    committed: &mut Vec<u32>,
    sampled: bool,
    dist: &[f32],
) {
    let attempted = ctrl.params().depth.max(1);
    let mut accepted = 0;
    for d in 0..attempted {
        let take = if sampled { rng.f32() < 0.6 } else { (committed.len() + d) % 5 != 0 };
        if !take {
            break;
        }
        let tok = if sampled { rng.weighted(dist) as u32 } else { greedy_tok(committed.len(), d) };
        committed.push(tok);
        accepted += 1;
    }
    let bonus = if sampled { rng.weighted(dist) as u32 } else { greedy_tok(committed.len(), 0) };
    committed.push(bonus);
    ctrl.observe_round(accepted, attempted);
}

/// The tentpole property: suspending a lane at any round boundary and
/// resuming from the checkpoint yields the same committed tokens, the
/// same RNG draw counter, and the same controller decisions as the
/// uninterrupted run — greedy (even cases) and sampled (odd cases),
/// including cut=0 (suspended before the first round).
#[test]
fn simulated_lane_resumes_bit_identically_at_t0_and_t_gt0() {
    check("lane-resume", 120, |rng, case| {
        let sampled = case % 2 == 1;
        let seed = rng.next_u64();
        let dist = random_dist(rng, 2 + rng.below(31));
        let rounds = 2 + rng.below(14);
        let cut = rng.below(rounds + 1);
        let cfg = ControllerConfig::default();
        let init = DynTreeParams { depth: 3, frontier_k: 4, branch: 4, budget: 31 };

        // uninterrupted reference lane
        let mut r_ref = Rng::new(seed);
        let mut c_ref = SpecController::new(cfg.clone(), init);
        let mut toks_ref = Vec::new();
        for _ in 0..rounds {
            lane_round(&mut r_ref, &mut c_ref, &mut toks_ref, sampled, &dist);
        }

        // suspended lane: run `cut` rounds, capture, resume, finish
        let mut r_a = Rng::new(seed);
        let mut c_a = SpecController::new(cfg.clone(), init);
        let mut toks_a = Vec::new();
        for _ in 0..cut {
            lane_round(&mut r_a, &mut c_a, &mut toks_a, sampled, &dist);
        }
        let mut ck = LaneCheckpoint::new();
        ck.reserve(1024, 8, VOCAB, 8);
        ck.capture_tokens(&toks_a, toks_a.len());
        ck.rng_seed = seed;
        ck.rng_draws = r_a.draws();
        let mut snap = ControllerSnapshot::default();
        snap.reserve(cfg.max_depth);
        c_a.snapshot_into(&mut snap);
        ck.controller = Some(snap);

        let mut r_b = Rng::resume(ck.rng_seed, ck.rng_draws);
        let mut c_b = SpecController::new(cfg, init);
        c_b.restore(ck.controller.as_ref().unwrap());
        let mut toks_b = ck.committed.clone();
        for _ in cut..rounds {
            lane_round(&mut r_b, &mut c_b, &mut toks_b, sampled, &dist);
        }

        assert_eq!(toks_b, toks_ref, "committed tokens diverge (cut {cut}/{rounds})");
        assert_eq!(r_b.draws(), r_ref.draws(), "draw counters diverge");
        assert_eq!(c_b.params(), c_ref.params(), "controller shape diverges");
        assert_eq!(c_b.rate_ewma.to_bits(), c_ref.rate_ewma.to_bits(), "rate EWMA diverges");
        assert_eq!(c_b.is_width_down(), c_ref.is_width_down(), "hysteresis latch diverges");
    });
}

/// After `reserve`, repeated captures of arbitrary in-bounds lane state
/// must never grow any checkpoint buffer (the footprint — total pinned
/// capacity — is capture-invariant). The byte-exact allocator check
/// lives in tests/count_alloc.rs; this property covers the full input
/// space.
#[test]
fn warm_checkpoint_capture_keeps_footprint_fixed() {
    check("warm-capture", 60, |rng, _| {
        let max_ctx = 64 + rng.below(192);
        let d = 8 + rng.below(56);
        let vocab = 128 + rng.below(512);
        let accept_a = 4 + rng.below(12);
        let cfg = ControllerConfig::default();

        let mut ck = LaneCheckpoint::new();
        ck.reserve(max_ctx, d, vocab, accept_a);
        ck.reserve_kv(max_ctx * 4, max_ctx * 2);
        let mut snap = ControllerSnapshot::default();
        snap.reserve(cfg.max_depth);
        ck.controller = Some(snap);
        let init = DynTreeParams { depth: 3, frontier_k: 4, branch: 4, budget: 31 };
        let mut ctrl = SpecController::new(cfg, init);
        let base = ck.footprint();

        for round in 0..8 {
            let m = 1 + rng.below(max_ctx);
            let toks: Vec<u32> = (0..m).map(|_| rng.below(vocab) as u32).collect();
            let feat: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
            let logits: Vec<f32> = (0..vocab).map(|_| rng.f32()).collect();
            let idx: Vec<i32> = (0..accept_a).map(|i| i as i32).collect();
            ctrl.observe_round(rng.below(4), 3);
            ck.capture_tokens(&toks, m);
            ck.capture_root(&feat, &logits);
            ck.capture_pending(-1, &idx, accept_a as i32);
            ctrl.snapshot_into(ck.controller.as_mut().unwrap());
            assert_eq!(ck.footprint(), base, "capture {round} grew a checkpoint buffer");
        }
        // eviction drops the KV capacity from the footprint and zeroes
        // the resident-byte accounting
        ck.kv_resident = true;
        let freed = ck.evict_kv();
        // reserve_kv may round capacities up; eviction frees at least the
        // requested KV floats
        assert!(freed >= (max_ctx * 4 + max_ctx * 2) as u64 * 4, "freed {freed} bytes too few");
        assert_eq!(ck.kv_bytes(), 0, "evicted checkpoint still pins KV bytes");
        assert!(ck.footprint() < base, "eviction must shrink the footprint");
    });
}

/// Store round-trips under random capacity / watermark / byte-budget
/// pressure: checkpoints are never lost (eviction drops KV, not state),
/// resident bytes respect the budget, take() returns the exact parked
/// state, and drain_all() comes back id-sorted.
#[test]
fn store_roundtrips_under_pressure_without_losing_lanes() {
    check("store-pressure", 100, |rng, _| {
        let slots = 1 + rng.below(6);
        let watermark = rng.below(slots + 1);
        let budget = if rng.below(2) == 0 { 0 } else { (1 + rng.below(64)) as u64 * 1024 };
        let store = CheckpointStore::new(slots, watermark, budget);
        assert_eq!(store.budget_bytes(), budget);

        let n = 1 + rng.below(12);
        let mut expected: Vec<(u64, Vec<u32>)> = Vec::new();
        let mut reported = 0u64;
        for i in 0..n {
            let id = 100 + i as u64;
            let mut ck = Box::new(LaneCheckpoint::new());
            ck.id = id;
            let toks: Vec<u32> = (0..1 + rng.below(16)).map(|_| rng.below(1000) as u32).collect();
            ck.capture_tokens(&toks, toks.len());
            ck.kv_target = vec![0.0; 256 * (1 + rng.below(8))];
            ck.kv_resident = true;
            reported += store.insert(ck) as u64;
            expected.push((id, toks));
            assert_eq!(store.len(), i + 1, "insert must never drop a checkpoint");
            if budget > 0 {
                assert!(
                    store.resident_bytes() <= budget,
                    "resident {} exceeds budget {budget}",
                    store.resident_bytes()
                );
            }
        }
        assert_eq!(store.evictions(), reported, "eviction counter disagrees with insert totals");
        assert!(expected.iter().all(|(id, _)| store.contains(*id)));

        // take one at random: exact state back, slot released, gone
        let pick = rng.below(expected.len());
        let (id, toks) = expected.remove(pick);
        let got = store.take(id).expect("parked checkpoint must be takeable");
        assert_eq!(got.committed, toks, "take returned a different lane's tokens");
        assert_eq!(got.kv_slot, None, "take must release the KV slot");
        if !got.kv_resident {
            assert_eq!(got.kv_bytes(), 0, "evicted checkpoint reports resident bytes");
        }
        assert!(!store.contains(id));
        assert!(store.take(id).is_none(), "double-take must miss");

        // drain: everything left, id-sorted, store empty afterwards
        let drained = store.drain_all();
        let ids: Vec<u64> = drained.iter().map(|c| c.id).collect();
        let mut want: Vec<u64> = expected.iter().map(|(id, _)| *id).collect();
        want.sort_unstable();
        assert_eq!(ids, want, "drain_all must return every parked lane in id order");
        assert!(store.is_empty());
        assert_eq!(store.resident_bytes(), 0, "drained store still accounts resident bytes");
    });
}

/// Preemption-signal bits are take-once: a governor's `request_all`
/// suspends each live lane exactly once, and `clear` (group teardown)
/// leaves nothing armed for the next group.
#[test]
fn preempt_signal_bits_are_take_once() {
    let s = PreemptSignal::new();
    assert!(!s.any());
    s.request(3);
    assert!(s.requested(3) && s.any());
    assert!(s.take(3), "armed bit must be takeable");
    assert!(!s.take(3), "take is one-shot");
    assert!(!s.any());
    s.request_all();
    assert!((0..64).all(|i| s.requested(i)));
    assert!(s.take(0) && s.take(63));
    s.clear();
    assert!(!s.any(), "clear must disarm every remaining bit");
}
