//! EDF admission-order properties (randomized, seeded, artifact-free):
//!
//! 1. deadline ordering with FCFS tiebreak — pops come out sorted by
//!    effective deadline, arrival order breaking ties;
//! 2. FCFS degradation — with no deadlines anywhere, EDF is exactly
//!    the FCFS order (the constant aging bound preserves arrival order);
//! 3. aging no-starvation — an unbounded request is served once its
//!    aging bound passes, no matter how many tight deadlines keep
//!    arriving behind it;
//! 4. compat-partition preservation — width-grouped admission over an
//!    EDF queue forms the same *kind* of groups (internally compatible,
//!    lossless, duplicate-free) as over FCFS; only the order changes.

use std::time::{Duration, Instant};

use eagle_serve::coordinator::queue::RequestQueue;
use eagle_serve::coordinator::request::{Method, Request};
use eagle_serve::coordinator::{AdmissionPolicy, Scheduler};

/// Tiny deterministic PRNG so every property runs over many seeds
/// without a rand dependency.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n.max(1)
    }
}

fn req(id: u64, deadline_ms: Option<u64>) -> Request {
    let mut r = Request::synthetic(id);
    r.deadline_ms = deadline_ms;
    r
}

/// The key EDF sorts by, recomputed independently of the queue: the
/// real deadline when it is tighter than the aging bound, else the
/// aging bound (arrival + aging). Ties break by push order (id, here).
fn effective_key(r: &Request, aging_ms: u64) -> Instant {
    let aged = r.arrival + Duration::from_millis(aging_ms);
    match r.deadline_ms {
        Some(ms) if ms > 0 => (r.arrival + Duration::from_millis(ms)).min(aged),
        _ => aged,
    }
}

#[test]
fn pops_are_sorted_by_effective_deadline_with_fcfs_tiebreak() {
    for seed in 0..50u64 {
        let mut rng = Lcg(seed * 2 + 1);
        let aging_ms = 60_000;
        let q = RequestQueue::new(256).with_edf(true).with_aging_ms(aging_ms);
        let n = 20 + rng.below(40);
        let mut pushed = Vec::new();
        for id in 0..n {
            // deadlines in a small set so ties are common
            let deadline_ms = match rng.below(4) {
                0 => None,
                k => Some(k * 500),
            };
            let r = req(id, deadline_ms);
            pushed.push((id, effective_key(&r, aging_ms)));
            q.push(r).unwrap();
        }
        let mut popped = Vec::new();
        while let Some(r) = q.pop_up_to(1).pop() {
            popped.push(r);
        }
        assert_eq!(popped.len(), pushed.len(), "seed {seed}: lossless");
        for w in popped.windows(2) {
            let ka = effective_key(&w[0], aging_ms);
            let kb = effective_key(&w[1], aging_ms);
            assert!(
                ka < kb || (ka == kb && w[0].id < w[1].id),
                "seed {seed}: out of EDF order: {} (key {ka:?}) before {} (key {kb:?})",
                w[0].id,
                w[1].id
            );
        }
    }
}

#[test]
fn no_deadlines_degrades_to_exact_fcfs() {
    for seed in 0..50u64 {
        let mut rng = Lcg(seed ^ 0xfcf5);
        let q = RequestQueue::new(256).with_edf(true);
        let n = 5 + rng.below(60);
        for id in 0..n {
            q.push(req(id, None)).unwrap();
        }
        let mut expect = 0u64;
        while let Some(r) = q.pop_up_to(1).pop() {
            assert_eq!(r.id, expect, "seed {seed}: EDF without deadlines must be FCFS");
            expect += 1;
        }
        assert_eq!(expect, n, "seed {seed}: drained everything");
    }
}

#[test]
fn aging_bound_prevents_starvation() {
    // an unbounded request whose aging bound has already passed must be
    // served before fresh tight-deadline arrivals, no matter how many
    // of them are queued behind it
    let aging_ms = 50;
    let q = RequestQueue::new(256).with_edf(true).with_aging_ms(aging_ms);
    let mut old = req(0, None);
    // back-date the arrival past the aging bound, the way a request
    // looks after starving through real wall time
    old.arrival = Instant::now() - Duration::from_millis(10 * aging_ms);
    q.push(old).unwrap();
    for id in 1..40 {
        q.push(req(id, Some(5_000))).unwrap();
    }
    let first = q.pop_up_to(1).pop().expect("nonempty");
    assert_eq!(first.id, 0, "aged request starved behind tight deadlines");
    assert!(q.aged_pops() >= 1, "aged pop not counted");
}

#[test]
fn runtime_flip_loses_nothing_and_restores_fcfs() {
    for seed in 0..20u64 {
        let mut rng = Lcg(seed ^ 0x0f11);
        let q = RequestQueue::new(256).with_edf(false);
        let n = 30 + rng.below(30);
        for id in 0..n {
            let deadline_ms = (rng.below(2) == 0).then(|| 100 + rng.below(2_000));
            q.push(req(id, deadline_ms)).unwrap();
        }
        // drain a prefix FCFS, flip to EDF mid-stream, drain the rest
        let cut = rng.below(n / 2) + 1;
        let mut seen = Vec::new();
        for _ in 0..cut {
            seen.push(q.pop_up_to(1).pop().unwrap().id);
        }
        q.set_edf_enabled(true);
        while let Some(r) = q.pop_up_to(1).pop() {
            seen.push(r.id);
        }
        seen.sort_unstable();
        let all: Vec<u64> = (0..n).collect();
        assert_eq!(seen, all, "seed {seed}: flip dropped or duplicated requests");
    }
}

#[test]
fn width_grouped_admission_over_edf_preserves_compat_partitions() {
    for seed in 0..30u64 {
        let mut rng = Lcg(seed ^ 0x9d0f);
        let q = RequestQueue::new(256).with_edf(true);
        let n = 8 + rng.below(24);
        let mut ids = Vec::new();
        for id in 0..n {
            let mut r = req(id, (rng.below(3) == 0).then(|| 200 + rng.below(1_000)));
            r.method = Method::Eagle;
            r.max_tokens = if rng.below(2) == 0 { 32 } else { 64 };
            r.temperature = if rng.below(4) == 0 { 0.8 } else { 0.0 };
            r.width_hint = Some([8usize, 16, 32][rng.below(3) as usize]);
            ids.push(id);
            q.push(r).unwrap();
        }
        q.close();
        let sched = Scheduler::new(usize::MAX, 0).with_policy(AdmissionPolicy::WidthGrouped {
            verify_widths: vec![8, 16, 32],
            max_t: 32,
        });
        let mut admitted = Vec::new();
        loop {
            let groups = sched.next_groups(&q);
            if groups.is_empty() {
                break;
            }
            for g in groups {
                // every multi-lane group is internally compatible: one
                // (max_tokens, tree, temperature-class) key, and every
                // lane's hint fits under the group's planned cap
                if g.requests.len() > 1 {
                    let key = |r: &Request| (r.max_tokens, r.tree.name(), r.temperature_class());
                    let k0 = key(&g.requests[0]);
                    for r in &g.requests {
                        assert!(r.width_batchable(), "seed {seed}: unbatchable lane in a group");
                        assert_eq!(key(r), k0, "seed {seed}: mixed compat class in one group");
                    }
                }
                if let Some(cap) = g.verify_cap {
                    for r in &g.requests {
                        assert!(
                            r.admission_width(32) <= cap,
                            "seed {seed}: lane hint {} above group cap {cap}",
                            r.admission_width(32)
                        );
                    }
                }
                admitted.extend(g.requests.into_iter().map(|r| r.id));
            }
        }
        admitted.sort_unstable();
        assert_eq!(admitted, ids, "seed {seed}: grouping lost or duplicated requests");
    }
}
